"""Shared schema for ``BENCH_*.json`` artifacts.

Every benchmark writer (``--only agg`` / ``--only transport`` / ``--only
soak``) funnels its payload through :func:`write_bench`, which stamps the
machine-comparable header — schema version, git sha, UTC timestamp, the
swept sizes — on top of the benchmark's own ``results`` / ``acceptance``
fields. ``benchmarks.compare`` consumes two such files (a committed baseline
and a fresh run) and renders the trend table the nightly workflow posts to
its step summary; :func:`numeric_metrics` defines what "comparable" means:
every numeric leaf, flattened to a ``/``-joined path.
"""
from __future__ import annotations

import datetime
import json
import subprocess

SCHEMA_VERSION = 1


def git_sha() -> str:
    """Current commit sha, or 'unknown' outside a git checkout (the schema
    must never make a benchmark run fail)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, check=False)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except (OSError, subprocess.SubprocessError):  # incl. TimeoutExpired
        return "unknown"


def finalize(payload: dict, *, benchmark: str, sizes=None) -> dict:
    """Stamp the shared header onto a benchmark's own payload fields."""
    return {
        "schema_version": SCHEMA_VERSION,
        "benchmark": benchmark,
        "git_sha": git_sha(),
        "timestamp": datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"),
        "sizes": [int(s) for s in sizes] if sizes is not None else None,
        **{k: v for k, v in payload.items() if k != "benchmark"},
    }


def write_bench(path: str, payload: dict, *, benchmark: str, sizes=None) -> dict:
    """Finalize + write one BENCH_*.json; returns the finalized payload."""
    final = finalize(payload, benchmark=benchmark, sizes=sizes)
    with open(path, "w") as f:
        json.dump(final, f, indent=2)
    return final


_HEADER_KEYS = ("schema_version", "git_sha", "timestamp", "sizes")


def numeric_metrics(payload: dict, prefix: str = "") -> dict[str, float]:
    """Flatten every numeric leaf of a BENCH payload into ``a/b/c`` paths —
    the comparable surface of a benchmark file. Header fields and booleans
    (acceptance flags are pass/fail, not trends) are skipped."""
    out: dict[str, float] = {}
    for key, value in payload.items():
        if not prefix and key in _HEADER_KEYS:
            continue
        path = f"{prefix}/{key}" if prefix else str(key)
        if isinstance(value, bool):
            continue
        if isinstance(value, (int, float)):
            out[path] = float(value)
        elif isinstance(value, dict):
            out.update(numeric_metrics(value, path))
    return out
