"""Render a trend table comparing fresh ``BENCH_*.json`` runs against
committed baselines.

    python -m benchmarks.compare --baseline-dir baseline --current-dir . \
        [--names BENCH_agg.json,BENCH_transport.json,BENCH_soak.json]

Prints a GitHub-flavored markdown table (the nightly workflow appends it to
``$GITHUB_STEP_SUMMARY``). Report-only by design: shared CI runners are far
too noisy for hard perf gates, so the exit code conveys file problems, never
regressions. Metrics are the numeric leaves of the shared schema
(``benchmarks._schema.numeric_metrics``); a missing baseline renders as new.
"""
from __future__ import annotations

import argparse
import json
import os

from ._schema import numeric_metrics

DEFAULT_NAMES = ("BENCH_agg.json", "BENCH_transport.json", "BENCH_soak.json",
                 "BENCH_llm.json", "BENCH_obs.json", "BENCH_gossip.json",
                 "BENCH_serve.json")


def load(path: str) -> dict | None:
    """A missing, corrupt, or non-object file is just 'no data' — a stale or
    truncated baseline must degrade every metric to 'new', never crash the
    nightly report."""
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            data = json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError, OSError):
        return None
    return data if isinstance(data, dict) else None


def compare_payloads(baseline: dict | None, current: dict) -> list[tuple[str, float | None, float, float | None]]:
    """-> rows of (metric path, baseline value | None, current value, delta %
    | None), ordered by metric path."""
    base_metrics = numeric_metrics(baseline) if baseline else {}
    cur_metrics = numeric_metrics(current)
    rows = []
    for path in sorted(cur_metrics):
        cur = cur_metrics[path]
        base = base_metrics.get(path)
        delta = None
        if base is not None and base != 0:
            delta = 100.0 * (cur - base) / abs(base)
        rows.append((path, base, cur, delta))
    return rows


def _fmt(v: float | None) -> str:
    if v is None:
        return "—"
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return f"{v:.4g}"


def render_markdown(name: str, baseline: dict | None, current: dict) -> str:
    lines = [f"### {name}"]
    base_sha = (baseline or {}).get("git_sha", "—")
    cur_sha = current.get("git_sha", "—")
    lines.append(f"baseline `{str(base_sha)[:12]}` → current `{str(cur_sha)[:12]}` "
                 f"({current.get('timestamp', '?')}) — report-only, no perf gate")
    lines.append("")
    lines.append("| metric | baseline | current | Δ% |")
    lines.append("| --- | ---: | ---: | ---: |")
    for path, base, cur, delta in compare_payloads(baseline, current):
        delta_s = "new" if delta is None and base is None else _fmt(delta)
        if delta is not None:
            delta_s = f"{delta:+.1f}%"
        lines.append(f"| `{path}` | {_fmt(base)} | {_fmt(cur)} | {delta_s} |")
    lines.append("")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline-dir", default="baseline",
                    help="directory holding the committed baseline BENCH_*.json")
    ap.add_argument("--current-dir", default=".",
                    help="directory holding the fresh BENCH_*.json")
    ap.add_argument("--names", default=",".join(DEFAULT_NAMES),
                    help="comma-separated BENCH file names to compare")
    args = ap.parse_args(argv)

    missing_current = 0
    for name in [n.strip() for n in args.names.split(",") if n.strip()]:
        current = load(os.path.join(args.current_dir, name))
        if current is None:
            print(f"### {name}\n\n_current run missing — benchmark did not write it_\n")
            missing_current += 1
            continue
        baseline = load(os.path.join(args.baseline_dir, name))
        print(render_markdown(name, baseline, current))
    return 1 if missing_current else 0


if __name__ == "__main__":
    raise SystemExit(main())
