"""Shared harness for the paper-table benchmarks.

Runs a complete federated experiment (threaded clients sharing an in-memory
weight store — the paper's own simulation setup) at reduced scale and reports
final global-test accuracy + wall time. All knobs mirror the paper's §4:
dataset, skew, node count, strategy, sync/async.
"""
from __future__ import annotations

import shutil
import tempfile
import time
from dataclasses import dataclass

import jax
import numpy as np

from repro.core import (
    AsyncFederatedNode,
    CachingFolder,
    FederatedCallback,
    InMemoryFolder,
    SyncFederatedNode,
    make_folder,
    run_multiprocess,
    run_threaded,
)
from repro.core.partition import partition_dataset, partition_sequence_dataset
from repro.core.strategies import get_strategy
from repro.data import (
    batch_iterator,
    lm_batch_iterator,
    make_synthetic_cifar,
    make_synthetic_mnist,
    make_synthetic_wikitext,
)
from repro.models.cnn import MnistCNN, ResNet
from repro.models import build_model
from repro.configs import get_config
from repro.optim import adam, adamw
from repro.training import Trainer


@dataclass
class FedResult:
    name: str
    accuracy_mean: float
    accuracy_std: float
    wall_seconds: float
    per_node_accuracy: list


def _make_image_model(dataset_name: str):
    if dataset_name == "mnist":
        return MnistCNN()
    return ResNet(blocks_per_stage=1)  # reduced ResNet for CPU budget


def _image_dataset(dataset_name: str, seed: int, num_train: int, num_test: int):
    if dataset_name == "mnist":
        return make_synthetic_mnist(num_train, num_test, seed=seed)
    return make_synthetic_cifar(num_train, num_test, seed=seed)


def run_image_experiment(
    *,
    dataset: str = "mnist",
    mode: str = "async",
    strategy: str = "fedavg",
    num_nodes: int = 2,
    skew: float = 0.9,
    epochs: int = 3,
    steps_per_epoch: int = 25,
    batch_size: int = 32,
    lr: float = 1e-3,
    seed: int = 0,
    num_train: int = 4000,
    num_test: int = 800,
    slowdowns: list | None = None,
) -> FedResult:
    data = _image_dataset(dataset, seed, num_train, num_test)
    shards = partition_dataset(data.x_train, data.y_train, num_nodes, skew, seed=seed)
    folder = InMemoryFolder()
    accs: dict[str, float] = {}

    def client(i: int):
        model = _make_image_model(dataset)
        # common initialization across clients (FedAvg requirement);
        # per-node seeds only drive data order
        params = model.init(jax.random.PRNGKey(seed * 101))
        trainer = Trainer(
            loss_fn=lambda p, b, r: model.loss(p, b),
            optimizer=adam(lr),
            init_params=params,
            seed=seed * 101 + i,
            name=f"n{i}",
            slowdown=(slowdowns or [0.0] * num_nodes)[i],
        )
        strat = get_strategy(strategy)
        if mode == "sync":
            node = SyncFederatedNode(strategy=strat, shared_folder=folder, node_id=f"n{i}",
                                     num_nodes=num_nodes, timeout=600)
        else:
            node = AsyncFederatedNode(strategy=strat, shared_folder=folder, node_id=f"n{i}")
        cb = FederatedCallback(node, num_examples_per_epoch=steps_per_epoch * batch_size)
        x, y = shards[i]
        data_fn = lambda epoch: batch_iterator(x, y, batch_size=batch_size, seed=i, epoch=epoch)
        trainer.fit(data_fn, epochs=epochs, steps_per_epoch=steps_per_epoch, callbacks=[cb])
        logits = model.apply(trainer.params, data.x_test)
        accs[f"n{i}"] = float((np.argmax(np.asarray(logits), -1) == data.y_test).mean())

    t0 = time.time()
    results = run_threaded([lambda i=i: client(i) for i in range(num_nodes)])
    wall = time.time() - t0
    errors = [r for r in results if r.error]
    if errors:
        raise RuntimeError(f"client failed: {errors[0].traceback}")
    vals = [accs[f"n{i}"] for i in range(num_nodes)]
    return FedResult(
        name=f"{dataset}/{mode}/{strategy}/n{num_nodes}/skew{skew}",
        accuracy_mean=float(np.mean(vals)),
        accuracy_std=float(np.std(vals)),
        wall_seconds=wall,
        per_node_accuracy=vals,
    )


def _mp_image_client(
    i: int,
    *,
    dataset: str,
    folder_uri: str,
    mode: str,
    strategy: str,
    num_nodes: int,
    skew: float,
    epochs: int,
    steps_per_epoch: int,
    batch_size: int,
    lr: float,
    seed: int,
    num_train: int,
    num_test: int,
    transport: str,
) -> dict:
    """One federated client in its own OS process.

    Module-level so the ``spawn`` start method can pickle it; regenerates its
    synthetic data shard deterministically from the seed instead of shipping
    arrays across the process boundary.
    """
    data = _image_dataset(dataset, seed, num_train, num_test)
    shards = partition_dataset(data.x_train, data.y_train, num_nodes, skew, seed=seed)
    folder = make_folder(folder_uri)
    model = _make_image_model(dataset)
    params = model.init(jax.random.PRNGKey(seed * 101))  # common init
    trainer = Trainer(
        loss_fn=lambda p, b, r: model.loss(p, b),
        optimizer=adam(lr),
        init_params=params,
        seed=seed * 101 + i,
        name=f"n{i}",
    )
    strat = get_strategy(strategy)
    if mode == "sync":
        node = SyncFederatedNode(strategy=strat, shared_folder=folder, node_id=f"n{i}",
                                 num_nodes=num_nodes, timeout=600, transport=transport)
    else:
        node = AsyncFederatedNode(strategy=strat, shared_folder=folder, node_id=f"n{i}",
                                  transport=transport)
    cb = FederatedCallback(node, num_examples_per_epoch=steps_per_epoch * batch_size)
    x, y = shards[i]
    data_fn = lambda epoch: batch_iterator(x, y, batch_size=batch_size, seed=i, epoch=epoch)
    trainer.fit(data_fn, epochs=epochs, steps_per_epoch=steps_per_epoch, callbacks=[cb])
    logits = model.apply(trainer.params, data.x_test)
    out = {
        "accuracy": float((np.argmax(np.asarray(logits), -1) == data.y_test).mean()),
        "pushes": node.num_pushes,
        "aggregations": node.num_aggregations,
        "skipped_pulls": node.num_skipped_pulls,
    }
    if isinstance(folder, CachingFolder):
        out["cache"] = folder.cache_stats()
    return out


def run_multiprocess_experiment(
    *,
    dataset: str = "mnist",
    mode: str = "async",
    strategy: str = "fedavg",
    num_nodes: int = 3,
    skew: float = 0.9,
    epochs: int = 3,
    steps_per_epoch: int = 25,
    batch_size: int = 32,
    lr: float = 1e-3,
    seed: int = 0,
    num_train: int = 4000,
    num_test: int = 800,
    folder_dir: str | None = None,
    transport: str = "full",
    cached: bool = True,
    kill_after: dict[int, float] | None = None,
    join_timeout: float = 1200.0,
) -> FedResult:
    """The paper-table experiment with real OS processes over a DiskFolder.

    Each client is a separate interpreter; the only shared state is
    ``folder_dir`` (defaults to a fresh temp dir — point it at an NFS/S3 mount
    to span machines). ``transport``/``cached`` select the wire fast path;
    ``kill_after`` injects SIGKILL crashes (see run_multiprocess).
    """
    cleanup_dir = None
    if folder_dir is None:
        folder_dir = cleanup_dir = tempfile.mkdtemp(prefix="fedbench_store_")
    folder_uri = ("cache+" if cached else "") + folder_dir
    kwargs = dict(
        dataset=dataset, folder_uri=folder_uri, mode=mode, strategy=strategy,
        num_nodes=num_nodes, skew=skew, epochs=epochs,
        steps_per_epoch=steps_per_epoch, batch_size=batch_size, lr=lr, seed=seed,
        num_train=num_train, num_test=num_test, transport=transport,
    )
    t0 = time.time()
    try:
        results = run_multiprocess(
            [(_mp_image_client, (i,), kwargs) for i in range(num_nodes)],
            names=[f"n{i}" for i in range(num_nodes)],
            kill_after=kill_after,
            join_timeout=join_timeout,
        )
    finally:
        if cleanup_dir is not None:
            shutil.rmtree(cleanup_dir, ignore_errors=True)
    wall = time.time() - t0
    survivors = [r for r in results if r.error is None]
    # Only deaths at injected-kill indices are expected; any other failure is
    # a broken run and must surface, not average into a healthy-looking row.
    tolerated = set(kill_after or {})
    unexpected = [r for i, r in enumerate(results) if r.error is not None and i not in tolerated]
    if unexpected or not survivors:
        failed = (unexpected or results)[0]
        raise RuntimeError(f"client {failed.node_id} failed: {failed.traceback or failed.error}")
    vals = [r.result["accuracy"] for r in survivors]
    return FedResult(
        name=f"{dataset}/mp-{mode}/{strategy}/{transport}/n{num_nodes}/skew{skew}",
        accuracy_mean=float(np.mean(vals)),
        accuracy_std=float(np.std(vals)),
        wall_seconds=wall,
        per_node_accuracy=vals,
    )


def run_centralized_image(*, dataset="mnist", epochs=3, steps_per_epoch=50,
                          batch_size=32, lr=1e-3, seed=0,
                          num_train=4000, num_test=800) -> float:
    data = _image_dataset(dataset, seed, num_train, num_test)
    model = _make_image_model(dataset)
    trainer = Trainer(loss_fn=lambda p, b, r: model.loss(p, b), optimizer=adam(lr),
                      init_params=model.init(jax.random.PRNGKey(seed)), seed=seed)
    data_fn = lambda epoch: batch_iterator(data.x_train, data.y_train,
                                           batch_size=batch_size, seed=seed, epoch=epoch)
    trainer.fit(data_fn, epochs=epochs, steps_per_epoch=steps_per_epoch)
    logits = model.apply(trainer.params, data.x_test)
    return float((np.argmax(np.asarray(logits), -1) == data.y_test).mean())


def run_lm_experiment(
    *,
    mode: str = "async",
    strategy: str = "fedavg",
    num_nodes: int = 2,
    epochs: int = 3,
    steps_per_epoch: int = 20,
    batch_size: int = 8,
    seq_len: int = 64,
    vocab: int = 256,
    lr: float = 1e-3,
    seed: int = 0,
) -> FedResult:
    cfg = get_config("pythia-14m").replace(vocab_size=vocab)
    data = make_synthetic_wikitext(vocab_size=vocab, train_tokens=80_000,
                                  test_tokens=8_000, seed=seed)
    shards = partition_sequence_dataset(data.train_tokens, num_nodes)
    folder = InMemoryFolder()
    accs: dict[str, float] = {}

    def evaluate(params):
        model = build_model(cfg)
        batch_accs = []
        for i, batch in enumerate(lm_batch_iterator(data.test_tokens, batch_size=8,
                                                    seq_len=seq_len, seed=7)):
            if i >= 4:
                break
            _, metrics = model.loss(params, batch)
            batch_accs.append(float(metrics["accuracy"]))
        return float(np.mean(batch_accs))

    def client(i: int):
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(seed * 77))  # common init
        trainer = Trainer(loss_fn=lambda p, b, r: model.loss(p, b), optimizer=adamw(lr),
                          init_params=params, seed=seed * 77 + i, name=f"n{i}")
        strat = get_strategy(strategy)
        if mode == "sync":
            node = SyncFederatedNode(strategy=strat, shared_folder=folder, node_id=f"n{i}",
                                     num_nodes=num_nodes, timeout=600)
        else:
            node = AsyncFederatedNode(strategy=strat, shared_folder=folder, node_id=f"n{i}")
        cb = FederatedCallback(node, num_examples_per_epoch=steps_per_epoch * batch_size)
        data_fn = lambda epoch: lm_batch_iterator(shards[i], batch_size=batch_size,
                                                  seq_len=seq_len, seed=i, epoch=epoch)
        trainer.fit(data_fn, epochs=epochs, steps_per_epoch=steps_per_epoch, callbacks=[cb])
        accs[f"n{i}"] = evaluate(trainer.params)

    t0 = time.time()
    results = run_threaded([lambda i=i: client(i) for i in range(num_nodes)])
    wall = time.time() - t0
    errors = [r for r in results if r.error]
    if errors:
        raise RuntimeError(f"client failed: {errors[0].traceback}")
    vals = [accs[f"n{i}"] for i in range(num_nodes)]
    return FedResult(
        name=f"lm/{mode}/{strategy}/n{num_nodes}",
        accuracy_mean=float(np.mean(vals)),
        accuracy_std=float(np.std(vals)),
        wall_seconds=wall,
        per_node_accuracy=vals,
    )


def csv_row(name: str, us_per_call: float, derived) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
