"""Roofline report generator: reads dry-run JSONL records and renders the
§Dry-run and §Roofline tables for EXPERIMENTS.md.

    PYTHONPATH=src python -m benchmarks.roofline results/dryrun_*.jsonl
    PYTHONPATH=src python -m benchmarks.roofline --markdown ... > tables.md
"""
from __future__ import annotations

import argparse
import glob
import json
import sys


def load(paths):
    records = {}
    for pattern in paths:
        for path in sorted(glob.glob(pattern)):
            with open(path) as f:
                for line in f:
                    r = json.loads(line)
                    records[(r["arch"], r["shape"], r["mesh"])] = r  # last wins
    return records


def fmt_bytes(b):
    if b >= 2**30:
        return f"{b / 2**30:.1f}G"
    if b >= 2**20:
        return f"{b / 2**20:.1f}M"
    return f"{b / 2**10:.0f}K"


def fmt_s(s):
    if s >= 1:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s * 1e3:.1f}ms"
    return f"{s * 1e6:.0f}us"


SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def render(records, markdown=False):
    sep = " | " if markdown else "  "
    rows = []
    header = ["arch", "shape", "mesh", "ok", "compute", "memory", "collective",
              "bound", "useful", "temp/chip", "args/chip"]
    rows.append(header)
    archs = sorted({k[0] for k in records})
    for arch in archs:
        for shape in SHAPE_ORDER:
            for mesh in ("16x16", "2x16x16"):
                r = records.get((arch, shape, mesh))
                if r is None:
                    continue
                if not r.get("ok"):
                    rows.append([arch, shape, mesh, "FAIL", "", "", "", "", "", "", ""])
                    continue
                m = r["memory_analysis"]
                rows.append([
                    arch, shape, mesh, "ok",
                    fmt_s(r["compute_s"]), fmt_s(r["memory_s"]), fmt_s(r["collective_s"]),
                    r["bottleneck"], f"{r['useful_flop_ratio']:.2f}",
                    fmt_bytes(m["temp_bytes"]), fmt_bytes(m["argument_bytes"]),
                ])
    widths = [max(len(str(row[i])) for row in rows) for i in range(len(rows[0]))]
    out = []
    for i, row in enumerate(rows):
        line = sep.join(str(c).ljust(w) for c, w in zip(row, widths))
        if markdown:
            line = "| " + line + " |"
        out.append(line)
        if markdown and i == 0:
            out.append("|" + "|".join("-" * (w + 2) for w in widths) + "|")
    return "\n".join(out)


def summarize(records):
    ok = [r for r in records.values() if r.get("ok")]
    fail = [r for r in records.values() if not r.get("ok")]
    lines = [f"{len(ok)} ok / {len(fail)} failed of {len(records)} combos"]
    if ok:
        by_bound = {}
        for r in ok:
            by_bound.setdefault(r["bottleneck"], []).append(r)
        for b, rs in sorted(by_bound.items()):
            lines.append(f"  {b}-bound: {len(rs)}")
        worst = sorted(
            (r for r in ok if r["shape"] == "train_4k" and r["mesh"] == "16x16"),
            key=lambda r: r["useful_flop_ratio"],
        )
        if worst:
            lines.append("  worst useful-flop ratio (train_4k 16x16): "
                         + ", ".join(f"{r['arch']}={r['useful_flop_ratio']:.2f}" for r in worst[:3]))
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="+")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args(argv)
    records = load(args.paths)
    print(render(records, markdown=args.markdown))
    print()
    print(summarize(records))


if __name__ == "__main__":
    main()
