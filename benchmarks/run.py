"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. ``us_per_call`` is wall-time per
federated experiment (μs); ``derived`` is the table's quantity (accuracy
mean±std, or speedup for the timing figure).

    PYTHONPATH=src python -m benchmarks.run             # all tables (reduced)
    PYTHONPATH=src python -m benchmarks.run --only table1 --trials 3
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def _report(name, seconds, derived):
    print(f"{name},{seconds * 1e6:.0f},{derived}", flush=True)


def _mean_std(results):
    accs = [r.accuracy_mean for r in results]
    return f"{np.mean(accs):.3f}±{np.std(accs):.3f}"


def table1_mnist_sync_vs_async(trials: int):
    """Table 1: sync vs async FedAvg accuracy across skew (MNIST, 2 nodes)."""
    from .fedbench import run_centralized_image, run_image_experiment

    t0 = time.time()
    acc = run_centralized_image(dataset="mnist")
    _report("table1/centralized", time.time() - t0, f"{acc:.3f}")
    for skew in (0.0, 0.9, 1.0):
        for mode in ("sync", "async"):
            results = []
            t0 = time.time()
            for trial in range(trials):
                results.append(run_image_experiment(
                    dataset="mnist", mode=mode, skew=skew, num_nodes=2, seed=trial))
            _report(f"table1/{mode}/skew{skew}", (time.time() - t0) / trials,
                    _mean_std(results))


def table2_mnist_strategies_nodes(trials: int, skew: float = 0.9, tag: str = "table2"):
    """Tables 2/3: strategy × node-count (MNIST), sync and async."""
    from .fedbench import run_image_experiment

    for strategy in ("fedavg", "fedavgm", "fedadam"):
        for num_nodes in (2, 3, 5):
            for mode in ("sync", "async"):
                results = []
                t0 = time.time()
                for trial in range(trials):
                    results.append(run_image_experiment(
                        dataset="mnist", mode=mode, strategy=strategy,
                        num_nodes=num_nodes, skew=skew, seed=trial))
                _report(f"{tag}/{strategy}{'_async' if mode == 'async' else ''}/n{num_nodes}",
                        (time.time() - t0) / trials, _mean_std(results))


def table3_mnist_strategies_full_skew(trials: int):
    table2_mnist_strategies_nodes(trials, skew=0.99, tag="table3")


def table4_cifar_sync_vs_async(trials: int):
    """Table 4: sync vs async FedAvg across skew (CIFAR-like, 2 nodes)."""
    from .fedbench import run_centralized_image, run_image_experiment

    t0 = time.time()
    acc = run_centralized_image(dataset="cifar", epochs=3)
    _report("table4/centralized", time.time() - t0, f"{acc:.3f}")
    for skew in (0.0, 0.9, 1.0):
        for mode in ("sync", "async"):
            results = []
            t0 = time.time()
            for trial in range(trials):
                results.append(run_image_experiment(
                    dataset="cifar", mode=mode, skew=skew, num_nodes=2, seed=trial,
                    epochs=3, steps_per_epoch=20))
            _report(f"table4/{mode}/skew{skew}", (time.time() - t0) / trials,
                    _mean_std(results))


def table5_cifar_strategies_nodes(trials: int, skew: float = 0.9, tag: str = "table5"):
    """Tables 5/6: strategy × node-count (CIFAR-like)."""
    from .fedbench import run_image_experiment

    for strategy in ("fedavg", "fedavgm"):
        for num_nodes in (2, 3, 5):
            for mode in ("sync", "async"):
                results = []
                t0 = time.time()
                for trial in range(trials):
                    results.append(run_image_experiment(
                        dataset="cifar", mode=mode, strategy=strategy,
                        num_nodes=num_nodes, skew=skew, seed=trial,
                        epochs=2, steps_per_epoch=20))
                _report(f"{tag}/{strategy}{'_async' if mode == 'async' else ''}/n{num_nodes}",
                        (time.time() - t0) / trials, _mean_std(results))


def table6_cifar_strategies_full_skew(trials: int):
    table5_cifar_strategies_nodes(trials, skew=0.99, tag="table6")


def table7_lm_nodes(trials: int):
    """Table 7: next-token accuracy, sync vs async FedAvg × node count (LM)."""
    from .fedbench import run_lm_experiment

    for num_nodes in (2, 3, 5):
        for mode in ("sync", "async"):
            results = []
            t0 = time.time()
            for trial in range(trials):
                results.append(run_lm_experiment(mode=mode, num_nodes=num_nodes, seed=trial))
            _report(f"table7/fedavg{'_async' if mode == 'async' else ''}/n{num_nodes}",
                    (time.time() - t0) / trials, _mean_std(results))


def figure_timing_straggler(trials: int):
    """Figure 1/2 claim: async avoids straggler idle time — exact virtual-clock
    model plus a real threaded run with an injected 40 ms/step slowdown."""
    from repro.core.simulation import simulate_timeline, straggler_speedup

    from .fedbench import run_image_experiment

    rng = np.random.default_rng(0)
    # NOTE: a CONSTANT k×-slower node gives sync wall == async wall (both are
    # bounded by the slow node's total); the async wall-clock win comes from
    # per-epoch VARIANCE (sync pays the per-round max), and the async
    # efficiency win from eliminating barrier idle. Report both.
    for jitter in (0.0, 0.5, 1.0):
        durations = [
            [1.0 + jitter * rng.random() for _ in range(20)] for _ in range(4)
        ]
        t0 = time.time()
        speedup = straggler_speedup(durations)
        sync_tl = simulate_timeline(durations, mode="sync")
        idle_frac = sum(sync_tl.per_node_idle) / (4 * sync_tl.wall_clock)
        _report(f"timing/vclock/jitter{jitter}_speedup", time.time() - t0, f"{speedup:.3f}")
        _report(f"timing/vclock/jitter{jitter}_sync_idle_frac", 0.0, f"{idle_frac:.3f}")
    # failure robustness: sync hangs (inf), async completes
    tl_sync = simulate_timeline([[1.0] * 5] * 2, mode="sync", failures={1: 2})
    tl_async = simulate_timeline([[1.0] * 5] * 2, mode="async", failures={1: 2})
    _report("timing/vclock/failure_sync_wall", 0.0, tl_sync.wall_clock)
    _report("timing/vclock/failure_async_wall", 0.0, tl_async.wall_clock)
    # real threads
    t0 = time.time()
    sync = run_image_experiment(mode="sync", num_nodes=2, skew=0.0, epochs=2,
                                steps_per_epoch=15, slowdowns=[0.0, 0.04])
    asy = run_image_experiment(mode="async", num_nodes=2, skew=0.0, epochs=2,
                               steps_per_epoch=15, slowdowns=[0.0, 0.04])
    _report("timing/threads/sync_vs_async_wall_ratio", time.time() - t0,
            f"{sync.wall_seconds / max(asy.wall_seconds, 1e-9):.3f}")


def bench_multiprocess(trials: int):
    """Process-scale federation: 3 OS processes over a DiskFolder, full vs
    delta+cached transport, plus a SIGKILL-robustness run (async survives)."""
    from .fedbench import run_multiprocess_experiment

    for transport in ("full", "delta"):
        results = []
        t0 = time.time()
        for trial in range(trials):
            results.append(run_multiprocess_experiment(
                dataset="mnist", mode="async", num_nodes=3, epochs=2,
                steps_per_epoch=15, transport=transport, cached=True, seed=trial))
        _report(f"mp/async/{transport}/n3", (time.time() - t0) / trials,
                _mean_std(results))
    t0 = time.time()
    res = run_multiprocess_experiment(
        dataset="mnist", mode="async", num_nodes=3, epochs=3,
        steps_per_epoch=15, kill_after={2: 20.0})
    _report("mp/async/crash1of3", time.time() - t0,
            f"{res.accuracy_mean:.3f} ({len(res.per_node_accuracy)} survivors)")


def bench_sharded(trials: int):
    """Sharded gossip store: per-step scan (state_hash + pull) cost at FIXED
    group size stays flat as the fleet grows 10x, while the flat store's scan
    grows with the fleet. Simulated nodes (one tiny deposit each), store-level
    only — this measures coordination cost, not training."""
    from repro.core import InMemoryFolder, NodeUpdate, WeightStore
    from repro.core.gossip import ShardedFolders, ShardedWeightStore

    group_size = 100
    params = {"w": np.zeros((16,), np.float32)}
    reps = max(3, trials)

    def scan_cost(store, probe):
        # Warm the decode caches through a full rotation of the bounded
        # summary sample — steady state is what the scan claim is about.
        for _ in range(12):
            store.state_hash(exclude_node=probe)
            store.pull(exclude=probe)
        # min over batches: scheduler noise only ever ADDS time, so the
        # fastest batch is the honest cost of the scan itself
        best = float("inf")
        for _ in range(7):
            t0 = time.time()
            for _ in range(reps):
                store.state_hash(exclude_node=probe)
                store.pull(exclude=probe)
            best = min(best, (time.time() - t0) / reps)
        return best

    per_fleet = {}
    for fleet in (1_000, 10_000):
        num_groups = fleet // group_size

        flat = WeightStore(InMemoryFolder(), decode_cache_entries=fleet)
        t0 = time.time()
        for i in range(fleet):
            flat.push(NodeUpdate(params, num_examples=1, node_id=f"n{i}", counter=0))
        flat_populate = time.time() - t0
        flat_scan = scan_cost(flat, "n0")

        sharded = ShardedWeightStore(
            ShardedFolders(num_groups, factory=lambda g: InMemoryFolder()),
            group_of=lambda nid: int(nid[1:]) % num_groups,
        )
        t0 = time.time()
        for i in range(fleet):
            sharded.push(NodeUpdate(params, num_examples=1, node_id=f"n{i}", counter=0))
        sharded_populate = time.time() - t0
        sharded_scan = scan_cost(sharded, "n0")

        per_fleet[fleet] = (flat_scan, sharded_scan)
        _report(f"sharded/flat_scan/n{fleet}", flat_scan,
                f"push_total={flat_populate:.2f}s")
        _report(f"sharded/sharded_scan/n{fleet}_g{num_groups}", sharded_scan,
                f"push_total={sharded_populate:.2f}s")

    growth_flat = per_fleet[10_000][0] / max(per_fleet[1_000][0], 1e-12)
    growth_sharded = per_fleet[10_000][1] / max(per_fleet[1_000][1], 1e-12)
    _report("sharded/scan_growth_10x_fleet/flat", 0.0, f"{growth_flat:.2f}x")
    _report("sharded/scan_growth_10x_fleet/sharded", 0.0,
            f"{growth_sharded:.2f}x (acceptance: < 2x at fixed group size)")


def bench_gossip(trials: int, sizes=None):
    """Hierarchical gossip scaling: per-push summary work and a cold reader's
    scan (state_hash + pull) as the fleet grows 10^3 → 10^5 simulated nodes at
    FIXED group size (100), on the 2-level summary tree (``shard<G>x2``) with
    the single-tier ring (``shard<G>``) alongside. The tree bounds every
    folder at O(group + branching) entries, so both probe costs should stay
    flat within ~3x across two decades of fleet growth while the single-tier
    curve inherits the O(num_groups) folder listings. Store-level only — one
    tiny deposit per node — this measures coordination cost, not training.
    Writes BENCH_gossip.json; acceptance is the 2-level push and fresh-scan
    costs at the largest fleet within 3x of the smallest, with exact pull
    coverage (fleet−1 examples, no double counting) at every size."""
    from repro.core import InMemoryFolder, NodeUpdate
    from repro.core.gossip import ShardedFolders, ShardedWeightStore

    group_size = 100
    sizes = sizes or [1_000, 10_000, 100_000]
    p = {"w": np.zeros((16,), np.float32)}
    seed_rounds = 4
    results = {}

    for fleet in sizes:
        num_groups = max(1, fleet // group_size)
        gof = lambda nid: int(nid[1:]) % num_groups  # noqa: E731
        per_tier = {}
        for levels in (1, 2):
            folders = ShardedFolders(num_groups, levels=levels,
                                     factory=lambda g: InMemoryFolder())
            store = ShardedWeightStore(folders, group_of=gof)
            # populate: deposit every node's update straight into its group
            # store (no gossip) — the O(fleet) setup is not the claim under
            # test, per-push and per-scan work at steady state are
            t0 = time.time()
            for i in range(fleet):
                store._store(i % num_groups).push(NodeUpdate(
                    p, num_examples=1, node_id=f"n{i}", counter=0))
            populate_s = time.time() - t0
            # representative rounds in ring order (node n{g} lives in group
            # g): one ascending pass cascades summaries the whole way around
            # each ring, so a handful of rounds reaches gossip steady state
            t0 = time.time()
            for r in range(1, seed_rounds + 1):
                for g in range(num_groups):
                    store.push(NodeUpdate(p, num_examples=1, node_id=f"n{g}",
                                          counter=r))
            seed_s = time.time() - t0

            # per-push summary work: a probe node's full push (refresh +
            # forward + tier folds), min over reps — noise only ever ADDS time
            ctr = {"c": seed_rounds}
            forwards0 = store.num_summary_forwards
            folds0 = store.num_super_folds

            def probe_push():
                ctr["c"] += 1
                store.push(NodeUpdate(p, num_examples=1, node_id="n0",
                                      counter=ctr["c"]))

            probe_push()  # warmup: fault in caches along the probe's chain
            push_s = min(_timed(probe_push) for _ in range(7))
            pushes_timed = 8

            # fresh scan: a cold reader (empty index memo + decode caches)
            # doing one skip-check + pull over the converged folders
            def fresh_scan():
                cold = ShardedWeightStore(folders, group_of=gof)
                t0 = time.time()
                cold.state_hash(exclude_node="n0")
                cold.pull(exclude="n0")
                return time.time() - t0

            scan_s = min(fresh_scan() for _ in range(3))

            # coverage: an unbounded-sample pull must weigh the foreign fleet
            # exactly once — summaries partition it, members fill the rest
            wide = ShardedWeightStore(folders, group_of=gof,
                                      summary_sample=max(16, 2 * num_groups))
            total = sum(u.num_examples for u in wide.pull(exclude="n0"))
            coverage_exact = bool(total == fleet - 1)

            own_keys = len(list(folders.group_folder(0).keys()))
            # how many rotating pulls a node needs before it has been served
            # every foreign (super-)summary once — the staleness window the
            # tree collapses from O(num_groups) to O(branching × levels)
            foreign = sum(len(v) for v in store.hierarchy.scope(0).values())
            rotation_pulls = int(np.ceil(foreign / store.summary_sample))
            per_tier[str(levels)] = {
                "num_groups": num_groups,
                "levels": levels,
                "branching": store.hierarchy.branching,
                "push_us": round(push_s * 1e6, 1),
                "fresh_scan_us": round(scan_s * 1e6, 1),
                "own_folder_keys": own_keys,
                "foreign_summary_entries": foreign,
                "rotation_pulls_to_cover": rotation_pulls,
                "forwards_per_push": round(
                    (store.num_summary_forwards - forwards0) / pushes_timed, 2),
                "super_folds_per_push": round(
                    (store.num_super_folds - folds0) / pushes_timed, 2),
                "populate_s": round(populate_s, 2),
                "seed_rounds_s": round(seed_s, 2),
                "coverage_exact": coverage_exact,
            }
            tag = f"gossip/L{levels}/n{fleet}_g{num_groups}"
            _report(f"{tag}/push", push_s,
                    f"folds/push={per_tier[str(levels)]['super_folds_per_push']}")
            _report(f"{tag}/fresh_scan", scan_s,
                    f"own_folder_keys={own_keys} coverage_exact={coverage_exact}")
            del store, wide, folders
        results[str(fleet)] = per_tier

    from ._schema import write_bench

    lo, hi = str(min(sizes)), str(max(sizes))
    growth = {}
    for levels in ("1", "2"):
        for metric in ("push_us", "fresh_scan_us"):
            growth[f"L{levels}_{metric}"] = round(
                results[hi][levels][metric]
                / max(results[lo][levels][metric], 1e-9), 2)
    span = max(sizes) / max(min(sizes), 1)
    payload = write_bench("BENCH_gossip.json", {
        "group_size": group_size,
        "seed_rounds": seed_rounds,
        "results": results,
        "acceptance": {
            "criterion": ("2-level push and fresh-scan cost at the largest "
                          "fleet within 3x of the smallest (single-tier "
                          "curve recorded alongside), exact pull coverage "
                          "at every size"),
            "fleet_span": f"{lo}->{hi}",
            "growth": growth,
            "passed": bool(
                (span <= 1 or (growth["L2_push_us"] <= 3.0
                               and growth["L2_fresh_scan_us"] <= 3.0))
                and all(t["coverage_exact"]
                        for r in results.values() for t in r.values())),
        },
    }, benchmark="hierarchical gossip scaling (per-push work + cold scan vs fleet size)",
        sizes=sizes)
    _report("gossip/BENCH_gossip.json", 0.0,
            f"acceptance_passed={payload['acceptance']['passed']}")


def bench_agg(trials: int, sizes=None):
    """Aggregation hot path at 10^6/10^7/10^8 params: the PR-2 per-leaf tree
    path vs the flat stacked-vector path vs the kernel-routed flat path, in
    the decode-cached steady state (peers' flats stable across rounds, own
    update fresh each round). Writes BENCH_agg.json so the perf trajectory
    has data; the acceptance bar is ≥5x flat-vs-tree at ≥10^7 params."""
    from repro.core.serialize import FlatUpdate, NodeUpdate
    from repro.core.strategies import FedAvg
    from repro.core.strategies_ref import FedAvgRef
    from repro.core.tree import LeafSpec

    K = 8
    sizes = sizes or [10**6, 10**7, 10**8]
    results = {}

    def timeit_interleaved(fns, reps, rounds):
        """min time per fn over interleaved batches: the 2-vCPU container's
        noise is time-correlated, so round-robin batches give every path a
        shot at a quiet window and min() discards scheduler spikes."""
        for fn in fns:  # warmup (jit, page-in, stack/scratch-buffer fill)
            fn()
        best = [float("inf")] * len(fns)
        for _ in range(rounds):
            for j, fn in enumerate(fns):
                t0 = time.time()
                for _ in range(reps):
                    fn()
                best[j] = min(best[j], (time.time() - t0) / reps)
        return best

    def transformer_tree(flat, d, vocab=512):
        """Split a flat vector into transformer-shaped leaf views: embed +
        blocks of q/k/v/o (d,d), mlp (d,4d)/(4d,d), layernorm vectors —
        realistic leaf-size distribution (megabyte mats + tiny vectors), which
        is what decides how much cache help the per-leaf path gets."""
        N = flat.size
        per_layer = 12 * d * d + 2 * d
        layers = max(1, (N - vocab * d) // per_layer)
        tree, off = {}, 0

        def take(shape):
            nonlocal off
            n = int(np.prod(shape))
            arr = flat[off:off + n].reshape(shape)
            off += n
            return arr

        tree["embed"] = {"w": take((vocab, d))}
        for l in range(int(layers)):
            if off + per_layer > N:
                break
            blk = {nm: {"w": take((d, d))} for nm in ("q", "k", "v", "o")}
            blk["mlp_in"] = {"w": take((d, 4 * d))}
            blk["mlp_out"] = {"w": take((4 * d, d))}
            blk["ln1"] = {"s": take((d,))}
            blk["ln2"] = {"s": take((d,))}
            tree[f"layer{l:02d}"] = blk
        tree["head"] = {"w": take((N - off,))}
        return tree

    for N in sizes:
        if N < 10_000:
            raise SystemExit(
                f"--agg-sizes values must be >= 10000 (got {N}): smaller "
                "vectors cannot hold even the minimal transformer layout")
        d = 192 if N < 3_000_000 else (512 if N < 3e7 else 1024)
        # shrink the model dim until embed + one block fit the budget, so
        # arbitrary small --agg-sizes smoke values (CI) never crash take()
        while 512 * d + 12 * d * d + 2 * d > N and d > 8:
            d //= 2
        base = (np.arange(N, dtype=np.float32) % 997) * np.float32(1e-3)
        flats = [base * np.float32(1.0 + 0.1 * k) for k in range(K)]
        trees = [transformer_tree(f, d) for f in flats]
        spec = LeafSpec.of(trees[0])
        L = len(spec.paths)
        tree_updates = [
            NodeUpdate(t, num_examples=k + 1, node_id=f"n{k}", counter=0)
            for k, t in enumerate(trees)
        ]
        flat_updates = [
            FlatUpdate(f, spec, num_examples=k + 1, node_id=f"n{k}", counter=0)
            for k, f in enumerate(flats)
        ]
        # own's flat is a *different array object* each federation round
        # (fresh trainer output), so every call pays the own-row write into
        # the stack; peers come from the decode cache (stable objects → zero
        # stack copies). Alternating two prebuilt owns models this without
        # benchmarking the allocator. reuse_output=True is the steady-state
        # trainer configuration (aggregate consumed — copied to device —
        # before the next federation step).
        owns = [
            FlatUpdate(flats[0].copy(), spec, num_examples=1, node_id="n0"),
            FlatUpdate(flats[0].copy(), spec, num_examples=1, node_id="n0"),
        ]
        step = {"i": 0}

        tree_strat = FedAvgRef()
        flat_strat = FedAvg(reuse_output=True)
        kernel_strat = FedAvg(use_kernel=True, reuse_output=True)

        def next_own():
            step["i"] += 1
            return owns[step["i"] % 2]

        def run_tree():
            tree_strat.aggregate(tree_updates[0], tree_updates[1:])

        def run_flat():
            flat_strat.aggregate(next_own(), flat_updates[1:])

        def run_flat_kernel():
            kernel_strat.aggregate(next_own(), flat_updates[1:])

        reps = max(1, int(2e7 // N))
        tree_s, flat_s, kern_s = timeit_interleaved(
            [run_tree, run_flat, run_flat_kernel], reps,
            rounds=max(5, trials))
        speedup = tree_s / max(flat_s, 1e-12)
        gbps = K * N * 4 / max(flat_s, 1e-12) / 1e9
        results[str(N)] = {
            "leaves": int(L),
            "model_dim": int(d),
            "clients": K,
            "tree_us": round(tree_s * 1e6, 1),
            "flat_us": round(flat_s * 1e6, 1),
            "flat_kernel_us": round(kern_s * 1e6, 1),
            "speedup_flat_vs_tree": round(speedup, 2),
            "flat_gbps": round(gbps, 2),
        }
        _report(f"agg/tree/N{N}_L{L}", tree_s, f"{K * N * 4 / tree_s / 1e9:.2f}GB/s")
        _report(f"agg/flat/N{N}_L{L}", flat_s, f"{gbps:.2f}GB/s")
        _report(f"agg/flat_kernel/N{N}_L{L}", kern_s, "jnp-ref on CPU")
        _report(f"agg/speedup/N{N}", 0.0, f"{speedup:.2f}x flat vs per-leaf")
        del flats, trees, tree_updates, flat_updates
    from ._schema import write_bench

    payload = write_bench("BENCH_agg.json", {
        "clients": K,
        "results": results,
        "acceptance": {
            "criterion": ">=5x flat vs per-leaf tree path at some size >=1e7 params",
            "passed": any(
                r["speedup_flat_vs_tree"] >= 5.0
                for n, r in results.items() if int(n) >= 10**7
            ),
        },
    }, benchmark="aggregation hot path (steady-state pull→aggregate)",
        sizes=sizes)
    _report("agg/BENCH_agg.json", 0.0,
            f"acceptance_passed={payload['acceptance']['passed']}")


def bench_transport(trials: int, sizes=None):
    """Transport pipelines at 10^6/10^7 params: bytes-on-wire (writer
    deposits + a steady reader's reads) and pull latency (steady: decodes
    each fresh delta; fresh: a cold reader reconstructing through the full
    reference chain) across ``full``, ``delta``, ``delta(chain=4)|zstd`` and
    ``topk(adaptive)``. Writes BENCH_transport.json; the acceptance bar is
    chain+envelope strictly below plain delta bytes-on-wire at 10^7 params
    with fresh-pull latency within 1.5x of the uncached delta path."""
    from repro.core import InMemoryFolder, NodeUpdate, WeightStore
    from repro.core.serialize import _zstd_module

    # prefer the real zstd frame; fall back to the deflate envelope when the
    # container has no zstd module (CI installs zstandard and runs the real
    # thing). Both the bare and the enveloped chain specs are measured: zstd
    # inflates at GB/s so the enveloped spec carries the acceptance check,
    # but deflate decodes ~40MB/s — judging the chain codec by np.load's
    # inflate speed would measure the fallback envelope, not the chains — so
    # without zstd the bare chain spec carries it (recorded in the JSON).
    envelope = "zstd" if _zstd_module() is not None else "npz"
    chain_env_spec = f"delta(chain=4)|{envelope}"
    accept_spec = chain_env_spec if envelope == "zstd" else "delta(chain=4)"
    specs = ["full", "delta", "delta(chain=4)", chain_env_spec,
             "topk(adaptive)"]
    sizes = sizes or [10**6, 10**7]
    pushes = 12
    frac = 0.005  # sparse local steps: the regime delta transports are for
    results = {}

    for N in sizes:
        base = (np.arange(N, dtype=np.float32) % 997) * np.float32(1e-3)
        per_spec = {}
        for spec in specs:
            rng = np.random.default_rng(1)
            folder = InMemoryFolder()
            writer = WeightStore(folder, transport=spec)
            reader = WeightStore(folder)
            cur = base
            steady, push_s = [], []
            for ctr in range(pushes):
                cur = cur.copy()
                idx = rng.integers(0, N, size=max(1, int(frac * N)))
                cur[idx] += rng.normal(size=idx.size).astype(np.float32)
                t0 = time.time()
                writer.push(NodeUpdate({"w": cur}, num_examples=1,
                                       node_id="n", counter=ctr))
                push_s.append(time.time() - t0)
                t0 = time.time()
                got = reader.pull_node("n")
                steady.append(time.time() - t0)
                assert got is not None
            # fresh (uncached) pull: min over a few cold readers — scheduler
            # noise only ever ADDS time
            fresh = min(
                _timed(lambda: WeightStore(folder).pull_node("n"))
                for _ in range(3)
            )
            stats = writer.transport_stats()
            wire = writer.bytes_written + reader.bytes_read
            per_spec[spec] = {
                "bytes_written": writer.bytes_written,
                "steady_bytes_read": reader.bytes_read,
                "bytes_on_wire": wire,
                "steady_pull_ms": round(1e3 * float(np.median(steady)), 3),
                "fresh_pull_ms": round(1e3 * fresh, 3),
                "push_ms": round(1e3 * float(np.median(push_s)), 3),
                "rebases": stats["rebases"],
                "reanchors": stats["reanchors"],
                "max_chain_depth": stats["max_chain_depth"],
            }
            _report(f"transport/{spec}/N{N}/wire", 0.0, f"{wire / 1e6:.2f}MB")
            _report(f"transport/{spec}/N{N}/fresh_pull", fresh,
                    f"steady={per_spec[spec]['steady_pull_ms']}ms")
        results[str(N)] = per_spec
    biggest = str(max(int(n) for n in results))
    chain_r, delta_r = results[biggest][accept_spec], results[biggest]["delta"]
    env_r = results[biggest][chain_env_spec]
    from ._schema import write_bench

    payload = {
        "pushes": pushes, "step_fraction": frac, "envelope": envelope,
        "results": results,
        "acceptance": {
            "criterion": (f"{accept_spec} strictly below plain delta "
                          "bytes-on-wire at the largest size, fresh pull "
                          "within 1.5x of the uncached delta path"),
            "note": (None if envelope == "zstd" else
                     "no zstd module in this container: the enveloped spec "
                     "ran with the deflate fallback (decodes ~40MB/s, which "
                     "measures np.load's inflate, not the chain codec), so "
                     "the bare chain spec carries the latency bound"),
            "at_params": int(biggest),
            "wire_ratio_chain_vs_delta": round(
                chain_r["bytes_on_wire"] / max(delta_r["bytes_on_wire"], 1), 3),
            "wire_ratio_chain_env_vs_delta": round(
                env_r["bytes_on_wire"] / max(delta_r["bytes_on_wire"], 1), 3),
            "fresh_pull_ratio_chain_vs_delta": round(
                chain_r["fresh_pull_ms"] / max(delta_r["fresh_pull_ms"], 1e-9), 3),
            "steady_pull_ratio_chain_vs_delta": round(
                chain_r["steady_pull_ms"] / max(delta_r["steady_pull_ms"], 1e-9), 3),
            "passed": bool(
                chain_r["bytes_on_wire"] < delta_r["bytes_on_wire"]
                and env_r["bytes_on_wire"] < delta_r["bytes_on_wire"]
                and chain_r["fresh_pull_ms"] <= 1.5 * delta_r["fresh_pull_ms"]),
        },
    }
    payload = write_bench(
        "BENCH_transport.json", payload,
        benchmark="transport pipelines (bytes-on-wire + pull latency)",
        sizes=sizes)
    _report("transport/BENCH_transport.json", 0.0,
            f"acceptance_passed={payload['acceptance']['passed']}")


def bench_llm(trials: int):
    """Federated-LLM wire cost: bytes/round and round latency for the smoke
    transformer (with LoRA adapters) under full, delta-chain, and adapter-only
    family transport. The LLM fine-tuning regime is dense — every local step
    moves every parameter — so value-deltas cannot shrink a round; only the
    leaf-family subset can, because it names the adapters *structurally*.
    Writes BENCH_llm.json; the acceptance bar is adapter-only federation
    shipping >=50x fewer bytes/round than full-model transport."""
    import jax

    from repro.core import InMemoryFolder, NodeUpdate, WeightStore
    from repro.core.tree import LeafSpec, tree_to_numpy
    from repro.models import ModelConfig, build_model

    from ._schema import write_bench

    cfg = ModelConfig(
        name="bench-lm", n_layers=4, d_model=256, n_heads=8, n_kv_heads=8,
        d_ff=1024, vocab_size=2048, activation="gelu", dtype="float32",
        lora_rank=8)
    model = build_model(cfg)
    params = tree_to_numpy(model.init(jax.random.PRNGKey(0)))
    spec = LeafSpec.of(params)
    view = spec.family_view(("adapters",))
    flat0 = spec.flatten(params)
    rounds = max(5, trials)
    specs = ["full", "delta(chain=4)", "family(adapters=full)"]
    results = {}
    for tspec in specs:
        rng = np.random.default_rng(0)
        folder = InMemoryFolder()
        writer = WeightStore(folder, transport=tspec)
        reader = WeightStore(folder)
        flat = flat0.copy()
        # round 0 is the one-time anchor (family/delta deposit a full base);
        # bytes/round is the steady-state cost, so it is recorded separately
        writer.push(NodeUpdate(spec.unflatten(flat), num_examples=1,
                               node_id="n", counter=0))
        assert reader.pull_node("n") is not None
        anchor_bytes = writer.bytes_written
        push_s, pull_s = [], []
        for ctr in range(1, rounds + 1):
            flat = flat + rng.normal(size=flat.size).astype(np.float32) * np.float32(1e-4)
            flat[view.indices] += (rng.normal(size=view.num_params)
                                   .astype(np.float32) * np.float32(1e-2))
            update = NodeUpdate(spec.unflatten(flat), num_examples=1,
                                node_id="n", counter=ctr)
            t0 = time.time()
            writer.push(update)
            push_s.append(time.time() - t0)
            t0 = time.time()
            got = reader.pull_node("n")
            pull_s.append(time.time() - t0)
            assert got is not None
            # family blobs must still carry the adapters exactly
            got_flat = spec.flatten(got.params)
            np.testing.assert_allclose(got_flat[view.indices],
                                       flat[view.indices], rtol=1e-5, atol=1e-6)
        bytes_per_round = (writer.bytes_written - anchor_bytes) / rounds
        round_ms = 1e3 * (float(np.median(push_s)) + float(np.median(pull_s)))
        results[tspec] = {
            "anchor_bytes": int(anchor_bytes),
            "bytes_per_round": int(bytes_per_round),
            "push_ms": round(1e3 * float(np.median(push_s)), 3),
            "pull_ms": round(1e3 * float(np.median(pull_s)), 3),
            "round_ms": round(round_ms, 3),
        }
        _report(f"llm/{tspec}/bytes_per_round", 0.0,
                f"{bytes_per_round / 1e6:.3f}MB")
        _report(f"llm/{tspec}/round_latency", round_ms / 1e3, "push+pull")
    ratio = (results["full"]["bytes_per_round"]
             / max(results["family(adapters=full)"]["bytes_per_round"], 1))
    payload = write_bench("BENCH_llm.json", {
        "model": {"name": cfg.name, "params": int(spec.num_params),
                  "adapter_params": int(view.num_params),
                  "adapter_fraction": round(view.num_params / spec.num_params, 5),
                  "lora_rank": cfg.lora_rank},
        "rounds": rounds,
        "results": results,
        "acceptance": {
            "criterion": ("adapter-only federation ships >=50x fewer "
                          "bytes/round than full-model transport"),
            "bytes_ratio_full_vs_adapters": round(ratio, 1),
            "passed": bool(ratio >= 50.0),
        },
    }, benchmark="federated LLM wire cost (full vs delta-chain vs adapter-only)")
    _report("llm/BENCH_llm.json", 0.0,
            f"acceptance_passed={payload['acceptance']['passed']}")


def _churn_lease_ttl(n: int) -> float:
    """Lease TTL for the churn soak at fleet size ``n``. The TTL is a
    deployment knob, not part of the bar: hundreds of node threads sharing
    one core starve a sub-second heartbeat cadence into spurious expiry, and
    a live worker whose lease lapses gets its nodes adopted out from under
    it — mass re-adoption thrash, not elastic membership. Scale the TTL with
    thread density so expiry means death; adoption latency is then read
    against the recorded TTL."""
    return max(2.0, n / 16)


def _churn_soak(n: int, uri: str):
    """One elastic-membership soak at fleet size ``n``: three workers claim
    leased slots, the seeded worker-kill chaos takes one whole worker down
    mid-soak, and the survivors must adopt every stranded lease. Returns the
    SoakReport (recovery + adoption latency both populated)."""
    from repro.core import ChaosSpec, FleetSpec, run_fleet_local

    spec = FleetSpec(
        store_uri=uri,
        name=f"churn{n}", num_nodes=n, rounds=5, runner="thread",
        param_size=256, round_sleep=0.02, settle=0.5,
        result_timeout=max(240.0, float(n)), lease_ttl=_churn_lease_ttl(n),
        chaos=ChaosSpec(seed=0, kill_workers=1, kill_workers_after=(1, 3)),
    )
    return run_fleet_local(spec, num_workers=3)


def bench_soak(trials: int, sizes=None, churn: bool = False):
    """Fleet chaos soak at 8→128 nodes: rounds/sec throughput and SIGKILL→
    resume recovery latency as the fleet grows, two workers partitioning the
    fleet over one shared DiskFolder. Thread runner — at 10² nodes an OS
    process per node measures interpreter startup, not federation — with the
    same store path, claim protocol, chaos schedule, and fleet-hash
    convergence check as the multi-host process soak (CI's soak-smoke job
    runs that one). Writes BENCH_soak.json; acceptance is every size passing
    the full soak bar (convergence + all victims resumed).

    ``churn=True`` (the ``--churn`` flag) additionally runs an elastic-
    membership soak per size — one of three workers killed whole mid-soak,
    survivors adopting its leases — and records worker-loss recovery and
    adoption latency under the same per-size schema; acceptance then also
    requires every churn soak to pass.

    ``sizes`` entries are either plain node counts or ``(nodes, store_spec)``
    pairs (the ``--soak-sizes 512:shard32x2`` form), pinning that size to an
    explicit store layout — e.g. a 2-level summary tree — so adoption latency
    can be read against store depth in BENCH_soak.json."""
    import shutil
    import tempfile

    from repro.core import ChaosSpec, FleetSpec, run_fleet_local

    from ._schema import write_bench

    sizes = sizes or [8, 32, 128]
    entries = [s if isinstance(s, tuple) else (s, None) for s in sizes]
    results = {}
    for n, store_spec in entries:
        best = spec = None
        for _ in range(max(1, trials)):
            # fresh store per trial: reusing one would make every node resume
            # at counter >= rounds and finish instantly, measuring nothing
            store_dir = tempfile.mkdtemp(prefix=f"bench_soak_{n}_")
            # ≥64 nodes federate through the sharded gossip store (groups of
            # 16): a flat store's per-push scan decodes every peer — O(fleet²)
            # per round, which measures the known flat-store wall, not the
            # launcher. Sharding is precisely the fix PR 2 shipped for this.
            # An explicit per-size spec (``512:shard32x2``) overrides the rule.
            if store_spec:
                uri = f"{store_spec}+{store_dir}"
            else:
                uri = f"shard{n // 16}+{store_dir}" if n >= 64 else store_dir
            spec = FleetSpec(
                store_uri=uri,
                name=f"bench{n}", num_nodes=n, rounds=5, runner="thread",
                param_size=256, round_sleep=0.01, settle=0.5,
                result_timeout=240.0,
                chaos=ChaosSpec(seed=0, kills=max(1, n // 16), restart_after=0.2,
                                stalls=max(1, n // 32), stall_duration=0.2),
            )
            report = run_fleet_local(spec, num_workers=2)
            shutil.rmtree(store_dir, ignore_errors=True)
            # a passing soak always beats a faster failed one: acceptance is
            # about crash-safety, throughput only breaks ties among passes
            if best is None or (report.passed, report.rounds_per_sec) > (
                    best.passed, best.rounds_per_sec):
                best = report
        if store_spec:
            import re as _re

            m = _re.match(r"^shard(\d+)(?:x(\d+))?$", store_spec)
            groups = int(m.group(1)) if m else 0
            levels = int(m.group(2) or 1) if m else 0
            store_label = f"sharded(groups={groups},levels={levels})"
        else:
            groups = n // 16 if n >= 64 else 0
            levels = 1 if n >= 64 else 0
            store_label = "sharded(group=16)" if n >= 64 else "flat"
        recovery = list(best.recovery_latency.values())
        key = f"{n}:{store_spec}" if store_spec else str(n)
        results[key] = {
            "nodes": n,
            "workers": 2,
            "store": store_label,
            "store_levels": levels,
            "rounds_per_node": spec.rounds,
            "total_pushes": best.total_pushes,
            "rounds_per_sec": round(best.rounds_per_sec, 2),
            "crashes_injected": best.crashes_injected,
            "restarts": best.restarts,
            "recovery_latency_mean_s": round(float(np.mean(recovery)), 3) if recovery else None,
            "recovery_latency_max_s": round(float(np.max(recovery)), 3) if recovery else None,
            "bytes_written": int(best.pipeline_stats.get("bytes_written", 0)),
            "bytes_read": int(best.pipeline_stats.get("bytes_read", 0)),
            "converged": best.converged,
            "passed": best.passed,
        }
        _report(f"soak/n{key}/rounds_per_sec", 0.0, f"{best.rounds_per_sec:.2f}")
        _report(f"soak/n{key}/recovery_mean_s", 0.0,
                results[key]["recovery_latency_mean_s"])
        if churn:
            churn_dir = tempfile.mkdtemp(prefix=f"bench_churn_{n}_")
            if store_spec:
                churn_uri = f"{store_spec}+{churn_dir}"
            else:
                churn_uri = f"shard{n // 16}+{churn_dir}" if n >= 64 else churn_dir
            creport = _churn_soak(n, churn_uri)
            shutil.rmtree(churn_dir, ignore_errors=True)
            adoption = list(creport.adoption_latency.values())
            crecovery = list(creport.recovery_latency.values())
            results[key].update({
                "churn_lease_ttl_s": _churn_lease_ttl(n),
                "churn_workers_lost": len(creport.workers_lost),
                "churn_nodes_adopted": sum(
                    1 for v in creport.adopted.values() if v),
                "churn_nodes_stranded": len(creport.stranded),
                "churn_adoption_latency_mean_s": round(
                    float(np.mean(adoption)), 3) if adoption else None,
                "churn_adoption_latency_max_s": round(
                    float(np.max(adoption)), 3) if adoption else None,
                "churn_recovery_latency_mean_s": round(
                    float(np.mean(crecovery)), 3) if crecovery else None,
                "churn_passed": creport.passed,
            })
            _report(f"soak/n{key}/churn_adoption_mean_s", 0.0,
                    results[key]["churn_adoption_latency_mean_s"])
            _report(f"soak/n{key}/churn_passed", 0.0, creport.passed)
    payload = write_bench("BENCH_soak.json", {
        "results": results,
        "acceptance": {
            "criterion": ("every fleet size passes the full soak bar: one "
                          "fleet state hash across workers, every "
                          "killed-then-restarted node resumed"
                          + ("; churn soaks additionally lose one whole "
                             "worker and every stranded lease is adopted"
                             if churn else "")),
            "passed": all(r["passed"] for r in results.values()) and all(
                r.get("churn_passed", True) for r in results.values()),
        },
    }, benchmark="fleet chaos soak (throughput + crash recovery vs fleet size)",
        sizes=[n for n, _spec in entries])
    _report("soak/BENCH_soak.json", 0.0,
            f"acceptance_passed={payload['acceptance']['passed']}")


def bench_obs(trials: int, sizes=None):
    """Telemetry overhead: full federated round latency (push/pull/aggregate
    over delta transport, obs flush every round) with the observability
    plane enabled vs disabled, at 10^6 and 10^7 params, plus a span-context
    microbench. Writes BENCH_obs.json; the acceptance bar is <=5% round
    latency overhead at the largest size — telemetry must be cheap enough
    to leave on for real soaks."""
    from repro.core import AsyncFederatedNode, InMemoryFolder, Telemetry

    sizes = sizes or [10**6, 10**7]
    rounds = 8
    frac = 0.005
    results = {}

    def run_mode(N, enabled):
        # a peer pushes fresh updates each round so the measured node takes
        # the full path: push + pull (fresh peer delta) + aggregate + flush
        rng = np.random.default_rng(0)
        base = (np.arange(N, dtype=np.float32) % 997) * np.float32(1e-3)
        folder = InMemoryFolder()
        peer = AsyncFederatedNode(shared_folder=folder, node_id="peer",
                                  transport="delta")
        tel = Telemetry("bench", enabled=enabled, flush_every=1)
        node = AsyncFederatedNode(shared_folder=folder, node_id="bench",
                                  transport="delta", telemetry=tel)
        cur_p, cur_n = base.copy(), base.copy()
        lat = []
        for _ in range(rounds):
            for cur in (cur_p, cur_n):
                idx = rng.integers(0, N, size=max(1, int(frac * N)))
                cur[idx] += rng.normal(size=idx.size).astype(np.float32)
            peer.update_parameters({"w": cur_p}, 1)
            t0 = time.time()
            node.update_parameters({"w": cur_n}, 1)
            lat.append(time.time() - t0)
        return float(np.median(lat))

    for N in sizes:
        # best-of-trials medians: scheduler noise only ever ADDS time, and
        # the overhead being measured is microseconds against a ~10ms round
        disabled = min(run_mode(N, False) for _ in range(max(trials, 2)))
        enabled = min(run_mode(N, True) for _ in range(max(trials, 2)))
        overhead = 100.0 * (enabled - disabled) / max(disabled, 1e-9)
        results[str(N)] = {
            "round_ms_disabled": round(1e3 * disabled, 3),
            "round_ms_enabled": round(1e3 * enabled, 3),
            "overhead_pct": round(overhead, 2),
        }
        _report(f"obs/N{N}/round_enabled", enabled,
                f"disabled={1e3 * disabled:.2f}ms overhead={overhead:.1f}%")

    # span-context microbench: the per-call cost the hot paths pay
    span_ns = {}
    for label, tel in (("disabled", Telemetry("m", enabled=False)),
                       ("enabled", Telemetry("m", enabled=True))):
        reps = 200_000
        t0 = time.perf_counter()
        for _ in range(reps):
            with tel.span("x"):
                pass
        span_ns[label] = round(1e9 * (time.perf_counter() - t0) / reps, 1)
    _report("obs/span_ns", 0.0,
            f"disabled={span_ns['disabled']}ns enabled={span_ns['enabled']}ns")

    from ._schema import write_bench

    biggest = str(max(int(n) for n in results))
    payload = write_bench("BENCH_obs.json", {
        "rounds": rounds, "step_fraction": frac,
        "results": results,
        "span_ns": span_ns,
        "acceptance": {
            "criterion": ("telemetry-enabled round latency within 5% of "
                          "disabled at the largest size (flush every round "
                          "included)"),
            "at_params": int(biggest),
            "overhead_pct": results[biggest]["overhead_pct"],
            "passed": results[biggest]["overhead_pct"] <= 5.0,
        },
    }, benchmark="observability plane overhead (enabled vs disabled rounds)",
        sizes=sizes)
    _report("obs/BENCH_obs.json", 0.0,
            f"acceptance_passed={payload['acceptance']['passed']}")


def bench_serve(trials: int, sizes=None):
    """Serving-tier SLOs: a pusher thread plays the fleet (fresh aggregated
    rounds) while a ServingNode serves batched greedy decode continuously.
    Measures tokens/sec, hot-swap latency percentiles, rounds-behind-store
    staleness, and per-token decode latency DURING swaps vs steady state.
    Writes BENCH_serve.json; acceptance (the zero-downtime claim, measured):
    p99 decode latency during swaps <= 2x steady-state p99 at the largest
    size."""
    import threading
    import uuid

    import jax

    from repro.api import connect
    from repro.configs import get_config
    from repro.core.serialize import NodeUpdate
    from repro.models import ModelConfig, build_model
    from repro.serving import ServingNode

    def _cfg_for(n: int) -> ModelConfig:
        if n <= 10**6:
            return get_config("pythia-14m").reduced()
        if n <= 3 * 10**7:
            return get_config("pythia-14m")
        return ModelConfig(
            name="servelm-95m",
            n_layers=12, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
            vocab_size=50304, activation="gelu", dtype="float32",
            source="Pythia-style ~100M (arXiv:2304.01373)")

    sizes = sizes or [10**5, 10**8]
    B, S, NT = 4, 32, 16
    results = {}
    for n in sizes:
        cfg = _cfg_for(int(n))
        params = build_model(cfg).init(jax.random.PRNGKey(0))
        n_params = int(sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params)))
        uri = f"memory://bench-serve-{uuid.uuid4().hex[:8]}"
        # delta wire: the pusher re-deposits the same weights under fresh
        # counters, so every round after the base anchor is a cheap no-change
        # delta — the bench measures the serving path, not npz encode time
        pusher = connect(uri, transport="delta")
        counter = 0

        def push():
            nonlocal counter
            pusher.push(NodeUpdate(params=params, num_examples=1,
                                   node_id="trainer", counter=counter,
                                   timestamp=time.time()))
            counter += 1

        push()
        node = ServingNode(connect(uri), cfg, poll_interval=0.02)
        node.start()
        assert node.wait_until_deployed(300.0), "bench store never deployed"
        rng = np.random.default_rng(0)
        prompts = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
        node.generate(prompts, new_tokens=NT)  # compile prefill + decode

        # steady phase: no pushes land, pure decode latency
        steady_ms = []
        t0 = time.time()
        for _ in range(max(3, 3 * trials)):
            _out, meta = node.generate(prompts, new_tokens=NT)
            steady_ms += [1e3 * (e - s) for s, e in meta["decode_spans"]]
        steady_s = time.time() - t0

        # swap phase: the pusher deposits fresh rounds while serving goes on
        n_push = 20
        pump = threading.Thread(
            target=lambda: [(push(), time.sleep(0.05)) for _ in range(n_push)])
        swap_batches = []
        pump.start()
        while pump.is_alive():
            _out, meta = node.generate(prompts, new_tokens=NT)
            swap_batches.append(meta["decode_spans"])
        pump.join()
        deadline = time.time() + 10.0
        while node.stats()["swaps"] < 2 and time.time() < deadline:
            time.sleep(0.05)

        intervals = node.swap_log()
        during_ms, clear_ms = [], []
        for spans in swap_batches:
            for s, e in spans:
                ms = 1e3 * (e - s)
                if any(s < i1 and e > i0 for i0, i1 in intervals):
                    during_ms.append(ms)
                else:
                    clear_ms.append(ms)
        stats = node.stats()
        node.stop()

        p99_steady = float(np.percentile(steady_ms, 99)) if steady_ms else 0.0
        p99_during = float(np.percentile(during_ms, 99)) if during_ms else p99_steady
        results[str(n_params)] = {
            "arch": cfg.name,
            "params": n_params,
            "tokens_per_sec": stats["tokens_per_sec"],
            "swaps": stats["swaps"],
            "swap_ms_p50": stats["swap_ms_p50"],
            "swap_ms_p99": stats["swap_ms_p99"],
            "staleness_mean": stats["staleness_mean"],
            "staleness_max": stats["staleness_max"],
            "decode_ms_p50_steady": round(float(np.percentile(steady_ms, 50)), 3),
            "decode_ms_p99_steady": round(p99_steady, 3),
            "decode_ms_p99_during_swap": round(p99_during, 3),
            "during_swap_samples": len(during_ms),
            "during_over_steady_p99": round(p99_during / max(p99_steady, 1e-9), 3),
        }
        _report(f"serve/N{n_params}/steady", steady_s,
                f"tok/s={stats['tokens_per_sec']} swap_p99={stats['swap_ms_p99']}ms "
                f"p99_during/steady={results[str(n_params)]['during_over_steady_p99']}")

    from ._schema import write_bench

    biggest = str(max(int(k) for k in results))
    ratio = results[biggest]["during_over_steady_p99"]
    payload = write_bench("BENCH_serve.json", {
        "batch": B, "prompt_len": S, "new_tokens": NT,
        "results": results,
        "acceptance": {
            "criterion": ("p99 per-token decode latency during hot swaps "
                          "<= 2x steady-state p99 at the largest size "
                          "(zero-downtime double buffering, measured)"),
            "at_params": int(biggest),
            "during_over_steady_p99": ratio,
            "passed": ratio <= 2.0,
        },
    }, benchmark="serving tier SLOs (throughput, swap latency, staleness)",
        sizes=sizes)
    _report("serve/BENCH_serve.json", 0.0,
            f"acceptance_passed={payload['acceptance']['passed']}")


def _timed(fn) -> float:
    t0 = time.time()
    fn()
    return time.time() - t0


def bench_kernels(trials: int):
    """Aggregation-path microbench: us_per_call for the fed_agg hot loop
    (jnp reference on CPU — the Pallas kernel is TPU-target, validated in
    tests under interpret=True)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.fed_agg.ref import fed_agg_ref

    for K, N in ((4, 1_000_000), (8, 2_000_000)):
        x = jnp.asarray(np.random.default_rng(0).normal(size=(K, N)).astype(np.float32))
        w = jnp.full((K,), 1.0 / K, jnp.float32)
        f = jax.jit(fed_agg_ref)
        f(x, w).block_until_ready()
        t0 = time.time()
        reps = 10
        for _ in range(reps):
            f(x, w).block_until_ready()
        dt = (time.time() - t0) / reps
        _report(f"kernels/fed_agg_ref_cpu/K{K}_N{N}", dt, f"{K * N * 4 / dt / 1e9:.2f}GB/s")


TABLES = {
    "table1": table1_mnist_sync_vs_async,
    "table2": table2_mnist_strategies_nodes,
    "table3": table3_mnist_strategies_full_skew,
    "table4": table4_cifar_sync_vs_async,
    "table5": table5_cifar_strategies_nodes,
    "table6": table6_cifar_strategies_full_skew,
    "table7": table7_lm_nodes,
    "timing": figure_timing_straggler,
    "multiprocess": bench_multiprocess,
    "sharded": bench_sharded,
    "gossip": bench_gossip,
    "kernels": bench_kernels,
    "agg": bench_agg,
    "transport": bench_transport,
    "llm": bench_llm,
    "soak": bench_soak,
    "obs": bench_obs,
    "serve": bench_serve,
}


def _parse_soak_size(token: str):
    """``'512'`` -> ``(512, None)``; ``'512:shard32x2'`` -> ``(512,
    'shard32x2')`` — a fleet size optionally pinned to a store layout."""
    if ":" in token:
        n, spec = token.split(":", 1)
        return int(float(n)), spec.strip()
    return int(float(token)), None


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None, choices=list(TABLES))
    ap.add_argument("--trials", type=int, default=1)
    ap.add_argument("--agg-sizes", default=None,
                    help="comma-separated param counts for --only agg "
                         "(default 1e6,1e7,1e8); e.g. --agg-sizes 200000 for "
                         "a CI smoke run")
    ap.add_argument("--transport-sizes", default=None,
                    help="comma-separated param counts for --only transport "
                         "(default 1e6,1e7); e.g. --transport-sizes 200000 "
                         "for a CI smoke run")
    ap.add_argument("--soak-sizes", default=None,
                    help="comma-separated fleet sizes for --only soak "
                         "(default 8,32,128); a size may pin its store "
                         "layout as <nodes>:<spec>, e.g. "
                         "--soak-sizes 8,512:shard32x2 runs the 512-node "
                         "soak over a 2-level summary tree")
    ap.add_argument("--gossip-sizes", default=None,
                    help="comma-separated fleet sizes for --only gossip "
                         "(default 1e3,1e4,1e5); e.g. --gossip-sizes "
                         "400,2000 for a CI smoke run")
    ap.add_argument("--obs-sizes", default=None,
                    help="comma-separated param counts for --only obs "
                         "(default 1e6,1e7); e.g. --obs-sizes 200000 for a "
                         "CI smoke run")
    ap.add_argument("--serve-sizes", default=None,
                    help="comma-separated param-scale targets for --only "
                         "serve (default 1e5,1e8 -> smoke + ~95M archs); "
                         "e.g. --serve-sizes 100000 for a CI smoke run")
    ap.add_argument("--churn", action="store_true",
                    help="with --only soak: also run an elastic-membership "
                         "soak per size (one of three workers killed whole, "
                         "survivors adopt its leases) and record adoption "
                         "latency in BENCH_soak.json")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    names = [args.only] if args.only else list(TABLES)
    for name in names:
        if name == "agg" and args.agg_sizes:
            bench_agg(args.trials,
                      sizes=[int(float(s)) for s in args.agg_sizes.split(",")])
        elif name == "transport" and args.transport_sizes:
            bench_transport(args.trials,
                            sizes=[int(float(s))
                                   for s in args.transport_sizes.split(",")])
        elif name == "soak" and (args.soak_sizes or args.churn):
            soak_sizes = ([_parse_soak_size(s)
                           for s in args.soak_sizes.split(",")]
                          if args.soak_sizes else None)
            bench_soak(args.trials, sizes=soak_sizes, churn=args.churn)
        elif name == "gossip" and args.gossip_sizes:
            bench_gossip(args.trials,
                         sizes=[int(float(s))
                                for s in args.gossip_sizes.split(",")])
        elif name == "obs" and args.obs_sizes:
            bench_obs(args.trials,
                      sizes=[int(float(s)) for s in args.obs_sizes.split(",")])
        elif name == "serve" and args.serve_sizes:
            bench_serve(args.trials,
                        sizes=[int(float(s))
                               for s in args.serve_sizes.split(",")])
        else:
            TABLES[name](args.trials)


if __name__ == "__main__":
    main()
