"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. ``us_per_call`` is wall-time per
federated experiment (μs); ``derived`` is the table's quantity (accuracy
mean±std, or speedup for the timing figure).

    PYTHONPATH=src python -m benchmarks.run             # all tables (reduced)
    PYTHONPATH=src python -m benchmarks.run --only table1 --trials 3
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def _report(name, seconds, derived):
    print(f"{name},{seconds * 1e6:.0f},{derived}", flush=True)


def _mean_std(results):
    accs = [r.accuracy_mean for r in results]
    return f"{np.mean(accs):.3f}±{np.std(accs):.3f}"


def table1_mnist_sync_vs_async(trials: int):
    """Table 1: sync vs async FedAvg accuracy across skew (MNIST, 2 nodes)."""
    from .fedbench import run_centralized_image, run_image_experiment

    t0 = time.time()
    acc = run_centralized_image(dataset="mnist")
    _report("table1/centralized", time.time() - t0, f"{acc:.3f}")
    for skew in (0.0, 0.9, 1.0):
        for mode in ("sync", "async"):
            results = []
            t0 = time.time()
            for trial in range(trials):
                results.append(run_image_experiment(
                    dataset="mnist", mode=mode, skew=skew, num_nodes=2, seed=trial))
            _report(f"table1/{mode}/skew{skew}", (time.time() - t0) / trials,
                    _mean_std(results))


def table2_mnist_strategies_nodes(trials: int, skew: float = 0.9, tag: str = "table2"):
    """Tables 2/3: strategy × node-count (MNIST), sync and async."""
    from .fedbench import run_image_experiment

    for strategy in ("fedavg", "fedavgm", "fedadam"):
        for num_nodes in (2, 3, 5):
            for mode in ("sync", "async"):
                results = []
                t0 = time.time()
                for trial in range(trials):
                    results.append(run_image_experiment(
                        dataset="mnist", mode=mode, strategy=strategy,
                        num_nodes=num_nodes, skew=skew, seed=trial))
                _report(f"{tag}/{strategy}{'_async' if mode == 'async' else ''}/n{num_nodes}",
                        (time.time() - t0) / trials, _mean_std(results))


def table3_mnist_strategies_full_skew(trials: int):
    table2_mnist_strategies_nodes(trials, skew=0.99, tag="table3")


def table4_cifar_sync_vs_async(trials: int):
    """Table 4: sync vs async FedAvg across skew (CIFAR-like, 2 nodes)."""
    from .fedbench import run_centralized_image, run_image_experiment

    t0 = time.time()
    acc = run_centralized_image(dataset="cifar", epochs=3)
    _report("table4/centralized", time.time() - t0, f"{acc:.3f}")
    for skew in (0.0, 0.9, 1.0):
        for mode in ("sync", "async"):
            results = []
            t0 = time.time()
            for trial in range(trials):
                results.append(run_image_experiment(
                    dataset="cifar", mode=mode, skew=skew, num_nodes=2, seed=trial,
                    epochs=3, steps_per_epoch=20))
            _report(f"table4/{mode}/skew{skew}", (time.time() - t0) / trials,
                    _mean_std(results))


def table5_cifar_strategies_nodes(trials: int, skew: float = 0.9, tag: str = "table5"):
    """Tables 5/6: strategy × node-count (CIFAR-like)."""
    from .fedbench import run_image_experiment

    for strategy in ("fedavg", "fedavgm"):
        for num_nodes in (2, 3, 5):
            for mode in ("sync", "async"):
                results = []
                t0 = time.time()
                for trial in range(trials):
                    results.append(run_image_experiment(
                        dataset="cifar", mode=mode, strategy=strategy,
                        num_nodes=num_nodes, skew=skew, seed=trial,
                        epochs=2, steps_per_epoch=20))
                _report(f"{tag}/{strategy}{'_async' if mode == 'async' else ''}/n{num_nodes}",
                        (time.time() - t0) / trials, _mean_std(results))


def table6_cifar_strategies_full_skew(trials: int):
    table5_cifar_strategies_nodes(trials, skew=0.99, tag="table6")


def table7_lm_nodes(trials: int):
    """Table 7: next-token accuracy, sync vs async FedAvg × node count (LM)."""
    from .fedbench import run_lm_experiment

    for num_nodes in (2, 3, 5):
        for mode in ("sync", "async"):
            results = []
            t0 = time.time()
            for trial in range(trials):
                results.append(run_lm_experiment(mode=mode, num_nodes=num_nodes, seed=trial))
            _report(f"table7/fedavg{'_async' if mode == 'async' else ''}/n{num_nodes}",
                    (time.time() - t0) / trials, _mean_std(results))


def figure_timing_straggler(trials: int):
    """Figure 1/2 claim: async avoids straggler idle time — exact virtual-clock
    model plus a real threaded run with an injected 40 ms/step slowdown."""
    from repro.core.simulation import simulate_timeline, straggler_speedup

    from .fedbench import run_image_experiment

    rng = np.random.default_rng(0)
    # NOTE: a CONSTANT k×-slower node gives sync wall == async wall (both are
    # bounded by the slow node's total); the async wall-clock win comes from
    # per-epoch VARIANCE (sync pays the per-round max), and the async
    # efficiency win from eliminating barrier idle. Report both.
    for jitter in (0.0, 0.5, 1.0):
        durations = [
            [1.0 + jitter * rng.random() for _ in range(20)] for _ in range(4)
        ]
        t0 = time.time()
        speedup = straggler_speedup(durations)
        sync_tl = simulate_timeline(durations, mode="sync")
        idle_frac = sum(sync_tl.per_node_idle) / (4 * sync_tl.wall_clock)
        _report(f"timing/vclock/jitter{jitter}_speedup", time.time() - t0, f"{speedup:.3f}")
        _report(f"timing/vclock/jitter{jitter}_sync_idle_frac", 0.0, f"{idle_frac:.3f}")
    # failure robustness: sync hangs (inf), async completes
    tl_sync = simulate_timeline([[1.0] * 5] * 2, mode="sync", failures={1: 2})
    tl_async = simulate_timeline([[1.0] * 5] * 2, mode="async", failures={1: 2})
    _report("timing/vclock/failure_sync_wall", 0.0, tl_sync.wall_clock)
    _report("timing/vclock/failure_async_wall", 0.0, tl_async.wall_clock)
    # real threads
    t0 = time.time()
    sync = run_image_experiment(mode="sync", num_nodes=2, skew=0.0, epochs=2,
                                steps_per_epoch=15, slowdowns=[0.0, 0.04])
    asy = run_image_experiment(mode="async", num_nodes=2, skew=0.0, epochs=2,
                               steps_per_epoch=15, slowdowns=[0.0, 0.04])
    _report("timing/threads/sync_vs_async_wall_ratio", time.time() - t0,
            f"{sync.wall_seconds / max(asy.wall_seconds, 1e-9):.3f}")


def bench_multiprocess(trials: int):
    """Process-scale federation: 3 OS processes over a DiskFolder, full vs
    delta+cached transport, plus a SIGKILL-robustness run (async survives)."""
    from .fedbench import run_multiprocess_experiment

    for transport in ("full", "delta"):
        results = []
        t0 = time.time()
        for trial in range(trials):
            results.append(run_multiprocess_experiment(
                dataset="mnist", mode="async", num_nodes=3, epochs=2,
                steps_per_epoch=15, transport=transport, cached=True, seed=trial))
        _report(f"mp/async/{transport}/n3", (time.time() - t0) / trials,
                _mean_std(results))
    t0 = time.time()
    res = run_multiprocess_experiment(
        dataset="mnist", mode="async", num_nodes=3, epochs=3,
        steps_per_epoch=15, kill_after={2: 20.0})
    _report("mp/async/crash1of3", time.time() - t0,
            f"{res.accuracy_mean:.3f} ({len(res.per_node_accuracy)} survivors)")


def bench_sharded(trials: int):
    """Sharded gossip store: per-step scan (state_hash + pull) cost at FIXED
    group size stays flat as the fleet grows 10x, while the flat store's scan
    grows with the fleet. Simulated nodes (one tiny deposit each), store-level
    only — this measures coordination cost, not training."""
    from repro.core import InMemoryFolder, NodeUpdate, WeightStore
    from repro.core.gossip import ShardedFolders, ShardedWeightStore

    group_size = 100
    params = {"w": np.zeros((16,), np.float32)}
    reps = max(3, trials)

    def scan_cost(store, probe):
        # Warm the decode caches through a full rotation of the bounded
        # summary sample — steady state is what the scan claim is about.
        for _ in range(12):
            store.state_hash(exclude_node=probe)
            store.pull(exclude=probe)
        # min over batches: scheduler noise only ever ADDS time, so the
        # fastest batch is the honest cost of the scan itself
        best = float("inf")
        for _ in range(7):
            t0 = time.time()
            for _ in range(reps):
                store.state_hash(exclude_node=probe)
                store.pull(exclude=probe)
            best = min(best, (time.time() - t0) / reps)
        return best

    per_fleet = {}
    for fleet in (1_000, 10_000):
        num_groups = fleet // group_size

        flat = WeightStore(InMemoryFolder(), decode_cache_entries=fleet)
        t0 = time.time()
        for i in range(fleet):
            flat.push(NodeUpdate(params, num_examples=1, node_id=f"n{i}", counter=0))
        flat_populate = time.time() - t0
        flat_scan = scan_cost(flat, "n0")

        sharded = ShardedWeightStore(
            ShardedFolders(num_groups, factory=lambda g: InMemoryFolder()),
            group_of=lambda nid: int(nid[1:]) % num_groups,
        )
        t0 = time.time()
        for i in range(fleet):
            sharded.push(NodeUpdate(params, num_examples=1, node_id=f"n{i}", counter=0))
        sharded_populate = time.time() - t0
        sharded_scan = scan_cost(sharded, "n0")

        per_fleet[fleet] = (flat_scan, sharded_scan)
        _report(f"sharded/flat_scan/n{fleet}", flat_scan,
                f"push_total={flat_populate:.2f}s")
        _report(f"sharded/sharded_scan/n{fleet}_g{num_groups}", sharded_scan,
                f"push_total={sharded_populate:.2f}s")

    growth_flat = per_fleet[10_000][0] / max(per_fleet[1_000][0], 1e-12)
    growth_sharded = per_fleet[10_000][1] / max(per_fleet[1_000][1], 1e-12)
    _report("sharded/scan_growth_10x_fleet/flat", 0.0, f"{growth_flat:.2f}x")
    _report("sharded/scan_growth_10x_fleet/sharded", 0.0,
            f"{growth_sharded:.2f}x (acceptance: < 2x at fixed group size)")


def bench_kernels(trials: int):
    """Aggregation-path microbench: us_per_call for the fed_agg hot loop
    (jnp reference on CPU — the Pallas kernel is TPU-target, validated in
    tests under interpret=True)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.fed_agg.ref import fed_agg_ref

    for K, N in ((4, 1_000_000), (8, 2_000_000)):
        x = jnp.asarray(np.random.default_rng(0).normal(size=(K, N)).astype(np.float32))
        w = jnp.full((K,), 1.0 / K, jnp.float32)
        f = jax.jit(fed_agg_ref)
        f(x, w).block_until_ready()
        t0 = time.time()
        reps = 10
        for _ in range(reps):
            f(x, w).block_until_ready()
        dt = (time.time() - t0) / reps
        _report(f"kernels/fed_agg_ref_cpu/K{K}_N{N}", dt, f"{K * N * 4 / dt / 1e9:.2f}GB/s")


TABLES = {
    "table1": table1_mnist_sync_vs_async,
    "table2": table2_mnist_strategies_nodes,
    "table3": table3_mnist_strategies_full_skew,
    "table4": table4_cifar_sync_vs_async,
    "table5": table5_cifar_strategies_nodes,
    "table6": table6_cifar_strategies_full_skew,
    "table7": table7_lm_nodes,
    "timing": figure_timing_straggler,
    "multiprocess": bench_multiprocess,
    "sharded": bench_sharded,
    "kernels": bench_kernels,
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None, choices=list(TABLES))
    ap.add_argument("--trials", type=int, default=1)
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    names = [args.only] if args.only else list(TABLES)
    for name in names:
        TABLES[name](args.trials)


if __name__ == "__main__":
    main()
