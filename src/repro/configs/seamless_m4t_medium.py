"""seamless-m4t-medium [audio] — enc-dec, multimodal [arXiv:2308.11596].

12L d_model=1024 16H (kv=16) d_ff=4096 vocab=256206. Interpreted as 12 encoder
+ 12 decoder layers (UnitY medium). The speech frontend (mel-spectrogram +
conv feature extractor) is STUBBED: the encoder consumes precomputed frame
embeddings of shape (B, frames, d_model).
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    arch_type="audio",
    source="arXiv:2308.11596 (SeamlessM4T); hf:facebook/seamless-m4t-medium",
    n_layers=12,           # decoder layers
    encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    activation="gelu",
    frontend="audio",
    frontend_tokens=1024,  # default frames per utterance for smoke/examples
    tie_embeddings=True,
)
