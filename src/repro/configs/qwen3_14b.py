"""qwen3-14b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B family].

40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    arch_type="dense",
    source="hf:Qwen/Qwen3-14B (qk_norm per Qwen3 family card)",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    activation="swiglu",
    qk_norm=True,
    tie_embeddings=True,
)
