"""minicpm3-4b [dense] — MLA (multi-head latent attention) [hf:openbmb/MiniCPM3-4B].

62L d_model=2560 40H (GQA kv=40) d_ff=6400 vocab=73448. MLA dims from the
model card: q_lora_rank=768, kv_lora_rank=256, qk_nope=64, qk_rope=32, v=64.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    arch_type="dense",
    source="hf:openbmb/MiniCPM3-4B (MLA per DeepSeek-V2, arXiv:2405.04434)",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    attention="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_dim=64,
    qk_rope_dim=32,
    v_head_dim=64,
    activation="swiglu",
    tie_embeddings=True,
)
