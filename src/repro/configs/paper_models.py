"""The paper's own experiment models (§4.2-4.4).

* MNIST CNN — two conv + pool + ReLU layers (§4.2); built via models.cnn.
* CIFAR ResNet-18 — GroupNorm variant (§4.3); built via models.cnn.
* pythia-14m — the WikiText LM (§4.4) [arXiv:2304.01373 Pythia suite]:
  6L d_model=128 4H d_ff=512, vocab 50304, gelu, rotary.
"""
from repro.models import ModelConfig

PYTHIA_14M = ModelConfig(
    name="pythia-14m",
    arch_type="dense",
    source="arXiv:2304.01373 (Pythia); hf:EleutherAI/pythia-14m",
    n_layers=6,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    d_ff=512,
    vocab_size=50304,
    activation="gelu",
    tie_embeddings=True,
)
