"""llama4-scout-17b-a16e [moe] — 16 experts top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1 with a
shared expert (Llama-4 routes top-1 + always-on shared expert).
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    arch_type="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    activation="swiglu",
    num_experts=16,
    experts_per_token=1,
    num_shared_experts=1,
    layer_pattern=("moe_attn",),
    qk_norm=True,
    tie_embeddings=True,
)
