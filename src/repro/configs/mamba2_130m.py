"""mamba2-130m [ssm] — SSD (state-space duality) [arXiv:2405.21060].

24L d_model=768, attention-free, d_ff=0, vocab=50280, ssm_state=128.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    arch_type="ssm",
    source="arXiv:2405.21060 (Mamba2); hf:state-spaces/mamba2-130m",
    n_layers=24,
    d_model=768,
    n_heads=24,            # d_inner / ssm_head_dim = 1536/64
    n_kv_heads=24,
    d_ff=0,                # attention-free, no MLP stack
    vocab_size=50280,
    attention="none",
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    layer_pattern=("ssm",),
    tie_embeddings=True,
)
