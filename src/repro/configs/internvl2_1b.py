"""internvl2-1b [vlm] — InternViT + InternLM2 backbone [arXiv:2404.16821].

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655. The InternViT vision
encoder + MLP projector are STUBBED (assignment carve-out): input_specs feeds
256 precomputed patch embeddings per example; the LM backbone is what trains.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    arch_type="vlm",
    source="arXiv:2404.16821 (InternVL2); hf:OpenGVLab/InternVL2-1B (Qwen2-0.5B LM)",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    activation="swiglu",
    frontend="vision",
    frontend_tokens=256,
    tie_embeddings=True,
)
