"""grok-1-314b [moe] — 8 experts top-2 [hf:xai-org/grok-1].

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072, MoE 8e top-2.
The largest assigned model: 314B total / ~86B active params.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    arch_type="moe",
    source="hf:xai-org/grok-1",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    activation="gelu",
    num_experts=8,
    experts_per_token=2,
    layer_pattern=("moe_attn",),
    attn_logit_softcap=30.0,
    tie_embeddings=True,
)
