"""Architecture config registry: ``get_config("<arch-id>")``.

The 10 assigned architectures (public-literature pool) plus the paper's own
experiment models. Every config cites its source in the module docstring and
``ModelConfig.source``.
"""
from __future__ import annotations

import importlib

from repro.models import ModelConfig

ARCH_IDS = [
    "mamba2-130m",
    "recurrentgemma-9b",
    "gemma-7b",
    "minicpm3-4b",
    "internvl2-1b",
    "llama4-scout-17b-a16e",
    "grok-1-314b",
    "granite-3-2b",
    "seamless-m4t-medium",
    "qwen3-14b",
]

PAPER_IDS = ["pythia-14m"]

_MODULES = {arch: "repro.configs." + arch.replace("-", "_") for arch in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    if arch in _MODULES:
        return importlib.import_module(_MODULES[arch]).CONFIG
    if arch == "pythia-14m":
        return importlib.import_module("repro.configs.paper_models").PYTHIA_14M
    raise KeyError(f"unknown arch {arch!r}; options: {ARCH_IDS + PAPER_IDS}")


def all_configs() -> dict[str, ModelConfig]:
    return {arch: get_config(arch) for arch in ARCH_IDS}
