"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1:2 [arXiv:2402.19427].

38L d_model=4096 16H (GQA kv=1) d_ff=12288 vocab=256000. Pattern: two RG-LRU
recurrent blocks per local-attention block (window 2048). 38 = 12×(R,R,A)+2R.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    source="arXiv:2402.19427 (Griffin/RecurrentGemma); hf:google/recurrentgemma-9b",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,          # MQA on the local-attention blocks
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    activation="geglu",
    sliding_window=2048,   # local attention window
    layer_pattern=("rglru", "rglru", "attn"),
    rglru_c=8.0,
    conv1d_width=4,
    tie_embeddings=True,
)
