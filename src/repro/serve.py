"""Join a live fleet read-only and serve its freshest weights.

    python -m repro.serve --store /mnt/shared/exp1 --arch pythia-14m --reduced

Works against any store the URI grammar accepts (``memory://`` is only
useful in-process; sharded/hierarchical ``shard<G>[x<L>]+`` URIs join via
the cross-group pull). The node deploys the freshest aggregated update in
the store, hot-swaps as trainers push new rounds, and serves synthetic
greedy-decode batches, printing per-batch throughput plus the swap/staleness
SLOs. With ``REPRO_OBS`` (or ``--obs``) the node also deposits ``obs/``
blobs, so ``python -m repro.obs watch --store <uri>`` shows its SERVE row.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.api import connect, serve


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--store", required=True, help="weight-store URI (see repro.api)")
    ap.add_argument("--arch", required=True, help="arch name from repro.configs")
    ap.add_argument("--reduced", action="store_true", help="reduced config (smoke scale)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8,
                    help="synthetic batches to serve before exiting (0 = until --timeout)")
    ap.add_argument("--timeout", type=float, default=60.0,
                    help="overall wall-clock budget in seconds")
    ap.add_argument("--wait", type=float, default=30.0,
                    help="seconds to wait for the first weights in the store")
    ap.add_argument("--poll-interval", type=float, default=0.25)
    ap.add_argument("--obs", action="store_true",
                    help="force telemetry on (default: REPRO_OBS env)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    store = connect(args.store)
    node = serve(
        store,
        args.arch,
        reduced=args.reduced,
        poll_interval=args.poll_interval,
        telemetry=True if args.obs else None,
        start=True,
    )
    try:
        if not node.wait_until_deployed(args.wait):
            print(f"serve: no deployable weights in {args.store!r} "
                  f"after {args.wait:.0f}s")
            return 1
        rng = np.random.default_rng(args.seed)
        deadline = time.monotonic() + args.timeout
        served = 0
        while time.monotonic() < deadline:
            prompts = rng.integers(
                0, node.cfg.vocab_size, (args.batch, args.prompt_len), dtype=np.int32
            )
            t0 = time.monotonic()
            out, meta = node.generate(prompts, new_tokens=args.new_tokens)
            dt = time.monotonic() - t0
            served += 1
            tps = out.size / dt
            print(
                f"batch {served}: tokens/s={tps:.1f} weights={meta['source']}"
                f"@{meta['counter']} swaps={node.stats()['swaps']}"
            )
            if args.requests and served >= args.requests:
                break
        print("SLO", json.dumps(node.stats()))
        return 0
    finally:
        node.stop()
        store.stop_prefetch()


if __name__ == "__main__":
    raise SystemExit(main())
