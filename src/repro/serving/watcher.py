"""StoreWatcher: turn a weight store into a deployment feed.

The serverless design has no publish step — every node's ``latest/<node>``
blob already IS an aggregated model (clients aggregate locally before
training on). A serving node therefore watches the store read-only and
deploys the *freshest* update visible: highest ``(counter, timestamp)``
across ``pull()``, which on sharded/hierarchical stores folds in cross-group
summaries (group summaries are spec-compatible weighted means, so they are
deployable too). Freshness polling rides the same decoded-update cache the
``Prefetcher`` warms — an unchanged store costs a ``version()`` listing
sweep, not a decode.

Updates whose layout does not match the serving model's :class:`LeafSpec`
(a different arch sharing the store, or a family-subset federation that
never ships full weights) are skipped and counted, never deployed.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass
from typing import Any

import numpy as np

from repro.core.tree import LeafSpec

_log = logging.getLogger("repro.serving")


@dataclass
class Deployment:
    """One deployable weight set pulled from the store.

    Exactly one of ``flat`` / ``params`` is set: flat-path updates share the
    store's cached f32 vector (READ-ONLY — copy before mutating), tree-path
    updates carry the decoded pytree.
    """

    source: str                 # node id the weights came from
    counter: int                # source's client-local round counter
    timestamp: float
    max_counter: int            # freshest counter seen anywhere in the store
    flat: np.ndarray | None = None
    params: Any = None


class StoreWatcher:
    """Synchronous freshest-update poller over any weight store.

    ``poll()`` returns a new :class:`Deployment` when the freshest
    spec-compatible update changed since the last call, else ``None``.
    ``last_max_counter`` always tracks the freshest counter seen (including
    updates that were not deployable), which is what rounds-behind-store
    staleness is measured against.
    """

    def __init__(self, store, *, spec: LeafSpec | None = None):
        self.store = store
        self.spec = spec
        self.last_max_counter: int | None = None
        self.skipped_incompatible = 0
        self._deployed_key: tuple | None = None

    def _extract(self, update) -> tuple[np.ndarray | None, Any] | None:
        """(flat, params) for a spec-compatible update, else None."""
        flat = getattr(update, "flat", None)
        spec = getattr(update, "spec", None)
        if self.spec is None:
            if flat is not None:
                return flat, None
            return None, update.params
        if flat is not None and self.spec.compatible(spec):
            return flat, None
        # tree-path fallback: deployable iff the tree has our exact layout
        try:
            params = update.params
            if self.spec.describes(params):
                return None, params
        except Exception:
            pass
        return None

    def poll(self) -> Deployment | None:
        updates = self.store.pull()
        best = None
        best_payload = None
        max_counter = None
        for u in updates:
            if u is None:
                continue
            counter = int(getattr(u, "counter", 0))
            if max_counter is None or counter > max_counter:
                max_counter = counter
            if best is not None and (counter, u.timestamp) <= (best.counter, best.timestamp):
                continue
            payload = self._extract(u)
            if payload is None:
                self.skipped_incompatible += 1
                continue
            best, best_payload = u, payload
        self.last_max_counter = max_counter
        if best is None:
            return None
        key = (best.node_id, best.counter, best.timestamp)
        if key == self._deployed_key:
            return None
        self._deployed_key = key
        flat, params = best_payload
        return Deployment(
            source=best.node_id,
            counter=int(best.counter),
            timestamp=float(best.timestamp),
            max_counter=int(max_counter if max_counter is not None else best.counter),
            flat=flat,
            params=params,
        )
