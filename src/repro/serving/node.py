"""ServingNode: hot-swapping batched decode over a federation store.

Double buffering, on both sides of the host/device boundary. The watcher
thread decodes a fresh deployment into the *standby* host f32 buffer
(``np.copyto`` for flat-path updates, ``LeafSpec.flatten_into`` for
tree-path ones), materializes the standby *device* leaf set, and publishes
it with one atomic reference flip. A decode batch snapshots the active tree
once at batch start, so:

  * a swap landing mid-batch never changes the weights a batch started with
    (no torn read — the batch finishes on its snapshot; per-buffer in-flight
    counts keep a buffer's device leaves untouched until the last batch
    referencing them completes);
  * requests never wait on a swap (zero downtime — the flip is a reference
    assignment, all decode/materialize work happens off the request path).

Device materialization is *chunk-throttled*: the standby device leaves are
updated in place through a donated ``dynamic_update_slice`` in ~2 MB slices
with a yield between slices. One leaf-sized host→device copy would serialize
with decode executions on the device stream and stall in-flight requests for
hundreds of ms at 10^8 params; many small ops interleave, which is what
keeps p99 decode latency during a swap within the SLO (measured in
``benchmarks.run --only serve``).

Telemetry spans ``serve.prefill`` / ``serve.decode`` / ``serve.swap`` plus a
``serve`` SLO dict (swap-latency percentiles, staleness-in-rounds, token
throughput) ride ``obs/`` blobs like every trainer's metrics do.
"""
from __future__ import annotations

import logging
import threading
import time
import uuid
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.telemetry import Telemetry
from repro.core.tree import LeafSpec
from repro.launch.steps import make_bulk_prefill_step, make_serve_step
from repro.models import ModelConfig, build_model

from .watcher import Deployment, StoreWatcher

_log = logging.getLogger("repro.serving")

_SLO_WINDOW = 512  # swap/staleness samples kept for percentile SLOs

_SWAP_CHUNK = 512 * 1024  # f32 elements per donated device write (~2 MB)
_SWAP_PAUSE_S = 0.001     # yield between chunks so queued decodes interleave
_SWAP_DRAIN_TIMEOUT_S = 30.0  # max wait for a batch still on the standby leaves


@partial(jax.jit, donate_argnums=(0,))
def _chunk_write(leaf, chunk, start):
    """Donated in-place write of an f32 chunk at flat offset ``start``.

    Donation reuses ``leaf``'s device buffer, so a swap never allocates or
    copies a whole leaf at once — the reshape round-trip is a bitcast.
    """
    flat = leaf.reshape((-1,))
    flat = jax.lax.dynamic_update_slice_in_dim(
        flat, chunk.astype(leaf.dtype), start, axis=0
    )
    return flat.reshape(leaf.shape)


def _percentile(sorted_vals: list[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1))))
    return sorted_vals[idx]


@dataclass
class _Deployed:
    """One published weight set; immutable once assigned to ``_deployed``."""

    params: Any        # device param tree (views of one host buffer's copy)
    source: str
    counter: int
    deployed_at: float
    buf: int | None = None  # device-buffer index (None: mesh/fallback tree)


class ServingNode:
    """Read-only federation member that serves the freshest store weights.

    Parameters
    ----------
    store:
        Any weight store (flat, sharded, hierarchical). The node only reads
        weights; its sole writes are its own ``obs/`` telemetry blobs.
    arch:
        Arch name from ``repro.configs`` or a full :class:`ModelConfig`.
    reduced:
        Shrink the config (``ModelConfig.reduced()``) — CI/smoke scale.
    poll_interval:
        Seconds between store freshness sweeps on the watcher thread.
    telemetry:
        ``Telemetry`` instance, bool, or None (``REPRO_OBS`` env default) —
        same contract as the trainer nodes.
    mesh:
        Optional ``jax.sharding.Mesh``: deployments are placed with
        ``launch.sharding.param_shardings`` instead of single-device.
    window_override:
        Optional sliding-window override threaded to prefill/decode.
    """

    def __init__(
        self,
        store,
        arch: str | ModelConfig,
        *,
        node_id: str | None = None,
        reduced: bool = False,
        poll_interval: float = 0.25,
        telemetry: "Telemetry | bool | None" = None,
        mesh=None,
        window_override: int | None = None,
        clock: Callable[[], float] = time.perf_counter,
    ):
        cfg = arch if isinstance(arch, ModelConfig) else get_config(arch)
        if reduced:
            cfg = cfg.reduced()
        if cfg.is_encdec:
            raise ValueError(
                "ServingNode covers decoder-only archs (the federated zoo); "
                "use repro.launch.serve.serve_batch for enc-dec one-shots"
            )
        self.cfg = cfg
        self.model = build_model(cfg)
        self.store = store
        self.node_id = node_id or f"serve-{uuid.uuid4().hex[:8]}"
        self.poll_interval = float(poll_interval)
        self._clock = clock
        if isinstance(telemetry, Telemetry):
            self.telemetry = telemetry
        else:
            self.telemetry = Telemetry(self.node_id, enabled=telemetry)
        if self.telemetry.enabled and hasattr(store, "attach_telemetry"):
            store.attach_telemetry(self.telemetry)

        shapes = jax.eval_shape(self.model.init, jax.random.PRNGKey(0))
        self.spec = LeafSpec.of(shapes)
        self.watcher = StoreWatcher(store, spec=self.spec)
        self._shardings = None
        if mesh is not None:
            from repro.launch.sharding import param_shardings

            self._shardings = param_shardings(shapes, mesh)

        self._prefill = jax.jit(make_bulk_prefill_step(cfg, window_override=window_override))
        self._serve_step = jax.jit(make_serve_step(cfg, window_override=window_override))
        self._window_override = window_override

        # double buffer: standby is written + materialized off the request
        # path, then published by flipping one reference
        self._buffers = [self.spec.empty_flat(), self.spec.empty_flat()]
        self._standby = 0
        self._deployed: _Deployed | None = None
        self._deployed_event = threading.Event()
        # device-side double buffer for the chunk-throttled swap: two leaf
        # lists updated in place via donation. In-place writes would tear a
        # batch still decoding on the standby leaves (two swaps back), so a
        # per-buffer in-flight count gates the overwrite.
        self._dev_leaves: list[list | None] = [None, None]
        self._buf_refs = [0, 0]
        self._buf_cv = threading.Condition()

        self._lock = threading.Lock()
        self._swaps = 0
        self._requests = 0
        self._tokens = 0
        self._serve_seconds = 0.0
        self._swap_ms: list[float] = []
        self._swap_log: list[tuple[float, float]] = []  # (t0, t1) monotonic
        self._stale_recent: list[float] = []

        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    # -- lifecycle ------------------------------------------------------

    def start(self) -> "ServingNode":
        """Start the watcher thread (idempotent)."""
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._run, name=f"serving-{self.node_id}", daemon=True
            )
            self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None
        self.flush_obs()

    def __enter__(self) -> "ServingNode":
        return self.start()

    def __exit__(self, *exc: object) -> None:
        self.stop()

    def wait_until_deployed(self, timeout: float | None = None) -> bool:
        """Block until the first weight set is live (True) or timeout."""
        return self._deployed_event.wait(timeout)

    # -- watcher thread -------------------------------------------------

    def _run(self) -> None:
        self.poll_once()  # immediate first sweep: resume-from-latest on start
        while not self._stop.wait(self.poll_interval):
            self.poll_once()

    def poll_once(self) -> bool:
        """One freshness sweep (also callable inline, e.g. from tests).
        Returns True when a new deployment was swapped in."""
        swapped = False
        try:
            with self.telemetry.span("serve.poll"):
                dep = self.watcher.poll()
            if dep is not None:
                self.deploy(dep)
                swapped = True
        except Exception:
            _log.warning("serving node %s: poll failed", self.node_id, exc_info=True)
        d = self._deployed
        if d is not None and self.watcher.last_max_counter is not None:
            behind = max(0, self.watcher.last_max_counter - d.counter)
            self.telemetry.observe_staleness(behind)
            with self._lock:
                self._stale_recent.append(float(behind))
                del self._stale_recent[:-_SLO_WINDOW]
        if self.telemetry.enabled:
            self.telemetry.end_round(aggregated=swapped)
            if self.telemetry.should_flush():
                self.flush_obs()
        return swapped

    def deploy(self, dep: Deployment) -> None:
        """Decode ``dep`` into the standby buffer, materialize the device
        tree, and publish it. Runs off the request path; ``generate`` never
        blocks on this."""
        t0 = self._clock()
        with self.telemetry.span("serve.swap"):
            idx = self._standby
            buf = self._buffers[idx]
            if dep.flat is not None:
                np.copyto(buf, dep.flat)
            else:
                self.spec.flatten_into(dep.params, buf)
            if self._shardings is not None:
                # mesh path: jnp.array (copy=True) so leaves own their memory
                # before device_put scatters them across the mesh
                tree = jax.tree.map(jnp.array, self.spec.unflatten(buf))
                tree = jax.device_put(tree, self._shardings)
                jax.block_until_ready(tree)
                buf_index = None
            else:
                tree = self._materialize_chunked(idx, buf)
                buf_index = idx
            # publish: one atomic reference flip; in-flight batches keep
            # their snapshot of the previous tree
            self._deployed = _Deployed(
                params=tree,
                source=dep.source,
                counter=dep.counter,
                deployed_at=self._clock(),
                buf=buf_index,
            )
            self._standby ^= 1
        t1 = self._clock()
        with self._lock:
            self._swaps += 1
            self._swap_ms.append((t1 - t0) * 1e3)
            del self._swap_ms[:-_SLO_WINDOW]
            self._swap_log.append((t0, t1))
            del self._swap_log[:-_SLO_WINDOW]
        self.telemetry.count("serve.swaps")
        self._deployed_event.set()

    def _materialize_chunked(self, idx: int, buf: np.ndarray) -> Any:
        """Write the standby host buffer into device leaf set ``idx`` in
        ~2 MB donated chunks, yielding between chunks so decode steps queued
        on the device stream interleave instead of stalling behind one
        leaf-sized copy."""
        # the in-place writes would tear a batch still decoding on this
        # buffer's previous leaves — wait for it to drain (the OTHER buffer
        # stays live the whole time; new batches snapshot that one)
        with self._buf_cv:
            drained = self._buf_cv.wait_for(
                lambda: self._buf_refs[idx] == 0, timeout=_SWAP_DRAIN_TIMEOUT_S
            )
        leaves = self._dev_leaves[idx] if drained else None
        if leaves is None:
            # first swap into this buffer — or a wedged batch at timeout, in
            # which case fresh allocations keep the old leaves intact
            leaves = [
                jnp.zeros(s, d)
                for s, d in zip(self.spec.shapes, self.spec.dtypes)
            ]
        out = []
        last = len(leaves) - 1
        for i, leaf in enumerate(leaves):
            o = int(self.spec.offsets[i])
            n = int(self.spec.sizes[i])
            pos = 0
            while pos < n:
                m = min(_SWAP_CHUNK, n - pos)
                chunk = jnp.asarray(buf[o + pos : o + pos + m])
                leaf = _chunk_write(leaf, chunk, jnp.int32(pos))
                leaf.block_until_ready()
                pos += m
                if pos < n or i < last:
                    time.sleep(_SWAP_PAUSE_S)
            out.append(leaf)
        self._dev_leaves[idx] = out
        return jax.tree_util.tree_unflatten(self.spec.treedef, out)

    # -- request path ---------------------------------------------------

    def generate(
        self,
        prompts,
        *,
        new_tokens: int,
        on_token: Callable[[int], None] | None = None,
    ) -> tuple[np.ndarray, dict]:
        """Batched greedy decode on the currently deployed weights.

        prompts: (B, S) int32 → ((B, new_tokens) continuations, meta).
        The active weight set is snapshotted once at batch start — a swap
        landing mid-batch does not affect this batch. ``on_token`` (if set)
        is called after each generated token with its index; meta carries
        per-token decode spans on the node's monotonic clock for SLO math.
        """
        # snapshot + in-flight increment under one lock: once the watcher
        # sees a zero refcount for the standby buffer, no new batch can
        # start on it (any new snapshot points at the active buffer)
        with self._buf_cv:
            dep = self._deployed
            if dep is not None and dep.buf is not None:
                self._buf_refs[dep.buf] += 1
        if dep is None:
            raise RuntimeError(
                f"serving node {self.node_id}: no weights deployed yet "
                "(wait_until_deployed, or check the store has pushed updates)"
            )
        try:
            return self._generate_on(dep, prompts, new_tokens, on_token)
        finally:
            if dep.buf is not None:
                with self._buf_cv:
                    self._buf_refs[dep.buf] -= 1
                    self._buf_cv.notify_all()

    def _generate_on(self, dep, prompts, new_tokens, on_token):
        prompts = jnp.asarray(prompts, jnp.int32)
        B, S = prompts.shape
        cache = self.model.init_cache(
            B, capacity=S + new_tokens, window_override=self._window_override
        )
        t_start = self._clock()
        with self.telemetry.span("serve.prefill"):
            tok, cache = self._prefill(dep.params, prompts, cache)
            tok.block_until_ready()
        t_prefill = self._clock()
        toks = [tok]
        decode_spans: list[tuple[float, float]] = []
        if on_token is not None:
            on_token(0)
        for t in range(1, new_tokens):
            ts = self._clock()
            with self.telemetry.span("serve.decode"):
                tok, cache = self._serve_step(dep.params, tok, cache, jnp.int32(S - 1 + t))
                tok.block_until_ready()
            decode_spans.append((ts, self._clock()))
            toks.append(tok)
            if on_token is not None:
                on_token(t)
        t_end = self._clock()
        n_tokens = B * new_tokens
        with self._lock:
            self._requests += 1
            self._tokens += n_tokens
            self._serve_seconds += t_end - t_start
        self.telemetry.count("serve.requests")
        self.telemetry.count("serve.tokens", n_tokens)
        meta = {
            "source": dep.source,
            "counter": dep.counter,
            "prefill_s": t_prefill - t_start,
            "decode_spans": decode_spans,
            "batch_span": (t_start, t_end),
        }
        return np.asarray(jnp.stack(toks, axis=1)), meta

    # -- SLOs / observability -------------------------------------------

    def swap_log(self) -> list[tuple[float, float]]:
        """Recent (start, end) swap intervals on the node's monotonic clock."""
        with self._lock:
            return list(self._swap_log)

    def stats(self) -> dict:
        """Serving SLO rollup — also the ``serve`` dict in obs payloads."""
        with self._lock:
            swap_sorted = sorted(self._swap_ms)
            stale = list(self._stale_recent)
            swaps, requests, tokens = self._swaps, self._requests, self._tokens
            serve_seconds = self._serve_seconds
        d = self._deployed
        return {
            "deployed": d is not None,
            "source": d.source if d else "",
            "counter": d.counter if d else -1,
            "swaps": swaps,
            "requests": requests,
            "tokens": tokens,
            "tokens_per_sec": round(tokens / serve_seconds, 3) if serve_seconds > 0 else 0.0,
            "swap_ms_p50": round(_percentile(swap_sorted, 0.5), 3),
            "swap_ms_p99": round(_percentile(swap_sorted, 0.99), 3),
            "swap_ms_max": round(swap_sorted[-1], 3) if swap_sorted else 0.0,
            "staleness_mean": round(sum(stale) / len(stale), 4) if stale else 0.0,
            "staleness_max": max(stale) if stale else 0.0,
            "skipped_incompatible": self.watcher.skipped_incompatible,
        }

    def flush_obs(self) -> None:
        """Deposit one ``obs/<node>/<seq>`` blob with the serve SLO dict."""
        if not self.telemetry.enabled:
            return
        try:
            transport = self.store.transport_stats() if hasattr(self.store, "transport_stats") else None
            payload = self.telemetry.snapshot(transport)
            payload["serve"] = self.stats()
            self.store.push_obs(
                self.node_id, payload["seq"], payload, keep=self.telemetry.obs_keep
            )
        except Exception:
            # observability must never take down serving
            _log.debug("serving node %s: obs flush failed", self.node_id, exc_info=True)
