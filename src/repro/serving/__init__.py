"""Serving tier: continuous deployment from the federation store to live
batched inference.

The store is the only coordination primitive here, exactly as in training:
a :class:`StoreWatcher` polls the store's ``latest/`` listings read-only and
picks the freshest aggregated weights it can see; a :class:`ServingNode`
decodes them into a preallocated flat standby buffer, hot-swaps with
zero-downtime double buffering, and serves batched greedy decode through the
same jitted ``serve_step`` the launch layer uses. SLOs (staleness in rounds,
swap latency) flow back into the store as ``obs/`` blobs, so
``python -m repro.obs watch`` shows the serving fleet next to the trainers.

Public entry points: ``repro.api.serve`` and ``python -m repro.serve``.
"""
from .node import ServingNode
from .watcher import Deployment, StoreWatcher

__all__ = ["Deployment", "ServingNode", "StoreWatcher"]
