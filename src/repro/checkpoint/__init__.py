"""Checkpointing: pytree ↔ npz files.

Same wire format as the weight store (key-path keyed npz), so a federated
node can bootstrap directly from a checkpoint and a checkpoint can be
deposited into a store. Writes are atomic (tmp + rename) and keep a bounded
number of retained steps.
"""
from __future__ import annotations

import json
import os
import re
import tempfile

from repro.core.serialize import deserialize_params, serialize_params
from repro.core.tree import PyTree

_CKPT_RE = re.compile(r"^step_(\d+)\.npz$")


def save_checkpoint(directory: str, step: int, params: PyTree, *, extra: dict | None = None, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    blob = serialize_params(params, meta={"step": int(step), **(extra or {})})
    path = os.path.join(directory, f"step_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        f.write(blob)
    os.replace(tmp, path)
    _gc(directory, keep)
    return path


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for name in os.listdir(directory) if (m := _CKPT_RE.match(name))]
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: int | None = None) -> tuple[PyTree, dict]:
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}.npz")
    with open(path, "rb") as f:
        return deserialize_params(f.read())


def _gc(directory: str, keep: int) -> None:
    names = sorted(n for n in os.listdir(directory) if _CKPT_RE.match(n))
    for name in names[:-keep] if keep > 0 else []:
        os.unlink(os.path.join(directory, name))
