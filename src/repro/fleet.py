"""``python -m repro.fleet`` — multi-host federation launcher + chaos soak CLI.

The fleet coordinates *through the shared folder alone* (spec, slot claims,
heartbeats, results are all ``fleet/`` blobs): no coordinator, no parent
process, exactly like the serverless federation it drives.

Single host, two simulated "hosts" (separate worker invocations)::

    python -m repro.fleet init   --store /tmp/soak --nodes 8 --rounds 8 --chaos-kills 2
    python -m repro.fleet worker --store /tmp/soak --worker-id hostA --max-slots 4 &
    python -m repro.fleet worker --store /tmp/soak --worker-id hostB --max-slots 4
    python -m repro.fleet report --store /tmp/soak --assert-passed

Multiple real hosts: point ``--store`` at a shared mount (NFS / gcsfuse /
s3fs) and run ``worker`` once per machine — nothing else changes. ``launch``
is the one-command local convenience (init + N in-process workers + report);
``watch`` tails progress read-only from any host.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.core.fleet import (
    ChaosSpec,
    FleetSpec,
    assemble_report,
    control_folder,
    read_spec,
    run_fleet_local,
    run_worker,
    watch,
    write_spec,
)


def _add_spec_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--strategy", default="fedavg")
    ap.add_argument("--transport", default=None,
                    help="pipeline spec string, e.g. 'delta(chain=4)|npz'")
    ap.add_argument("--runner", choices=("process", "thread"), default="process")
    ap.add_argument("--param-size", type=int, default=256)
    ap.add_argument("--round-sleep", type=float, default=0.05)
    ap.add_argument("--settle", type=float, default=1.0)
    ap.add_argument("--result-timeout", type=float, default=180.0)
    ap.add_argument("--name", default="soak")
    ap.add_argument("--seed", type=int, default=0, help="chaos schedule seed")
    ap.add_argument("--chaos-kills", type=int, default=0,
                    help="SIGKILL-then-restart victims (seeded, randomized)")
    ap.add_argument("--chaos-stalls", type=int, default=0,
                    help="slow-node stall victims (seeded, randomized)")
    ap.add_argument("--chaos-kill-workers", type=int, default=0,
                    help="whole-WORKER kill victims: the drawn worker dies "
                         "(SIGKILL, no cleanup) and survivors must adopt its "
                         "stranded slot leases")
    ap.add_argument("--lease-ttl", type=float, default=15.0,
                    help="slot-lease freshness window in seconds; a worker "
                         "silent this long forfeits its slots to adoption")
    ap.add_argument("--stall-duration", type=float, default=1.0)
    ap.add_argument("--restart-after", type=float, default=0.5)
    ap.add_argument("--kill-grace", type=float, default=30.0)


def _spec_from_args(args: argparse.Namespace) -> FleetSpec:
    return FleetSpec(
        store_uri=args.store,
        name=args.name,
        num_nodes=args.nodes,
        rounds=args.rounds,
        strategy=args.strategy,
        transport=args.transport,
        runner=args.runner,
        param_size=args.param_size,
        round_sleep=args.round_sleep,
        settle=args.settle,
        result_timeout=args.result_timeout,
        lease_ttl=args.lease_ttl,
        chaos=ChaosSpec(
            seed=args.seed,
            kills=args.chaos_kills,
            stalls=args.chaos_stalls,
            stall_duration=args.stall_duration,
            restart_after=args.restart_after,
            kill_grace=args.kill_grace,
            kill_workers=args.chaos_kill_workers,
        ),
    )


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="repro.fleet", description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="command", required=True)

    p_init = sub.add_parser("init", help="serialize a FleetSpec into the shared folder")
    p_init.add_argument("--store", required=True,
                        help="data-plane folder URI (cache+/shard<G>+ grammar)")
    _add_spec_args(p_init)

    p_worker = sub.add_parser("worker", help="claim slots and run this host's nodes")
    p_worker.add_argument("--store", required=True)
    p_worker.add_argument("--worker-id", default=None)
    p_worker.add_argument("--max-slots", type=int, default=None)
    p_worker.add_argument("--timeout", type=float, default=None)
    p_worker.add_argument("--spec-timeout", type=float, default=60.0,
                          help="how long to poll for the spec blob")

    p_watch = sub.add_parser("watch", help="tail fleet progress (read-only)")
    p_watch.add_argument("--store", required=True)
    p_watch.add_argument("--interval", type=float, default=2.0)
    p_watch.add_argument("--timeout", type=float, default=600.0)

    p_report = sub.add_parser("report", help="assemble + print the SoakReport")
    p_report.add_argument("--store", required=True)
    p_report.add_argument("--json", action="store_true", dest="as_json")
    p_report.add_argument("--assert-passed", action="store_true",
                          help="exit 1 unless the soak passed (CI gate)")

    p_launch = sub.add_parser(
        "launch", help="init + N local workers + report, in one command")
    p_launch.add_argument("--store", required=True)
    p_launch.add_argument("--workers", type=int, default=2)
    p_launch.add_argument("--timeout", type=float, default=None)
    p_launch.add_argument("--assert-passed", action="store_true")
    _add_spec_args(p_launch)

    args = ap.parse_args(argv)

    if args.command == "init":
        spec = _spec_from_args(args)
        write_spec(control_folder(spec.store_uri), spec)
        print(f"fleet spec written to {spec.store_uri!r}: "
              f"{spec.num_nodes} nodes x {spec.rounds} rounds, "
              f"chaos kills={spec.chaos.kills} stalls={spec.chaos.stalls} "
              f"seed={spec.chaos.seed}")
        return 0

    if args.command == "worker":
        # The CLI worker is its own OS process, so worker-kill chaos can be
        # the real thing: a drawn victim SIGKILLs itself (exit 137) and its
        # node children — survivors must adopt the lapsed leases.
        report = run_worker(args.store, worker_id=args.worker_id,
                            max_slots=args.max_slots, timeout=args.timeout,
                            spec_timeout=args.spec_timeout,
                            worker_kill_mode="sigkill")
        print(f"worker {report.worker_id}: slots={report.slots} "
              f"crashes_injected={report.crashes_injected} "
              f"restarts={report.restarts} "
              f"adoptions={sorted(report.adoptions)} "
              f"fleet_state_hash={report.fleet_state_hash} "
              f"all_results_seen={report.all_results_seen}")
        return 0 if report.all_results_seen else 1

    if args.command == "watch":
        report = watch(args.store, interval=args.interval, timeout=args.timeout)
        print(report.summary())
        return 0 if report.passed else 1

    if args.command == "report":
        control = control_folder(args.store)
        report = assemble_report(control, read_spec(control))
        if args.as_json:
            print(json.dumps(report.to_dict(), indent=2, sort_keys=True, default=str))
        else:
            print(report.summary())
        if args.assert_passed and not report.passed:
            print("soak FAILED acceptance (see summary above)", file=sys.stderr)
            return 1
        return 0

    if args.command == "launch":
        spec = _spec_from_args(args)
        report = run_fleet_local(spec, num_workers=args.workers,
                                 timeout=args.timeout)
        print(report.summary())
        if args.assert_passed and not report.passed:
            print("soak FAILED acceptance (see summary above)", file=sys.stderr)
            return 1
        return 0

    raise AssertionError(f"unhandled command {args.command!r}")


if __name__ == "__main__":
    raise SystemExit(main())
