"""``python -m repro.obs`` — the serverless observability CLI.

Fleet telemetry lives in the store itself (``obs/<node>/<seq>`` blobs each
node deposits; see ``repro.core.telemetry``), so the dashboard is just a
reader — coordinator-free, runnable from any host that can see the mount,
and adding nothing to the data path::

    python -m repro.obs watch --store /mnt/shared/exp1          # live dashboard
    python -m repro.obs watch --store /mnt/shared/exp1 --once   # one snapshot
    python -m repro.obs trace --store /mnt/shared/exp1 --out trace.json

``watch`` prints a per-node table: round rate, update staleness (the FedAsync
signal), bytes moved, round-phase latencies, and flags stragglers (round rate
under half the fleet median). Serving-tier nodes (``repro.api.serve``) show up
in their own SERVE table — deploys, tokens/sec, rounds-behind-store staleness,
swap-latency percentiles — fed purely from the same store blobs. ``trace`` merges every node's span ring into
one Chrome trace-event JSON — open it at https://ui.perfetto.dev (or
chrome://tracing) to see the fleet's pull/decode/aggregate/encode/push/train
phases on a single timeline.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.core.telemetry import chrome_trace, collect_obs, telemetry_rollups


def render_dashboard(obs_by_node: dict, *, printer=print) -> dict:
    """Print one dashboard frame from collected obs payloads; returns the
    rollups dict it rendered (handy for tests and callers)."""
    rollups = telemetry_rollups(obs_by_node)
    nodes = rollups["nodes"]
    fleet = rollups["fleet"]
    if not nodes:
        printer("[obs] no obs/ blobs found — is telemetry enabled? "
                "(REPRO_OBS=1 or telemetry=True on the node)")
        return rollups
    # Serving nodes report SLOs, not training rounds — split them out so they
    # get their own table and don't drag the straggler median down.
    serving = {n: v for n, v in nodes.items() if v.get("role") == "serve"}
    trainers = {n: v for n, v in nodes.items() if n not in serving}
    rates = sorted(v["rounds_per_sec"] for v in trainers.values()) or [0.0]
    median_rate = rates[len(rates) // 2]
    churn = fleet.get("adoptions", 0)
    printer(f"[obs] {fleet['nodes_reporting']} nodes reporting, "
            f"{fleet.get('rounds_total', 0)} rounds total, "
            f"fleet staleness mean {fleet.get('staleness_mean', 0.0):.2f}"
            + (f", {churn} adopted" if churn else "")
            + (f", {len(serving)} serving" if serving else ""))
    header = (f"{'node':<14} {'rounds':>6} {'r/s':>6} {'stale(mean/p90)':>16} "
              f"{'MB w/r':>12} {'pull':>8} {'push':>8} {'agg':>8} {'train':>8} "
              f"{'churn':>6} flags")
    printer(header)
    stragglers = []
    for node_id, v in trainers.items():
        phase = v["phase_ms"]
        flags = []
        if median_rate > 0 and v["rounds_per_sec"] < 0.5 * median_rate:
            flags.append("STRAGGLER")
            stragglers.append(node_id)
        if v.get("adopted"):
            flags.append("ADOPTED")
        if v["dropped_spans"]:
            flags.append(f"dropped={v['dropped_spans']}")
        # CHURN column: the lease epoch the node runs at — 0 for founding
        # claims, >0 once a surviving worker adopted the slot.
        churn_txt = f"e{v.get('lease_epoch', 0)}" if v.get("adopted") else "-"
        printer(
            f"{node_id:<14} {v['rounds']:>6} {v['rounds_per_sec']:>6.2f} "
            f"{v['staleness_mean']:>8.2f}/{v['staleness_p90']:<7.2f} "
            f"{v['bytes_written'] / 1e6:>5.2f}/{v['bytes_read'] / 1e6:<6.2f} "
            f"{phase.get('pull', 0.0):>6.2f}ms {phase.get('push', 0.0):>6.2f}ms "
            f"{phase.get('aggregate', 0.0):>6.2f}ms {phase.get('train', 0.0):>6.2f}ms "
            f"{churn_txt:>6} {' '.join(flags)}")
    if stragglers:
        printer(f"stragglers (< 0.5x median {median_rate:.2f} r/s): "
                + ", ".join(stragglers))
    if serving:
        printer(f"{'node':<14} {'deploys':>7} {'tok/s':>8} {'stale(mean/max)':>16} "
                f"{'swap p50/p99 ms':>16} flags")
        for node_id, v in serving.items():
            s = v["serve"]
            flags = ["SERVE"]
            if not s.get("deployed"):
                flags.append("WAITING")
            if s.get("skipped_incompatible"):
                flags.append(f"skipped={s['skipped_incompatible']}")
            printer(
                f"{node_id:<14} {s.get('swaps', 0):>7} "
                f"{s.get('tokens_per_sec', 0.0):>8.1f} "
                f"{s.get('staleness_mean', 0.0):>8.2f}/{s.get('staleness_max', 0.0):<7.2f} "
                f"{s.get('swap_ms_p50', 0.0):>7.1f}/{s.get('swap_ms_p99', 0.0):<8.1f} "
                f"{' '.join(flags)}")
    return rollups


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="repro.obs", description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="command", required=True)

    p_watch = sub.add_parser("watch", help="read-only fleet metrics dashboard")
    p_watch.add_argument("--store", required=True,
                         help="data-plane folder URI (cache+/shard<G>+ grammar)")
    p_watch.add_argument("--interval", type=float, default=2.0)
    p_watch.add_argument("--timeout", type=float, default=600.0)
    p_watch.add_argument("--once", action="store_true",
                         help="print one snapshot and exit")

    p_trace = sub.add_parser(
        "trace", help="export merged spans as Chrome trace-event JSON")
    p_trace.add_argument("--store", required=True)
    p_trace.add_argument("--out", default="trace.json",
                         help="output path ('-' for stdout)")

    args = ap.parse_args(argv)

    if args.command == "watch":
        deadline = time.monotonic() + args.timeout
        while True:
            rollups = render_dashboard(collect_obs(args.store))
            if args.once:
                return 0 if rollups["nodes"] else 1
            if time.monotonic() >= deadline:
                return 0
            time.sleep(args.interval)

    if args.command == "trace":
        obs = collect_obs(args.store)
        doc = chrome_trace(obs)
        spans = sum(1 for e in doc["traceEvents"] if e["ph"] == "X")
        if args.out == "-":
            json.dump(doc, sys.stdout)
            print()
        else:
            with open(args.out, "w") as f:
                json.dump(doc, f)
            print(f"wrote {args.out}: {spans} spans from {len(obs)} nodes "
                  f"(open at https://ui.perfetto.dev)")
        return 0 if spans else 1

    raise AssertionError(f"unhandled command {args.command!r}")


if __name__ == "__main__":
    raise SystemExit(main())
