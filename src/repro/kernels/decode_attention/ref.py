"""Oracle for decode_attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k, v, valid) -> jnp.ndarray:
    """q:(B,KV,G,hd) k/v:(B,C,KV,hd) valid:(C,) → (B,KV,G,hd)."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bkgh,bckh->bkgc", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgc,bckh->bkgh", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
