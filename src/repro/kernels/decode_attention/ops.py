"""Public decode-attention op with platform dispatch.

Called from repro.models.attention.attn_decode(use_kernel=True) with the
(B,1,KV,G,hd)-shaped q of a single decode step.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import on_tpu

from .kernel import decode_attention as decode_kernel
from .ref import decode_attention_ref


def decode_attention(qg, k, v, valid, *, softcap: float = 0.0, force_kernel: bool = False):
    """qg: (B,1,KV,G,hd) (model layout) → (B,1,KV,G,hd)."""
    q = qg[:, 0]
    if softcap == 0.0 and (on_tpu() or force_kernel):
        out = decode_kernel(q, k, v, valid, interpret=not on_tpu())
    else:
        out = decode_attention_ref(q, k, v, valid)
    return out[:, None]
