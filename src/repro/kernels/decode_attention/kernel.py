"""decode_attention — flash-decode: one query token vs a long ring cache.

Serving hot spot for decode_32k / long_500k: a single token's GQA attention
over a KV cache of up to 512k slots. The (B·KV) axis is the major grid dim;
the cache is tiled along its ring axis (minor, sequential) with online-softmax
scratch — identical math to flash_attention but with a (G, hd) query tile and
a slot-validity mask instead of causal masking (ring slots may be empty or
out-of-window; the mask comes precomputed from slot_pos).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, acc_ref, m_ref, l_ref):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)          # (G, hd)
    k = k_ref[0].astype(jnp.float32)          # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    valid = valid_ref[...][:, 0] != 0          # (bk,)

    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                  # (G, bk)
    scores = jnp.where(valid[None, :], scores, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, scores.max(axis=-1, keepdims=True))
    p = jnp.exp(scores - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ci == nc - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(
    q: jnp.ndarray,       # (B, KV, G, hd)
    k: jnp.ndarray,       # (B, C, KV, hd)
    v: jnp.ndarray,
    valid: jnp.ndarray,   # (C,) bool — precomputed ring-slot validity
    *,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    B, KV, G, hd = q.shape
    C = k.shape[1]
    block_k = min(block_k, C)
    assert C % block_k == 0, (C, block_k)
    qf = q.reshape(B * KV, G, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, C, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, C, hd)
    validf = valid.astype(jnp.int32)[:, None]

    out = pl.pallas_call(
        _decode_kernel,
        grid=(B * KV, C // block_k),
        in_specs=[
            pl.BlockSpec((1, G, hd), lambda b, c: (b, 0, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((block_k, 1), lambda b, c: (c, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda b, c: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf, validf)
    return out.reshape(B, KV, G, hd)
