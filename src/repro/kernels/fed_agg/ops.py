"""Public fed_agg / fed_opt ops: flat-vector aggregation entry points.

``aggregate_flat`` is what the vectorized strategies call with
``use_kernel=True``: one generalized weighted-sum launch over the (K, N)
stacked client flats. ``fed_opt_flat`` is the fused adaptive-strategy chain
(FedAdam / FedYogi / FedAdagrad state update in one pass). On CPU the jnp
references are used unless ``force_kernel`` (tests) — interpret-mode Pallas
over 10^8 elements would be pointlessly slow.

``aggregate_pytrees`` (the PR-2 entry point — re-flattens every tree on every
call) is kept for the per-leaf reference path and the benchmark baseline; hot
code should pull stacked flats from the store instead.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.tree import LeafSpec, PyTree
from repro.kernels import on_tpu

from .kernel import fed_agg, fed_opt
from .ref import fed_agg_ref, fed_opt_ref


def aggregate_flat(stacked, weights, *, force_kernel: bool = False):
    """(K, N) stacked flats × (K,) coefficients → (N,) Σ_k w_k·x_k."""
    if on_tpu():
        return fed_agg(stacked, weights, interpret=False)
    if force_kernel:
        return fed_agg(stacked, weights, interpret=True)
    return fed_agg_ref(stacked, weights)


def fed_opt_flat(stacked, weights, x, m, v, *, variant: str, server_lr: float,
                 beta1: float, beta2: float, tau: float,
                 force_kernel: bool = False) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Fused pseudo-gradient + moment + server-step chain over stacked flats;
    returns numpy (x', m', v')."""
    kwargs = dict(lr=float(server_lr), b1=float(beta1), b2=float(beta2),
                  tau=float(tau), variant=variant)
    if on_tpu():
        out = fed_opt(stacked, weights, x, m, v, interpret=False, **kwargs)
    elif force_kernel:
        out = fed_opt(stacked, weights, x, m, v, interpret=True, **kwargs)
    else:
        out = fed_opt_ref(stacked, weights, x, m, v, **kwargs)
    return tuple(np.asarray(a) for a in out)


def aggregate_pytrees(trees: Sequence[PyTree], weights: Sequence[float], *,
                      force_kernel: bool = False) -> PyTree:
    """Example-count-weighted mean of K parameter pytrees (FedAvg eq. 1).

    PR-2 compatibility path: flattens every tree per call. The flat hot path
    (store-pulled ``FlatUpdate``s + ``Strategy`` stack cache) avoids exactly
    this repeated concat-copy."""
    total = float(sum(weights))
    norm = np.asarray([float(w) / total for w in weights], np.float32)
    spec = LeafSpec.of(trees[0])
    stacked = np.stack([spec.flatten(tree) for tree in trees])
    out = aggregate_flat(stacked, norm, force_kernel=force_kernel)
    return spec.unflatten(np.asarray(out))
