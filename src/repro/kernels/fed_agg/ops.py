"""Public fed_agg ops: pytree-level weighted aggregation.

``aggregate_pytrees`` is what ``FedAvg(use_kernel=True)`` calls: flatten every
client's params to one f32 vector, stack, run the kernel, unflatten. On CPU
the jnp reference is used unless ``force_kernel`` (tests) — interpret-mode
Pallas over 10^8 elements would be pointlessly slow.
"""
from __future__ import annotations

from typing import Sequence

import jax
import numpy as np

from repro.core.tree import PyTree, tree_flatten_to_vector
from repro.kernels import on_tpu

from .kernel import fed_agg
from .ref import fed_agg_ref


def aggregate_flat(stacked, weights, *, force_kernel: bool = False):
    if on_tpu():
        return fed_agg(stacked, weights, interpret=False)
    if force_kernel:
        return fed_agg(stacked, weights, interpret=True)
    return fed_agg_ref(stacked, weights)


def aggregate_pytrees(trees: Sequence[PyTree], weights: Sequence[float], *,
                      force_kernel: bool = False) -> PyTree:
    """Example-count-weighted mean of K parameter pytrees (FedAvg eq. 1)."""
    total = float(sum(weights))
    norm = np.asarray([float(w) / total for w in weights], np.float32)
    flats, unflatten = [], None
    for tree in trees:
        flat, unflatten = tree_flatten_to_vector(tree)
        flats.append(flat)
    stacked = np.stack(flats)
    out = aggregate_flat(stacked, norm, force_kernel=force_kernel)
    return unflatten(np.asarray(out))
