"""Pure-jnp oracle for fed_agg."""
from __future__ import annotations

import jax.numpy as jnp


def fed_agg_ref(stacked: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """(K,N) × (K,) → (N,): Σ_k w_k · x_k in f32."""
    return jnp.einsum("k,kn->n", weights.astype(jnp.float32), stacked.astype(jnp.float32))
