"""Pure-jnp oracles for fed_agg / fed_opt."""
from __future__ import annotations

import jax.numpy as jnp


def fed_agg_ref(stacked: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """(K,N) × (K,) → (N,): Σ_k w_k · x_k in f32."""
    return jnp.einsum("k,kn->n", weights.astype(jnp.float32), stacked.astype(jnp.float32))


def fed_opt_ref(stacked, weights, x, m, v, *, lr, b1, b2, tau, variant="adam"):
    """Unfused reference of the adaptive-aggregation chain (Reddi et al. 2021):
    weighted mean → pseudo-gradient → moment updates → server step."""
    avg = fed_agg_ref(stacked, weights)
    d = x.astype(jnp.float32) - avg
    m = b1 * m.astype(jnp.float32) + (1.0 - b1) * d
    d2 = d * d
    v = v.astype(jnp.float32)
    if variant == "adam":
        v = b2 * v + (1.0 - b2) * d2
    elif variant == "yogi":
        v = v - (1.0 - b2) * d2 * jnp.sign(v - d2)
    elif variant == "adagrad":
        v = v + d2
    else:
        raise ValueError(f"unknown fed_opt variant {variant!r}")
    return x - lr * m / (jnp.sqrt(v) + tau), m, v
