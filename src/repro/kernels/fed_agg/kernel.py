"""fed_agg — fused weighted aggregation over stacked client parameters.

The paper's hot loop: FedAvg's Σ_k (n_k/n)·w_k over K client parameter
vectors (eq. 1). On a serving/training silo this runs over the *entire*
flattened model (up to 10^11 elements) each federation round, so it is a
pure memory-bandwidth kernel: tile the flat parameter axis into VMEM-sized
columns and compute each output tile as a (1,K)×(K,BN) matmul — one pass over
HBM, no intermediate (K,N) temporaries like the naive jnp formulation.

Layout: stacked (K, N) f32, weights (K,) f32 (pre-normalized), out (N,) f32.
Block: (K, BN) with BN = 64·128 lanes → K·BN·4 B ≤ 2 MiB VMEM for K ≤ 64.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BN = 8192  # flat-axis tile (64 × 128 lanes)


def _fed_agg_kernel(w_ref, x_ref, o_ref):
    # x: (K, BN) f32 block; w: (K, 1) f32 (full); o: (1, BN)
    x = x_ref[...]
    w = w_ref[...]
    # (1, K) @ (K, BN) — lands on the MXU; f32 accumulation
    o_ref[...] = jax.lax.dot_general(
        w.T, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def fed_agg(stacked: jnp.ndarray, weights: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    """stacked: (K, N) f32; weights: (K,) f32 → (N,) f32 = weightsᵀ·stacked."""
    K, N = stacked.shape
    pad = (-N) % BN
    if pad:
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
    Np = N + pad
    out = pl.pallas_call(
        _fed_agg_kernel,
        grid=(Np // BN,),
        in_specs=[
            pl.BlockSpec((K, 1), lambda i: (0, 0)),       # weights, every tile
            pl.BlockSpec((K, BN), lambda i: (0, i)),      # one column stripe
        ],
        out_specs=pl.BlockSpec((1, BN), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, Np), jnp.float32),
        interpret=interpret,
    )(weights.astype(jnp.float32)[:, None], stacked.astype(jnp.float32))
    return out[0, :N]
