"""fed_agg / fed_opt — fused aggregation kernels over stacked client flats.

The paper's hot loop: FedAvg's Σ_k (n_k/n)·w_k over K client parameter
vectors (eq. 1). On a serving/training silo this runs over the *entire*
flattened model (up to 10^11 elements) each federation round, so it is a
pure memory-bandwidth problem: tile the flat parameter axis into VMEM-sized
columns and compute each output tile as a (1,K)×(K,BN) matmul — one pass over
HBM, no intermediate (K,N) temporaries like the naive jnp formulation.

``fed_agg`` accepts arbitrary per-client coefficients (not just normalized
example weights), which is what lets FedAvg / FedBuff / PartialFedAvg /
FedAsync's factorized lerp chain all share one kernel. For fleets wider than
``BK`` clients the (K, N) stack is streamed in (BK, BN) tiles with on-chip
accumulation — the kernel never needs K full rows resident at once, so
10^8-param × hundreds-of-clients aggregations stay within VMEM.

``fed_opt`` fuses the adaptive-strategy chain (Reddi et al. 2021):
avg → pseudo-gradient Δ = x − avg → moment updates (adam/yogi/adagrad) →
server step, in a single pass over each (K, BN) stripe — five elementwise
passes and one matvec collapse into one HBM read per operand.

Layout: stacked (K, N) f32, weights (K,) f32, state vectors (N,) f32.
Block: (K, BN) with BN = 64·128 lanes → K·BN·4 B ≤ 2 MiB VMEM for K ≤ 64.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BN = 8192  # flat-axis tile (64 × 128 lanes)
BK = 64    # client-axis tile: wider fleets stream K in BK-row stripes


def _wsum(w, x):
    # (1, K) @ (K, BN) — lands on the MXU; f32 accumulation
    return jax.lax.dot_general(
        w.T, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def _fed_agg_kernel(w_ref, x_ref, o_ref):
    # x: (K, BN) f32 block; w: (K, 1) f32 (full); o: (1, BN)
    o_ref[...] = _wsum(w_ref[...], x_ref[...])


def _fed_agg_acc_kernel(w_ref, x_ref, o_ref):
    # K-tiled: same output tile revisited across the k grid axis; init at
    # k == 0, then accumulate each (BK, BN) stripe's partial weighted sum.
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += _wsum(w_ref[...], x_ref[...])


@functools.partial(jax.jit, static_argnames=("interpret",))
def fed_agg(stacked: jnp.ndarray, weights: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    """stacked: (K, N) f32; weights: (K,) f32 → (N,) f32 = weightsᵀ·stacked."""
    K, N = stacked.shape
    stacked = stacked.astype(jnp.float32)
    weights = weights.astype(jnp.float32)
    pad = (-N) % BN
    if pad:
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
    Np = N + pad
    if K <= BK:
        out = pl.pallas_call(
            _fed_agg_kernel,
            grid=(Np // BN,),
            in_specs=[
                pl.BlockSpec((K, 1), lambda i: (0, 0)),       # weights, every tile
                pl.BlockSpec((K, BN), lambda i: (0, i)),      # one column stripe
            ],
            out_specs=pl.BlockSpec((1, BN), lambda i: (0, i)),
            out_shape=jax.ShapeDtypeStruct((1, Np), jnp.float32),
            interpret=interpret,
        )(weights[:, None], stacked)
        return out[0, :N]
    # Stream the client axis: zero-padded rows contribute nothing (their
    # weight is zero), and the k grid axis is innermost so each output tile
    # finishes its accumulation before the next column stripe starts.
    padk = (-K) % BK
    if padk:
        stacked = jnp.pad(stacked, ((0, padk), (0, 0)))
        weights = jnp.pad(weights, (0, padk))
    Kp = K + padk
    out = pl.pallas_call(
        _fed_agg_acc_kernel,
        grid=(Np // BN, Kp // BK),
        in_specs=[
            pl.BlockSpec((BK, 1), lambda i, k: (k, 0)),
            pl.BlockSpec((BK, BN), lambda i, k: (k, i)),
        ],
        out_specs=pl.BlockSpec((1, BN), lambda i, k: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, Np), jnp.float32),
        interpret=interpret,
    )(weights[:, None], stacked)
    return out[0, :N]


def _opt_step(avg, p, m, v, *, lr, b1, b2, tau, variant):
    """Δ → moments → server step on one (1, BN) tile; shared by the fused and
    the two-pass (wide-fleet) fed_opt variants."""
    d = p - avg                                  # pseudo-gradient Δ
    m = b1 * m + (1.0 - b1) * d
    d2 = d * d
    if variant == "adam":
        v = b2 * v + (1.0 - b2) * d2
    elif variant == "yogi":
        v = v - (1.0 - b2) * d2 * jnp.sign(v - d2)
    elif variant == "adagrad":
        v = v + d2
    else:
        raise ValueError(f"unknown fed_opt variant {variant!r}")
    return p - lr * m / (jnp.sqrt(v) + tau), m, v


def _fed_opt_kernel(w_ref, x_ref, p_ref, m_ref, v_ref, po_ref, mo_ref, vo_ref,
                    *, lr, b1, b2, tau, variant):
    """One (K, BN) stripe of the fused adaptive-aggregation chain."""
    avg = _wsum(w_ref[...], x_ref[...])         # (1, BN) weighted mean
    po_ref[...], mo_ref[...], vo_ref[...] = _opt_step(
        avg, p_ref[...], m_ref[...], v_ref[...],
        lr=lr, b1=b1, b2=b2, tau=tau, variant=variant)


def _fed_opt_apply_kernel(a_ref, p_ref, m_ref, v_ref, po_ref, mo_ref, vo_ref,
                          *, lr, b1, b2, tau, variant):
    """Elementwise pass over a precomputed weighted mean — the second stage of
    the wide-fleet (K > BK) path, where the mean comes from the K-streaming
    fed_agg so no more than a (BK, BN) stripe is ever resident."""
    po_ref[...], mo_ref[...], vo_ref[...] = _opt_step(
        a_ref[...], p_ref[...], m_ref[...], v_ref[...],
        lr=lr, b1=b1, b2=b2, tau=tau, variant=variant)


@functools.partial(jax.jit,
                   static_argnames=("lr", "b1", "b2", "tau", "variant", "interpret"))
def fed_opt(stacked: jnp.ndarray, weights: jnp.ndarray, x: jnp.ndarray,
            m: jnp.ndarray, v: jnp.ndarray, *, lr: float, b1: float, b2: float,
            tau: float, variant: str = "adam",
            interpret: bool = True) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused FedAdam/FedYogi/FedAdagrad step (Reddi et al. 2021):

        avg = weightsᵀ·stacked;  Δ = x − avg
        m' = b1·m + (1−b1)·Δ;    v' = variant(v, Δ²)
        x' = x − lr·m' / (√v' + tau)

    Returns (x', m', v'), all (N,) f32. ``lr``/``b1``/``b2``/``tau`` are
    compile-time constants (hyperparameters). Fleets wider than ``BK`` run
    the two-pass route — K-streaming ``fed_agg`` for the mean, then one
    fused elementwise pass — so no more than a (BK, BN) stripe is ever
    resident in VMEM."""
    K, N = stacked.shape
    pad = (-N) % BN
    row = lambda a: a.astype(jnp.float32)[None, :]
    hp = dict(lr=float(lr), b1=float(b1), b2=float(b2), tau=float(tau),
              variant=variant)
    vec = lambda: pl.BlockSpec((1, BN), lambda i: (0, i))
    x, m, v = row(x), row(m), row(v)
    if pad:
        x, m, v = (jnp.pad(a, ((0, 0), (0, pad))) for a in (x, m, v))
    Np = N + pad
    if K > BK:
        avg = fed_agg(stacked, weights, interpret=interpret)
        avg = avg[None, :]
        if pad:
            avg = jnp.pad(avg, ((0, 0), (0, pad)))
        xo, mo, vo = pl.pallas_call(
            functools.partial(_fed_opt_apply_kernel, **hp),
            grid=(Np // BN,),
            in_specs=[vec(), vec(), vec(), vec()],
            out_specs=[vec(), vec(), vec()],
            out_shape=[jax.ShapeDtypeStruct((1, Np), jnp.float32)] * 3,
            interpret=interpret,
        )(avg, x, m, v)
        return xo[0, :N], mo[0, :N], vo[0, :N]
    stacked = stacked.astype(jnp.float32)
    if pad:
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
    xo, mo, vo = pl.pallas_call(
        functools.partial(_fed_opt_kernel, **hp),
        grid=(Np // BN,),
        in_specs=[
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((K, BN), lambda i: (0, i)),
            vec(), vec(), vec(),
        ],
        out_specs=[vec(), vec(), vec()],
        out_shape=[jax.ShapeDtypeStruct((1, Np), jnp.float32)] * 3,
        interpret=interpret,
    )(weights.astype(jnp.float32)[:, None], stacked, x, m, v)
    return xo[0, :N], mo[0, :N], vo[0, :N]
