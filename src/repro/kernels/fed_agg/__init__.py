from . import ops, ref
from .kernel import fed_agg
