from . import ops, ref
from .kernel import flash_attention
