"""flash_attention — causal (optionally sliding-window) fused attention.

TPU adaptation of FlashAttention: grid (batch·kv_heads, q_blocks, k_blocks)
with the k axis innermost (sequential on TPU), online-softmax statistics
(m, l) and the output accumulator kept in VMEM scratch across k steps.
Q/K/V tiles are MXU-aligned (block_q × head_dim, block_k × head_dim); the
(S, S) score matrix never exists — each step materializes one
(G·block_q, block_k) tile in VMEM.

GQA layout: q (B, KV, G, S, hd) — the G query heads of one KV group are
folded into the q tile so a single K/V load serves all of them.

Sliding window and causality are handled by masking (functional everywhere,
incl. interpret mode); fully-masked tiles are cheap but not skipped — block
pruning is an XLA-level scheduling concern noted in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  block_q: int, block_k: int, seq_len: int, window: int, softscale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0]              # (G*block_q, hd)
    k = k_ref[0]                 # (block_k, hd)
    v = v_ref[0]
    scores = jax.lax.dot_general(
        q.astype(jnp.float32), k.astype(jnp.float32),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    ) * softscale                # (G*block_q, block_k)

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0) % block_q
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    mask = kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    scores = jnp.where(mask, scores, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, scores.max(axis=-1, keepdims=True))
    p = jnp.exp(scores - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v.astype(jnp.float32), (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jnp.ndarray,   # (B, S, KV, G, hd)
    k: jnp.ndarray,   # (B, S, KV, hd)
    v: jnp.ndarray,
    *,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    B, S, KV, G, hd = q.shape
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    scale = 1.0 / math.sqrt(hd)
    # fold (B, KV) into the grid's major axis; interleave G at block level so
    # one K/V tile serves all G query heads of its KV group
    qf = (
        q.transpose(0, 2, 1, 3, 4)                   # (B, KV, S, G, hd)
        .reshape(B * KV, S // block_q, block_q, G, hd)
        .transpose(0, 1, 3, 2, 4)                     # (BKV, nq, G, bq, hd)
        .reshape(B * KV, S // block_q, G * block_q, hd)
    )
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, seq_len=S,
        window=window, softscale=scale,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B * KV, S // block_q, S // block_k),
        in_specs=[
            pl.BlockSpec((1, 1, G * block_q, hd), lambda b, i, j: (b, i, 0, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G * block_q, hd), lambda b, i, j: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, S // block_q, G * block_q, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G * block_q, hd), jnp.float32),
            pltpu.VMEM((G * block_q, 1), jnp.float32),
            pltpu.VMEM((G * block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = (
        out.reshape(B * KV, S // block_q, G, block_q, hd)
        .transpose(0, 2, 1, 3, 4)
        .reshape(B, KV, G, S, hd)
        .transpose(0, 3, 1, 2, 4)
    )
    return out
