"""Public flash attention op with platform dispatch."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import on_tpu

from .kernel import flash_attention as flash_kernel
from .ref import flash_attention_ref


def flash_attention(q, k, v, *, window: int = 0, force_kernel: bool = False) -> jnp.ndarray:
    """(B,S,KV,G,hd)×(B,S,KV,hd)² → (B,S,KV,G,hd) causal attention."""
    S = q.shape[1]
    if on_tpu():
        return flash_kernel(q, k, v, window=window, interpret=False)
    if force_kernel:
        bq = bk = min(128, S)
        return flash_kernel(q, k, v, window=window, block_q=bq, block_k=bk, interpret=True)
    return flash_attention_ref(q, k, v, window=window)
