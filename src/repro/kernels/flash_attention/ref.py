"""Pure-jnp oracle for flash_attention: masked softmax attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, window: int = 0) -> jnp.ndarray:
    """q: (B,S,KV,G,hd); k/v: (B,S,KV,hd) → (B,S,KV,G,hd), causal."""
    B, S, KV, G, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
