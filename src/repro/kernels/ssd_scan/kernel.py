"""ssd_scan — Mamba2 SSD chunked scan as a Pallas TPU kernel.

The SSD dual form splits the sequence into chunks: each chunk does three small
MXU matmuls (C·Bᵀ∘L, scores·X, C·stateᵀ) entirely in VMEM, and a (P,N) f32
running state carried across chunks in scratch — the inter-chunk linear
recurrence. Grid: (batch·heads, n_chunks) with the chunk axis minor
(sequential on TPU), so the state scratch persists exactly along the
recurrence direction.

Per-(B,H) layouts: x (S,P) dt-premultiplied, dA (S,) = dt·A, B/C (S,N).
VMEM per step @ c=256, P=64, N=128: x 64 KiB, B/C 64 KiB each, L (c,c)
256 KiB f32, state 32 KiB — comfortably < 1 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, da_ref, b_ref, c_ref, y_ref, state_ref, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)       # (c, P)
    da = da_ref[0].astype(jnp.float32)     # (c, 1)
    bm = b_ref[0].astype(jnp.float32)      # (c, N)
    cm = c_ref[0].astype(jnp.float32)      # (c, N)

    cums = jnp.cumsum(da, axis=0)          # (c, 1)
    # intra-chunk decay matrix L[i,j] = exp(cums_i - cums_j) for j <= i
    diff = cums - cums.T                   # (c, c)
    tri = jax.lax.broadcasted_iota(jnp.int32, diff.shape, 1) <= jax.lax.broadcasted_iota(
        jnp.int32, diff.shape, 0
    )
    L = jnp.where(tri, jnp.exp(diff), 0.0)

    scores = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * L                                   # (c, c)
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # inter-chunk contribution: C_l · state_prevᵀ · exp(cums_l)
    prev = state_ref[...]                  # (P, N)
    y += jax.lax.dot_general(cm, prev, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32) * jnp.exp(cums)
    y_ref[0] = y.astype(y_ref.dtype)

    # state update: state · exp(cums_last) + (x ∘ decay)ᵀ · B
    last = cums[chunk - 1]                 # (1,)
    decay = jnp.exp(last[None, :] - cums)  # (c, 1)
    state_ref[...] = prev * jnp.exp(last)[None, :] + jax.lax.dot_general(
        x * decay, bm, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jnp.ndarray,    # (BH, S, P) — dt-premultiplied inputs
    dA: jnp.ndarray,   # (BH, S)
    Bm: jnp.ndarray,   # (BH, S, N)
    Cm: jnp.ndarray,   # (BH, S, N)
    *,
    chunk: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    BH, S, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dA[..., None], Bm, Cm)
