"""Oracles for ssd_scan: the model's chunked dual form and the O(S)
sequential recurrence (both in repro.models.ssm)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.ssm import ssd_chunked, ssd_sequential


def ssd_scan_ref(x, dA, Bm, Cm, *, chunk: int = 256) -> jnp.ndarray:
    """(BH,S,P)-layout wrapper over ssd_chunked (adds a singleton head dim)."""
    y, _ = ssd_chunked(
        x[:, :, None, :], dA[:, :, None], Bm[:, :, None, :], Cm[:, :, None, :], chunk
    )
    return y[:, :, 0, :]


def ssd_scan_sequential(x, dA, Bm, Cm) -> jnp.ndarray:
    y, _ = ssd_sequential(
        x[:, :, None, :], dA[:, :, None], Bm[:, :, None, :], Cm[:, :, None, :]
    )
    return y[:, :, 0, :]
