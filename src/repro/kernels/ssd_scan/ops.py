"""Public ssd op in model layout (B,S,H,P) with platform dispatch."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import on_tpu
from repro.models.ssm import ssd_chunked

from .kernel import ssd_scan


def ssd(x, dA, Bm, Cm, *, chunk: int = 256, force_kernel: bool = False):
    """x:(B,S,H,P) dA:(B,S,H) Bm/Cm:(B,S,H,N) → y:(B,S,H,P)."""
    if on_tpu() or force_kernel:
        B, S, H, P = x.shape
        N = Bm.shape[-1]
        fold = lambda a: a.transpose(0, 2, 1, 3).reshape(B * H, S, a.shape[-1])
        y = ssd_scan(
            fold(x), dA.transpose(0, 2, 1).reshape(B * H, S), fold(Bm), fold(Cm),
            chunk=chunk, interpret=not on_tpu(),
        )
        return y.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    y, _ = ssd_chunked(x, dA, Bm, Cm, chunk)
    return y
