from . import ops, ref
from .kernel import ssd_scan
