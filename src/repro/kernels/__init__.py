"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel subpackage ships:
  kernel.py — ``pl.pallas_call`` body with explicit BlockSpec VMEM tiling
  ops.py    — jit'd public wrapper (padding, dtype plumbing, platform switch)
  ref.py    — pure-jnp oracle used by the allclose test sweeps

Kernels run natively on TPU; on CPU (this container) they execute under
``interpret=True`` which evaluates the kernel body block-by-block — bitwise
semantics, no MXU. ``ops`` defaults to the jnp reference on CPU for speed and
to the kernel on TPU; tests force ``interpret=True`` to validate the bodies.
"""


def on_tpu() -> bool:
    import jax

    return jax.default_backend() == "tpu"
