"""Local training loop with callback hooks (the Keras-fit analogue).

The federated layer (repro.core.FederatedCallback) plugs into
``on_epoch_end`` exactly as the paper plugs its FlwrFederatedCallback into
Keras. The loop itself is an ordinary jit'd JAX step; for distributed silos
the same Trainer accepts a Mesh + shardings (see repro.launch.train).
"""
from __future__ import annotations

import random as _random
import time
from dataclasses import dataclass
from typing import Any, Callable, Iterable, Iterator, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tree import PyTree, tree_to_numpy
from repro.optim import Optimizer, apply_updates

LossFn = Callable[[PyTree, Any, jax.Array], tuple[jnp.ndarray, dict]]


@dataclass
class TrainState:
    params: PyTree
    opt_state: PyTree
    step: int = 0


def make_train_step(loss_fn: LossFn, optimizer: Optimizer):
    """(state, batch, rng) -> (state, metrics). Pure, jit-able."""

    def train_step(params, opt_state, batch, rng):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch, rng)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = dict(metrics)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


class Trainer:
    def __init__(
        self,
        *,
        loss_fn: LossFn,
        optimizer: Optimizer,
        init_params: PyTree,
        eval_fn: Callable[[PyTree, Any], dict] | None = None,
        seed: int = 0,
        jit: bool = True,
        slowdown: float = 0.0,
        name: str = "trainer",
        telemetry=None,
    ):
        """``slowdown``: artificial seconds of sleep per step — used by the
        straggler experiments to make one node slower, as the paper does with
        heterogeneous hardware.

        ``telemetry``: an optional ``repro.core.telemetry.Telemetry`` — each
        ``run_epoch`` then records a ``train`` span and feeds step throughput
        into the node's ``obs/`` snapshots. Usually the same instance the
        federated node carries."""
        self.optimizer = optimizer
        self.telemetry = telemetry
        self.eval_fn = eval_fn
        self.params = init_params
        self.opt_state = optimizer.init(init_params)
        self.step = 0
        self.name = name
        self.slowdown = slowdown
        self.rng = jax.random.PRNGKey(seed)
        self.rng_py = _random.Random(seed)
        self._train_step = make_train_step(loss_fn, optimizer)
        if jit:
            self._train_step = jax.jit(self._train_step)
        self.log: list[dict] = []
        self.crashed = False

    # -- params plumbing for federation --------------------------------------
    def host_params(self) -> PyTree:
        return tree_to_numpy(self.params)

    def set_params(self, params: PyTree) -> None:
        # Preserve leaf dtypes of the live params (store may hold f32 numpy).
        # Host-numpy leaves stay numpy: jnp.asarray would canonicalize
        # int64/float64 to 32-bit under the default jax config, silently
        # corrupting non-federated personal leaves that must round-trip
        # bit-exact (PartialFedAvg's exact-dtype passthrough).
        def _cast(old, new):
            if isinstance(old, (np.ndarray, np.generic)):
                return np.asarray(new, dtype=np.asarray(old).dtype)
            return jnp.asarray(new, dtype=old.dtype)

        self.params = jax.tree.map(_cast, self.params, params)

    # -- core loop ------------------------------------------------------------
    def run_epoch(self, batches: Iterable, steps: int | None = None) -> dict:
        # Metric values stay on device for the whole epoch: a per-step
        # float(v) would block on each step's result and serialize JAX's
        # async dispatch. One device_get at the end pays one sync.
        tel = self.telemetry
        t_epoch = tel.clock() if tel is not None and tel.enabled else None
        step_metrics: list[dict] = []
        count = 0
        for i, batch in enumerate(batches):
            if steps is not None and i >= steps:
                break
            self.rng, step_rng = jax.random.split(self.rng)
            self.params, self.opt_state, metrics = self._train_step(
                self.params, self.opt_state, batch, step_rng
            )
            if self.slowdown:
                time.sleep(self.slowdown)
            self.step += 1
            count += 1
            step_metrics.append(metrics)
        metrics_acc: dict[str, float] = {}
        for metrics in jax.device_get(step_metrics):
            for k, v in metrics.items():
                metrics_acc[k] = metrics_acc.get(k, 0.0) + float(v)
        if t_epoch is not None:
            # one span per epoch, recorded after the epoch's single device
            # sync — no extra mid-epoch host round-trips
            dur = tel.clock() - t_epoch
            tel.recorder.record("train", t_epoch, dur)
            tel.note_train(count, dur)
        return {k: v / max(1, count) for k, v in metrics_acc.items()}

    def fit(
        self,
        data_fn: Callable[[int], Iterable] | Iterable,
        *,
        epochs: int,
        steps_per_epoch: int | None = None,
        callbacks: Sequence = (),
        crash_at_epoch: int | None = None,
        verbose: bool = False,
    ) -> list[dict]:
        """Run local training with end-of-epoch callback hooks.

        ``data_fn`` is either a callable epoch→iterable (fresh shuffling per
        epoch) or a single reusable iterable. ``crash_at_epoch`` injects a
        failure for the robustness experiments.
        """
        for cb in callbacks:
            cb.on_train_begin(self)
        try:
            for epoch in range(epochs):
                if crash_at_epoch is not None and epoch >= crash_at_epoch:
                    self.crashed = True
                    raise RuntimeError(f"{self.name}: injected crash at epoch {epoch}")
                for cb in callbacks:
                    cb.on_epoch_begin(self, epoch)
                batches = data_fn(epoch) if callable(data_fn) else data_fn
                logs = self.run_epoch(batches, steps_per_epoch)
                if self.eval_fn is not None:
                    logs.update(self.eval_fn(self.params, None))
                logs["epoch"] = epoch
                self.log.append(logs)
                if verbose:
                    print(f"[{self.name}] epoch {epoch}: " + ", ".join(f"{k}={v:.4f}" for k, v in logs.items() if isinstance(v, float)))
                for cb in callbacks:
                    cb.on_epoch_end(self, epoch, logs)
        finally:
            # Teardown even on an injected crash: a FederatedCallback must
            # get the chance to stop its node's prefetcher thread.
            for cb in callbacks:
                cb.on_train_end(self)
        return self.log
