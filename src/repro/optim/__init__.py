"""Optimizers, schedules and gradient transforms — optax-like, self-contained.

API: an ``Optimizer`` is a pair of pure functions
    init(params)            -> state pytree
    update(grads, state, params) -> (updates, new_state)
apply with ``apply_updates(params, updates)`` (updates are *added*).

Implemented: sgd (+momentum/nesterov), adam, adamw, adafactor-lite (factored
second moment — used for the biggest assigned models so the dry-run optimizer
state is memory-realistic), global-norm clipping, gradient accumulation, and
warmup-cosine / constant / linear schedules.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]  # step -> lr


# --------------------------------------------------------------------------
# Schedules
# --------------------------------------------------------------------------


def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_schedule(lr: float, total_steps: int, end_fraction: float = 0.0) -> Schedule:
    def fn(step):
        frac = jnp.clip(step / max(1, total_steps), 0.0, 1.0)
        return jnp.asarray(lr * (1.0 - (1.0 - end_fraction) * frac), jnp.float32)

    return fn


def warmup_cosine_schedule(lr: float, warmup_steps: int, total_steps: int, min_ratio: float = 0.1) -> Schedule:
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = step / jnp.maximum(1.0, warmup_steps)
        progress = (step - warmup_steps) / jnp.maximum(1.0, total_steps - warmup_steps)
        progress = jnp.clip(progress, 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * progress))
        return jnp.asarray(lr * jnp.where(step < warmup_steps, warm, cos), jnp.float32)

    return fn


def _resolve_schedule(lr) -> Schedule:
    return lr if callable(lr) else constant_schedule(float(lr))


# --------------------------------------------------------------------------
# Optimizer core
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]]
    name: str = "optimizer"


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


class ScaleState(NamedTuple):
    step: jnp.ndarray


def sgd(lr, momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    sched = _resolve_schedule(lr)

    def init(params):
        mom = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params) if momentum else None
        return {"step": jnp.zeros((), jnp.int32), "momentum": mom}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(step)
        if momentum:
            buf = jax.tree.map(lambda b, g: momentum * b + g.astype(jnp.float32), state["momentum"], grads)
            if nesterov:
                upd = jax.tree.map(lambda b, g: -(lr_t * (momentum * b + g)), buf, grads)
            else:
                upd = jax.tree.map(lambda b: -(lr_t * b), buf)
            return upd, {"step": step, "momentum": buf}
        upd = jax.tree.map(lambda g: -(lr_t * g.astype(jnp.float32)), grads)
        return upd, {"step": step, "momentum": None}

    return Optimizer(init, update, "sgd")


def _adam_core(lr, b1, b2, eps, weight_decay, name) -> Optimizer:
    sched = _resolve_schedule(lr)

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(step)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def _upd(m_, v_, p):
            u = -(lr_t * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps))
            if weight_decay:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u

        upd = jax.tree.map(_upd, m, v, params)
        return upd, {"step": step, "m": m, "v": v}

    return Optimizer(init, update, name)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    return _adam_core(lr, b1, b2, eps, 0.0, "adam")


def adamw(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8, weight_decay: float = 0.01) -> Optimizer:
    return _adam_core(lr, b1, b2, eps, weight_decay, "adamw")


def adafactor(lr, eps: float = 1e-30, decay: float = 0.8) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern 2018, simplified).

    Matrices keep row/col second-moment vectors instead of a full moment
    tensor → optimizer state is O(n+m) not O(nm). Used for the 314B-param
    dry-run so per-chip optimizer memory is realistic.
    """
    sched = _resolve_schedule(lr)

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def per_leaf(p):
            if _factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros_like(p, jnp.float32)}

        return {"step": jnp.zeros((), jnp.int32), "moments": jax.tree.map(per_leaf, params, is_leaf=lambda x: hasattr(x, "ndim"))}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(step)
        beta = 1.0 - (step.astype(jnp.float32)) ** (-decay)

        def per_leaf(g, mom):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if "vr" in mom:
                vr = beta * mom["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * mom["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = jnp.sqrt(vr[..., :, None] * vc[..., None, :] / jnp.maximum(vr.mean(axis=-1, keepdims=True)[..., None], eps))
                upd = -(lr_t * g / jnp.maximum(denom, 1e-12))
                return upd, {"vr": vr, "vc": vc}
            v = beta * mom["v"] + (1 - beta) * g2
            return -(lr_t * g / jnp.sqrt(v)), {"v": v}

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state["moments"])
        outs = [per_leaf(g, m) for g, m in zip(flat_g, flat_m)]
        upd = jax.tree.unflatten(treedef, [o[0] for o in outs])
        moms = jax.tree.unflatten(treedef, [o[1] for o in outs])
        return upd, {"step": step, "moments": moms}

    return Optimizer(init, update, "adafactor")


# --------------------------------------------------------------------------
# Gradient transforms
# --------------------------------------------------------------------------


def global_norm(tree: PyTree) -> jnp.ndarray:
    sq = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(sq)))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads)


def chain_clip(optimizer: Optimizer, max_norm: float) -> Optimizer:
    def update(grads, state, params):
        return optimizer.update(clip_by_global_norm(grads, max_norm), state, params)

    return Optimizer(optimizer.init, update, f"{optimizer.name}+clip{max_norm}")


def with_accumulation(optimizer: Optimizer, accumulate_steps: int) -> Optimizer:
    """Gradient accumulation: buffers grads; applies the inner optimizer every
    ``accumulate_steps`` micro-steps (paper §4.4 uses accumulation of 10)."""
    if accumulate_steps <= 1:
        return optimizer

    def init(params):
        return {
            "inner": optimizer.init(params),
            "acc": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32) / accumulate_steps, state["acc"], grads)
        count = state["count"] + 1

        def do_apply(_):
            upd, inner = optimizer.update(acc, state["inner"], params)
            zeroed = jax.tree.map(jnp.zeros_like, acc)
            return upd, {"inner": inner, "acc": zeroed, "count": jnp.zeros((), jnp.int32)}

        def do_skip(_):
            upd = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
            return upd, {"inner": state["inner"], "acc": acc, "count": count}

        return jax.lax.cond(count >= accumulate_steps, do_apply, do_skip, operand=None)

    return Optimizer(init, update, f"{optimizer.name}+acc{accumulate_steps}")


OPTIMIZERS = {
    "sgd": sgd,
    "adam": adam,
    "adamw": adamw,
    "adafactor": adafactor,
}


def get_optimizer(name: str, lr, **kwargs) -> Optimizer:
    if name not in OPTIMIZERS:
        raise KeyError(f"unknown optimizer {name!r}; options {sorted(OPTIMIZERS)}")
    return OPTIMIZERS[name](lr, **kwargs)
