"""The public facade: one place where the store/transport/serving grammar
is documented, validated, and dispatched.

Everything below exists as lower-level constructors too (``WeightStore``,
``ShardedWeightStore``, ``make_folder``, ``ServingNode``) and those keep
working — but new code should come through here, because this is the one
spot where the three mini-languages meet:

**Folder-URI stages** (``connect(uri)``), outermost-first, ``+``-chained::

    [shard<G>[x<L>]+][retry+|cache+ ...]<base>

    ============  =====================================================
    stage         meaning
    ============  =====================================================
    shard<G>+     partition the fleet into G node-group folders with
                  ring gossip of group summaries (O(group) scans)
    shard<G>x<L>+ same, gossiping through an L-level summary tree
                  (planetary scale; must be the OUTERMOST stage)
    retry+        capped exponential-backoff retries on transient I/O
    cache+        read-through blob cache in front of the base folder
    <base>        ``memory://`` (anonymous, fresh per call) |
                  ``memory://<name>`` (process-global shared registry) |
                  ``s3://bucket/prefix`` | a local path
    ============  =====================================================

**Transport pipeline specs** (``connect(..., transport=...)``), innermost
policy stage plus optional envelope, ``|``-chained::

    "delta(chain=4)|zstd"      delta chains, zstd envelope
    "topk(adaptive)"           adaptive sparse top-k
    "family(adapters=topk)"    per-leaf-family sub-policies
    full | quantized | delta | delta_q | topk      (legacy names, mapped)

**Leaf-family selectors** (``connect(..., families=...)``): a registered
family name (``"adapters"``, ``"embeddings"``, ``"norms"``, or anything
``register_family``-ed), a sequence of names, or a ``{name: sub-policy}``
mapping — sugar for the ``family(...)`` transport stage above.

``connect`` returns the right store kind for the URI (sharded URIs →
``ShardedWeightStore``); ``serve`` turns any store + arch into a running
:class:`~repro.serving.ServingNode`.
"""
from __future__ import annotations

from typing import Any

from repro.core.gossip import ShardedFolders, ShardedWeightStore
from repro.core.store import WeightStore, make_folder
from repro.core.telemetry import Telemetry
from repro.core.transport import (
    family_transport_spec,
    normalize_transport,
    parse_folder_uri,
)

__all__ = ["connect", "serve"]


def connect(
    uri: str,
    *,
    transport: str | None = None,
    families: Any = None,
    prefetch: "bool | float | tuple[float, str] | None" = None,
    telemetry: "Telemetry | bool | None" = None,
    quantized: bool = False,
    keep_history: bool = False,
    compress: str = "none",
    **store_kwargs: Any,
):
    """Open a weight store behind any folder URI the grammar accepts.

    Parameters
    ----------
    uri:
        Folder URI — see the stage table in the module docstring. The full
        grammar is validated here; a malformed URI raises ``ValueError``
        before any folder is created.
    transport:
        Pipeline spec string or legacy name (``full``/``quantized``/
        ``delta``/``delta_q``/``topk``). Normalized to the canonical spec,
        so legacy names and their spec spellings are interchangeable.
    families:
        Leaf-family selector — sugar for ``transport="family(...)"``.
        Mutually exclusive with ``transport``.
    prefetch:
        Background cache warming: ``True`` (default interval), a float
        interval in seconds, or ``(interval, node_id)`` — the tuple form is
        required for sharded stores, whose prefetch is scoped to one node's
        home group.
    telemetry:
        A :class:`Telemetry` to attach, or ``True`` to create and attach one
        (reachable afterwards as ``store.telemetry``).
    quantized, keep_history, compress, **store_kwargs:
        Forwarded to the store constructor (``rebase_every``,
        ``topk_fraction``, ``decode_cache_entries``, ...).

    Returns the store: ``ShardedWeightStore`` for ``shard...+`` URIs,
    ``WeightStore`` otherwise.
    """
    parse_folder_uri(uri)  # validate the whole URI up front (clear errors)
    if families is not None:
        if transport is not None:
            raise ValueError("pass either transport= or families=, not both")
        transport = family_transport_spec(families)
    elif transport is not None:
        # normalize eagerly so a bad spec fails here, not at first push;
        # legacy names (full/quantized/...) map to their canonical specs
        transport = normalize_transport(transport)
    elif quantized:
        # legacy quantized=True flag → canonical spec, so it works uniformly
        # for sharded stores too (whose ctor has no quantized kwarg)
        transport = normalize_transport(None, quantized=True)

    folder = make_folder(uri)
    if isinstance(folder, ShardedFolders):
        store = ShardedWeightStore(
            folder,
            transport=transport,
            keep_history=keep_history,
            compress=compress,
            **store_kwargs,
        )
    else:
        store = WeightStore(
            folder,
            transport=transport,
            keep_history=keep_history,
            compress=compress,
            **store_kwargs,
        )

    if telemetry:
        tel = telemetry if isinstance(telemetry, Telemetry) else Telemetry(enabled=True)
        store.attach_telemetry(tel)
        store.telemetry = tel

    if prefetch:
        if isinstance(prefetch, tuple):
            interval, node_id = prefetch
            store.start_prefetch(float(interval), exclude=node_id)
        elif isinstance(folder, ShardedFolders):
            raise ValueError(
                "sharded stores scope prefetch to one node's home group: "
                "pass prefetch=(interval, node_id)"
            )
        else:
            interval = 0.1 if prefetch is True else float(prefetch)
            store.start_prefetch(interval)
    return store


def serve(
    store,
    arch,
    *,
    node_id: str | None = None,
    reduced: bool = False,
    poll_interval: float = 0.25,
    telemetry: "Telemetry | bool | None" = None,
    mesh=None,
    start: bool = True,
    wait: float | None = None,
    **node_kwargs: Any,
):
    """Join a store read-only as a serving node.

    ``store`` is a store instance or a ``connect()``-able URI; ``arch`` an
    arch name from ``repro.configs`` or a full ``ModelConfig``. With
    ``start=True`` (default) the watcher thread is already running on
    return; ``wait`` additionally blocks up to that many seconds for the
    first weight set to go live. Returns the :class:`ServingNode`.
    """
    from repro.serving import ServingNode

    if isinstance(store, str):
        store = connect(store)
    node = ServingNode(
        store,
        arch,
        node_id=node_id,
        reduced=reduced,
        poll_interval=poll_interval,
        telemetry=telemetry,
        mesh=mesh,
        **node_kwargs,
    )
    if start:
        node.start()
        if wait is not None:
            node.wait_until_deployed(wait)
    return node
