"""Federated training launcher.

Each federated *silo* is one process owning a device mesh; silos share only a
weight-store folder (DiskFolder on a shared mount in production, InMemoryFolder
under --simulate threading). This is the paper's serverless workflow scaled to
pjit-distributed nodes:

    # two real silos on two machines, shared NFS/gcsfuse mount:
    python -m repro.launch.train --arch pythia-14m --node-id silo0 --num-nodes 2 \
        --store /mnt/shared/exp1 --mode async --strategy fedavg
    python -m repro.launch.train ... --node-id silo1 ...

    # single-process simulation of N silos (paper's setup):
    python -m repro.launch.train --arch pythia-14m --simulate 3 --mode async
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.core import (
    AsyncFederatedNode,
    FederatedCallback,
    InMemoryFolder,
    SyncFederatedNode,
    get_strategy,
    make_folder,
    run_threaded,
)
from repro.core.partition import partition_sequence_dataset
from repro.data import lm_batch_iterator, make_synthetic_wikitext
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.optim import adamw, with_accumulation
from repro.training import Trainer
from repro.configs import get_config


def make_lm_trainer(cfg, tokens, *, seq_len, batch_size, seed, lr, accum=1, slowdown=0.0, name="node"):
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    opt = with_accumulation(adamw(lr), accum)

    def loss_fn(p, batch, rng):
        return model.loss(p, batch)

    trainer = Trainer(loss_fn=loss_fn, optimizer=opt, init_params=params, seed=seed,
                      slowdown=slowdown, name=name)

    def data_fn(epoch):
        return lm_batch_iterator(tokens, batch_size=batch_size, seq_len=seq_len,
                                 seed=seed, epoch=epoch)

    return trainer, data_fn


def evaluate_lm(cfg, params, tokens, *, seq_len, batch_size=8, max_batches=8):
    model = build_model(cfg)
    accs, losses = [], []
    for i, batch in enumerate(
        lm_batch_iterator(tokens, batch_size=batch_size, seq_len=seq_len, seed=999)
    ):
        if i >= max_batches:
            break
        loss, metrics = model.loss(params, batch)
        losses.append(float(loss))
        accs.append(float(metrics["accuracy"]))
    return {"eval_loss": float(np.mean(losses)), "eval_accuracy": float(np.mean(accs))}


def run_client(cfg, node_id, folder, args, tokens_shard, eval_tokens):
    strategy = get_strategy(args.strategy)
    if args.mode == "sync":
        node = SyncFederatedNode(strategy=strategy, shared_folder=folder, node_id=node_id,
                                 num_nodes=args.num_nodes, timeout=args.timeout)
    else:
        node = AsyncFederatedNode(strategy=strategy, shared_folder=folder, node_id=node_id)
    trainer, data_fn = make_lm_trainer(
        cfg, tokens_shard, seq_len=args.seq_len, batch_size=args.batch_size,
        seed=args.seed + hash(node_id) % 1000, lr=args.lr, accum=args.accum,
        name=node_id,
    )
    steps = args.steps_per_epoch
    num_examples = steps * args.batch_size
    cb = FederatedCallback(node, num_examples_per_epoch=num_examples)
    trainer.fit(data_fn, epochs=args.epochs, steps_per_epoch=steps, callbacks=[cb],
                verbose=args.verbose)
    metrics = evaluate_lm(cfg, trainer.params, eval_tokens, seq_len=args.seq_len)
    return {"node": node_id, "pushes": node.num_pushes, "aggregations": node.num_aggregations,
            **metrics}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="pythia-14m")
    ap.add_argument("--mode", default="async", choices=["async", "sync"])
    ap.add_argument("--strategy", default="fedavg")
    ap.add_argument("--store", default="memory://")
    ap.add_argument("--node-id", default=None, help="run as ONE real silo (production)")
    ap.add_argument("--num-nodes", type=int, default=2)
    ap.add_argument("--simulate", type=int, default=0, help="simulate N silos via threads")
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--steps-per-epoch", type=int, default=50)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--lr", type=float, default=2e-5)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--timeout", type=float, default=300.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reduced", action="store_true", help="use the reduced config")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = cfg.replace(vocab_size=min(cfg.vocab_size, args.vocab))

    data = make_synthetic_wikitext(vocab_size=cfg.vocab_size, seed=args.seed)
    num_nodes = args.simulate or args.num_nodes
    shards = partition_sequence_dataset(data.train_tokens, num_nodes)

    if args.simulate:
        folder = InMemoryFolder() if args.store == "memory://" else make_folder(args.store)
        args.num_nodes = num_nodes
        fns = [
            (lambda i=i: run_client(cfg, f"node{i}", folder, args, shards[i], data.test_tokens))
            for i in range(num_nodes)
        ]
        results = run_threaded(fns, names=[f"node{i}" for i in range(num_nodes)])
        for r in results:
            if r.error:
                print(f"[{r.node_id}] FAILED: {r.error}")
            else:
                print(json.dumps(r.result))
        return 0

    if args.node_id is None:
        ap.error("need --node-id (production) or --simulate N")
    idx = int(args.node_id[-1]) if args.node_id[-1].isdigit() else 0
    folder = make_folder(args.store)
    result = run_client(cfg, args.node_id, folder, args, shards[idx % num_nodes], data.test_tokens)
    print(json.dumps(result))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
