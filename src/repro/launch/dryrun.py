"""Multi-pod dry run: prove every (arch × input-shape × mesh) combination
lowers, SPMD-partitions and compiles on the production mesh, and extract the
roofline inputs (FLOPs / HBM bytes / collective bytes / per-device memory).

The XLA_FLAGS line above MUST precede every other import — jax locks the
device count at first init. Do not set it globally: smoke tests and benches
run on 1 device.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --multi-pod
    ... --out results.jsonl        # append JSON records
"""
# The forced device count MUST be set before any other import — jax locks the
# device count at first init. (This is why these two lines lead the module.)
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core.tree import path_str
from repro.launch import costs as C
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import (
    batch_shardings,
    cache_shardings,
    opt_state_shardings,
    param_shardings,
    with_shardings,
)
from repro.launch.specs import (
    SHAPES,
    cache_specs,
    decode_window_override,
    input_specs,
    params_specs,
)
from repro.launch.steps import default_optimizer, make_prefill_step, make_serve_step, make_train_step
from repro.models import build_model

FSDP_PARAM_THRESHOLD = 3_000_000_000  # params; above this, weights also shard on data axes


def count_params(shapes) -> int:
    return int(sum(np.prod(l.shape, dtype=np.float64) for l in jax.tree.leaves(shapes)))


def active_param_fraction(cfg, params_shapes) -> float:
    """MoE active fraction for MODEL_FLOPS = 6·N_active·D."""
    if not cfg.num_experts:
        return 1.0
    leaves = jax.tree_util.tree_flatten_with_path(params_shapes)[0]
    total = moe = 0.0
    for path, leaf in leaves:
        n = float(np.prod(leaf.shape, dtype=np.float64))
        total += n
        p = path_str(path)
        if "/moe/" in p and ("/wi/" in p or "/wg/" in p or "/wo/" in p):
            moe += n
    active = total - moe * (1.0 - cfg.experts_per_token / cfg.num_experts)
    return active / total


def layer_trips(cfg) -> int:
    return max(1, cfg.n_layers // len(cfg.pattern))


def build_lowerable(cfg, shape, mesh, *, fsdp: bool, remat: bool = True, microbatches: int = 8):
    """Returns (fn, arg_structs tuple, donate_argnums, n_tokens)."""
    model = build_model(cfg)
    p_shapes = params_specs(cfg)
    p_shard = param_shardings(p_shapes, mesh, fsdp=fsdp)
    p_structs = with_shardings(p_shapes, p_shard)
    data = input_specs(cfg, shape)
    d_structs = with_shardings(data, batch_shardings(data, mesh))

    if shape.kind == "train":
        optimizer = default_optimizer(cfg, count_params(p_shapes))
        opt_shapes = jax.eval_shape(optimizer.init, p_shapes)
        o_structs = with_shardings(opt_shapes, opt_state_shardings(opt_shapes, mesh, fsdp=fsdp))
        fn = make_train_step(cfg, optimizer, remat=remat, microbatches=microbatches)
        n_tokens = data["tokens"].shape[0] * data["tokens"].shape[1]
        return fn, (p_structs, o_structs, d_structs), (0, 1), n_tokens

    if shape.kind == "prefill":
        fn = make_prefill_step(cfg)
        n_tokens = data["tokens"].shape[0] * data["tokens"].shape[1]
        return fn, (p_structs, d_structs), (), n_tokens

    # decode
    wo = decode_window_override(cfg, shape)
    fn = make_serve_step(cfg, window_override=wo)
    c_shapes = cache_specs(cfg, shape, p_shapes)
    c_structs = with_shardings(c_shapes, cache_shardings(c_shapes, mesh))
    n_tokens = shape.global_batch  # one new token per sequence
    return fn, (p_structs, d_structs["token"], c_structs, d_structs["pos"]), (2,), n_tokens


def _parse_override(kv: str):
    key, _, val = kv.partition("=")
    for cast in (int, float):
        try:
            return key, cast(val)
        except ValueError:
            continue
    if val in ("true", "false"):
        return key, val == "true"
    return key, val


def run_one(arch: str, shape_name: str, *, multi_pod: bool, fsdp: str = "auto",
            remat: bool = True, microbatches: int = 8, overrides: dict | None = None,
            tag: str = "", verbose: bool = True) -> dict:
    t0 = time.time()
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))

    p_shapes = params_specs(cfg)
    n_params = count_params(p_shapes)
    use_fsdp = (n_params > FSDP_PARAM_THRESHOLD) if fsdp == "auto" else (fsdp == "on")

    fn, arg_structs, donate, n_tokens = build_lowerable(
        cfg, shape, mesh, fsdp=use_fsdp, remat=remat, microbatches=microbatches
    )

    with mesh:
        lowered = jax.jit(fn, donate_argnums=donate).lower(*arg_structs)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}

    # trip-aware global costs from the jaxpr
    jc = C.jaxpr_costs(fn, *arg_structs)
    trips = layer_trips(cfg)
    coll = C.collective_bytes(compiled.as_text(), loop_trip_count=trips)
    terms = C.roofline_terms(
        total_flops=jc.flops, total_bytes=jc.bytes, coll_bytes=coll["total"], chips=chips
    )
    act_frac = active_param_fraction(cfg, p_shapes)
    mf = (C.model_flops_train if shape.kind == "train" else C.model_flops_infer)(
        n_params, n_tokens, act_frac
    )

    record = {
        "arch": arch,
        "tag": tag,
        "overrides": overrides or {},
        "microbatches": microbatches,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "kind": shape.kind,
        "fsdp": use_fsdp,
        "n_params": n_params,
        "active_fraction": round(act_frac, 4),
        "n_tokens": n_tokens,
        "flops_global": jc.flops,
        "bytes_global": jc.bytes,
        "collective_bytes": coll["total"],
        "collectives": {k: v for k, v in coll.items() if k != "total" and v},
        "model_flops": mf,
        "useful_flop_ratio": mf / jc.flops if jc.flops else 0.0,
        "compute_s": terms["compute_s"],
        "memory_s": terms["memory_s"],
        "collective_s": terms["collective_s"],
        "bottleneck": terms["bottleneck"].replace("_s", ""),
        "xla_flops_per_device": ca.get("flops", 0.0),
        "xla_bytes_per_device": ca.get("bytes accessed", 0.0),
        "memory_analysis": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
        "compile_seconds": round(time.time() - t0, 1),
        "ok": True,
    }
    if verbose:
        print(f"== {arch} × {shape_name} × {record['mesh']} (fsdp={use_fsdp}) ==")
        print(f"  memory_analysis: {mem}")
        print(f"  cost_analysis: flops/device={ca.get('flops', 0):.3e} "
              f"bytes/device={ca.get('bytes accessed', 0):.3e}")
        print(f"  roofline: compute={terms['compute_s']:.4f}s memory={terms['memory_s']:.4f}s "
              f"collective={terms['collective_s']:.4f}s -> {record['bottleneck']}-bound")
        print(f"  model/HLO flop ratio: {record['useful_flop_ratio']:.3f} "
              f"(compile {record['compile_seconds']}s)")
    return record


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", choices=[*SHAPES, "all"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--fsdp", default="auto", choices=["auto", "on", "off"])
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (repeatable), e.g. --set moe_dispatch=gather")
    ap.add_argument("--tag", default="", help="label for §Perf iteration records")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    args = ap.parse_args(argv)
    overrides = dict(_parse_override(kv) for kv in args.set)

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                try:
                    rec = run_one(arch, shape_name, multi_pod=mp, fsdp=args.fsdp,
                                  remat=not args.no_remat, microbatches=args.microbatches,
                                  overrides=overrides, tag=args.tag)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": "2x16x16" if mp else "16x16",
                           "ok": False, "error": f"{type(e).__name__}: {e}"}
                    failures += 1
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
