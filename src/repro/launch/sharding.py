"""Parameter / batch / cache sharding rules with a divisibility resolver.

Rules are *path-based* and aligned to the **trailing** dims of each leaf, so
they apply uniformly to plain params, layer-stacked params (leading group
axis), optimizer moments (m/… and v/… mirror param paths), and ring caches.

JAX requires jit input shardings to divide dims evenly; ``resolve`` drops any
mesh axis that does not divide its dim (documented fallback: replicate).
Vocab is padded to a multiple of 256 at model level, so embeddings always
shard on "model" (16 | padded_vocab).
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.tree import path_str
from .mesh import data_axes

# column-parallel matmuls: shard OUTPUT (last) dim on "model";
# FSDP additionally shards the input (second-to-last) dim on the data axes.
_COL = re.compile(
    r"(wq|wk|wv|wi|wg|wq_a|wq_b|wkv_a|wk_rope|wk_b|wv_b|w_gate|w_rec|w_z|w_x|w_B|w_C|w_dt|w_a)/w$"
)
# row-parallel matmuls: shard INPUT (second-to-last) dim on "model".
_ROW = re.compile(r"(wo|out_proj|w_out)/w$")
_EMBED = re.compile(r"(embed|unembed)/table$")
_ROUTER = re.compile(r"router/w$")

# cache leaves (trailing-dims layout)
_CACHE_KV = {"k": 4, "v": 4}          # (..., B, C, KV, hd)
_CACHE_LATENT = {"c_kv": 3, "k_rope": 3}  # (..., B, C, R)
_CACHE_STATE = {"conv": 3, "ssm": 4, "h": 2}  # (..., B, rest...)


def _pad_spec(ndim: int, trailing: list) -> P:
    return P(*([None] * (ndim - len(trailing)) + trailing))


def param_spec(path: str, ndim: int, *, fsdp: bool, dp) -> P:
    """Trailing-dim aligned PartitionSpec for a parameter leaf."""
    if ndim < 2:
        return P()
    if _EMBED.search(path):
        return _pad_spec(ndim, ["model", dp if fsdp else None])
    if _ROUTER.search(path):
        return P()
    if _COL.search(path):
        return _pad_spec(ndim, [dp if fsdp else None, "model"])
    if _ROW.search(path):
        return _pad_spec(ndim, ["model", dp if fsdp else None])
    return P()


def cache_spec(path: str, ndim: int, *, dp) -> P:
    """KV caches: batch on data axes, ring/seq dim on "model" (sequence-
    parallel cache → per-chip cache memory /16; XLA inserts the partial-
    softmax collectives). States: batch on data axes only."""
    leaf = path.rsplit("/", 1)[-1]
    if leaf in _CACHE_KV:
        return _pad_spec(ndim, [dp, "model", None, None])
    if leaf in _CACHE_LATENT:
        return _pad_spec(ndim, [dp, "model", None])
    if leaf in ("cross_k", "cross_v"):
        return _pad_spec(ndim, [dp, None, None, None])
    if leaf in _CACHE_STATE:
        n_rest = {"conv": 2, "ssm": 3, "h": 1}[leaf]
        return _pad_spec(ndim, [dp] + [None] * n_rest)
    return P()  # slot_pos etc.


def batch_spec(ndim: int, *, dp) -> P:
    return _pad_spec(ndim, [dp] + [None] * (ndim - 1))


def resolve(spec: P, shape: tuple, mesh: Mesh) -> NamedSharding:
    """Drop axes that don't divide their dim; returns a NamedSharding."""
    out = []
    for dim, axis in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axis is None:
            out.append(None)
            continue
        axes = axis if isinstance(axis, tuple) else (axis,)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        if dim % size == 0:
            out.append(axis)
        else:
            # fallback 1: a single data axis; fallback 2: replicate
            if isinstance(axis, tuple) and len(axis) > 1 and dim % mesh.shape[axis[-1]] == 0:
                out.append(axis[-1])
            else:
                out.append(None)
    return NamedSharding(mesh, P(*out))


def _dp(mesh: Mesh):
    axes = data_axes(mesh)
    return axes if len(axes) > 1 else axes[0]


def tree_shardings(tree: Any, mesh: Mesh, spec_fn) -> Any:
    """Map (path, leaf) → resolved NamedSharding over a pytree of
    ShapeDtypeStructs or arrays."""

    def _one(path, leaf):
        spec = spec_fn(path_str(path), len(leaf.shape))
        return resolve(spec, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(_one, tree)


def param_shardings(params: Any, mesh: Mesh, *, fsdp: bool = False) -> Any:
    dp = _dp(mesh)
    return tree_shardings(params, mesh, lambda p, nd: param_spec(p, nd, fsdp=fsdp, dp=dp))


def opt_state_shardings(opt_state: Any, mesh: Mesh, *, fsdp: bool = False) -> Any:
    # optimizer moment paths embed the param path ("m/blocks/.../wq/w"),
    # so the same rules apply; scalars and factored moments replicate.
    dp = _dp(mesh)
    return tree_shardings(opt_state, mesh, lambda p, nd: param_spec(p, nd, fsdp=fsdp, dp=dp))


def cache_shardings(cache: Any, mesh: Mesh) -> Any:
    dp = _dp(mesh)
    return tree_shardings(cache, mesh, lambda p, nd: cache_spec(p, nd, dp=dp))


def batch_shardings(batch: Any, mesh: Mesh) -> Any:
    dp = _dp(mesh)
    return tree_shardings(batch, mesh, lambda p, nd: batch_spec(nd, dp=dp))


def with_shardings(tree: Any, shardings: Any) -> Any:
    """Attach shardings to ShapeDtypeStructs (for .lower())."""
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh), tree, shardings
    )
