"""Assigned input shapes + ShapeDtypeStruct builders for every step kind.

Shapes (assigned):
    train_4k     seq=4096    global_batch=256   (training     → train_step)
    prefill_32k  seq=32768   global_batch=32    (inference    → prefill_step)
    decode_32k   seq=32768   global_batch=128   (decode       → serve_step)
    long_500k    seq=524288  global_batch=1     (long decode  → serve_step)

Carve-outs (DESIGN.md §4):
  * vlm: 256 stub patch embeddings count against the token budget
    (text = seq − 256); decode shapes are pure text continuation.
  * audio enc-dec: seq budget split 50/50 encoder frames / decoder tokens;
    decode caches a fixed 4096-frame encoder memory.
  * long_500k: SSM/hybrid run natively; all attention archs decode with the
    sliding-window variant (window = cfg.long_context_window) — the
    full-quadratic variant is what gets skipped, not the arch.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models import ModelConfig, build_model


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

AUDIO_DECODE_FRAMES = 4096  # bounded encoder memory for decode shapes


def decode_window_override(cfg: ModelConfig, shape: InputShape) -> int | None:
    """Sliding-window override for long-context decode on attention archs."""
    if shape.name != "long_500k":
        return None
    if cfg.arch_type == "ssm" or cfg.sliding_window:
        return None  # natively sub-quadratic / already windowed
    if cfg.attention == "none":
        return None
    return cfg.long_context_window


def input_specs(cfg: ModelConfig, shape: InputShape, *, batch_override: int | None = None) -> dict:
    """ShapeDtypeStruct stand-ins for the step's data inputs (no params)."""
    B = batch_override or shape.global_batch
    S = shape.seq_len
    i32 = jnp.int32
    f = jnp.dtype(cfg.dtype)

    if shape.kind in ("train", "prefill"):
        if cfg.is_encdec:
            s2 = S // 2
            specs = {
                "frames": jax.ShapeDtypeStruct((B, s2, cfg.d_model), f),
                "tokens": jax.ShapeDtypeStruct((B, s2), i32),
            }
            if shape.kind == "train":
                specs["labels"] = jax.ShapeDtypeStruct((B, s2), i32)
            return specs
        specs = {}
        s_text = S
        if cfg.frontend == "vision":
            s_text = S - cfg.frontend_tokens
            specs["embeds"] = jax.ShapeDtypeStruct((B, cfg.frontend_tokens, cfg.d_model), f)
        specs["tokens"] = jax.ShapeDtypeStruct((B, s_text), i32)
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, s_text), i32)
        return specs

    # decode: one token against a seq_len-deep cache
    return {
        "token": jax.ShapeDtypeStruct((B,), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
    }


def cache_specs(cfg: ModelConfig, shape: InputShape, params_shapes=None, *, batch_override: int | None = None) -> dict:
    """Abstract cache pytree for decode shapes (eval_shape — no allocation)."""
    B = batch_override or shape.global_batch
    model = build_model(cfg)
    wo = decode_window_override(cfg, shape)
    if cfg.is_encdec:
        frames = jax.ShapeDtypeStruct((B, AUDIO_DECODE_FRAMES, cfg.d_model), jnp.dtype(cfg.dtype))
        return jax.eval_shape(
            lambda p, fr: model.init_cache(p, fr, capacity=shape.seq_len, window_override=wo),
            params_shapes, frames,
        )
    return jax.eval_shape(
        lambda: model.init_cache(B, capacity=shape.seq_len, window_override=wo)
    )


def params_specs(cfg: ModelConfig) -> dict:
    model = build_model(cfg)
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))
