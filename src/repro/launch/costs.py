"""Roofline cost accounting.

XLA's ``compiled.cost_analysis()`` visits ``while`` bodies ONCE — a 64-layer
scanned model reports 1 layer of FLOPs (verified empirically). The roofline
therefore uses a **jaxpr walker** that recurses through scan/pjit/remat and
multiplies scan-body costs by trip count: exact, trip-aware, *global* (whole
program, all chips) counts.

  * flops — dot_general / conv_general_dilated (2·M·N·K model); elementwise
    ignored (matmul-dominated workloads).
  * bytes — an HBM-traffic model: operands+results of matmul-class ops, plus
    results of gather/scatter/dynamic-slice/update ops (cache read/write) and
    all scan-carried state. Pre-fusion, so an upper-ish bound; documented in
    EXPERIMENTS.md §Roofline.

Collective bytes come from the partitioned HLO text (``collective_bytes``):
result-shape bytes of all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute ops, with non-ENTRY computations (loop bodies) multiplied
by the layer-scan trip count.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Any

import jax
import numpy as np
from jax.extend import core as jcore


# ---------------------------------------------------------------------------
# jaxpr walker
# ---------------------------------------------------------------------------


@dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    by_prim: dict = field(default_factory=dict)

    def add(self, prim: str, flops: float, bytes_: float, mult: float) -> None:
        self.flops += flops * mult
        self.bytes += bytes_ * mult
        agg = self.by_prim.setdefault(prim, [0.0, 0.0])
        agg[0] += flops * mult
        agg[1] += bytes_ * mult


def _size_bytes(aval) -> float:
    if not hasattr(aval, "shape"):
        return 0.0
    return float(np.prod(aval.shape, dtype=np.float64)) * aval.dtype.itemsize


def _dot_flops(eqn) -> float:
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    dnums = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dnums
    batch = np.prod([lhs.shape[i] for i in lb], dtype=np.float64)
    contract = np.prod([lhs.shape[i] for i in lc], dtype=np.float64)
    lhs_free = np.prod(
        [d for i, d in enumerate(lhs.shape) if i not in lc and i not in lb], dtype=np.float64
    )
    rhs_free = np.prod(
        [d for i, d in enumerate(rhs.shape) if i not in rc and i not in rb], dtype=np.float64
    )
    return 2.0 * batch * contract * lhs_free * rhs_free


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval  # kernel
    # flops = 2 · out_elems · (kernel elems per output channel)
    kernel_per_out = np.prod(rhs.shape, dtype=np.float64) / rhs.shape[-1]
    return 2.0 * np.prod(out.shape, dtype=np.float64) * kernel_per_out


# Ops whose OUTPUTS are genuine HBM writes. broadcast/iota/select are always
# fusion-resident on XLA:TPU and are deliberately NOT counted.
_MEMORY_PRIMS = {
    "gather", "scatter", "scatter-add", "dynamic_slice", "dynamic_update_slice",
    "take", "concatenate",
}


def _sub_jaxprs(eqn):
    """All jaxprs referenced by an eqn's params (generic: covers pjit/jit,
    remat2, closed_call, custom_*_call — any call-like primitive)."""
    out = []
    for v in eqn.params.values():
        if isinstance(v, jcore.ClosedJaxpr):
            out.append(v.jaxpr)
        elif isinstance(v, jcore.Jaxpr):
            out.append(v)
        elif isinstance(v, (tuple, list)):
            for item in v:
                if isinstance(item, jcore.ClosedJaxpr):
                    out.append(item.jaxpr)
                elif isinstance(item, jcore.Jaxpr):
                    out.append(item)
    return out


def _walk(jaxpr, costs: Costs, mult: float) -> None:
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "scan":
            length = eqn.params["length"]
            inner = eqn.params["jaxpr"].jaxpr
            # carried state traffic: read+write once per iteration
            carry_bytes = sum(_size_bytes(v.aval) for v in eqn.outvars)
            costs.add("scan_carry", 0.0, carry_bytes, mult)
            _walk(inner, costs, mult * length)
        elif name == "while":
            # bounded decode loops: treat body once (not used in hot paths)
            _walk(eqn.params["body_jaxpr"].jaxpr, costs, mult)
        elif name == "cond":
            branches = eqn.params["branches"]
            sub = Costs()
            _walk(branches[0].jaxpr, sub, 1.0)
            costs.add("cond", sub.flops, sub.bytes, mult)
        elif name == "dot_general":
            io_bytes = sum(_size_bytes(v.aval) for v in (*eqn.invars, *eqn.outvars))
            costs.add(name, _dot_flops(eqn), io_bytes, mult)
        elif name == "conv_general_dilated":
            io_bytes = sum(_size_bytes(v.aval) for v in (*eqn.invars, *eqn.outvars))
            costs.add(name, _conv_flops(eqn), io_bytes, mult)
        elif name in _MEMORY_PRIMS:
            costs.add(name, 0.0, sum(_size_bytes(v.aval) for v in eqn.outvars), mult)
        elif name == "pallas_call":
            # kernel-aware: HBM traffic = the call's operands/results (tiles
            # stream through VMEM); flops = kernel body × grid size.
            io_bytes = sum(_size_bytes(v.aval) for v in (*eqn.invars, *eqn.outvars))
            grid = 1.0
            gm = eqn.params.get("grid_mapping")
            if gm is not None and getattr(gm, "grid", None):
                grid = float(np.prod([g for g in gm.grid if isinstance(g, int)]))
            sub = Costs()
            inner = eqn.params.get("jaxpr")
            if inner is not None:
                _walk(inner.jaxpr if hasattr(inner, "jaxpr") else inner, sub, 1.0)
            costs.add(name, sub.flops * grid, io_bytes, mult)
        else:
            for sub in _sub_jaxprs(eqn):
                _walk(sub, costs, mult)


def jaxpr_costs(fn, *args, **kwargs) -> Costs:
    closed = jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)
    costs = Costs()
    _walk(closed.jaxpr, costs, 1.0)
    # program inputs/outputs cross HBM once
    io = sum(_size_bytes(v.aval) for v in (*closed.jaxpr.invars, *closed.jaxpr.outvars))
    costs.add("program_io", 0.0, io, 1.0)
    return costs


# ---------------------------------------------------------------------------
# collective bytes from partitioned HLO
# ---------------------------------------------------------------------------

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _result_bytes(line: str, op: str) -> float:
    """Result-shape bytes of an HLO instruction: the shape tokens between
    '=' and the op name (handles tuple-shaped results, e.g. all-to-all)."""
    if "=" not in line:
        return 0.0
    rhs = line.split("=", 1)[1]
    cut = rhs.find(f" {op}(")
    if cut == -1:
        cut = rhs.find(f"{op}(")
    region = rhs[:cut] if cut != -1 else rhs
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(region):
        if dtype not in _DTYPE_BYTES:
            continue
        n = np.prod([int(d) for d in dims.split(",") if d], dtype=np.float64) if dims else 1.0
        total += float(n) * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str, loop_trip_count: float = 1.0) -> dict:
    """Per-collective result bytes; non-ENTRY computations (fusion regions /
    loop bodies) are multiplied by ``loop_trip_count`` (the layer-scan trips).
    """
    out = {c: 0.0 for c in _COLLECTIVES}
    out["total"] = 0.0
    in_entry = False
    for raw in hlo_text.splitlines():
        line = raw.strip()
        if raw.startswith("ENTRY"):
            in_entry = True
            continue
        if raw and not raw[0].isspace() and raw.rstrip().endswith("{"):
            in_entry = False
            continue
        for coll in _COLLECTIVES:
            op = coll if f" {coll}(" in line else (f"{coll}-start" if f" {coll}-start(" in line else None)
            if op:
                mult = 1.0 if in_entry else loop_trip_count
                b = _result_bytes(line, op) * mult
                out[coll] += b
                out["total"] += b
                break
    return out


# ---------------------------------------------------------------------------
# roofline terms (TPU v5e)
# ---------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link


def roofline_terms(
    *, total_flops: float, total_bytes: float, coll_bytes: float, chips: int
) -> dict:
    compute_s = total_flops / (chips * PEAK_FLOPS_BF16)
    memory_s = total_bytes / (chips * HBM_BW)
    collective_s = coll_bytes / (chips * ICI_BW)
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": collective_s}
    terms["bottleneck"] = max(terms, key=lambda k: terms[k] if k.endswith("_s") else -1)
    return terms


def model_flops_train(n_params: int, n_tokens: int, active_fraction: float = 1.0) -> float:
    """6·N·D (fwd+bwd); MoE uses active params."""
    return 6.0 * n_params * active_fraction * n_tokens


def model_flops_infer(n_params: int, n_tokens: int, active_fraction: float = 1.0) -> float:
    return 2.0 * n_params * active_fraction * n_tokens
