"""Batched serving driver: prefill a batch of prompts, then decode greedily.

A federated-learning framework still needs to *serve* what it trains; this
driver runs the same ``prefill_step``/``serve_step`` the dry-run lowers, on
whatever devices exist (CPU here, a mesh in production).

    python -m repro.launch.serve --arch mamba2-130m --reduced --batch 4 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.steps import make_bulk_prefill_step, make_serve_step
from repro.models import build_model
from repro.models.frontends import stub_audio_frames, stub_patch_embeddings


def serve_batch(cfg, params, prompts, *, new_tokens: int, frames=None, embeds=None):
    """prompts: (B, S) int32 → (B, new_tokens) greedy continuations.

    One jitted ``bulk_prefill_step`` fills the decode cache from the whole
    prompt (its argmax IS the first generated token), then ``serve_step``
    extends one token at a time. ``embeds`` (VLM prefix) shifts decode
    positions past the prefix.
    """
    model = build_model(cfg)
    B, S = prompts.shape
    n_prefix = 0 if embeds is None else embeds.shape[1]
    capacity = n_prefix + S + new_tokens
    if cfg.is_encdec:
        cache = model.init_cache(params, frames, capacity=capacity)
    else:
        cache = model.init_cache(B, capacity=capacity)
    prefill = jax.jit(make_bulk_prefill_step(cfg))
    serve_step = jax.jit(make_serve_step(cfg))

    tok, cache = prefill(params, prompts, cache) if embeds is None else prefill(
        params, prompts, cache, embeds
    )
    out = [tok]
    for t in range(1, new_tokens):
        tok, cache = serve_step(params, tok, cache, jnp.int32(n_prefix + S - 1 + t))
        out.append(tok)
    return jnp.stack(out, axis=1)


def serve_batch_loop(cfg, params, prompts, *, new_tokens: int, frames=None):
    """Token-at-a-time reference: the prompt is pushed through ``serve_step``
    one position at a time. Kept as the equivalence oracle for ``serve_batch``
    (tests assert identical continuations) and for archs mid-bringup."""
    model = build_model(cfg)
    B, S = prompts.shape
    capacity = S + new_tokens
    if cfg.is_encdec:
        cache = model.init_cache(params, frames, capacity=capacity)
    else:
        cache = model.init_cache(B, capacity=capacity)
    serve_step = jax.jit(make_serve_step(cfg))

    for t in range(1, S):
        _, cache = serve_step(params, prompts[:, t - 1], cache, jnp.int32(t - 1))
    out = []
    tok = prompts[:, -1]
    for t in range(new_tokens):
        tok, cache = serve_step(params, tok, cache, jnp.int32(S - 1 + t))
        out.append(tok)
    return jnp.stack(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    rng = jax.random.PRNGKey(args.seed)
    params = model.init(rng)
    prompts = jax.random.randint(rng, (args.batch, args.prompt_len), 0, cfg.vocab_size, jnp.int32)
    kwargs = {}
    if cfg.is_encdec:
        kwargs["frames"] = stub_audio_frames(rng, cfg, args.batch, 64)
    t0 = time.time()
    out = serve_batch(cfg, params, prompts, new_tokens=args.new_tokens, **kwargs)
    dt = time.time() - t0
    tps = args.batch * (args.prompt_len + args.new_tokens) / dt
    print(f"arch={cfg.name} batch={args.batch} tokens/s={tps:.1f}")
    print("continuations:", np.asarray(out)[:2].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
