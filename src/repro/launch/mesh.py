"""Production mesh construction (TPU v5e pods).

A FUNCTION (not module-level constant) so importing never touches jax device
state. Single pod: 16×16 = 256 chips ("data", "model"). Multi-pod: 2×16×16 =
512 chips ("pod", "data", "model") — the pod axis is an outer data-parallel
axis whose gradient all-reduce crosses the inter-pod DCN once per step.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    model_axis = min(model_axis, n)
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def data_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """The (pure) data-parallel axes of a mesh: everything except "model"."""
    return tuple(a for a in mesh.axis_names if a != "model")
