"""Step builders: train_step / prefill_step / serve_step per architecture.

These are the functions the dry-run lowers and the launchers jit. All three
are pure (params, state, batch) functions suitable for pjit/GSPMD.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import ModelConfig, build_model
from repro.optim import Optimizer, adafactor, adamw, apply_updates

# Models whose optimizer-moment memory would not fit with full Adam on the
# production mesh use factored moments (Adafactor) — standard practice for
# 100B+ training.
ADAFACTOR_THRESHOLD = 50_000_000_000


def default_optimizer(cfg: ModelConfig, approx_params: int | None = None) -> Optimizer:
    if approx_params is not None and approx_params >= ADAFACTOR_THRESHOLD:
        return adafactor(1e-4)
    if cfg.name.startswith("grok-1"):
        return adafactor(1e-4)
    return adamw(2e-5)


def make_train_step(cfg: ModelConfig, optimizer: Optimizer, *, remat: bool = True,
                    microbatches: int = 1) -> Callable:
    """One optimizer step. ``microbatches`` > 1 scans the global batch in
    micro-slices, accumulating grads in f32 — activation memory drops by the
    microbatch factor at the cost of re-reading weights per micro-step (the
    standard trade for fitting long-sequence training on 16 GB chips).
    """
    model = build_model(cfg)

    def grad_fn(params, batch):
        def loss_fn(p):
            return model.loss(p, batch, remat=remat)

        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:]),
                batch,
            )

            def body(acc, mb):
                (l, m), g = grad_fn(params, mb)
                acc = jax.tree.map(lambda a, gi: a + gi.astype(jnp.float32) / microbatches, acc, g)
                return acc, (l, m)

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, (losses, metricses) = jax.lax.scan(body, zeros, micro)
            loss = losses.mean()
            metrics = jax.tree.map(lambda v: v.mean(axis=0), metricses)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        out_metrics = {"loss": loss, **metrics}
        return params, opt_state, out_metrics

    return train_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    model = build_model(cfg)

    def prefill_step(params, batch):
        if cfg.is_encdec:
            logits, _ = model.apply(params, batch["tokens"], batch["frames"])
        else:
            logits, _ = model.apply(params, batch["tokens"], batch.get("embeds"))
        # next-token ids for the last position (what a serving stack returns)
        next_token = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1).astype(jnp.int32)
        return next_token, logits[:, -1]

    return prefill_step


def make_bulk_prefill_step(cfg: ModelConfig, *, window_override: int | None = None) -> Callable:
    """Cache-filling bulk prefill for serving: one full-sequence pass fills a
    fresh decode cache (``model.prefill``) and returns the greedy next token —
    the fused replacement for feeding a prompt through ``serve_step`` one
    token at a time. (``make_prefill_step`` is the cache-less dry-run probe.)
    """
    model = build_model(cfg)

    def bulk_prefill_step(params, tokens, cache, extra_embeds=None):
        if cfg.is_encdec:
            logits, cache = model.prefill(params, tokens, cache, window_override=window_override)
        else:
            logits, cache = model.prefill(
                params, tokens, cache, extra_embeds, window_override=window_override
            )
        next_token = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1).astype(jnp.int32)
        return next_token, cache

    return bulk_prefill_step


def make_serve_step(cfg: ModelConfig, *, window_override: int | None = None) -> Callable:
    model = build_model(cfg)

    def serve_step(params, token, cache, pos):
        logits, cache = model.decode_step(params, token, cache, pos, window_override=window_override)
        next_token = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1).astype(jnp.int32)
        return next_token, cache

    return serve_step
