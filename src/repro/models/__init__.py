from .common import ModelConfig
from .cnn import MnistCNN, ResNet
from .encdec import EncDecLM
from .transformer import DecoderLM


def build_model(cfg: ModelConfig):
    """Config → model object with init/apply/loss (+decode for LMs)."""
    if cfg.is_encdec:
        return EncDecLM(cfg)
    return DecoderLM(cfg)


__all__ = ["ModelConfig", "DecoderLM", "EncDecLM", "MnistCNN", "ResNet", "build_model"]
