"""Decoder-only LM assembly (all assigned archs except seamless-m4t).

Layers are grouped by the config's repeating ``pattern`` (e.g. RecurrentGemma's
("rglru","rglru","attn")); each pattern position has its params stacked over a
leading group axis and the whole stack is consumed by one ``jax.lax.scan`` —
a 64-layer grok-1 lowers to a single compact scanned HLO body. A remainder
(n_layers % len(pattern)) is applied unstacked as a tail.

Block types:
  attn      — pre-norm attention (GQA or MLA) + pre-norm MLP
  moe_attn  — pre-norm attention + pre-norm MoE (aux loss accumulated)
  ssm       — pre-norm Mamba2 SSD block (no separate MLP)
  rglru     — pre-norm RG-LRU recurrent block + pre-norm MLP

The VLM (internvl2) prepends stub patch embeddings to the token embeddings;
only text positions produce logits/loss.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import ssm as ssm_mod
from .common import (
    ModelConfig,
    embedding_apply,
    embedding_init,
    mlp_apply,
    mlp_init,
    rmsnorm_apply,
    rmsnorm_init,
    softmax_cross_entropy,
    token_accuracy,
    unembed_apply,
)


# ---------------------------------------------------------------------------
# Per-block init / apply / cache
# ---------------------------------------------------------------------------


def _init_block(rng, cfg: ModelConfig, kind: str) -> dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    if kind == "attn":
        attn_init = attn_mod.init_mla if cfg.attention == "mla" else attn_mod.init_attention
        return {
            "norm1": rmsnorm_init(cfg.d_model, cfg.jdtype),
            "attn": attn_init(k1, cfg),
            "norm2": rmsnorm_init(cfg.d_model, cfg.jdtype),
            "mlp": mlp_init(k2, cfg),
        }
    if kind == "moe_attn":
        return {
            "norm1": rmsnorm_init(cfg.d_model, cfg.jdtype),
            "attn": attn_mod.init_attention(k1, cfg),
            "norm2": rmsnorm_init(cfg.d_model, cfg.jdtype),
            "moe": moe_mod.init_moe(k2, cfg),
        }
    if kind == "ssm":
        return {
            "norm1": rmsnorm_init(cfg.d_model, cfg.jdtype),
            "ssm": ssm_mod.init_mamba2(k1, cfg),
        }
    if kind == "rglru":
        return {
            "norm1": rmsnorm_init(cfg.d_model, cfg.jdtype),
            "rglru": rglru_mod.init_rglru(k1, cfg),
            "norm2": rmsnorm_init(cfg.d_model, cfg.jdtype),
            "mlp": mlp_init(k2, cfg),
        }
    raise ValueError(f"unknown block kind {kind!r}")


def _apply_block_full(p, cfg: ModelConfig, kind: str, x, *, window_override=None, use_flash=False):
    """Full-sequence forward. Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "moe_attn"):
        h = rmsnorm_apply(p["norm1"], x, cfg.norm_eps)
        if cfg.attention == "mla" and kind == "attn":
            a = attn_mod.mla_full(p["attn"], cfg, h, window=window_override)
        else:
            a = attn_mod.attn_full(p["attn"], cfg, h, window=window_override, use_flash=use_flash)
        x = x + a
        h = rmsnorm_apply(p["norm2"], x, cfg.norm_eps)
        if kind == "moe_attn":
            out, aux = moe_mod.moe_apply(p["moe"], cfg, h)
        else:
            out = mlp_apply(p["mlp"], h, cfg.activation)
        return x + out, aux
    if kind == "ssm":
        h = rmsnorm_apply(p["norm1"], x, cfg.norm_eps)
        return x + ssm_mod.mamba2_full(p["ssm"], cfg, h), aux
    if kind == "rglru":
        h = rmsnorm_apply(p["norm1"], x, cfg.norm_eps)
        x = x + rglru_mod.rglru_full(p["rglru"], cfg, h)
        h = rmsnorm_apply(p["norm2"], x, cfg.norm_eps)
        return x + mlp_apply(p["mlp"], h, cfg.activation), aux
    raise ValueError(kind)


def _init_block_cache(cfg: ModelConfig, kind: str, batch: int, capacity: int):
    if kind in ("attn", "moe_attn"):
        window = cfg.sliding_window
        cap = min(capacity, window) if window else capacity
        if cfg.attention == "mla" and kind == "attn":
            return attn_mod.init_mla_cache(cfg, batch, cap)
        return attn_mod.init_attn_cache(cfg, batch, cap)
    if kind == "ssm":
        return ssm_mod.init_ssm_cache(cfg, batch)
    if kind == "rglru":
        return rglru_mod.init_rglru_cache(cfg, batch)
    raise ValueError(kind)


def _apply_block_decode(p, cfg: ModelConfig, kind: str, x, cache, pos, *, window_override=None):
    if kind in ("attn", "moe_attn"):
        h = rmsnorm_apply(p["norm1"], x, cfg.norm_eps)
        if cfg.attention == "mla" and kind == "attn":
            a, cache = attn_mod.mla_decode(p["attn"], cfg, h, cache, pos, window=window_override,
                                           absorb=cfg.mla_absorb)
        else:
            a, cache = attn_mod.attn_decode(p["attn"], cfg, h, cache, pos, window=window_override)
        x = x + a
        h = rmsnorm_apply(p["norm2"], x, cfg.norm_eps)
        if kind == "moe_attn":
            out, _ = moe_mod.moe_apply(p["moe"], cfg, h)
        else:
            out = mlp_apply(p["mlp"], h, cfg.activation)
        return x + out, cache
    if kind == "ssm":
        h = rmsnorm_apply(p["norm1"], x, cfg.norm_eps)
        out, cache = ssm_mod.mamba2_decode(p["ssm"], cfg, h, cache)
        return x + out, cache
    if kind == "rglru":
        h = rmsnorm_apply(p["norm1"], x, cfg.norm_eps)
        out, cache = rglru_mod.rglru_decode(p["rglru"], cfg, h, cache)
        x = x + out
        h = rmsnorm_apply(p["norm2"], x, cfg.norm_eps)
        return x + mlp_apply(p["mlp"], h, cfg.activation), cache
    raise ValueError(kind)


def _apply_block_prefill(p, cfg: ModelConfig, kind: str, x, cache, *, window_override=None):
    """Full-sequence forward that also fills the block's decode cache —
    ``_apply_block_decode``'s contract ((x, cache) in/out) at
    ``_apply_block_full``'s cost. ``cache`` must be fresh."""
    if kind in ("attn", "moe_attn"):
        h = rmsnorm_apply(p["norm1"], x, cfg.norm_eps)
        if cfg.attention == "mla" and kind == "attn":
            a, cache = attn_mod.mla_prefill(p["attn"], cfg, h, cache, window=window_override)
        else:
            a, cache = attn_mod.attn_prefill(p["attn"], cfg, h, cache, window=window_override)
        x = x + a
        h = rmsnorm_apply(p["norm2"], x, cfg.norm_eps)
        if kind == "moe_attn":
            out, _ = moe_mod.moe_apply(p["moe"], cfg, h)
        else:
            out = mlp_apply(p["mlp"], h, cfg.activation)
        return x + out, cache
    if kind == "ssm":
        h = rmsnorm_apply(p["norm1"], x, cfg.norm_eps)
        out, cache = ssm_mod.mamba2_prefill(p["ssm"], cfg, h, cache)
        return x + out, cache
    if kind == "rglru":
        h = rmsnorm_apply(p["norm1"], x, cfg.norm_eps)
        out, cache = rglru_mod.rglru_prefill(p["rglru"], cfg, h, cache)
        x = x + out
        h = rmsnorm_apply(p["norm2"], x, cfg.norm_eps)
        return x + mlp_apply(p["mlp"], h, cfg.activation), cache
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


class DecoderLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.pattern = cfg.pattern
        U = len(self.pattern)
        self.n_groups = cfg.n_layers // U
        self.tail = tuple(self.pattern[: cfg.n_layers % U])

    # -- init ----------------------------------------------------------------
    def init(self, rng) -> dict:
        cfg = self.cfg
        k_emb, k_layers, k_tail = jax.random.split(rng, 3)
        params = {"embed": embedding_init(k_emb, cfg.padded_vocab, cfg.d_model, cfg.jdtype)}
        blocks = {}
        for u, kind in enumerate(self.pattern):
            ks = jax.random.split(jax.random.fold_in(k_layers, u), self.n_groups)
            blocks[f"u{u}_{kind}"] = jax.vmap(lambda k, kind=kind: _init_block(k, cfg, kind))(ks)
        params["blocks"] = blocks
        if self.tail:
            params["tail"] = {
                f"t{i}_{kind}": _init_block(jax.random.fold_in(k_tail, i), cfg, kind)
                for i, kind in enumerate(self.tail)
            }
        params["final_norm"] = rmsnorm_init(cfg.d_model, cfg.jdtype)
        if not cfg.tie_embeddings:
            k_un = jax.random.fold_in(k_emb, 7)
            params["unembed"] = embedding_init(k_un, cfg.padded_vocab, cfg.d_model, cfg.jdtype)
        return params

    # -- embedding frontends ---------------------------------------------------
    def _embed(self, params, tokens, extra_embeds=None):
        cfg = self.cfg
        x = embedding_apply(params["embed"], tokens) * jnp.asarray(
            cfg.d_model**0.5, cfg.jdtype
        )
        if extra_embeds is not None:
            # VLM / audio-LM: prepend stub modality embeddings
            x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
        return x

    # -- full forward ----------------------------------------------------------
    def apply(self, params, tokens, extra_embeds=None, *, window_override=None,
              remat: bool = False, use_flash: bool = False):
        """→ (logits (B,S_text,padded_vocab), aux_loss)."""
        cfg = self.cfg
        x = self._embed(params, tokens, extra_embeds)
        n_text = tokens.shape[1]

        def group_body(carry, group_params):
            x, aux = carry
            for u, kind in enumerate(self.pattern):
                x, a = _apply_block_full(
                    group_params[f"u{u}_{kind}"], cfg, kind, x,
                    window_override=window_override, use_flash=use_flash,
                )
                aux = aux + a
            return (x, aux), None

        if remat:
            if cfg.remat_policy == "dots":
                policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                body = jax.checkpoint(group_body, policy=policy)
            else:
                body = jax.checkpoint(group_body)
        else:
            body = group_body
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params["blocks"])
        for i, kind in enumerate(self.tail):
            x, a = _apply_block_full(
                params["tail"][f"t{i}_{kind}"], cfg, kind, x,
                window_override=window_override, use_flash=use_flash,
            )
            aux = aux + a
        x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
        x = x[:, -n_text:]  # only text positions produce logits (VLM prefix)
        logits = unembed_apply(params.get("unembed", params["embed"]), x)
        return logits, aux

    # -- loss -------------------------------------------------------------------
    def loss(self, params, batch, rng=None, *, remat: bool = False, use_flash: bool = False):
        cfg = self.cfg
        logits, aux = self.apply(
            params, batch["tokens"], batch.get("embeds"), remat=remat, use_flash=use_flash
        )
        ce = softmax_cross_entropy(logits, batch["labels"], valid_vocab=cfg.vocab_size)
        loss = ce.mean() + cfg.router_aux_weight * aux
        return loss, {"ce": ce.mean(), "aux": aux, "accuracy": token_accuracy(logits, batch["labels"])}

    # -- decode -------------------------------------------------------------------
    def init_cache(self, batch: int, capacity: int, *, window_override=None) -> dict:
        cfg = self.cfg
        eff_cfg = cfg if window_override is None else cfg.replace(sliding_window=window_override)
        caches = {}
        for u, kind in enumerate(self.pattern):
            one = _init_block_cache(eff_cfg, kind, batch, capacity)
            caches[f"u{u}_{kind}"] = jax.tree.map(
                lambda l: jnp.broadcast_to(l[None], (self.n_groups,) + l.shape), one
            )
        if self.tail:
            caches["tail"] = {
                f"t{i}_{kind}": _init_block_cache(eff_cfg, kind, batch, capacity)
                for i, kind in enumerate(self.tail)
            }
        return caches

    def decode_step(self, params, token, cache, pos, *, window_override=None):
        """token: (B,) int32; pos: scalar int32 → (logits (B,padded_vocab), cache)."""
        cfg = self.cfg
        x = self._embed(params, token[:, None])

        def group_body(x, scanned):
            group_params, group_cache = scanned
            new_cache = {}
            for u, kind in enumerate(self.pattern):
                key = f"u{u}_{kind}"
                x, new_cache[key] = _apply_block_decode(
                    group_params[key], cfg, kind, x, group_cache[key], pos,
                    window_override=window_override,
                )
            return x, new_cache

        tail_cache = cache.get("tail") if isinstance(cache, dict) else None
        scan_cache = {k: v for k, v in cache.items() if k != "tail"}
        x, new_scan_cache = jax.lax.scan(group_body, x, (params["blocks"], scan_cache))
        new_cache = dict(new_scan_cache)
        if self.tail:
            new_tail = {}
            for i, kind in enumerate(self.tail):
                key = f"t{i}_{kind}"
                x, new_tail[key] = _apply_block_decode(
                    params["tail"][key], cfg, kind, x, tail_cache[key], pos,
                    window_override=window_override,
                )
            new_cache["tail"] = new_tail
        x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
        logits = unembed_apply(params.get("unembed", params["embed"]), x[:, 0])
        return logits, new_cache

    def prefill(self, params, tokens, cache, extra_embeds=None, *, window_override=None):
        """Bulk prefill: one full-sequence pass that fills a *fresh* decode
        cache (``init_cache``) and returns the last position's logits — the
        serving replacement for feeding a prompt through ``decode_step`` one
        token at a time. Positions start at 0 (the VLM prefix, if any,
        occupies positions 0..P-1). → (logits (B, padded_vocab), cache)."""
        cfg = self.cfg
        x = self._embed(params, tokens, extra_embeds)

        def group_body(x, scanned):
            group_params, group_cache = scanned
            new_cache = {}
            for u, kind in enumerate(self.pattern):
                key = f"u{u}_{kind}"
                x, new_cache[key] = _apply_block_prefill(
                    group_params[key], cfg, kind, x, group_cache[key],
                    window_override=window_override,
                )
            return x, new_cache

        tail_cache = cache.get("tail") if isinstance(cache, dict) else None
        scan_cache = {k: v for k, v in cache.items() if k != "tail"}
        x, new_cache = jax.lax.scan(group_body, x, (params["blocks"], scan_cache))
        new_cache = dict(new_cache)
        if self.tail:
            new_tail = {}
            for i, kind in enumerate(self.tail):
                key = f"t{i}_{kind}"
                x, new_tail[key] = _apply_block_prefill(
                    params["tail"][key], cfg, kind, x, tail_cache[key],
                    window_override=window_override,
                )
            new_cache["tail"] = new_tail
        x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
        logits = unembed_apply(params.get("unembed", params["embed"]), x[:, -1])
        return logits, new_cache
