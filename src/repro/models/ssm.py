"""Mamba2 — state-space duality (SSD) block [arXiv:2405.21060].

Full-sequence path uses the *chunked dual form*: intra-chunk attention-like
matmuls (MXU-friendly) + an inter-chunk linear recurrence over chunk states.
``ssd_chunked`` is the pure-jnp reference; the Pallas kernel
(repro.kernels.ssd_scan) implements the same contraction with VMEM tiling and
is validated against ``ssd_sequential`` / ``ssd_chunked``.

Decode carries (conv_state, ssm_state) — O(1) per token, which is why
mamba2 runs the ``long_500k`` shape natively.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelConfig, dense_apply, dense_init, rmsnorm_apply, rmsnorm_init


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def segsum(x: jnp.ndarray) -> jnp.ndarray:
    """Lower-triangular cumulative segment sums: out[..., i, j] = sum x[j+1..i]."""
    c = x.shape[-1]
    cum = jnp.cumsum(x, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((c, c), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jnp.ndarray,      # (B, S, H, P) — inputs, already multiplied by dt
    dA: jnp.ndarray,     # (B, S, H)    — dt * A (negative)
    Bm: jnp.ndarray,     # (B, S, H, N) — input matrix (groups broadcast to H)
    Cm: jnp.ndarray,     # (B, S, H, N)
    chunk: int,
    initial_state: jnp.ndarray | None = None,  # (B, H, P, N)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD dual form. Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    if S % chunk != 0:
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = x.shape[1]
    nc = Sp // chunk

    xc = x.reshape(Bsz, nc, chunk, H, P).astype(jnp.float32)
    dAc = dA.reshape(Bsz, nc, chunk, H).transpose(0, 3, 1, 2).astype(jnp.float32)  # (B,H,nc,c)
    Bc = Bm.reshape(Bsz, nc, chunk, H, N).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, nc, chunk, H, N).astype(jnp.float32)

    A_cumsum = jnp.cumsum(dAc, axis=-1)                       # (B,H,nc,c)
    L = jnp.exp(segsum(dAc))                                  # (B,H,nc,c,c)
    # 1. intra-chunk (diagonal blocks)
    y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp", Cc, Bc, L, xc)
    # 2. chunk states: contribution of each chunk to its final state
    decay_states = jnp.exp(A_cumsum[..., -1:] - A_cumsum)     # (B,H,nc,c)
    states = jnp.einsum("bcshn,bhcs,bcshp->bchpn", Bc, decay_states, xc)  # (B,nc,H,P,N)
    # 3. inter-chunk recurrence: state_{c} = decay_c * state_{c-1} + states_c
    chunk_decay = jnp.exp(A_cumsum[..., -1])                  # (B,H,nc)
    init = (
        jnp.zeros((Bsz, H, P, N), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def step(carry, inp):
        st, dec = inp                                          # (B,H,P,N), (B,H)
        prev = carry
        new = prev * dec[..., None, None] + st
        return new, prev                                       # emit state ENTERING the chunk

    states_t = states.transpose(1, 0, 2, 3, 4)                 # (nc,B,H,P,N)
    decay_t = chunk_decay.transpose(2, 0, 1)                   # (nc,B,H)
    final_state, prev_states = jax.lax.scan(step, init, (states_t, decay_t))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)         # (B,nc,H,P,N)
    # 4. inter-chunk output: y_off[l] = C_l · (decay into l) · prev_state
    state_decay_out = jnp.exp(A_cumsum)                        # (B,H,nc,c)
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp", Cc, prev_states, state_decay_out)

    y = (y_diag + y_off).reshape(Bsz, Sp, H, P)[:, :S]
    return y.astype(x.dtype), final_state


def ssd_sequential(x, dA, Bm, Cm, initial_state=None):
    """O(S) sequential oracle: h_t = exp(dA_t) h_{t-1} + B_t ⊗ x_t; y_t = C_t·h_t."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    h0 = (
        jnp.zeros((Bsz, H, P, N), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def step(h, inp):
        xt, dat, bt, ct = inp
        h = h * jnp.exp(dat)[..., None, None] + xt[..., :, None] * bt[..., None, :]
        y = jnp.einsum("bhpn,bhn->bhp", h, ct)
        return h, y

    xs = (
        x.transpose(1, 0, 2, 3).astype(jnp.float32),
        dA.transpose(1, 0, 2).astype(jnp.float32),
        Bm.transpose(1, 0, 2, 3).astype(jnp.float32),
        Cm.transpose(1, 0, 2, 3).astype(jnp.float32),
    )
    h, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), h


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_head_dim
    return d_inner, H, cfg.ssm_state


def init_mamba2(rng, cfg: ModelConfig) -> dict:
    d_inner, H, N = _dims(cfg)
    dt = cfg.jdtype
    ks = jax.random.split(rng, 6)
    conv_ch = d_inner + 2 * N  # x, B, C all pass through the causal conv
    k_z, k_x, k_B, k_C, k_dt = jax.random.split(ks[0], 5)
    return {
        # separate projections (not one fused in_proj) so each output dim can
        # shard independently on the "model" mesh axis (d_inner % 16 == 0
        # even when the fused width is not divisible)
        "w_z": dense_init(k_z, cfg.d_model, d_inner, dt),
        "w_x": dense_init(k_x, cfg.d_model, d_inner, dt),
        "w_B": dense_init(k_B, cfg.d_model, N, dt),
        "w_C": dense_init(k_C, cfg.d_model, N, dt),
        "w_dt": dense_init(k_dt, cfg.d_model, H, dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch), jnp.float32) * 0.1).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm": rmsnorm_init(d_inner, dt),
        "out_proj": dense_init(ks[2], d_inner, cfg.d_model, dt),
    }


def _project_in(p: dict, cfg: ModelConfig, x: jnp.ndarray):
    z = dense_apply(p["w_z"], x)
    xBC = jnp.concatenate(
        [dense_apply(p["w_x"], x), dense_apply(p["w_B"], x), dense_apply(p["w_C"], x)], axis=-1
    )
    dt_raw = dense_apply(p["w_dt"], x)
    return z, xBC, dt_raw


def causal_conv1d(xBC: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv along time. xBC: (B,S,C); w: (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xBC.shape[1]] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def mamba2_full(p: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    B, S, _ = x.shape
    d_inner, H, N = _dims(cfg)
    z, xBC, dt_raw = _project_in(p, cfg, x)
    xBC = causal_conv1d(xBC, p["conv_w"], p["conv_b"])
    xs, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])     # (B,S,H)
    A = -jnp.exp(p["A_log"])                                            # (H,)
    xh = xs.reshape(B, S, H, cfg.ssm_head_dim)
    xdt = xh * dt[..., None].astype(xh.dtype)
    dA = dt * A
    Bh = jnp.broadcast_to(Bm[:, :, None, :], (B, S, H, N))
    Ch = jnp.broadcast_to(Cm[:, :, None, :], (B, S, H, N))
    y, _ = ssd_chunked(xdt, dA, Bh, Ch, cfg.ssm_chunk)
    y = y + xh * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B, S, d_inner)
    y = rmsnorm_apply(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return dense_apply(p["out_proj"], y)


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=None) -> dict:
    d_inner, H, N = _dims(cfg)
    dt = dtype or cfg.jdtype
    conv_ch = d_inner + 2 * N
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dt),
        "ssm": jnp.zeros((batch, H, cfg.ssm_head_dim, N), jnp.float32),
    }


def mamba2_decode(p: dict, cfg: ModelConfig, x: jnp.ndarray, cache: dict) -> tuple[jnp.ndarray, dict]:
    """x: (B,1,D) → (y (B,1,D), cache)."""
    B = x.shape[0]
    d_inner, H, N = _dims(cfg)
    z, xBC, dt_raw = _project_in(p, cfg, x)
    # conv over the (width-1) history + current token
    window = jnp.concatenate([cache["conv"], xBC], axis=1)               # (B,K,C)
    conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"])[:, None]
    new_conv = window[:, 1:]
    xs, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(B, H, cfg.ssm_head_dim).astype(jnp.float32)
    h = cache["ssm"] * jnp.exp(dt * A)[..., None, None] + (
        (xh * dt[..., None])[..., :, None] * Bm[:, 0, None, None, :].astype(jnp.float32)
    )
    y = jnp.einsum("bhpn,bn->bhp", h, Cm[:, 0].astype(jnp.float32))
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(B, 1, d_inner).astype(x.dtype)
    y = rmsnorm_apply(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return dense_apply(p["out_proj"], y), {"conv": new_conv, "ssm": h}


def mamba2_prefill(p: dict, cfg: ModelConfig, x: jnp.ndarray, cache: dict) -> tuple[jnp.ndarray, dict]:
    """``mamba2_full`` that also produces the decode cache — serving's bulk
    prefill. ``ssd_chunked`` already tracks the final SSM state (the full
    path discards it); the conv cache is the trailing (ssm_conv-1) raw xBC
    rows. Seeds from ``cache`` (zeros == fresh), so the result matches the
    recurrence ``mamba2_decode`` would have run token by token."""
    B, S, _ = x.shape
    d_inner, H, N = _dims(cfg)
    z, xBC, dt_raw = _project_in(p, cfg, x)
    K = cfg.ssm_conv
    window = jnp.concatenate([cache["conv"].astype(xBC.dtype), xBC], axis=1)  # (B,K-1+S,C)
    conv_out = jax.nn.silu(
        sum(window[:, i : i + S] * p["conv_w"][i] for i in range(K)) + p["conv_b"]
    )
    xs, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(B, S, H, cfg.ssm_head_dim)
    xdt = xh * dt[..., None].astype(xh.dtype)
    dA = dt * A
    Bh = jnp.broadcast_to(Bm[:, :, None, :], (B, S, H, N))
    Ch = jnp.broadcast_to(Cm[:, :, None, :], (B, S, H, N))
    y, final_state = ssd_chunked(xdt, dA, Bh, Ch, cfg.ssm_chunk, initial_state=cache["ssm"])
    y = y + xh * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B, S, d_inner)
    y = rmsnorm_apply(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return dense_apply(p["out_proj"], y), {"conv": window[:, S:], "ssm": final_state}
