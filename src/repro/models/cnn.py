"""Paper-experiment vision models: the MNIST CNN (§4.2) and a ResNet for
CIFAR (§4.3), pure-functional JAX.

The paper's MNIST net: two conv layers with max pooling + ReLU, then dense.
The CIFAR net is ResNet-18-style; we use GroupNorm instead of BatchNorm so the
model stays purely functional (no mutable running stats) — running-stat
averaging is orthogonal to the federation mechanism under study, and GN-ResNets
are the standard choice in FL research for exactly this reason (noted in
DESIGN.md §3).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def _conv_init(rng, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    w = jax.random.normal(rng, (kh, kw, cin, cout), jnp.float32) * math.sqrt(2.0 / fan_in)
    return {"w": w, "b": jnp.zeros((cout,), jnp.float32)}


def _conv(p, x, stride=1):
    out = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out + p["b"]


def _dense_init(rng, d_in, d_out):
    w = jax.random.normal(rng, (d_in, d_out), jnp.float32) * math.sqrt(1.0 / d_in)
    return {"w": w, "b": jnp.zeros((d_out,), jnp.float32)}


def _dense(p, x):
    return x @ p["w"] + p["b"]


def _groupnorm_init(c):
    return {"scale": jnp.ones((c,), jnp.float32), "bias": jnp.zeros((c,), jnp.float32)}


def _groupnorm(p, x, groups=8):
    B, H, W, C = x.shape
    g = min(groups, C)
    xg = x.reshape(B, H, W, g, C // g)
    mean = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + 1e-5)
    return xg.reshape(B, H, W, C) * p["scale"] + p["bias"]


def _maxpool(x, k=2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, k, k, 1), "VALID"
    )


# ---------------------------------------------------------------------------
# MNIST CNN (paper §4.2)
# ---------------------------------------------------------------------------


class MnistCNN:
    """conv(32)→pool→relu → conv(64)→pool→relu → dense(128) → dense(10)."""

    def __init__(self, num_classes: int = 10, in_channels: int = 1, hw: int = 28):
        self.num_classes = num_classes
        self.in_channels = in_channels
        self.flat = (hw // 4) ** 2 * 64

    def init(self, rng) -> dict:
        ks = jax.random.split(rng, 4)
        return {
            "conv1": _conv_init(ks[0], 3, 3, self.in_channels, 32),
            "conv2": _conv_init(ks[1], 3, 3, 32, 64),
            "fc1": _dense_init(ks[2], self.flat, 128),
            "fc2": _dense_init(ks[3], 128, self.num_classes),
        }

    def apply(self, params, x):
        x = jax.nn.relu(_maxpool(_conv(params["conv1"], x)))
        x = jax.nn.relu(_maxpool(_conv(params["conv2"], x)))
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(_dense(params["fc1"], x))
        return _dense(params["fc2"], x)

    def loss(self, params, batch, rng=None):
        logits = self.apply(params, batch["x"])
        labels = batch["y"]
        logp = jax.nn.log_softmax(logits)
        ce = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
        acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
        return ce, {"accuracy": acc}


# ---------------------------------------------------------------------------
# ResNet (paper §4.3 uses ResNet-18 on CIFAR-10)
# ---------------------------------------------------------------------------


def _block_init(rng, cin, cout, stride):
    ks = jax.random.split(rng, 3)
    p = {
        "conv1": _conv_init(ks[0], 3, 3, cin, cout),
        "gn1": _groupnorm_init(cout),
        "conv2": _conv_init(ks[1], 3, 3, cout, cout),
        "gn2": _groupnorm_init(cout),
    }
    if stride != 1 or cin != cout:
        p["proj"] = _conv_init(ks[2], 1, 1, cin, cout)
    return p


def _block(p, x, stride):
    h = jax.nn.relu(_groupnorm(p["gn1"], _conv(p["conv1"], x, stride)))
    h = _groupnorm(p["gn2"], _conv(p["conv2"], h))
    shortcut = _conv(p["proj"], x, stride) if "proj" in p else x
    return jax.nn.relu(h + shortcut)


class ResNet:
    """ResNet-18 topology (2-2-2-2 basic blocks), GroupNorm, CIFAR stem."""

    STAGES = [(64, 1), (128, 2), (256, 2), (512, 2)]

    def __init__(self, num_classes: int = 10, in_channels: int = 3, width: int = 1,
                 blocks_per_stage: int = 2):
        self.num_classes = num_classes
        self.in_channels = in_channels
        self.width = width
        self.bps = blocks_per_stage

    def init(self, rng) -> dict:
        ks = jax.random.split(rng, 2 + len(self.STAGES) * self.bps)
        params = {
            "stem": _conv_init(ks[0], 3, 3, self.in_channels, 64 * self.width // 1),
            "gn0": _groupnorm_init(64 * self.width // 1),
        }
        cin = 64 * self.width // 1
        idx = 1
        for s, (cout_base, stride) in enumerate(self.STAGES):
            cout = cout_base * self.width // 1
            for b in range(self.bps):
                params[f"s{s}b{b}"] = _block_init(ks[idx], cin, cout, stride if b == 0 else 1)
                cin = cout
                idx += 1
        params["fc"] = _dense_init(ks[idx], cin, self.num_classes)
        return params

    def apply(self, params, x):
        x = jax.nn.relu(_groupnorm(params["gn0"], _conv(params["stem"], x)))
        for s, (_, stride) in enumerate(self.STAGES):
            for b in range(self.bps):
                x = _block(params[f"s{s}b{b}"], x, stride if b == 0 else 1)
        x = x.mean(axis=(1, 2))
        return _dense(params["fc"], x)

    def loss(self, params, batch, rng=None):
        logits = self.apply(params, batch["x"])
        labels = batch["y"]
        logp = jax.nn.log_softmax(logits)
        ce = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
        acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
        return ce, {"accuracy": acc}
