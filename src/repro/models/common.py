"""Shared model config + primitive layers (pure-functional JAX).

Every architecture in the assigned pool is expressible through ``ModelConfig``
feature flags; the assembly lives in transformer.py / encdec.py. Params are
nested dicts of jnp arrays; layer stacks keep a leading layer axis and are
consumed by ``jax.lax.scan`` so 64-layer models lower to compact HLO.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    arch_type: str = "dense"  # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""          # citation (paper / model card)

    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0          # 0 → d_model // n_heads
    d_ff: int = 512
    vocab_size: int = 1024

    # attention features
    attention: str = "gqa"     # gqa | mla | none
    lora_rank: int = 0         # >0 → LoRA adapter on the q projection
                               # (adapter-only federation ships just these)
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0    # 0 → full causal; >0 → local attention window
    attn_logit_softcap: float = 0.0
    attn_qblock: int = 256     # chunked-attention q-tile (§Perf knob: bigger tile
                               # → fewer K/V HBM re-reads, more VMEM per tile)
    attn_probs_bf16: bool = False  # cast softmax probs to bf16 before P·V
                                   # (§Perf H2: halves prob traffic; ~1e-3 rel err)

    # activation
    activation: str = "swiglu"  # swiglu | geglu | gelu

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    num_shared_experts: int = 0
    router_aux_weight: float = 0.01
    moe_dispatch: str = "einsum"  # einsum (GShard-style baseline) | gather (§Perf H1)

    # MLA (MiniCPM3 / DeepSeek-style)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    mla_absorb: bool = False  # absorbed-matmul decode (§Perf hillclimb)

    # SSM (Mamba2 SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256

    # hybrid layer pattern, e.g. ("rglru", "rglru", "attn") for RecurrentGemma
    layer_pattern: tuple[str, ...] = ()
    rglru_c: float = 8.0
    conv1d_width: int = 4

    # encoder-decoder (audio)
    encoder_layers: int = 0

    # modality frontend stub: "vision" feeds patch embeddings, "audio" frames
    frontend: str = ""
    frontend_tokens: int = 0   # patches / frames per example

    remat_policy: str = "full"  # full | dots (save matmul outputs — §Perf H3:
                                # cuts remat recompute FLOPs for more memory)
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    vocab_pad_to: int = 256
    dtype: str = "bfloat16"
    # long-context decode support: dense archs flip this on for long_500k
    long_context_window: int = 8192

    # ---- derived ----
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return ((self.vocab_size + p - 1) // p) * p

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def pattern(self) -> tuple[str, ...]:
        if self.layer_pattern:
            return self.layer_pattern
        if self.arch_type == "ssm":
            return ("ssm",)
        return ("moe_attn",) if self.num_experts else ("attn",)

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: ≤2 pattern units of layers, d_model ≤ 256, ≤4 experts."""
        unit = len(self.pattern)
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        return self.replace(
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 2 * unit),
            encoder_layers=min(self.encoder_layers, 2) if self.encoder_layers else 0,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=min(self.n_kv_heads, n_heads),
            head_dim=64 if self.head_dim else 0,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            experts_per_token=min(self.experts_per_token, 2) if self.experts_per_token else 0,
            q_lora_rank=min(self.q_lora_rank, 64) if self.q_lora_rank else 0,
            kv_lora_rank=min(self.kv_lora_rank, 32) if self.kv_lora_rank else 0,
            ssm_state=min(self.ssm_state, 32) if self.ssm_state else 0,
            ssm_chunk=32 if self.ssm_state else self.ssm_chunk,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            frontend_tokens=min(self.frontend_tokens, 16) if self.frontend_tokens else 0,
            dtype="float32",
        )


# --------------------------------------------------------------------------
# Primitive layers (functional: init_* returns params, apply is a function)
# --------------------------------------------------------------------------


def dense_init(rng, d_in: int, d_out: int, dtype, scale: float | None = None) -> dict:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    w = jax.random.normal(rng, (d_in, d_out), jnp.float32) * scale
    return {"w": w.astype(dtype)}


def dense_apply(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    return x @ p["w"]


def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_apply(p: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def embedding_init(rng, vocab: int, d: int, dtype) -> dict:
    return {"table": (jax.random.normal(rng, (vocab, d), jnp.float32) * 0.02).astype(dtype)}


def embedding_apply(p: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["table"], tokens, axis=0)


def unembed_apply(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Tied unembedding: logits in f32 (loss stability)."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32), p["table"].astype(jnp.float32))


# -- rotary ------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    freqs = rope_frequencies(x.shape[-1], theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos, sin = jnp.cos(angles)[..., None, :], jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- gated MLP ----------------------------------------------------------------


def mlp_init(rng, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(rng, 3)
    dt = cfg.jdtype
    if cfg.activation in ("swiglu", "geglu"):
        return {
            "wi": dense_init(k1, cfg.d_model, d_ff, dt),
            "wg": dense_init(k2, cfg.d_model, d_ff, dt),
            "wo": dense_init(k3, d_ff, cfg.d_model, dt),
        }
    return {
        "wi": dense_init(k1, cfg.d_model, d_ff, dt),
        "wo": dense_init(k3, d_ff, cfg.d_model, dt),
    }


def mlp_apply(p: dict, x: jnp.ndarray, activation: str) -> jnp.ndarray:
    h = dense_apply(p["wi"], x)
    if activation == "swiglu":
        h = jax.nn.silu(h) * dense_apply(p["wg"], x)
    elif activation == "geglu":
        h = jax.nn.gelu(h, approximate=True) * dense_apply(p["wg"], x)
    elif activation == "gelu":
        h = jax.nn.gelu(h, approximate=True)
    else:
        raise ValueError(f"unknown activation {activation}")
    return dense_apply(p["wo"], h)


# -- losses -------------------------------------------------------------------


def softmax_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray, valid_vocab: int | None = None) -> jnp.ndarray:
    """Per-token CE in f32; padded vocab tail masked out."""
    logits = logits.astype(jnp.float32)
    if valid_vocab is not None and valid_vocab < logits.shape[-1]:
        mask = jnp.arange(logits.shape[-1]) < valid_vocab
        logits = jnp.where(mask, logits, -1e30)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return logz - gold


def token_accuracy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
