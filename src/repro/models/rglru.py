"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block: two branches — (linear → GeLU) gate branch and (linear → causal conv1d
→ RG-LRU) recurrent branch — merged multiplicatively then projected out.

RG-LRU recurrence (per channel):
    r_t = σ(W_a x_t),  i_t = σ(W_x x_t)
    a_t = exp(-c · softplus(Λ) · r_t)
    h_t = a_t h_{t-1} + sqrt(1 − a_t²) · (i_t ⊙ x_t)

Training/prefill uses ``jax.lax.associative_scan`` (log-depth parallel scan —
the TPU-native answer to the paper's custom GPU scan kernel); decode is a
single fused step carrying (conv_state, h).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelConfig, dense_apply, dense_init
from .ssm import causal_conv1d


def init_rglru(rng, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    dr = d  # lru width = d_model
    dt = cfg.jdtype
    ks = jax.random.split(rng, 6)
    # Λ init so that a ∈ [0.9, 0.999] at r=1 (Griffin appendix)
    u = jax.random.uniform(ks[0], (dr,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / cfg.rglru_c))  # softplus^{-1}
    return {
        "w_gate": dense_init(ks[1], d, dr, dt),     # GeLU branch
        "w_rec": dense_init(ks[2], d, dr, dt),      # recurrent branch input
        "conv_w": (jax.random.normal(ks[3], (cfg.conv1d_width, dr), jnp.float32) * 0.1).astype(dt),
        "conv_b": jnp.zeros((dr,), dt),
        "w_a": dense_init(ks[4], dr, dr, dt, scale=0.02),
        "w_x": dense_init(ks[5], dr, dr, dt, scale=0.02),
        "lambda": lam,
        "w_out": dense_init(jax.random.split(ks[0])[1], dr, d, dt),
    }


def _gates(p: dict, cfg: ModelConfig, x: jnp.ndarray):
    r = jax.nn.sigmoid(dense_apply(p["w_a"], x).astype(jnp.float32))
    i = jax.nn.sigmoid(dense_apply(p["w_x"], x).astype(jnp.float32))
    log_a = -cfg.rglru_c * jax.nn.softplus(p["lambda"]) * r
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) * (i * x.astype(jnp.float32))
    return a, gated_in


def rglru_scan(a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray | None = None) -> jnp.ndarray:
    """h_t = a_t h_{t-1} + b_t along axis 1, via parallel associative scan."""
    if h0 is not None:
        # fold initial state into the first element
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(l, r):
        a1, b1 = l
        a2, b2 = r
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_full(p: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """x: (B,S,D) → (B,S,D)."""
    gate = jax.nn.gelu(dense_apply(p["w_gate"], x), approximate=True)
    u = dense_apply(p["w_rec"], x)
    u = causal_conv1d(u, p["conv_w"], p["conv_b"])
    a, b = _gates(p, cfg, u)
    h = rglru_scan(a, b).astype(x.dtype)
    return dense_apply(p["w_out"], h * gate)


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype=None) -> dict:
    dr = cfg.d_model
    dt = dtype or cfg.jdtype
    return {
        "conv": jnp.zeros((batch, cfg.conv1d_width - 1, dr), dt),
        "h": jnp.zeros((batch, dr), jnp.float32),
    }


def rglru_decode(p: dict, cfg: ModelConfig, x: jnp.ndarray, cache: dict) -> tuple[jnp.ndarray, dict]:
    """x: (B,1,D)."""
    gate = jax.nn.gelu(dense_apply(p["w_gate"], x), approximate=True)
    u = dense_apply(p["w_rec"], x)                     # (B,1,dr)
    window = jnp.concatenate([cache["conv"], u], axis=1)
    conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"])[:, None]
    a, b = _gates(p, cfg, conv_out)
    h = a[:, 0] * cache["h"] + b[:, 0]
    out = dense_apply(p["w_out"], h[:, None].astype(x.dtype) * gate)
    return out, {"conv": window[:, 1:], "h": h}


def rglru_prefill(p: dict, cfg: ModelConfig, x: jnp.ndarray, cache: dict) -> tuple[jnp.ndarray, dict]:
    """``rglru_full`` that also produces the decode cache — serving's bulk
    prefill: associative scan seeded with the cached h, depthwise conv over
    the cached raw-u window (zeros == fresh). x: (B,S,D)."""
    B, S, _ = x.shape
    gate = jax.nn.gelu(dense_apply(p["w_gate"], x), approximate=True)
    u = dense_apply(p["w_rec"], x)
    K = cfg.conv1d_width
    window = jnp.concatenate([cache["conv"].astype(u.dtype), u], axis=1)  # (B,K-1+S,dr)
    conv_out = jax.nn.silu(
        sum(window[:, i : i + S] * p["conv_w"][i] for i in range(K)) + p["conv_b"]
    )
    a, b = _gates(p, cfg, conv_out)
    h = rglru_scan(a, b, h0=cache["h"])
    out = dense_apply(p["w_out"], h.astype(x.dtype) * gate)
    return out, {"conv": window[:, S:], "h": h[:, -1]}
