"""Modality frontend STUBS (the one allowed carve-out).

[vlm] and [audio] architectures specify the transformer backbone only; the
ViT / conv-codec frontends are stubbed: ``input_specs()`` (repro.launch.specs)
provides precomputed patch/frame embeddings of the right shape, and these
helpers generate concrete embeddings for smoke tests / examples.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelConfig


def stub_patch_embeddings(rng, cfg: ModelConfig, batch: int) -> jnp.ndarray:
    """(B, n_patches, d_model) — stands in for InternViT + MLP projector."""
    n = cfg.frontend_tokens
    return jax.random.normal(rng, (batch, n, cfg.d_model), jnp.float32).astype(cfg.jdtype) * 0.02


def stub_audio_frames(rng, cfg: ModelConfig, batch: int, n_frames: int | None = None) -> jnp.ndarray:
    """(B, n_frames, d_model) — stands in for mel-spec + conv feature extractor."""
    n = n_frames or cfg.frontend_tokens
    return jax.random.normal(rng, (batch, n, cfg.d_model), jnp.float32).astype(cfg.jdtype) * 0.02
