"""Mixture-of-Experts layer — GShard/Switch-style capacity-based dispatch.

TPU-idiomatic: routing is expressed as two einsums against a one-hot dispatch
tensor (token → expert, capacity-slot), so the whole layer is dense matmuls
the MXU likes, and expert weights shard cleanly (experts stay stacked on a
leading E axis; d_ff shards on the "model" mesh axis). Tokens overflowing an
expert's capacity are dropped (standard Switch behaviour); the router adds the
usual load-balance auxiliary loss.

Supports top-1 (llama4-scout, 16e) and top-2 (grok-1, 8e) routing plus
optional shared experts (llama4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelConfig, dense_init, mlp_apply, mlp_init


def init_moe(rng, cfg: ModelConfig) -> dict:
    ks = jax.random.split(rng, 4)
    dt = cfg.jdtype
    E, D, F = cfg.num_experts, cfg.d_model, cfg.d_ff

    def stack_init(key, d_in, d_out):
        keys = jax.random.split(key, E)
        return {"w": jnp.stack([dense_init(k, d_in, d_out, dt)["w"] for k in keys])}

    p = {
        "router": dense_init(ks[0], D, E, dt, scale=0.02),
        "wi": stack_init(ks[1], D, F),   # (E, D, F)
        "wg": stack_init(ks[2], D, F),
        "wo": stack_init(ks[3], F, D),   # (E, F, D)
    }
    if cfg.num_shared_experts:
        p["shared"] = mlp_init(jax.random.split(ks[0])[0], cfg, d_ff=F * cfg.num_shared_experts)
    return p


def _top_k_gating(logits: jnp.ndarray, k: int):
    """logits: (N, E) → (gates (N,k), indices (N,k)). Gates renormalized."""
    gates_all = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, idx = jax.lax.top_k(gates_all, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx, gates_all


def moe_apply_gather(p: dict, cfg: ModelConfig, x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sort/gather-based dispatch (§Perf hillclimb H1).

    The einsum dispatch materializes a one-hot (N, E, C) tensor — at
    prefill_32k that is PB-scale and its einsums add O(N·E·C·D) useless FLOPs.
    Here routing is index arithmetic instead: argsort (token, choice) pairs by
    expert, compute each pair's position within its expert via one cumsum,
    *gather* tokens into the (E·C, D) expert buffer and *scatter-add* the
    gated outputs back. Zero matmul FLOPs for routing; HBM traffic linear in
    N·D. Same capacity-drop semantics as the einsum path (verified allclose).
    """
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    N = B * S
    xt = x.reshape(N, D)
    logits = xt @ p["router"]["w"]
    gates, idx, gates_all = _top_k_gating(logits, k)
    capacity = N if N <= 64 else max(1, int(cfg.moe_capacity_factor * k * N / E))

    flat_expert = idx.reshape(N * k)                       # expert of each (token, choice)
    flat_token = jnp.repeat(jnp.arange(N, dtype=jnp.int32), k)
    flat_gate = gates.reshape(N * k)
    order = jnp.argsort(flat_expert, stable=True)
    s_expert = flat_expert[order]
    s_token = flat_token[order]
    s_gate = flat_gate[order]
    # position of each entry within its expert's run of the sorted array
    ar = jnp.arange(N * k, dtype=jnp.int32)
    counts = jnp.bincount(flat_expert, length=E)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos = ar - starts[s_expert].astype(jnp.int32)
    kept = pos < capacity
    slot = jnp.where(kept, s_expert * capacity + pos, E * capacity)  # overflow slot

    # gather tokens into expert buffers; slot E*C is a scratch row
    token_for_slot = jnp.full((E * capacity + 1,), N, jnp.int32).at[slot].set(
        jnp.where(kept, s_token, N)
    )[: E * capacity]
    x_pad = jnp.concatenate([xt, jnp.zeros((1, D), xt.dtype)], axis=0)
    expert_in = x_pad[token_for_slot].reshape(E, capacity, D)

    h = jnp.einsum("ecd,edf->ecf", expert_in, p["wi"]["w"])
    if cfg.activation in ("swiglu", "geglu"):
        g = jnp.einsum("ecd,edf->ecf", expert_in, p["wg"]["w"])
        act = jax.nn.silu if cfg.activation == "swiglu" else (lambda t: jax.nn.gelu(t, approximate=True))
        h = act(h) * g
    else:
        h = jax.nn.gelu(h, approximate=True)
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["wo"]["w"]).reshape(E * capacity, D)

    # scatter gated outputs back to tokens
    contrib = expert_out[jnp.where(kept, slot, 0)] * jnp.where(kept, s_gate, 0.0)[:, None].astype(x.dtype)
    out = jnp.zeros((N, D), x.dtype).at[s_token].add(contrib)

    if "shared" in p:
        out = out + mlp_apply(p["shared"], xt, cfg.activation)

    frac = jnp.mean(jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32), axis=0)
    prob = jnp.mean(gates_all, axis=0)
    aux = E * jnp.sum(frac * prob)
    return out.reshape(B, S, D), aux


def moe_apply(p: dict, cfg: ModelConfig, x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) → (out, aux_loss). Dispatch per cfg.moe_dispatch."""
    if cfg.moe_dispatch == "gather":
        return moe_apply_gather(p, cfg, x)
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    N = B * S
    xt = x.reshape(N, D)
    logits = xt @ p["router"]["w"]
    gates, idx, gates_all = _top_k_gating(logits, k)

    # Decode calls see only N = batch tokens; capacity-dropping there would
    # diverge from the full-sequence forward, so small token counts get full
    # capacity (no drops). Training keeps the standard Switch capacity rule.
    capacity = N if N <= 64 else max(1, int(cfg.moe_capacity_factor * k * N / E))
    # position of each (token, choice) within its expert's capacity buffer
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)            # (N, k, E)
    flat_choice = onehot.reshape(N * k, E)
    pos_in_expert = jnp.cumsum(flat_choice, axis=0) * flat_choice - 1  # (N*k, E)
    pos = pos_in_expert.reshape(N, k, E).max(-1)                 # (N, k)
    kept = (pos < capacity) & (pos >= 0)
    gates = gates * kept.astype(gates.dtype)

    # dispatch tensor (N, E, C) — one-hot over both expert and capacity slot
    dispatch = (
        jax.nn.one_hot(idx, E, dtype=x.dtype)[..., None]
        * jax.nn.one_hot(jnp.where(kept, pos, capacity), capacity + 1, dtype=x.dtype)[..., :-1][:, :, None, :]
    ).sum(1)                                                     # (N, E, C)
    combine = (
        (gates.astype(x.dtype)[..., None, None]
         * jax.nn.one_hot(idx, E, dtype=x.dtype)[..., None]
         * jax.nn.one_hot(jnp.where(kept, pos, capacity), capacity + 1, dtype=x.dtype)[..., :-1][:, :, None, :])
    ).sum(1)                                                     # (N, E, C)

    expert_in = jnp.einsum("nec,nd->ecd", dispatch, xt)          # (E, C, D)
    h = jnp.einsum("ecd,edf->ecf", expert_in, p["wi"]["w"])
    if cfg.activation in ("swiglu", "geglu"):
        g = jnp.einsum("ecd,edf->ecf", expert_in, p["wg"]["w"])
        act = jax.nn.silu if cfg.activation == "swiglu" else (lambda t: jax.nn.gelu(t, approximate=True))
        h = act(h) * g
    else:
        h = jax.nn.gelu(h, approximate=True)
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["wo"]["w"])     # (E, C, D)
    out = jnp.einsum("nec,ecd->nd", combine, expert_out)

    if "shared" in p:
        out = out + mlp_apply(p["shared"], xt, cfg.activation)

    # load-balance aux loss (Switch): E * Σ_e fraction_e · router_prob_e
    frac = jnp.mean(jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32), axis=0)
    prob = jnp.mean(gates_all, axis=0)
    aux = E * jnp.sum(frac * prob)
    return out.reshape(B, S, D), aux
