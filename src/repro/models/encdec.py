"""Encoder-decoder transformer (seamless-m4t-medium backbone, arXiv:2308.11596).

The speech frontend (mel-spectrogram + conv feature extractor) is a STUB per
the assignment carve-out: the encoder consumes precomputed frame embeddings
(B, S_src, d_model). The decoder is a standard causal transformer with
cross-attention over the encoder memory; ``decode_step`` carries a
self-attention ring cache plus a precomputed cross-attention K/V memory.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from .common import (
    ModelConfig,
    dense_apply,
    dense_init,
    embedding_apply,
    embedding_init,
    mlp_apply,
    mlp_init,
    rmsnorm_apply,
    rmsnorm_init,
    softmax_cross_entropy,
    token_accuracy,
    unembed_apply,
)


def _init_cross_attn(rng, cfg: ModelConfig) -> dict:
    hd = cfg.head_dim_
    dt = cfg.jdtype
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    return {
        "wq": dense_init(k1, cfg.d_model, cfg.n_heads * hd, dt),
        "wk": dense_init(k2, cfg.d_model, cfg.n_kv_heads * hd, dt),
        "wv": dense_init(k3, cfg.d_model, cfg.n_kv_heads * hd, dt),
        "wo": dense_init(k4, cfg.n_heads * hd, cfg.d_model, dt),
    }


def _cross_kv(p, cfg: ModelConfig, memory):
    B, T, _ = memory.shape
    hd = cfg.head_dim_
    k = dense_apply(p["wk"], memory).reshape(B, T, cfg.n_kv_heads, hd)
    v = dense_apply(p["wv"], memory).reshape(B, T, cfg.n_kv_heads, hd)
    return k, v


def _cross_attend(p, cfg: ModelConfig, x, k, v):
    B, S, _ = x.shape
    hd = cfg.head_dim_
    q = dense_apply(p["wq"], x).reshape(B, S, cfg.n_heads, hd)
    G = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, S, cfg.n_kv_heads, G, hd)
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg.astype(jnp.float32), k.astype(jnp.float32)) * scale
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v.astype(jnp.float32)).astype(x.dtype)
    return dense_apply(p["wo"], out.reshape(B, S, cfg.n_heads * hd))


def _init_enc_layer(rng, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(rng)
    return {
        "norm1": rmsnorm_init(cfg.d_model, cfg.jdtype),
        "attn": attn_mod.init_attention(k1, cfg),
        "norm2": rmsnorm_init(cfg.d_model, cfg.jdtype),
        "mlp": mlp_init(k2, cfg),
    }


def _init_dec_layer(rng, cfg: ModelConfig) -> dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "norm1": rmsnorm_init(cfg.d_model, cfg.jdtype),
        "self_attn": attn_mod.init_attention(k1, cfg),
        "norm_x": rmsnorm_init(cfg.d_model, cfg.jdtype),
        "cross": _init_cross_attn(k2, cfg),
        "norm2": rmsnorm_init(cfg.d_model, cfg.jdtype),
        "mlp": mlp_init(k3, cfg),
    }


class EncDecLM:
    """Speech-to-text enc-dec; encoder input is stub frame embeddings."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, rng) -> dict:
        cfg = self.cfg
        k_emb, k_enc, k_dec = jax.random.split(rng, 3)
        enc_ks = jax.random.split(k_enc, cfg.encoder_layers)
        dec_ks = jax.random.split(k_dec, cfg.n_layers)
        return {
            "embed": embedding_init(k_emb, cfg.padded_vocab, cfg.d_model, cfg.jdtype),
            "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_ks),
            "enc_norm": rmsnorm_init(cfg.d_model, cfg.jdtype),
            "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_ks),
            "final_norm": rmsnorm_init(cfg.d_model, cfg.jdtype),
        }

    def encode(self, params, frames, *, remat: bool = False):
        """frames: (B, S_src, D) stub embeddings → memory (B, S_src, D).

        Encoder self-attention is bidirectional (full, non-causal)."""
        cfg = self.cfg

        def body(x, p):
            h = rmsnorm_apply(p["norm1"], x, cfg.norm_eps)
            # non-causal: reuse attn machinery with an all-true mask via window=0
            B, S, _ = h.shape
            positions = jnp.arange(S)[None, :]
            q, k, v = attn_mod._project_qkv(p["attn"], cfg, h, positions)
            G = cfg.n_heads // cfg.n_kv_heads
            qg = q.reshape(B, S, cfg.n_kv_heads, G, cfg.head_dim_)
            mask = jnp.ones((S, S), bool)
            x = x + dense_apply(
                p["attn"]["wo"],
                attn_mod._sdpa(qg, k, v, mask, 0.0).reshape(B, S, cfg.n_heads * cfg.head_dim_),
            )
            h = rmsnorm_apply(p["norm2"], x, cfg.norm_eps)
            return x + mlp_apply(p["mlp"], h, cfg.activation), None

        body_fn = jax.checkpoint(body) if remat else body
        x, _ = jax.lax.scan(body_fn, frames.astype(cfg.jdtype), params["enc_layers"])
        return rmsnorm_apply(params["enc_norm"], x, cfg.norm_eps)

    def apply(self, params, tokens, frames, *, remat: bool = False):
        """Teacher-forced decode over full target sequence → (logits, aux=0)."""
        cfg = self.cfg
        memory = self.encode(params, frames, remat=remat)
        x = embedding_apply(params["embed"], tokens) * jnp.asarray(cfg.d_model**0.5, cfg.jdtype)

        def body(x, p):
            h = rmsnorm_apply(p["norm1"], x, cfg.norm_eps)
            x = x + attn_mod.attn_full(p["self_attn"], cfg, h)
            h = rmsnorm_apply(p["norm_x"], x, cfg.norm_eps)
            k, v = _cross_kv(p["cross"], cfg, memory)
            x = x + _cross_attend(p["cross"], cfg, h, k, v)
            h = rmsnorm_apply(p["norm2"], x, cfg.norm_eps)
            return x + mlp_apply(p["mlp"], h, cfg.activation), None

        body_fn = jax.checkpoint(body) if remat else body
        x, _ = jax.lax.scan(body_fn, x, params["dec_layers"])
        x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
        return unembed_apply(params["embed"], x), jnp.zeros((), jnp.float32)

    def loss(self, params, batch, rng=None, *, remat: bool = False):
        cfg = self.cfg
        logits, _ = self.apply(params, batch["tokens"], batch["frames"], remat=remat)
        ce = softmax_cross_entropy(logits, batch["labels"], valid_vocab=cfg.vocab_size)
        return ce.mean(), {"ce": ce.mean(), "accuracy": token_accuracy(logits, batch["labels"])}

    # -- decode ----------------------------------------------------------------
    def init_cache(self, params, frames, capacity: int, *, window_override: int | None = None) -> dict:
        """Precompute encoder memory + per-layer cross K/V; empty self cache."""
        cfg = self.cfg
        memory = self.encode(params, frames)
        cross_kv = jax.vmap(lambda p: _cross_kv(p, cfg, memory))(params["dec_layers"]["cross"])
        B = frames.shape[0]
        window = window_override if window_override is not None else cfg.sliding_window
        cap = min(capacity, window) if window else capacity
        one = attn_mod.init_attn_cache(cfg, B, cap)
        self_cache = jax.tree.map(lambda l: jnp.broadcast_to(l[None], (cfg.n_layers,) + l.shape), one)
        return {"cross_k": cross_kv[0], "cross_v": cross_kv[1], "self": self_cache}

    def decode_step(self, params, token, cache, pos, *, window_override: int | None = None):
        cfg = self.cfg
        x = embedding_apply(params["embed"], token[:, None]) * jnp.asarray(cfg.d_model**0.5, cfg.jdtype)

        def body(x, scanned):
            p, self_cache, ck, cv = scanned
            h = rmsnorm_apply(p["norm1"], x, cfg.norm_eps)
            a, self_cache = attn_mod.attn_decode(p["self_attn"], cfg, h, self_cache, pos,
                                                 window=window_override)
            x = x + a
            h = rmsnorm_apply(p["norm_x"], x, cfg.norm_eps)
            x = x + _cross_attend(p["cross"], cfg, h, ck, cv)
            h = rmsnorm_apply(p["norm2"], x, cfg.norm_eps)
            return x + mlp_apply(p["mlp"], h, cfg.activation), self_cache

        x, new_self = jax.lax.scan(
            body, x, (params["dec_layers"], cache["self"], cache["cross_k"], cache["cross_v"])
        )
        x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
        logits = unembed_apply(params["embed"], x[:, 0])
        return logits, {**cache, "self": new_self}

    def prefill(self, params, tokens, cache, *, window_override: int | None = None):
        """Bulk decoder prefill against the precomputed encoder memory: one
        full-sequence pass fills a fresh self-attention ring cache →
        (last-position logits, cache)."""
        cfg = self.cfg
        x = embedding_apply(params["embed"], tokens) * jnp.asarray(cfg.d_model**0.5, cfg.jdtype)

        def body(x, scanned):
            p, self_cache, ck, cv = scanned
            h = rmsnorm_apply(p["norm1"], x, cfg.norm_eps)
            a, self_cache = attn_mod.attn_prefill(p["self_attn"], cfg, h, self_cache,
                                                  window=window_override)
            x = x + a
            h = rmsnorm_apply(p["norm_x"], x, cfg.norm_eps)
            x = x + _cross_attend(p["cross"], cfg, h, ck, cv)
            h = rmsnorm_apply(p["norm2"], x, cfg.norm_eps)
            return x + mlp_apply(p["mlp"], h, cfg.activation), self_cache

        x, new_self = jax.lax.scan(
            body, x, (params["dec_layers"], cache["self"], cache["cross_k"], cache["cross_v"])
        )
        x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
        logits = unembed_apply(params["embed"], x[:, -1])
        return logits, {**cache, "self": new_self}
