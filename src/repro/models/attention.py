"""Attention variants: GQA/MQA (+qk_norm, sliding window, softcap) and MLA.

Two entry points per variant:
  * full-sequence causal (training / prefill) — optionally dispatching to the
    Pallas flash kernel on TPU (repro.kernels.flash_attention),
  * single-token decode against a fixed-capacity KV cache. The cache is a
    ring buffer of capacity C: full attention uses C = max_len, sliding-window
    attention uses C = window, which is what makes `long_500k` decode feasible
    for dense architectures.

MLA (multi-head latent attention, MiniCPM3/DeepSeek-V2) caches the compressed
latent (kv_lora_rank + rope_dim per token) instead of per-head K/V. The decode
path has a naive form (reconstruct K/V each step) and an *absorbed* form
(fold W_uk into the query and W_uv into the output projection) — the absorbed
form is a §Perf hillclimb in EXPERIMENTS.md.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import ModelConfig, apply_rope, dense_apply, dense_init, rmsnorm_apply, rmsnorm_init

NEG_INF = -1e30


def _maybe_softcap(scores: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap > 0:
        scores = jnp.tanh(scores / cap) * cap
    return scores


def _sdpa(q, k, v, mask, softcap: float) -> jnp.ndarray:
    """q: (B,S,KV,G,hd), k/v: (B,T,KV,hd), mask: (B,S,T) or (S,T)."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bskgh,btkh->bkgst", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    scores = _maybe_softcap(scores, softcap)
    if mask.ndim == 2:
        mask_b = mask[None, None, None]
    else:
        mask_b = mask[:, None, None]
    scores = jnp.where(mask_b, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v.astype(jnp.float32))
    return out.astype(v.dtype)


def chunked_sdpa(
    qg: jnp.ndarray,       # (B, S, KV, G, hd)
    k: jnp.ndarray,        # (B, T, KV, hd)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    qblock: int = 256,
    probs_bf16: bool = False,
) -> jnp.ndarray:
    """Memory-bounded attention: lax.scan over query blocks, full softmax per
    row against (a slice of) K. Never materializes the S×T score matrix —
    the XLA-native analogue of flash attention, required for the 4k/32k
    full-sequence shapes. With a sliding ``window``, each q-block only reads
    a (window + qblock) K/V slice → FLOPs drop from O(S·T) to O(S·window).
    """
    B, S, KV, G, hd = qg.shape
    T = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    if S % qblock != 0:
        qblock = math.gcd(S, qblock) or S
    nblk = S // qblock
    qb = qg.reshape(B, nblk, qblock, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)

    use_slice = window > 0 and causal
    span = min(T, window + qblock) if use_slice else T

    def body(_, inp):
        blk_idx, qblk = inp
        q0 = blk_idx * qblock
        if use_slice:
            start = jnp.clip(q0 + qblock - span, 0, T - span)
            ks = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
            kpos = start + jnp.arange(span)
        else:
            ks, vs = k, v
            kpos = jnp.arange(T)
        scores = jnp.einsum("bskgh,btkh->bkgst", qblk.astype(jnp.float32), ks.astype(jnp.float32)) * scale
        scores = _maybe_softcap(scores, softcap)
        qpos = q0 + jnp.arange(qblock)
        mask = jnp.ones((qblock, kpos.shape[0]), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window > 0:
            mask &= kpos[None, :] > qpos[:, None] - window
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        if probs_bf16:
            out = jnp.einsum("bkgst,btkh->bskgh", probs.astype(jnp.bfloat16), vs)
        else:
            out = jnp.einsum("bkgst,btkh->bskgh", probs, vs.astype(jnp.float32))
        return None, out.astype(v.dtype)

    # flash-style backward: recompute block scores/probs instead of saving
    # them as scan residuals (f32 (B,KV,G,qblk,T) per block would dominate HBM)
    body = jax.checkpoint(body)
    _, outs = jax.lax.scan(body, None, (jnp.arange(nblk), qb))
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, KV, G, hd)


# sequences longer than this use the chunked path in attn_full
CHUNKED_THRESHOLD = 1024


def causal_mask(seq: int, window: int = 0, offset: int = 0) -> jnp.ndarray:
    """(S, T) causal mask; optional sliding window; offset for prefix caches."""
    qpos = jnp.arange(seq)[:, None] + offset
    kpos = jnp.arange(seq + offset)[None, :]
    mask = kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    return mask


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def init_attention(rng, cfg: ModelConfig) -> dict:
    hd = cfg.head_dim_
    dt = cfg.jdtype
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(k1, cfg.d_model, cfg.n_heads * hd, dt),
        "wk": dense_init(k2, cfg.d_model, cfg.n_kv_heads * hd, dt),
        "wv": dense_init(k3, cfg.d_model, cfg.n_kv_heads * hd, dt),
        "wo": dense_init(k4, cfg.n_heads * hd, cfg.d_model, dt),
    }
    if cfg.lora_rank:
        # LoRA adapter on the q projection: B starts at zero so the adapter
        # is initially a no-op (standard LoRA init).
        ka = jax.random.fold_in(k1, 1)
        p["lora_a"] = dense_init(ka, cfg.d_model, cfg.lora_rank, dt)
        p["lora_b"] = {"w": jnp.zeros((cfg.lora_rank, cfg.n_heads * hd), dt)}
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dt)
        p["k_norm"] = rmsnorm_init(hd, dt)
    return p


def _project_qkv(p: dict, cfg: ModelConfig, x: jnp.ndarray, positions: jnp.ndarray):
    B, S, _ = x.shape
    hd = cfg.head_dim_
    q_flat = dense_apply(p["wq"], x)
    if "lora_a" in p:
        q_flat = q_flat + dense_apply(p["lora_b"], dense_apply(p["lora_a"], x))
    q = q_flat.reshape(B, S, cfg.n_heads, hd)
    k = dense_apply(p["wk"], x).reshape(B, S, cfg.n_kv_heads, hd)
    v = dense_apply(p["wv"], x).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm_apply(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm_apply(p["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _full_causal_out(cfg: ModelConfig, qg, k, v, *, window: int, use_flash: bool):
    """Full-sequence causal dispatch shared by attn_full / attn_prefill."""
    S = qg.shape[1]
    if use_flash and cfg.attn_logit_softcap == 0:
        from repro.kernels.flash_attention import ops as flash_ops

        return flash_ops.flash_attention(qg, k, v, window=window)
    if S > CHUNKED_THRESHOLD:
        return chunked_sdpa(qg, k, v, causal=True, window=window,
                            softcap=cfg.attn_logit_softcap, qblock=cfg.attn_qblock,
                            probs_bf16=cfg.attn_probs_bf16)
    return _sdpa(qg, k, v, causal_mask(S, window), cfg.attn_logit_softcap)


def attn_full(
    p: dict, cfg: ModelConfig, x: jnp.ndarray, *, window: int | None = None,
    use_flash: bool = False,
) -> jnp.ndarray:
    """Full-sequence causal attention (train / prefill)."""
    B, S, _ = x.shape
    window = cfg.sliding_window if window is None else window
    positions = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(p, cfg, x, positions)
    G = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, S, cfg.n_kv_heads, G, cfg.head_dim_)
    out = _full_causal_out(cfg, qg, k, v, window=window, use_flash=use_flash)
    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim_)
    return dense_apply(p["wo"], out)


def fill_ring(cache: dict, entries: dict, seq: int) -> dict:
    """Scatter a length-``seq`` prefix (positions 0..seq-1) into a ring cache.

    Only the last min(seq, capacity) positions survive — exactly the state a
    token-at-a-time decode loop would have left behind after wrapping.
    Restricting the scatter to those positions keeps slot indices unique, so
    the update never depends on duplicate-index ordering.
    """
    capacity = cache["slot_pos"].shape[0]
    keep = min(seq, capacity)
    pos = jnp.arange(seq - keep, seq, dtype=jnp.int32)
    slots = pos % capacity
    out = dict(cache)
    for name, val in entries.items():
        out[name] = cache[name].at[:, slots].set(val[:, seq - keep:].astype(cache[name].dtype))
    out["slot_pos"] = cache["slot_pos"].at[slots].set(pos)
    return out


def init_attn_cache(cfg: ModelConfig, batch: int, capacity: int, dtype=None) -> dict:
    """Ring-buffer KV cache. ``capacity`` = window for sliding attention,
    = max_len for full attention."""
    dt = dtype or cfg.jdtype
    hd = cfg.head_dim_
    return {
        "k": jnp.zeros((batch, capacity, cfg.n_kv_heads, hd), dt),
        "v": jnp.zeros((batch, capacity, cfg.n_kv_heads, hd), dt),
        "slot_pos": jnp.full((capacity,), -1, jnp.int32),  # global pos per slot
    }


def attn_decode(
    p: dict, cfg: ModelConfig, x: jnp.ndarray, cache: dict, pos: jnp.ndarray,
    *, window: int | None = None, use_kernel: bool = False,
) -> tuple[jnp.ndarray, dict]:
    """One-token decode. x: (B,1,D); pos: scalar global position."""
    B = x.shape[0]
    hd = cfg.head_dim_
    window = cfg.sliding_window if window is None else window
    positions = jnp.full((1, 1), pos, jnp.int32)
    q, k, v = _project_qkv(p, cfg, x, positions)
    C = cache["k"].shape[1]
    slot = pos % C
    cache = {
        "k": jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0)),
        "slot_pos": jax.lax.dynamic_update_slice(cache["slot_pos"], positions[0], (slot,)),
    }
    valid = cache["slot_pos"] >= 0
    valid &= cache["slot_pos"] <= pos
    if window and window > 0:
        valid &= cache["slot_pos"] > pos - window
    G = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, 1, cfg.n_kv_heads, G, hd)
    if use_kernel:
        from repro.kernels.decode_attention import ops as dec_ops

        out = dec_ops.decode_attention(qg, cache["k"], cache["v"], valid, softcap=cfg.attn_logit_softcap)
    else:
        out = _sdpa(qg, cache["k"], cache["v"], valid[None, None, :], cfg.attn_logit_softcap)
    out = out.reshape(B, 1, cfg.n_heads * hd)
    return dense_apply(p["wo"], out), cache


def attn_prefill(
    p: dict, cfg: ModelConfig, x: jnp.ndarray, cache: dict,
    *, window: int | None = None, use_flash: bool = False,
) -> tuple[jnp.ndarray, dict]:
    """``attn_full`` that also fills the decode ring cache — serving's bulk
    prefill. Equivalent to pushing the prompt through ``attn_decode`` one
    token at a time (same projections, same rope positions, same ring
    occupancy) at full-sequence matmul cost. ``cache`` must be fresh
    (``init_attn_cache``); positions start at 0."""
    B, S, _ = x.shape
    window = cfg.sliding_window if window is None else window
    positions = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(p, cfg, x, positions)
    G = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, S, cfg.n_kv_heads, G, cfg.head_dim_)
    out = _full_causal_out(cfg, qg, k, v, window=window, use_flash=use_flash)
    out = dense_apply(p["wo"], out.reshape(B, S, cfg.n_heads * cfg.head_dim_))
    return out, fill_ring(cache, {"k": k, "v": v}, S)


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention)
# ---------------------------------------------------------------------------


def init_mla(rng, cfg: ModelConfig) -> dict:
    dt = cfg.jdtype
    ks = jax.random.split(rng, 8)
    qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
    p = {
        # query path: down-project → norm → up-project to per-head (nope+rope)
        "wq_a": dense_init(ks[0], cfg.d_model, cfg.q_lora_rank, dt),
        "q_a_norm": rmsnorm_init(cfg.q_lora_rank, dt),
        "wq_b": dense_init(ks[1], cfg.q_lora_rank, cfg.n_heads * qk_dim, dt),
        # kv path: shared compressed latent + shared rope key
        "wkv_a": dense_init(ks[2], cfg.d_model, cfg.kv_lora_rank, dt),
        "kv_a_norm": rmsnorm_init(cfg.kv_lora_rank, dt),
        "wk_rope": dense_init(ks[3], cfg.d_model, cfg.qk_rope_dim, dt),
        "wk_b": dense_init(ks[4], cfg.kv_lora_rank, cfg.n_heads * cfg.qk_nope_dim, dt),
        "wv_b": dense_init(ks[5], cfg.kv_lora_rank, cfg.n_heads * cfg.v_head_dim, dt),
        "wo": dense_init(ks[6], cfg.n_heads * cfg.v_head_dim, cfg.d_model, dt),
    }
    return p


def _mla_q(p, cfg, x, positions):
    B, S, _ = x.shape
    qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
    q_lat = rmsnorm_apply(p["q_a_norm"], dense_apply(p["wq_a"], x), cfg.norm_eps)
    q = dense_apply(p["wq_b"], q_lat).reshape(B, S, cfg.n_heads, qk_dim)
    q_nope, q_rope = q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim :]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p, cfg, x, positions):
    c_kv = rmsnorm_apply(p["kv_a_norm"], dense_apply(p["wkv_a"], x), cfg.norm_eps)
    k_rope = apply_rope(dense_apply(p["wk_rope"], x)[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_full_absorbed(p: dict, cfg: ModelConfig, x: jnp.ndarray, *, window: int | None = None) -> jnp.ndarray:
    """Absorbed-matmul MLA for the FULL-SEQUENCE path (§Perf hillclimb H2).

    The naive path expands the latent cache into per-head K (H·qk_nope) and
    V (H·v_dim) for all S positions — H× the HBM traffic of the latent
    itself. Here W_uk folds into the query (per-head latent queries) and
    W_uv into the output: attention scores and context are computed directly
    against the (S, kv_rank) latent, which is read once per q-block instead
    of H-sized expansions. Trades score FLOPs (dim 64+32 → 256+32 per pair)
    for an H× cut in K/V bytes — the right trade for a memory-bound shape.
    """
    B, S, _ = x.shape
    window = cfg.sliding_window if window is None else window
    positions = jnp.arange(S)[None, :]
    q_nope, q_rope = _mla_q(p, cfg, x, positions)            # (B,S,H,dn), (B,S,H,dr)
    c_kv, k_rope = _mla_latent(p, cfg, x, positions)         # (B,S,R), (B,S,dr)
    H, R = cfg.n_heads, cfg.kv_lora_rank
    wk_b = p["wk_b"]["w"].reshape(R, H, cfg.qk_nope_dim)
    # fold W_uk into the query: per-head latent-space queries (B,S,H,R)
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, wk_b.astype(q_nope.dtype))
    # unified "key" = [latent ; rope] shared across heads (MQA, kv=1)
    q_full = jnp.concatenate([q_lat, q_rope], axis=-1)       # (B,S,H,R+dr)
    k_full = jnp.concatenate([c_kv, k_rope], axis=-1)        # (B,S,R+dr)
    # score scale must match the naive path: 1/sqrt(qk_nope+qk_rope), but
    # chunked_sdpa scales by 1/sqrt(R+dr) — pre-scale q to compensate.
    fix = math.sqrt(R + cfg.qk_rope_dim) / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    qg = (q_full * fix).reshape(B, S, 1, H, R + cfg.qk_rope_dim)
    # context in latent space: pad the latent "values" to key width
    v_lat = jnp.pad(c_kv, ((0, 0), (0, 0), (0, cfg.qk_rope_dim)))[:, :, None, :]
    kk = k_full[:, :, None, :]                                # (B,S,1,R+dr)
    if S > CHUNKED_THRESHOLD:
        ctx = chunked_sdpa(qg, kk, v_lat, causal=True, window=window or 0,
                           qblock=cfg.attn_qblock, probs_bf16=cfg.attn_probs_bf16)
    else:
        ctx = _sdpa(qg, kk, v_lat, causal_mask(S, window), 0.0)
    ctx_lat = ctx.reshape(B, S, H, R + cfg.qk_rope_dim)[..., :R]
    wv_b = p["wv_b"]["w"].reshape(R, H, cfg.v_head_dim)
    out = jnp.einsum("bshr,rhd->bshd", ctx_lat, wv_b.astype(ctx_lat.dtype))
    return dense_apply(p["wo"], out.reshape(B, S, H * cfg.v_head_dim))


def mla_full(p: dict, cfg: ModelConfig, x: jnp.ndarray, *, window: int | None = None) -> jnp.ndarray:
    if cfg.mla_absorb:
        return mla_full_absorbed(p, cfg, x, window=window)
    B, S, _ = x.shape
    window = cfg.sliding_window if window is None else window
    positions = jnp.arange(S)[None, :]
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    c_kv, k_rope = _mla_latent(p, cfg, x, positions)
    H = cfg.n_heads
    k_nope = dense_apply(p["wk_b"], c_kv).reshape(B, S, H, cfg.qk_nope_dim)
    v = dense_apply(p["wv_b"], c_kv).reshape(B, S, H, cfg.v_head_dim)
    # unify nope+rope into one head_dim so the shared chunked path applies:
    # k_rope is shared across heads (MQA-style) → broadcast.
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)                     # (B,S,H,dn+dr)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, cfg.qk_rope_dim))], axis=-1
    )
    # pad v up to qk head_dim so sdpa shapes line up, slice after
    qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk_dim - cfg.v_head_dim)))
    qg = q_full.reshape(B, S, H, 1, qk_dim)
    if S > CHUNKED_THRESHOLD:
        out = chunked_sdpa(qg, k_full, vp, causal=True, window=window or 0,
                           qblock=cfg.attn_qblock, probs_bf16=cfg.attn_probs_bf16)
    else:
        out = _sdpa(qg, k_full, vp, causal_mask(S, window), 0.0)
    out = out.reshape(B, S, H, qk_dim)[..., : cfg.v_head_dim]
    return dense_apply(p["wo"], out.reshape(B, S, H * cfg.v_head_dim))


def init_mla_cache(cfg: ModelConfig, batch: int, capacity: int, dtype=None) -> dict:
    dt = dtype or cfg.jdtype
    return {
        "c_kv": jnp.zeros((batch, capacity, cfg.kv_lora_rank), dt),
        "k_rope": jnp.zeros((batch, capacity, cfg.qk_rope_dim), dt),
        "slot_pos": jnp.full((capacity,), -1, jnp.int32),
    }


def mla_decode(
    p: dict, cfg: ModelConfig, x: jnp.ndarray, cache: dict, pos: jnp.ndarray,
    *, window: int | None = None, absorb: bool = False,
) -> tuple[jnp.ndarray, dict]:
    """One-token MLA decode.

    naive (absorb=False): reconstruct per-head K/V from all cached latents —
      cost O(C · kv_rank · H·hd) matmuls per step.
    absorbed (absorb=True): score directly in the latent space by folding
      W_uk into the query (q_lat = q_nope @ W_uk^T per head) and W_uv into the
      output — cost O(C · (kv_rank + rope)) per head, no K/V materialization.
    """
    B = x.shape[0]
    window = cfg.sliding_window if window is None else window
    positions = jnp.full((1, 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    c_kv_new, k_rope_new = _mla_latent(p, cfg, x, positions)
    C = cache["c_kv"].shape[1]
    slot = pos % C
    cache = {
        "c_kv": jax.lax.dynamic_update_slice(cache["c_kv"], c_kv_new, (0, slot, 0)),
        "k_rope": jax.lax.dynamic_update_slice(cache["k_rope"], k_rope_new, (0, slot, 0)),
        "slot_pos": jax.lax.dynamic_update_slice(cache["slot_pos"], positions[0], (slot,)),
    }
    valid = (cache["slot_pos"] >= 0) & (cache["slot_pos"] <= pos)
    if window and window > 0:
        valid &= cache["slot_pos"] > pos - window
    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    ckv = cache["c_kv"].astype(jnp.float32)        # (B,C,R)
    krope = cache["k_rope"].astype(jnp.float32)    # (B,C,r)
    H = cfg.n_heads

    if absorb:
        wk_b = p["wk_b"]["w"].astype(jnp.float32).reshape(cfg.kv_lora_rank, H, cfg.qk_nope_dim)
        # fold W_uk into the query: per-head latent query (B,1,H,R)
        q_lat = jnp.einsum("bshd,rhd->bshr", q_nope.astype(jnp.float32), wk_b)
        scores = jnp.einsum("bshr,bcr->bhsc", q_lat, ckv)
        scores += jnp.einsum("bshd,bcd->bhsc", q_rope.astype(jnp.float32), krope)
        scores = jnp.where(valid[None, None, None], scores * scale, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx_lat = jnp.einsum("bhsc,bcr->bshr", probs, ckv)  # latent-space context
        wv_b = p["wv_b"]["w"].astype(jnp.float32).reshape(cfg.kv_lora_rank, H, cfg.v_head_dim)
        out = jnp.einsum("bshr,rhd->bshd", ctx_lat, wv_b)
    else:
        k_nope = dense_apply(p["wk_b"], cache["c_kv"]).reshape(B, C, H, cfg.qk_nope_dim)
        v = dense_apply(p["wv_b"], cache["c_kv"]).reshape(B, C, H, cfg.v_head_dim)
        scores = jnp.einsum("bshd,bchd->bhsc", q_nope.astype(jnp.float32), k_nope.astype(jnp.float32))
        scores += jnp.einsum("bshd,bcd->bhsc", q_rope.astype(jnp.float32), krope)
        scores = jnp.where(valid[None, None, None], scores * scale, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhsc,bchd->bshd", probs, v.astype(jnp.float32))
    out = out.astype(x.dtype).reshape(B, 1, H * cfg.v_head_dim)
    return dense_apply(p["wo"], out), cache


def mla_prefill(
    p: dict, cfg: ModelConfig, x: jnp.ndarray, cache: dict,
    *, window: int | None = None,
) -> tuple[jnp.ndarray, dict]:
    """``mla_full`` that also fills the latent decode cache. The latent
    projection is recomputed for the ring fill — two thin matmuls
    (d_model → kv_rank / rope_dim), noise next to the attention itself."""
    out = mla_full(p, cfg, x, window=window)
    positions = jnp.arange(x.shape[1])[None, :]
    c_kv, k_rope = _mla_latent(p, cfg, x, positions)
    return out, fill_ring(cache, {"c_kv": c_kv, "k_rope": k_rope}, x.shape[1])
