"""Multi-host fleet launcher + chaos soak harness.

Generalizes ``run_multiprocess`` from "N processes, one parent" to
"N nodes × M hosts, **no parent required**". Everything the fleet needs to
coordinate — the declarative :class:`FleetSpec`, slot claims, heartbeats,
per-node results, per-worker reports — lives *in the shared folder itself*
as ``fleet/``-prefixed blobs (meta-dispatched like every other deposit, and
excluded from all federation state hashes), so the launcher mirrors the
serverless design exactly: there is no coordinator in the data path.

The moving parts:

* **FleetSpec** — nodes, rounds, strategy, transport pipeline spec, store URI
  (the existing ``cache+`` / ``shard<G>[x<L>]+`` grammar), runner kind and a
  seeded chaos schedule. ``repro.fleet init`` serializes it to the shared folder;
  from then on any host can join.

* **Workers** (``repro.fleet worker --store <uri>``) — each host reads the
  spec, *claims node slots* via atomic ``put_if_absent`` writes (link(2) on
  DiskFolder — atomic even on NFS), runs its claimed nodes in local OS
  processes under a :class:`ProcessSupervisor` (or threads, for in-process
  soaks at 10²-node scale), drives the chaos schedule against them, and
  deposits heartbeat + result blobs. A restarted worker (same ``worker_id``)
  reclaims its own slots.

* **Leases + crash adoption** — slot claims are not permanent: each claim is
  a lease blob (``fleet/lease/<node>/<epoch>``) whose deadline a background
  :class:`_LeaseKeeper` refreshes while its worker lives. A claim is valid
  only while its lease is fresh. When a *worker* dies (not just a node), its
  leases silently expire, and any surviving worker's adoption sweep
  re-claims the stranded slot via ``put_if_absent`` on the **next** lease
  epoch — CAS-by-key, so exactly one adopter wins by construction — then
  resumes the node from its own ``latest/`` blob. Updates pushed by adopted
  nodes carry their lease epoch in the wire meta, which FedAsync's epoch-gap
  discount uses to keep resurrected stragglers from yanking consensus.

* **Chaos engine** — extends ``kill_after`` into a *seeded, randomized
  schedule* derived deterministically from ``(seed, node_id)``: victims park
  mid-round after a drawn number of federation pushes, the worker SIGKILLs
  them the moment the parked heartbeat lands (backstop timer otherwise), then
  respawns them after ``restart_after`` — the reborn node must *resume*
  (counter, params, strategy state) from its own deposits. Stall events make
  drawn nodes sleep mid-soak (the slow-node/straggler case async federation
  must absorb). ``ChaosSpec.kill_workers`` escalates to *worker-level* chaos:
  victim workers drawn deterministically from ``(seed, worker_id)`` die whole
  (SIGKILL of the worker process and its node children under the process
  runner; an abort that strands every client mid-round under the thread
  runner), exercising the lease-expiry → adoption path end-to-end.

* **SoakReport** (``repro.fleet watch`` / ``report``, or any worker) —
  assembled purely from the folder: rounds completed per node, crashes
  injected / survived, restart recoveries (``resumed``), recovery latency,
  per-pipeline :class:`PipelineStats` rollups, wall-clock / bytes budgets.
  The soak *passes* only if every node finished its rounds, every
  killed-then-restarted node reports ``resumed=True``, and **every worker
  independently computed the same fleet-wide ``state_hash``** over the data
  plane after quiescence.
"""
from __future__ import annotations

import hashlib
import json
import os
import signal
import threading
import time
import uuid
from dataclasses import asdict, dataclass, field
from typing import Any, Callable

import numpy as np

from repro.logs import get_logger

from .node import AsyncFederatedNode
from .serialize import deserialize_fleet_blob, serialize_fleet_blob
from .simulation import ProcessSupervisor
from .store import SharedFolder, make_folder
from .strategies import STRATEGIES, get_strategy
from .telemetry import Telemetry, collect_obs, telemetry_rollups
from .transport import normalize_transport, parse_folder_uri

_log = get_logger("fleet")

FLEET_PREFIX = "fleet/"
SPEC_KEY = "fleet/spec"
_CLAIM_PREFIX = "fleet/claim/"  # legacy permanent claims (read-compat only)
_LEASE_PREFIX = "fleet/lease/"
_HEARTBEAT_PREFIX = "fleet/heartbeat/"
_RESULT_PREFIX = "fleet/result/"
_WORKER_PREFIX = "fleet/worker/"


# --------------------------------------------------------------------------
# Declarative specs
# --------------------------------------------------------------------------


@dataclass
class ChaosSpec:
    """Seeded chaos parameters; the concrete per-node schedule is derived
    deterministically by :func:`chaos_schedule` (same seed + node set →
    identical schedule on every host, with no host-to-host messages)."""

    seed: int = 0
    kills: int = 0                 # distinct SIGKILL-then-restart victims
    park_after: tuple = (2, 4)     # victim parks after U[a,b] federation pushes
    kill_grace: float = 30.0       # backstop SIGKILL this long after spawn
    restart_after: float = 0.5     # delay before the victim is respawned
    stalls: int = 0                # distinct slow-node stall victims
    stall_after: tuple = (1, 3)    # stall after U[a,b] pushes
    stall_duration: float = 1.0
    kill_workers: int = 0          # whole-WORKER kill victims (lease adoption)
    kill_workers_after: tuple = (1, 3)  # fire once a victim's node pushed U[a,b]

    def to_dict(self) -> dict:
        d = asdict(self)
        d["park_after"] = list(self.park_after)
        d["stall_after"] = list(self.stall_after)
        d["kill_workers_after"] = list(self.kill_workers_after)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ChaosSpec":
        d = dict(d)
        for key in ("park_after", "stall_after", "kill_workers_after"):
            if key in d:
                d[key] = tuple(int(v) for v in d[key])
        return cls(**d)


@dataclass
class FleetSpec:
    """One soak, declaratively: everything a joining host needs to run its
    share of the fleet. Serialized to the shared folder (``fleet/spec``) —
    the spec travels with the store, not with any process."""

    store_uri: str                 # data plane; cache+/shard<G>[x<L>]+ grammar
    name: str = "soak"
    num_nodes: int = 8
    rounds: int = 10               # federation pushes per node, across incarnations
    strategy: str = "fedavg"
    transport: str | None = None   # pipeline spec string (transport.py grammar)
    runner: str = "process"        # "process" (real SIGKILLs) | "thread" (in-process soaks)
    param_size: int = 256          # synthetic consensus model size (f32 entries)
    round_sleep: float = 0.05      # local "training" time per round
    settle: float = 1.0            # quiescence wait before the fleet hash
    result_timeout: float = 180.0  # how long a worker waits for ALL fleet results
    node_prefix: str = "node"
    lease_ttl: float = 15.0        # slot-lease freshness horizon (store clock domain)
    chaos: ChaosSpec = field(default_factory=ChaosSpec)

    def __post_init__(self) -> None:
        if isinstance(self.chaos, dict):
            self.chaos = ChaosSpec.from_dict(self.chaos)
        if self.num_nodes < 1:
            raise ValueError(f"num_nodes must be >= 1, got {self.num_nodes}")
        if self.rounds < 1:
            raise ValueError(f"rounds must be >= 1, got {self.rounds}")
        if self.runner not in ("process", "thread"):
            raise ValueError(f"runner must be 'process' or 'thread', got {self.runner!r}")
        if self.param_size < 1:
            raise ValueError(f"param_size must be >= 1, got {self.param_size}")
        if self.lease_ttl <= 0:
            raise ValueError(f"lease_ttl must be > 0, got {self.lease_ttl}")
        if self.chaos.kills < 0 or self.chaos.stalls < 0 or self.chaos.kill_workers < 0:
            raise ValueError(
                "chaos.kills / chaos.stalls / chaos.kill_workers must be >= 0")
        if self.chaos.kill_workers and self.rounds < 2:
            raise ValueError("worker-kill chaos needs rounds >= 2 (a victim's "
                             "node must push at least once before its worker "
                             "dies, so the adopter has a blob to resume from)")
        if self.chaos.kills + self.chaos.stalls > self.num_nodes:
            raise ValueError(
                f"chaos victims ({self.chaos.kills} kills + {self.chaos.stalls} "
                f"stalls) exceed num_nodes={self.num_nodes}")
        if self.chaos.kills and self.rounds < 2:
            raise ValueError("kill chaos needs rounds >= 2 (a victim must push "
                             "at least once before dying, and finish after)")
        # Fail fast on misspelled strategy/transport — at spec construction,
        # not inside every spawned client N processes later (same convention
        # as ShardedWeightStore's throwaway-pipeline probe). The grammar-only
        # normalize (no zstd import probe) keeps a spec WRITABLE from a host
        # without the module its workers have.
        if self.strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {self.strategy!r}; "
                             f"options: {sorted(STRATEGIES)}")
        if self.transport is not None:
            normalize_transport(self.transport)

    # -- store access --------------------------------------------------------
    def connect(self, **kwargs):
        """Open this spec's data-plane store through the ``repro.api`` facade
        (the spec's transport is the default; any ``connect()`` kwarg can
        override or extend it)."""
        from repro.api import connect  # late: repro.api imports this module

        kwargs.setdefault("transport", self.transport)
        return connect(self.store_uri, **kwargs)

    # -- node naming ---------------------------------------------------------
    def node_id(self, slot: int) -> str:
        return f"{self.node_prefix}{slot:04d}"

    def node_ids(self) -> list[str]:
        return [self.node_id(s) for s in range(self.num_nodes)]

    def target_of(self, slot: int) -> float:
        """Per-node consensus target for the synthetic quadratic clients —
        distinct but bounded, so the fleet's convex hull stays small."""
        return float(slot % 5)

    # -- wire ----------------------------------------------------------------
    def to_dict(self) -> dict:
        d = asdict(self)
        d["chaos"] = self.chaos.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "FleetSpec":
        d = dict(d)
        if "chaos" in d and isinstance(d["chaos"], dict):
            d["chaos"] = ChaosSpec.from_dict(d["chaos"])
        return cls(**d)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "FleetSpec":
        return cls.from_dict(json.loads(text))


# --------------------------------------------------------------------------
# Seeded chaos schedule
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ChaosEvent:
    node_id: str
    kind: str                  # "kill" | "stall"
    after_pushes: int          # trigger once the node has pushed this often
    restart_after: float = 0.0  # kill only: respawn delay
    duration: float = 0.0       # stall only: sleep length


def _node_rng(seed: int, node_id: str) -> np.random.Generator:
    """Per-node generator keyed on (seed, node_id) — the schedule is a pure
    function of the spec, independent of iteration order or host."""
    digest = hashlib.sha256(f"{seed}:{node_id}".encode()).digest()
    return np.random.default_rng(int.from_bytes(digest[:8], "big"))


def chaos_schedule(spec: FleetSpec) -> dict[str, list[ChaosEvent]]:
    """The concrete, deterministic chaos schedule for ``spec``: node id →
    events. Every host derives the same schedule from the spec alone, so the
    chaos engine needs no coordination either — each worker injects exactly
    the events of the nodes it claimed."""
    chaos = spec.chaos
    ids = spec.node_ids()
    rng = np.random.default_rng(chaos.seed)
    order = [ids[i] for i in rng.permutation(len(ids))]
    victims = order[:chaos.kills]
    stalled = order[chaos.kills:chaos.kills + chaos.stalls]
    out: dict[str, list[ChaosEvent]] = {}
    for nid in victims:
        r = _node_rng(chaos.seed, nid)
        lo, hi = chaos.park_after
        park = int(r.integers(min(lo, hi), max(lo, hi) + 1))
        # a victim must have pushed at least once (there must be a blob to
        # resume from) and must NOT have finished its rounds already
        park = max(1, min(park, spec.rounds - 1))
        out[nid] = [ChaosEvent(nid, "kill", park, restart_after=chaos.restart_after)]
    for nid in stalled:
        r = _node_rng(chaos.seed, nid)
        lo, hi = chaos.stall_after
        after = max(1, min(int(r.integers(min(lo, hi), max(lo, hi) + 1)), spec.rounds))
        out.setdefault(nid, []).append(
            ChaosEvent(nid, "stall", after, duration=chaos.stall_duration))
    return out


# --------------------------------------------------------------------------
# Control plane: spec + claims + heartbeats in the shared folder
# --------------------------------------------------------------------------


def fleet_control_uri(store_uri: str) -> str:
    """The control-plane folder URI for a data-plane store URI: the innermost
    base with every ``cache+`` / ``shard<G>[x<L>]+`` wrapper stripped. For a flat
    disk store, control and data share one folder (``fleet/`` keys are
    excluded from every state hash); for a sharded store the control blobs
    live in the base directory *above* the per-group folders."""
    _wrappers, base = parse_folder_uri(store_uri)
    if base.startswith("memory://"):
        raise ValueError(
            "the fleet control plane must be reachable by every host — "
            "use a shared mount (disk path) or s3://, not memory://")
    return base


def control_folder(store_uri: str) -> SharedFolder:
    return make_folder(fleet_control_uri(store_uri))


def write_spec(control: SharedFolder, spec: FleetSpec) -> None:
    control.put(SPEC_KEY, serialize_fleet_blob("spec", spec.to_dict()))


def read_spec(control: SharedFolder, *, timeout: float = 0.0,
              poll: float = 0.2) -> FleetSpec:
    """Read (polling up to ``timeout`` — a worker may come up before the
    launcher) the fleet spec from the control folder."""
    deadline = time.monotonic() + timeout
    while True:
        blob = control.get(SPEC_KEY)
        if blob is not None:
            kind, payload = deserialize_fleet_blob(blob)
            if kind == "spec":
                return FleetSpec.from_dict(payload)
        if time.monotonic() >= deadline:
            raise TimeoutError(f"no fleet spec at {SPEC_KEY!r} after {timeout}s")
        time.sleep(poll)


def claim_key(slot: int) -> str:
    return f"{_CLAIM_PREFIX}{slot:04d}"


# -- leased slot claims -------------------------------------------------------
#
# A slot claim is a *lease*: ``fleet/lease/<node>/<epoch>`` carries the owning
# worker, the lease epoch, and a deadline in the store's wall-clock domain
# that the owner's _LeaseKeeper refreshes while it lives. Epoch keys are
# write-once (put_if_absent / link(2)), so contention — the initial claim race
# at epoch 0, and every adoption race at epoch N+1 — is CAS-by-key with
# exactly one winner by construction. Epochs only move forward: a worker that
# observes an expired lease adopts the slot by winning the NEXT epoch's key,
# and the stale epoch keys are garbage-collected by the winner. Ownership of
# a slot is therefore: "holder of the freshest epoch key, while fresh".


def lease_key(node_id: str, epoch: int) -> str:
    return f"{_LEASE_PREFIX}{node_id}/{epoch:06d}"


def _parse_lease_key(key: str) -> tuple[str, int] | None:
    if not key.startswith(_LEASE_PREFIX):
        return None
    node_id, _, tail = key[len(_LEASE_PREFIX):].rpartition("/")
    if not node_id or not tail.isdigit():
        return None
    return node_id, int(tail)


def _lease_blob(spec: FleetSpec, worker_id: str, slot: int, epoch: int) -> bytes:
    now = time.time()
    return serialize_fleet_blob("lease", {
        "worker": worker_id, "slot": slot, "node_id": spec.node_id(slot),
        "epoch": epoch, "deadline": now + spec.lease_ttl, "time": now})


def lease_fresh(payload: dict, now: float | None = None) -> bool:
    """A lease is valid only while its heartbeat-refreshed deadline has not
    lapsed. Deadlines live in the store's wall-clock domain (every worker
    reads the same mount, so ``time.time()`` skew between hosts must stay
    well under ``lease_ttl`` — the same assumption NFS lock daemons make)."""
    return float(payload.get("deadline", 0.0)) >= (
        time.time() if now is None else now)


def read_lease_index(control: SharedFolder) -> dict[str, tuple[int, dict | None]]:
    """node id -> (freshest lease epoch, its payload — None if unreadable)."""
    freshest: dict[str, int] = {}
    for key in control.keys():
        parsed = _parse_lease_key(key)
        if parsed is None:
            continue
        nid, epoch = parsed
        if epoch > freshest.get(nid, -1):
            freshest[nid] = epoch
    return {nid: (epoch, _read_fleet_blob(control, lease_key(nid, epoch)))
            for nid, epoch in freshest.items()}


def _gc_stale_leases(control: SharedFolder, node_id: str, below_epoch: int) -> None:
    """Delete superseded lease epochs for ``node_id`` — except epoch 0, which
    is the permanent founding-roster record (worker-kill victim ranking and
    the report's ``workers_lost`` are both computed from epoch-0 payloads)."""
    for key in control.keys():
        parsed = _parse_lease_key(key)
        if parsed is not None and parsed[0] == node_id and 0 < parsed[1] < below_epoch:
            control.delete(key)


def try_adopt(control: SharedFolder, spec: FleetSpec, worker_id: str,
              node_id: str, slot: int, epoch: int) -> bool:
    """CAS-claim ``node_id`` at lease ``epoch`` (the expired lease's epoch
    + 1). Racing adopters all target the same write-once key, so exactly one
    wins; the winner GCs the superseded epoch keys. True iff we adopted."""
    won = control.put_if_absent(
        lease_key(node_id, epoch), _lease_blob(spec, worker_id, slot, epoch))
    if won:
        _gc_stale_leases(control, node_id, epoch)
        _log.info("worker %s: adopted %s at lease epoch %d", worker_id,
                  node_id, epoch)
    return won


def claim_leases(control: SharedFolder, spec: FleetSpec, worker_id: str, *,
                 max_slots: int | None = None) -> dict[int, int]:
    """Claim up to ``max_slots`` node slots for ``worker_id``; returns
    slot -> lease epoch claimed at. Unleased slots are claimed at epoch 0;
    a worker restarting under the same id re-validates its own fresh leases;
    expired leases (own or foreign) are adopted at the next epoch. Slots
    under a *fresh* foreign lease are never touched — concurrent workers
    partition the fleet with no messages between them."""
    mine: dict[int, int] = {}
    index = read_lease_index(control)
    for slot in range(spec.num_nodes):
        if max_slots is not None and len(mine) >= max_slots:
            break
        nid = spec.node_id(slot)
        have = index.get(nid)
        if have is None:
            if control.put_if_absent(
                    lease_key(nid, 0), _lease_blob(spec, worker_id, slot, 0)):
                mine[slot] = 0
                continue
            # lost the epoch-0 race; the winner's blob is visible now
            have = (0, _read_fleet_blob(control, lease_key(nid, 0)))
        epoch, payload = have
        if payload is None:
            continue
        now = time.time()
        if payload.get("worker") == worker_id and lease_fresh(payload, now):
            # ours (a previous incarnation under this id): refresh and keep.
            # Only the owner ever rewrites a live epoch key, so this plain
            # put races nobody.
            control.put(lease_key(nid, epoch),
                        _lease_blob(spec, worker_id, slot, epoch))
            mine[slot] = epoch
        elif not lease_fresh(payload, now):
            # expired — even if it was ours: adopt at the next epoch so a
            # concurrent adopter and we cannot both think we own it
            if try_adopt(control, spec, worker_id, nid, slot, epoch + 1):
                mine[slot] = epoch + 1
    return mine


def claim_slots(control: SharedFolder, spec: FleetSpec, worker_id: str, *,
                max_slots: int | None = None) -> list[int]:
    """Lease-based slot claim (see :func:`claim_leases`); returns the claimed
    slot numbers, sorted."""
    return sorted(claim_leases(control, spec, worker_id, max_slots=max_slots))


class _LeaseKeeper:
    """One per worker: refreshes every owned lease at ``lease_ttl / 3`` so
    ownership survives exactly as long as the worker does. Worker death —
    SIGKILL, OOM, power loss — needs no cleanup path: the keeper dies with
    the process, the leases lapse, and survivors adopt. ``stop()`` is for
    *simulated* death (thread-runner worker-kill chaos) and orderly exits."""

    def __init__(self, control: SharedFolder, spec: FleetSpec, worker_id: str):
        self._control = control
        self._spec = spec
        self._worker_id = worker_id
        self._owned: dict[str, tuple[int, int]] = {}  # node -> (slot, epoch)
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def add(self, node_id: str, slot: int, epoch: int) -> None:
        with self._lock:
            self._owned[node_id] = (slot, epoch)

    def drop(self, node_id: str) -> None:
        with self._lock:
            self._owned.pop(node_id, None)

    def owns(self, node_id: str) -> bool:
        with self._lock:
            return node_id in self._owned

    def owned(self) -> dict[str, tuple[int, int]]:
        with self._lock:
            return dict(self._owned)

    def epoch_of(self, node_id: str) -> int:
        with self._lock:
            entry = self._owned.get(node_id)
        return entry[1] if entry is not None else 0

    def refresh_now(self) -> None:
        for nid, (slot, epoch) in self.owned().items():
            try:
                self._control.put(
                    lease_key(nid, epoch),
                    _lease_blob(self._spec, self._worker_id, slot, epoch))
            except Exception:
                _log.debug("worker %s: lease refresh of %s failed",
                           self._worker_id, nid, exc_info=True)

    def start(self) -> None:
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._run, daemon=True,
                name=f"lease-keeper-{self._worker_id}")
            self._thread.start()

    def _run(self) -> None:
        interval = max(0.05, self._spec.lease_ttl / 3.0)
        while not self._stop.wait(interval):
            self.refresh_now()

    def stop(self) -> None:
        self._stop.set()
        t = self._thread
        if t is not None:
            t.join(timeout=2.0)


def _heartbeat(control: SharedFolder, node_id: str, payload: dict) -> None:
    control.put(f"{_HEARTBEAT_PREFIX}{node_id}", serialize_fleet_blob("heartbeat", payload))


def _read_fleet_blob(control: SharedFolder, key: str) -> dict | None:
    blob = control.get(key)
    if blob is None:
        return None
    try:
        _kind, payload = deserialize_fleet_blob(blob)
    except (ValueError, KeyError):
        return None
    return payload


# --------------------------------------------------------------------------
# Worker-level chaos: seeded whole-worker kills
# --------------------------------------------------------------------------


def founding_workers(control: SharedFolder) -> list[str]:
    """The workers holding epoch-0 leases — the roster worker-kill chaos
    draws its victims from. Epoch 0 never changes after the initial claims,
    so every host derives the same set (late joiners and adopters hold only
    higher epochs and are never victims)."""
    out: set[str] = set()
    for key in control.keys():
        parsed = _parse_lease_key(key)
        if parsed is not None and parsed[1] == 0:
            payload = _read_fleet_blob(control, key)
            if payload is not None and payload.get("worker") is not None:
                out.add(str(payload["worker"]))
    return sorted(out)


def worker_kill_victims(control: SharedFolder, chaos: ChaosSpec) -> list[str]:
    """The ``chaos.kill_workers`` victim worker ids, deterministically from
    ``(seed, worker_id)``: rank founding workers by a seeded hash, take the
    first N. Any host computes the same list from the store alone."""
    if chaos.kill_workers < 1:
        return []
    ranked = sorted(
        founding_workers(control),
        key=lambda w: hashlib.sha256(
            f"{chaos.seed}:workerkill:{w}".encode()).hexdigest())
    return ranked[:chaos.kill_workers]


class _KillSwitch:
    """Executes ``ChaosSpec.kill_workers`` against the worker it lives in.

    Waits until the whole fleet is claimed (the victim rank must be computed
    over the complete founding roster on every host), checks whether this
    worker is drawn, then fires once one of its nodes has pushed a seeded
    number of times — i.e. mid-soak, while other nodes are still mid-round,
    so slots are genuinely stranded.

    Firing in ``sigkill`` mode (the CLI worker — a real OS process) SIGKILLs
    the supervised node children and then the worker process itself: no
    cleanup, no lease release, exactly a host loss. ``simulate`` mode (for
    in-process workers sharing a test/benchmark process) stops the lease
    keeper, aborts the clients, and makes ``run_worker`` return without a
    results-wait or worker blob — the same observable store state as a real
    death, minus the signal.
    """

    def __init__(self, control: SharedFolder, spec: FleetSpec, worker_id: str,
                 slots: list[int], keeper: _LeaseKeeper, *,
                 mode: str = "simulate"):
        if mode not in ("simulate", "sigkill", "off"):
            raise ValueError(f"unknown worker-kill mode {mode!r}")
        self._control = control
        self._spec = spec
        self._worker_id = worker_id
        self._slots = list(slots)
        self._keeper = keeper
        self.mode = mode
        self.fired = False
        self.abort = threading.Event()  # thread-runner clients watch this
        self._halt = threading.Event()  # stops the watcher without aborting
        self._reaper: Callable[[], None] | None = None
        self._thread: threading.Thread | None = None

    def set_reaper(self, fn: Callable[[], None]) -> None:
        """Runner hook that SIGKILLs/aborts this worker's node children when
        the switch fires — a dead worker takes its children with it."""
        self._reaper = fn

    def start(self) -> None:
        if (self._spec.chaos.kill_workers < 1 or self.mode == "off"
                or not self._slots):
            return
        self._thread = threading.Thread(
            target=self._run, daemon=True,
            name=f"kill-switch-{self._worker_id}")
        self._thread.start()

    def _run(self) -> None:
        spec, chaos = self._spec, self._spec.chaos
        deadline = time.monotonic() + default_worker_timeout(spec)
        while not self._halt.is_set():
            if len(read_lease_index(self._control)) >= spec.num_nodes:
                break
            if time.monotonic() >= deadline:
                return  # fleet never fully claimed: worker-kill chaos forfeits
            time.sleep(0.05)
        if self._worker_id not in worker_kill_victims(self._control, chaos):
            return
        r = _node_rng(chaos.seed, f"worker:{self._worker_id}")
        lo, hi = chaos.kill_workers_after
        threshold = max(1, min(int(r.integers(min(lo, hi), max(lo, hi) + 1)),
                               spec.rounds - 1))
        nids = [spec.node_id(s) for s in self._slots]
        while not self._halt.is_set() and time.monotonic() < deadline:
            for nid in nids:
                hb = _read_fleet_blob(
                    self._control, f"{_HEARTBEAT_PREFIX}{nid}")
                if hb is not None and int(hb.get("pushes", 0)) >= threshold:
                    self.fire()
                    return
            time.sleep(0.05)

    def fire(self) -> None:
        _log.warning("worker %s: worker-kill chaos firing (%s mode)",
                     self._worker_id, self.mode)
        self.fired = True
        self._halt.set()
        self._keeper.stop()  # death means silence: leases must lapse
        self.abort.set()
        reaper = self._reaper
        if reaper is not None:
            try:
                reaper()
            except Exception:
                _log.debug("worker %s: reaper failed", self._worker_id,
                           exc_info=True)
        if self.mode == "sigkill":
            os.kill(os.getpid(), signal.SIGKILL)

    def stop(self) -> None:
        self._halt.set()
        t = self._thread
        if t is not None:
            t.join(timeout=2.0)


# --------------------------------------------------------------------------
# The soak client (module-level: spawn must pickle it)
# --------------------------------------------------------------------------


class _SimulatedCrash(RuntimeError):
    """Thread-runner stand-in for a SIGKILL: the client dies mid-round
    without depositing a result; the worker restarts it with resume."""


class _WorkerAborted(RuntimeError):
    """Thread-runner stand-in for whole-worker death: the client aborts
    mid-round with no result and is NOT restarted by its own worker — a
    surviving worker must adopt the stranded slot."""


def _soak_client(spec_dict: dict, slot: int, *, park_after_pushes: int | None = None,
                 stall_after: int | None = None, stall_duration: float = 0.0,
                 crash_mode: str = "sigkill", adopted_epoch: int = 0,
                 abort_event: "threading.Event | None" = None) -> dict:
    """One fleet node: quadratic consensus training federated through the
    spec's store. Pushes a heartbeat every federation step (via the node's
    ``on_step`` hook), deposits its result blob itself on completion — the
    worker never relays data — and, as a chaos victim, parks mid-round after
    ``park_after_pushes`` pushes so the SIGKILL lands deterministically.

    A nonzero ``adopted_epoch`` means this run is a surviving worker resuming
    a slot stranded by worker death: the node stamps the lease epoch into its
    wire updates (FedAsync's epoch-gap discount reads it back) and counts
    the adoption in telemetry. ``abort_event`` (thread runner only) is the
    worker-kill switch: when set, the client dies mid-round exactly as its
    host would."""
    spec = FleetSpec.from_dict(spec_dict)
    node_id = spec.node_id(slot)
    control = control_folder(spec.store_uri)
    data = make_folder(spec.store_uri)
    t0 = time.time()
    state: dict[str, Any] = {"first_push": None}
    # Every soak node runs instrumented: the node flushes an obs/ snapshot
    # each round (flush_every=1 — soak rounds are few and blobs tiny), which
    # is what SoakReport's telemetry rollups and `repro.obs` read back.
    tel = Telemetry(node_id, enabled=True, flush_every=1)
    adopted = adopted_epoch > 0
    if adopted:
        tel.count("node.adopted")
        tel.count("node.lease_epoch", adopted_epoch)

    def on_step(node, _aggregated) -> None:
        if state["first_push"] is None:
            state["first_push"] = time.time()
        # heartbeats are thin telemetry deposits: liveness plus the brief
        # rollup (round count, staleness, phase means)
        _heartbeat(control, node_id, {
            "node_id": node_id, "slot": slot, "counter": node.counter,
            "pushes": node.num_pushes, "status": "running",
            "resumed": node.resumed is not None, "time": time.time(),
            "adopted": adopted, "lease_epoch": adopted_epoch,
            "obs": tel.brief()})

    node = AsyncFederatedNode(
        strategy=get_strategy(spec.strategy), shared_folder=data,
        node_id=node_id, transport=spec.transport, on_step=on_step,
        telemetry=tel, lease_epoch=adopted_epoch)
    resumed = node.resumed is not None
    start_counter = node.counter
    if resumed:
        w = np.asarray(node.resumed.params["w"], np.float32).copy()
    else:
        w = np.zeros((spec.param_size,), np.float32)
    target = np.float32(spec.target_of(slot))

    while node.counter < spec.rounds:
        if abort_event is not None and abort_event.is_set():
            raise _WorkerAborted(node_id)  # the host died under us
        w = w + np.float32(0.3) * (target - w)  # local "training"
        aggregated = node.update_parameters({"w": w}, num_examples=1 + slot % 5)
        if aggregated is not None:
            w = np.asarray(aggregated["w"], np.float32)
        if park_after_pushes is not None and node.num_pushes >= park_after_pushes:
            _heartbeat(control, node_id, {
                "node_id": node_id, "slot": slot, "counter": node.counter,
                "pushes": node.num_pushes, "status": "parked",
                "resumed": resumed, "time": time.time()})
            if crash_mode == "raise":
                raise _SimulatedCrash(node_id)
            while True:  # mid-round: hold still until the SIGKILL lands
                time.sleep(0.05)
        if stall_after is not None and node.num_pushes == stall_after:
            time.sleep(stall_duration)  # the slow-node stall
        time.sleep(spec.round_sleep)

    result = {
        "node_id": node_id, "slot": slot, "resumed": resumed,
        "start_counter": start_counter, "final_counter": node.counter,
        "pushes": node.num_pushes, "aggregations": node.num_aggregations,
        "skipped_pulls": node.num_skipped_pulls,
        "wall_seconds": time.time() - t0,
        "first_push_unix": state["first_push"],
        "finished_unix": time.time(),
        "params_l2": float(np.linalg.norm(w)),
        "adopted": adopted, "lease_epoch": adopted_epoch,
        "transport_stats": dict(node.transport_stats()),
    }
    blob = serialize_fleet_blob("result", result)
    if adopted:
        # An adopter's deposit always stands: if the node's original driver is
        # still alive (its worker's lease lapsed spuriously — starvation, not
        # death — and we split-brained it), the churn ledger must still read
        # adopted=True for this stranded lease no matter which driver wrote.
        control.put(f"{_RESULT_PREFIX}{node_id}", blob)
    elif not control.put_if_absent(f"{_RESULT_PREFIX}{node_id}", blob):
        # Epoch-0 deposit racing an adopter that already wrote: never clobber
        # it — this driver lost its lease, the adopter owns the record.
        _log.info("%s: result already deposited by an adopter; keeping theirs",
                  node_id)
    _heartbeat(control, node_id, {
        "node_id": node_id, "slot": slot, "counter": node.counter,
        "pushes": node.num_pushes, "status": "done", "resumed": resumed,
        "adopted": adopted, "lease_epoch": adopted_epoch,
        "time": time.time()})
    return result


# --------------------------------------------------------------------------
# Workers
# --------------------------------------------------------------------------


@dataclass
class WorkerReport:
    worker_id: str
    slots: list[int]
    crashes_injected: int = 0
    restarts: int = 0
    fleet_state_hash: str | None = None
    all_results_seen: bool = False
    wall_seconds: float = 0.0
    recoveries: dict = field(default_factory=dict)  # node -> SIGKILL→first-push s
    results: dict = field(default_factory=dict)     # node -> result payload
    adoptions: dict = field(default_factory=dict)   # node -> lease-lapse→adopt s
    killed: bool = False                            # worker-kill chaos fired here


def default_worker_timeout(spec: FleetSpec) -> float:
    """Generous bound on one worker's run phase: startup + rounds + chaos."""
    per_round = spec.round_sleep + 1.0
    chaos = spec.chaos.kill_grace + spec.chaos.restart_after if spec.chaos.kills else 0.0
    churn = spec.lease_ttl * 4 if spec.chaos.kill_workers else 0.0
    return (120.0 + spec.rounds * per_round + chaos + churn
            + spec.chaos.stalls * spec.chaos.stall_duration)


def fleet_state_hash(spec_or_uri: "FleetSpec | str") -> str:
    """The fleet-wide data-plane state hash every worker must agree on after
    quiescence. Built over the spec's store URI, so flat and sharded fleets
    hash exactly what their nodes federate through (fleet/ and state/ control
    blobs excluded)."""
    uri = spec_or_uri.store_uri if isinstance(spec_or_uri, FleetSpec) else spec_or_uri
    from repro.api import connect  # late: repro.api imports this module

    return connect(uri).state_hash()


def wait_all_results(control: SharedFolder, spec: FleetSpec, *,
                     timeout: float, poll: float = 0.25) -> bool:
    """Block until every fleet node's result blob is present (global
    quiescence) or ``timeout`` passes; True on full coverage."""
    want = {f"{_RESULT_PREFIX}{nid}" for nid in spec.node_ids()}
    deadline = time.monotonic() + timeout
    while True:
        have = {k for k in control.keys() if k.startswith(_RESULT_PREFIX)}
        if want <= have:
            return True
        if time.monotonic() >= deadline:
            return False
        time.sleep(poll)


def run_worker(store_uri: str | None = None, *, spec: FleetSpec | None = None,
               worker_id: str | None = None, max_slots: int | None = None,
               timeout: float | None = None, spec_timeout: float = 60.0,
               control: SharedFolder | None = None,
               worker_kill_mode: str = "simulate") -> WorkerReport:
    """One host's whole contribution to the soak: read the spec, claim slot
    leases, run + chaos the claimed nodes (keeping the leases fresh and
    adopting any slots stranded by a dead worker), wait for fleet-wide
    quiescence, compute the fleet state hash independently, deposit the
    worker report. Run this once per host (``python -m repro.fleet worker``);
    no invocation is special — the fleet has no parent.

    ``worker_kill_mode`` controls how worker-kill chaos lands on a drawn
    victim: ``"sigkill"`` (the CLI — this worker is its own OS process)
    really SIGKILLs; ``"simulate"`` (in-process workers) aborts the clients
    and returns early without a report; ``"off"`` makes this worker immune."""
    if control is None:
        if store_uri is None:
            if spec is None:
                raise ValueError("need store_uri, spec, or control")
            store_uri = spec.store_uri
        control = control_folder(store_uri)
    if spec is None:
        spec = read_spec(control, timeout=spec_timeout)
    worker_id = worker_id or f"worker-{uuid.uuid4().hex[:8]}"
    if timeout is None:
        timeout = default_worker_timeout(spec)
    t0 = time.time()
    claims = claim_leases(control, spec, worker_id, max_slots=max_slots)
    slots = sorted(claims)
    _log.info("worker %s: claimed slots %s of fleet %r (%s runner)",
              worker_id, slots, spec.name, spec.runner)
    keeper = _LeaseKeeper(control, spec, worker_id)
    for slot, epoch in claims.items():
        keeper.add(spec.node_id(slot), slot, epoch)
    keeper.start()
    switch = _KillSwitch(control, spec, worker_id, slots, keeper,
                         mode=worker_kill_mode)
    switch.start()
    try:
        schedule = chaos_schedule(spec)
        runner = (_run_slots_threaded if spec.runner == "thread"
                  else _run_slots_processes)
        report = runner(control, spec, worker_id, claims, schedule, timeout,
                        keeper=keeper, switch=switch)
    finally:
        switch.stop()
    if switch.fired:
        # This worker is "dead": no results wait, no hash, no worker blob —
        # its silence (and lapsing leases) IS the signal survivors act on.
        report.killed = True
        report.wall_seconds = time.time() - t0
        return report
    # Global quiescence, then the fleet-wide hash every worker must agree on.
    report.all_results_seen = wait_all_results(control, spec, timeout=spec.result_timeout)
    if not report.all_results_seen:
        _log.warning("worker %s: quiescence timeout — not every node deposited "
                     "a result within %.0fs", worker_id, spec.result_timeout)
    time.sleep(spec.settle)
    report.fleet_state_hash = fleet_state_hash(spec)
    report.wall_seconds = time.time() - t0
    keeper.stop()
    control.put(f"{_WORKER_PREFIX}{worker_id}", serialize_fleet_blob("worker", {
        "worker": worker_id, "slots": list(report.slots),
        "crashes_injected": report.crashes_injected,
        "restarts": report.restarts,
        "fleet_state_hash": report.fleet_state_hash,
        "all_results_seen": report.all_results_seen,
        "wall_seconds": report.wall_seconds,
        "recoveries": dict(report.recoveries),
        "adoptions": dict(report.adoptions),
        "time": time.time()}))
    return report


def _chaos_kwargs(events: list[ChaosEvent]) -> dict:
    kwargs: dict[str, Any] = {}
    for ev in events:
        if ev.kind == "kill":
            kwargs["park_after_pushes"] = ev.after_pushes
        elif ev.kind == "stall":
            kwargs["stall_after"] = ev.after_pushes
            kwargs["stall_duration"] = ev.duration
    return kwargs


def _stray_leases(control: SharedFolder, spec: FleetSpec,
                  keeper: _LeaseKeeper) -> list[tuple[str, int, int, float]]:
    """Slots stranded by a dead worker, as seen from this worker: the lease
    is not ours, not fresh, and the node has no result blob yet. Returns
    ``(node_id, slot, lapsed_epoch, lapsed_deadline)`` per stray."""
    out: list[tuple[str, int, int, float]] = []
    for nid, (epoch, payload) in read_lease_index(control).items():
        if payload is None or keeper.owns(nid) or lease_fresh(payload):
            continue
        if control.get(f"{_RESULT_PREFIX}{nid}") is not None:
            continue  # finished before its worker died: nothing to adopt
        try:
            slot = int(payload["slot"])
        except (KeyError, TypeError, ValueError):
            continue
        if not 0 <= slot < spec.num_nodes or spec.node_id(slot) != nid:
            continue
        out.append((nid, slot, epoch, float(payload.get("deadline", 0.0))))
    return out


def _run_slots_processes(control: SharedFolder, spec: FleetSpec, worker_id: str,
                         claims: dict[int, int],
                         schedule: dict[str, list[ChaosEvent]],
                         timeout: float, *, keeper: _LeaseKeeper,
                         switch: _KillSwitch) -> WorkerReport:
    """Run the claimed slots as real OS processes under a ProcessSupervisor,
    injecting this worker's share of the chaos schedule: SIGKILL a victim the
    moment its parked heartbeat lands (backstop timer otherwise), respawn it
    after the scheduled delay — the respawn must resume, not restart. Between
    polls the worker sweeps for leases stranded by a *dead worker* and adopts
    them: CAS the next lease epoch, then spawn the node here with resume."""
    slots = sorted(claims)
    report = WorkerReport(worker_id, list(slots))
    sup = ProcessSupervisor()
    spec_dict = spec.to_dict()
    slot_of = {spec.node_id(s): s for s in slots}
    # A dead worker takes its children with it: when the kill switch fires it
    # SIGKILLs every supervised node first, then this process. Otherwise the
    # orphaned children would finish and deposit results, and the stranded
    # slots the survivors must adopt would never exist.
    switch.set_reaper(lambda: [_safe_kill(sup, n) for n in list(slot_of)])
    kill_events: dict[str, ChaosEvent] = {}
    killed_at: dict[str, float] = {}
    adopt_at: dict[str, float] = {}
    restart_due: dict[str, float] = {}
    adopt_every = max(0.5, spec.lease_ttl / 2)
    next_adopt_scan = time.monotonic() + adopt_every
    results_deadline: float | None = None
    want_results = spec.chaos.kill_workers > 0
    try:
        for slot in slots:
            nid = spec.node_id(slot)
            events = schedule.get(nid, [])
            sup.spawn(nid, _soak_client, (spec_dict, slot), _chaos_kwargs(events))
            kill = next((e for e in events if e.kind == "kill"), None)
            if kill is not None:
                kill_events[nid] = kill
                # backstop: if the parked heartbeat never shows (crashed some
                # other way, wedged before parking), SIGKILL anyway
                sup.schedule_kill(nid, spec.chaos.kill_grace)
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if switch.fired:
                return report  # this worker is dead; the reaper ran already
            for nid in list(kill_events):
                if control.get(f"{_RESULT_PREFIX}{nid}") is not None:
                    # Clean finish before the chaos landed (e.g. resumed past
                    # its rounds): disarm the backstop — a spurious SIGKILL
                    # after the result blob would count a crash that never
                    # happened and restart a node that already finished.
                    kill_events.pop(nid)
                    sup.cancel_scheduled_kills(nid)
                    _log.info("worker %s: %s finished before chaos; backstop "
                              "disarmed", worker_id, nid)
                    continue
                hb = _read_fleet_blob(control, f"{_HEARTBEAT_PREFIX}{nid}")
                if hb is not None and hb.get("status") == "parked":
                    sup.kill(nid)  # mid-round, deterministically
            for nid in sup.poll():
                kill = kill_events.pop(nid, None)
                if kill is None:
                    continue
                if control.get(f"{_RESULT_PREFIX}{nid}") is not None:
                    # Settled *cleanly* between the last scan and the backstop
                    # firing — that's a finish, not a crash.
                    sup.cancel_scheduled_kills(nid)
                    _log.info("worker %s: %s settled cleanly; not a crash",
                              worker_id, nid)
                    continue
                _log.info("worker %s: chaos SIGKILL landed on %s",
                          worker_id, nid)
                killed_at[nid] = time.time()
                report.crashes_injected += 1
                restart_due[nid] = time.monotonic() + kill.restart_after
            now = time.monotonic()
            for nid, due in list(restart_due.items()):
                if now >= due:
                    del restart_due[nid]
                    # restart WITHOUT the park: the reborn node must resume
                    # from its own deposits and run to completion
                    _log.info("worker %s: restarting %s (must resume)",
                              worker_id, nid)
                    sup.spawn(nid, _soak_client, (spec_dict, slot_of[nid]), {})
                    report.restarts += 1
            if spec.chaos.kill_workers and now >= next_adopt_scan:
                next_adopt_scan = now + adopt_every
                for nid, slot, epoch, lapsed in _stray_leases(control, spec, keeper):
                    if not try_adopt(control, spec, worker_id, nid, slot,
                                     epoch + 1):
                        continue  # another survivor won the CAS
                    keeper.add(nid, slot, epoch + 1)
                    slot_of[nid] = slot
                    report.adoptions[nid] = max(0.0, time.time() - lapsed)
                    adopt_at[nid] = time.time()
                    sup.spawn(nid, _soak_client, (spec_dict, slot),
                              {"adopted_epoch": epoch + 1})
            own_done = not sup.unsettled() and not restart_due
            if own_done:
                if not want_results:
                    break
                # Churn soaks linger briefly after their own slots finish so
                # a lease stranded by a late worker death still gets adopted.
                if results_deadline is None:
                    results_deadline = time.monotonic() + spec.result_timeout
                have = {k[len(_RESULT_PREFIX):] for k in control.keys()
                        if k.startswith(_RESULT_PREFIX)}
                if set(spec.node_ids()) <= have or time.monotonic() >= results_deadline:
                    break
            else:
                results_deadline = None
            time.sleep(0.05)
        sup.join(max(0.0, deadline - time.monotonic()))
    finally:
        sup.shutdown()
    for nid in slot_of:
        res = sup.result(nid)
        if res is not None and res.error is None and isinstance(res.result, dict):
            report.results[nid] = res.result
    for nid, t_evt in {**killed_at, **adopt_at}.items():
        first_push = (report.results.get(nid) or {}).get("first_push_unix")
        if first_push:
            report.recoveries[nid] = max(0.0, first_push - t_evt)
    return report


def _safe_kill(sup: ProcessSupervisor, name: str) -> None:
    try:
        sup.kill(name)
    except Exception:
        pass  # the reaper runs during worker death; best-effort only


def _run_slots_threaded(control: SharedFolder, spec: FleetSpec, worker_id: str,
                        claims: dict[int, int],
                        schedule: dict[str, list[ChaosEvent]],
                        timeout: float, *, keeper: _LeaseKeeper,
                        switch: _KillSwitch) -> WorkerReport:
    """Thread runner for in-process soaks (the 10²-node benchmark regime,
    where an OS process per node would be interpreter-startup-bound). Chaos
    kills become mid-round exceptions that abort the client without a result
    deposit — same observable contract as a SIGKILL minus the signal — and
    the restarted client must resume exactly as in process mode. Worker-kill
    chaos becomes the switch's abort event: every client of a drawn worker
    raises mid-round and is NOT restarted here, stranding its lease for a
    surviving worker's adoption sweep."""
    slots = sorted(claims)
    report = WorkerReport(worker_id, list(slots))
    spec_dict = spec.to_dict()
    lock = threading.Lock()
    killed_at: dict[str, float] = {}
    adopt_at: dict[str, float] = {}
    threads: list[threading.Thread] = []

    def drive(slot: int, adopted_epoch: int = 0) -> None:
        nid = spec.node_id(slot)
        # Adopted slots run clean: their chaos events belonged to the dead
        # worker's incarnation, and re-parking a resumed node would deadlock.
        events = [] if adopted_epoch else schedule.get(nid, [])
        kwargs = _chaos_kwargs(events)
        kill = next((e for e in events if e.kind == "kill"), None)
        while True:
            try:
                result = _soak_client(spec_dict, slot, crash_mode="raise",
                                      adopted_epoch=adopted_epoch,
                                      abort_event=switch.abort, **kwargs)
            except _WorkerAborted:
                return  # worker death: no result, no restart — strand it
            except _SimulatedCrash:
                _log.info("worker %s: simulated crash of %s; restarting",
                          worker_id, nid)
                with lock:
                    report.crashes_injected += 1
                    killed_at[nid] = time.time()
                time.sleep(kill.restart_after if kill is not None else 0.0)
                kwargs = {}  # the restart runs clean — and must resume
                with lock:
                    report.restarts += 1
                continue
            with lock:
                report.results[nid] = result
            return

    def start_driver(slot: int, adopted_epoch: int = 0) -> None:
        t = threading.Thread(target=drive, args=(slot, adopted_epoch),
                             daemon=True, name=f"fleet-{spec.node_id(slot)}")
        threads.append(t)
        t.start()

    for slot in slots:
        start_driver(slot)
    deadline = time.monotonic() + timeout
    adopt_every = max(0.5, spec.lease_ttl / 2)
    next_adopt_scan = time.monotonic() + adopt_every
    results_deadline: float | None = None
    want_results = spec.chaos.kill_workers > 0
    while time.monotonic() < deadline:
        if switch.fired:
            return report  # dead worker: leave the drivers to abort
        now = time.monotonic()
        if spec.chaos.kill_workers and now >= next_adopt_scan:
            next_adopt_scan = now + adopt_every
            for nid, slot, epoch, lapsed in _stray_leases(control, spec, keeper):
                if not try_adopt(control, spec, worker_id, nid, slot, epoch + 1):
                    continue  # another survivor won the CAS
                keeper.add(nid, slot, epoch + 1)
                with lock:
                    report.adoptions[nid] = max(0.0, time.time() - lapsed)
                adopt_at[nid] = time.time()
                start_driver(slot, adopted_epoch=epoch + 1)
        own_done = all(not t.is_alive() for t in threads)
        if own_done:
            if not want_results:
                break
            # Churn soaks linger after their own slots finish so a lease
            # stranded by a late worker death still gets adopted here.
            if results_deadline is None:
                results_deadline = time.monotonic() + spec.result_timeout
            have = {k[len(_RESULT_PREFIX):] for k in control.keys()
                    if k.startswith(_RESULT_PREFIX)}
            if set(spec.node_ids()) <= have or time.monotonic() >= results_deadline:
                break
        else:
            results_deadline = None
        time.sleep(0.05)
    for t in threads:
        t.join(timeout=0.5)
    # Recoveries are derived AFTER the joins, only for drivers that delivered
    # a result — a straggler thread past the deadline can at worst add a
    # killed_at entry nobody reads, never a half-built latency.
    with lock:
        for nid, t_evt in {**killed_at, **adopt_at}.items():
            first_push = (report.results.get(nid) or {}).get("first_push_unix")
            if first_push:
                report.recoveries[nid] = max(0.0, first_push - t_evt)
    return report


# --------------------------------------------------------------------------
# Fleet-wide report (watch / any worker)
# --------------------------------------------------------------------------


@dataclass
class SoakReport:
    """Everything the soak acceptance needs, assembled purely from the shared
    folder — by ``repro.fleet watch``, by any worker, by anything that can
    read the mount."""

    name: str
    num_nodes: int
    rounds: int
    claims: dict            # slot -> worker id
    results: dict           # node -> result payload
    workers: dict           # worker id -> worker payload
    victims: list           # scheduled SIGKILL victims (from the seeded schedule)
    stalled: list           # scheduled slow nodes
    resumed: dict           # node -> bool
    rounds_completed: dict  # node -> final counter
    crashes_injected: int
    restarts: int
    recovery_latency: dict  # node -> seconds (SIGKILL → restarted node's first push)
    stranded: list          # nodes whose lease epoch advanced (worker died under them)
    adopted: dict           # node -> bool (result deposited by an adopter)
    adoption_latency: dict  # node -> seconds (lease lapse → adoption CAS win)
    workers_lost: list      # founding workers that never deposited a report
    fleet_hashes: dict      # worker -> fleet state hash
    pipeline_stats: dict    # summed PipelineStats counters across all nodes
    telemetry: dict         # obs/ rollups: per-node staleness + phase latency
    total_pushes: int
    wall_seconds: float
    rounds_per_sec: float
    complete: bool          # every node deposited a result
    converged: bool         # complete AND all workers computed one hash
    recovered: bool         # every scheduled victim resumed
    passed: bool

    def to_dict(self) -> dict:
        return asdict(self)

    def summary(self) -> str:
        hashes = sorted(set(self.fleet_hashes.values()))
        lines = [
            f"fleet {self.name!r}: {len(self.results)}/{self.num_nodes} nodes "
            f"reported, {len(self.workers)} workers",
            f"  rounds/node: {self.rounds}  total pushes: {self.total_pushes}  "
            f"rounds/sec: {self.rounds_per_sec:.2f}",
            f"  crashes injected: {self.crashes_injected}  restarts: {self.restarts}  "
            f"victims resumed: {sum(bool(self.resumed.get(v)) for v in self.victims)}"
            f"/{len(self.victims)}",
            f"  fleet state hash: {hashes if len(hashes) != 1 else hashes[0]} "
            f"({'converged' if self.converged else 'NOT converged'})",
            self._telemetry_line(),
            f"  passed: {self.passed}",
        ]
        if self.recovery_latency:
            mean = sum(self.recovery_latency.values()) / len(self.recovery_latency)
            lines.insert(3, f"  recovery latency: mean {mean:.2f}s over "
                            f"{len(self.recovery_latency)} restarts")
        if self.stranded or self.workers_lost:
            n_adopted = sum(bool(self.adopted.get(n)) for n in self.stranded)
            churn = (f"  churn: workers lost {len(self.workers_lost)} "
                     f"({', '.join(self.workers_lost) or 'none'})  "
                     f"stranded nodes adopted {n_adopted}/{len(self.stranded)}")
            if self.adoption_latency:
                mean = (sum(self.adoption_latency.values())
                        / len(self.adoption_latency))
                churn += f"  adoption latency mean {mean:.2f}s"
            lines.insert(-2, churn)
        return "\n".join(lines)

    def _telemetry_line(self) -> str:
        fleet = (self.telemetry or {}).get("fleet") or {}
        if not fleet.get("nodes_reporting"):
            return "  telemetry: no obs/ blobs found"
        phases = fleet.get("phase_ms") or {}
        phase_txt = " ".join(
            f"{name} {phases[name]:.2f}ms"
            for name in ("pull", "push", "aggregate") if name in phases)
        return (
            f"  telemetry: {fleet['nodes_reporting']}/{self.num_nodes} nodes, "
            f"staleness mean {fleet.get('staleness_mean', 0.0):.2f} "
            f"p90 {fleet.get('staleness_p90_max', 0.0):.2f}, "
            f"phase means {phase_txt or 'n/a'}")


def assemble_report(control: SharedFolder, spec: FleetSpec | None = None) -> SoakReport:
    """Fold every ``fleet/`` blob in the control folder into one SoakReport.
    Read-only — safe to run concurrently with the fleet, from any host."""
    if spec is None:
        spec = read_spec(control)
    results: dict[str, dict] = {}
    workers: dict[str, dict] = {}
    claims: dict[int, str] = {}
    leases: dict[str, tuple[int, dict]] = {}  # node -> (freshest epoch, payload)
    founding: set[str] = set()
    for key in control.keys():
        if not key.startswith(FLEET_PREFIX) or key == SPEC_KEY:
            continue
        payload = _read_fleet_blob(control, key)
        if payload is None:
            continue
        if key.startswith(_RESULT_PREFIX):
            results[str(payload.get("node_id"))] = payload
        elif key.startswith(_WORKER_PREFIX):
            workers[str(payload.get("worker"))] = payload
        elif key.startswith(_CLAIM_PREFIX):
            claims[int(payload.get("slot", -1))] = str(payload.get("worker"))
        elif key.startswith(_LEASE_PREFIX):
            parsed = _parse_lease_key(key)
            if parsed is None:
                continue
            nid, epoch = parsed
            if epoch == 0 and payload.get("worker") is not None:
                founding.add(str(payload["worker"]))
            if nid not in leases or epoch > leases[nid][0]:
                leases[nid] = (epoch, payload)
    # Leases are the live claim ledger; a legacy permanent claim blob only
    # stands where no lease was ever written for its slot.
    for nid, (_epoch, payload) in leases.items():
        try:
            claims[int(payload["slot"])] = str(payload.get("worker"))
        except (KeyError, TypeError, ValueError):
            pass
    schedule = chaos_schedule(spec)
    victims = sorted(n for n, evs in schedule.items()
                     if any(e.kind == "kill" for e in evs))
    stalled = sorted(n for n, evs in schedule.items()
                     if any(e.kind == "stall" for e in evs))
    resumed = {n: bool(r.get("resumed")) for n, r in results.items()}
    rounds_completed = {n: int(r.get("final_counter", 0)) for n, r in results.items()}
    crashes = sum(int(w.get("crashes_injected", 0)) for w in workers.values())
    restarts = sum(int(w.get("restarts", 0)) for w in workers.values())
    recovery: dict[str, float] = {}
    for w in workers.values():
        for nid, latency in (w.get("recoveries") or {}).items():
            recovery[str(nid)] = float(latency)
    # Churn ledger: a lease epoch above 0 means the founding worker died under
    # that node and someone CAS-won the next epoch — the node was stranded.
    stranded = sorted(n for n, (epoch, _p) in leases.items() if epoch > 0)
    adopted = {n: bool(results[n].get("adopted")) for n in stranded
               if n in results}
    adoption_latency: dict[str, float] = {}
    for w in workers.values():
        for nid, latency in (w.get("adoptions") or {}).items():
            adoption_latency[str(nid)] = float(latency)
    workers_lost = sorted(founding - set(workers))
    hashes = {wid: str(w["fleet_state_hash"]) for wid, w in workers.items()
              if w.get("fleet_state_hash")}
    stats: dict[str, float] = {}
    for r in results.values():
        for k, v in (r.get("transport_stats") or {}).items():
            if isinstance(v, (int, float)):
                stats[k] = stats.get(k, 0) + v
    # Telemetry rollups come from the DATA plane's obs/ blobs alone — the
    # per-node staleness/latency picture survives even when a node died
    # before depositing its fleet/ result.
    try:
        telemetry = telemetry_rollups(collect_obs(spec.store_uri))
    except Exception:
        _log.debug("telemetry rollup failed for %s", spec.store_uri,
                   exc_info=True)
        telemetry = {"nodes": {}, "fleet": {"nodes_reporting": 0}}
    total_pushes = sum(int(r.get("pushes", 0)) for r in results.values())
    wall = max([float(w.get("wall_seconds", 0.0)) for w in workers.values()]
               + [float(r.get("wall_seconds", 0.0)) for r in results.values()]
               + [0.0])
    # Throughput over the *active* federation span (first push → last finish),
    # not the worker wall, which also counts quiescence waits and settle time.
    starts = [r.get("first_push_unix") for r in results.values() if r.get("first_push_unix")]
    ends = [r.get("finished_unix") for r in results.values() if r.get("finished_unix")]
    active = (max(ends) - min(starts)) if starts and ends else 0.0
    complete = set(results) >= set(spec.node_ids())
    converged = complete and len(hashes) >= 1 and len(set(hashes.values())) == 1
    recovered = all(resumed.get(v, False) for v in victims)
    # A node-kill victim orphaned by its worker's death may never eat its
    # scheduled SIGKILL — only victims that were NOT stranded owe a crash.
    crash_ok = crashes >= len([v for v in victims if v not in set(stranded)])
    adopted_ok = all(adopted.get(n, False) for n in stranded)
    churn_ok = spec.chaos.kill_workers < 1 or len(workers_lost) >= 1
    passed = (
        complete and converged and recovered and adopted_ok and churn_ok
        and crash_ok
        and all(rounds_completed.get(n, 0) >= spec.rounds for n in spec.node_ids())
    )
    return SoakReport(
        name=spec.name, num_nodes=spec.num_nodes, rounds=spec.rounds,
        claims=claims, results=results, workers=workers,
        victims=victims, stalled=stalled, resumed=resumed,
        rounds_completed=rounds_completed, crashes_injected=crashes,
        restarts=restarts, recovery_latency=recovery,
        stranded=stranded, adopted=adopted,
        adoption_latency=adoption_latency, workers_lost=workers_lost,
        fleet_hashes=hashes,
        pipeline_stats=stats, telemetry=telemetry, total_pushes=total_pushes,
        wall_seconds=wall,
        rounds_per_sec=(total_pushes / active) if active > 0 else 0.0,
        complete=complete, converged=converged, recovered=recovered,
        passed=passed)


def watch(store_uri: str, *, interval: float = 2.0, timeout: float = 600.0,
          printer: Callable[[str], None] = print) -> SoakReport:
    """Poll the control folder until the soak completes (every node reported
    AND every claiming worker deposited its fleet hash) or ``timeout``
    passes; prints one progress line per poll. Pure reader — running it adds
    nothing to the data path."""
    control = control_folder(store_uri)
    spec = read_spec(control, timeout=timeout)
    deadline = time.monotonic() + timeout
    while True:
        report = assemble_report(control, spec)
        expected_workers = set(report.claims.values())
        printer(
            f"[fleet {spec.name}] nodes {len(report.results)}/{spec.num_nodes} "
            f"workers {len(report.fleet_hashes)}/{max(1, len(expected_workers))} "
            f"crashes {report.crashes_injected} restarts {report.restarts}")
        done = report.complete and expected_workers and (
            expected_workers <= set(report.fleet_hashes))
        if done or time.monotonic() >= deadline:
            return report
        time.sleep(interval)


def run_fleet_local(spec: FleetSpec, num_workers: int = 2, *,
                    timeout: float | None = None,
                    worker_prefix: str = "local") -> SoakReport:
    """Single-host convenience (and the benchmark harness): write the spec,
    run ``num_workers`` worker loops concurrently in this process — each
    claiming its share of slots exactly as separate hosts would — and
    assemble the fleet report. The multi-host flow is the same thing with
    ``repro.fleet worker`` once per machine."""
    if num_workers < 1:
        raise ValueError(f"num_workers must be >= 1, got {num_workers}")
    control = control_folder(spec.store_uri)
    write_spec(control, spec)
    per_worker = -(-spec.num_nodes // num_workers)  # ceil
    errors: list[BaseException] = []

    def run(i: int) -> None:
        try:
            run_worker(spec=spec, control=control,
                       worker_id=f"{worker_prefix}{i}", max_slots=per_worker,
                       timeout=timeout)
        except BaseException as e:  # noqa: BLE001 - surfaced after join
            errors.append(e)

    threads = [threading.Thread(target=run, args=(i,), daemon=True,
                                name=f"fleet-worker-{i}")
               for i in range(num_workers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        raise errors[0]
    return assemble_report(control, spec)
