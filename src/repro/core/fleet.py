"""Multi-host fleet launcher + chaos soak harness.

Generalizes ``run_multiprocess`` from "N processes, one parent" to
"N nodes × M hosts, **no parent required**". Everything the fleet needs to
coordinate — the declarative :class:`FleetSpec`, slot claims, heartbeats,
per-node results, per-worker reports — lives *in the shared folder itself*
as ``fleet/``-prefixed blobs (meta-dispatched like every other deposit, and
excluded from all federation state hashes), so the launcher mirrors the
serverless design exactly: there is no coordinator in the data path.

The moving parts:

* **FleetSpec** — nodes, rounds, strategy, transport pipeline spec, store URI
  (the existing ``cache+`` / ``shard<G>+`` grammar), runner kind and a seeded
  chaos schedule. ``repro.fleet init`` serializes it to the shared folder;
  from then on any host can join.

* **Workers** (``repro.fleet worker --store <uri>``) — each host reads the
  spec, *claims node slots* via atomic ``put_if_absent`` writes (link(2) on
  DiskFolder — atomic even on NFS), runs its claimed nodes in local OS
  processes under a :class:`ProcessSupervisor` (or threads, for in-process
  soaks at 10²-node scale), drives the chaos schedule against them, and
  deposits heartbeat + result blobs. A restarted worker (same ``worker_id``)
  reclaims its own slots.

* **Chaos engine** — extends ``kill_after`` into a *seeded, randomized
  schedule* derived deterministically from ``(seed, node_id)``: victims park
  mid-round after a drawn number of federation pushes, the worker SIGKILLs
  them the moment the parked heartbeat lands (backstop timer otherwise), then
  respawns them after ``restart_after`` — the reborn node must *resume*
  (counter, params, strategy state) from its own deposits. Stall events make
  drawn nodes sleep mid-soak (the slow-node/straggler case async federation
  must absorb).

* **SoakReport** (``repro.fleet watch`` / ``report``, or any worker) —
  assembled purely from the folder: rounds completed per node, crashes
  injected / survived, restart recoveries (``resumed``), recovery latency,
  per-pipeline :class:`PipelineStats` rollups, wall-clock / bytes budgets.
  The soak *passes* only if every node finished its rounds, every
  killed-then-restarted node reports ``resumed=True``, and **every worker
  independently computed the same fleet-wide ``state_hash``** over the data
  plane after quiescence.
"""
from __future__ import annotations

import hashlib
import json
import threading
import time
import uuid
from dataclasses import asdict, dataclass, field
from typing import Any, Callable

import numpy as np

from repro.logs import get_logger

from .node import AsyncFederatedNode
from .serialize import deserialize_fleet_blob, serialize_fleet_blob
from .simulation import ProcessSupervisor
from .store import SharedFolder, WeightStore, make_folder
from .strategies import STRATEGIES, get_strategy
from .telemetry import Telemetry, collect_obs, telemetry_rollups
from .transport import normalize_transport, parse_folder_uri

_log = get_logger("fleet")

FLEET_PREFIX = "fleet/"
SPEC_KEY = "fleet/spec"
_CLAIM_PREFIX = "fleet/claim/"
_HEARTBEAT_PREFIX = "fleet/heartbeat/"
_RESULT_PREFIX = "fleet/result/"
_WORKER_PREFIX = "fleet/worker/"


# --------------------------------------------------------------------------
# Declarative specs
# --------------------------------------------------------------------------


@dataclass
class ChaosSpec:
    """Seeded chaos parameters; the concrete per-node schedule is derived
    deterministically by :func:`chaos_schedule` (same seed + node set →
    identical schedule on every host, with no host-to-host messages)."""

    seed: int = 0
    kills: int = 0                 # distinct SIGKILL-then-restart victims
    park_after: tuple = (2, 4)     # victim parks after U[a,b] federation pushes
    kill_grace: float = 30.0       # backstop SIGKILL this long after spawn
    restart_after: float = 0.5     # delay before the victim is respawned
    stalls: int = 0                # distinct slow-node stall victims
    stall_after: tuple = (1, 3)    # stall after U[a,b] pushes
    stall_duration: float = 1.0

    def to_dict(self) -> dict:
        d = asdict(self)
        d["park_after"] = list(self.park_after)
        d["stall_after"] = list(self.stall_after)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ChaosSpec":
        d = dict(d)
        for key in ("park_after", "stall_after"):
            if key in d:
                d[key] = tuple(int(v) for v in d[key])
        return cls(**d)


@dataclass
class FleetSpec:
    """One soak, declaratively: everything a joining host needs to run its
    share of the fleet. Serialized to the shared folder (``fleet/spec``) —
    the spec travels with the store, not with any process."""

    store_uri: str                 # data plane; cache+/shard<G>+ grammar
    name: str = "soak"
    num_nodes: int = 8
    rounds: int = 10               # federation pushes per node, across incarnations
    strategy: str = "fedavg"
    transport: str | None = None   # pipeline spec string (transport.py grammar)
    runner: str = "process"        # "process" (real SIGKILLs) | "thread" (in-process soaks)
    param_size: int = 256          # synthetic consensus model size (f32 entries)
    round_sleep: float = 0.05      # local "training" time per round
    settle: float = 1.0            # quiescence wait before the fleet hash
    result_timeout: float = 180.0  # how long a worker waits for ALL fleet results
    node_prefix: str = "node"
    chaos: ChaosSpec = field(default_factory=ChaosSpec)

    def __post_init__(self) -> None:
        if isinstance(self.chaos, dict):
            self.chaos = ChaosSpec.from_dict(self.chaos)
        if self.num_nodes < 1:
            raise ValueError(f"num_nodes must be >= 1, got {self.num_nodes}")
        if self.rounds < 1:
            raise ValueError(f"rounds must be >= 1, got {self.rounds}")
        if self.runner not in ("process", "thread"):
            raise ValueError(f"runner must be 'process' or 'thread', got {self.runner!r}")
        if self.param_size < 1:
            raise ValueError(f"param_size must be >= 1, got {self.param_size}")
        if self.chaos.kills < 0 or self.chaos.stalls < 0:
            raise ValueError("chaos.kills / chaos.stalls must be >= 0")
        if self.chaos.kills + self.chaos.stalls > self.num_nodes:
            raise ValueError(
                f"chaos victims ({self.chaos.kills} kills + {self.chaos.stalls} "
                f"stalls) exceed num_nodes={self.num_nodes}")
        if self.chaos.kills and self.rounds < 2:
            raise ValueError("kill chaos needs rounds >= 2 (a victim must push "
                             "at least once before dying, and finish after)")
        # Fail fast on misspelled strategy/transport — at spec construction,
        # not inside every spawned client N processes later (same convention
        # as ShardedWeightStore's throwaway-pipeline probe). The grammar-only
        # normalize (no zstd import probe) keeps a spec WRITABLE from a host
        # without the module its workers have.
        if self.strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {self.strategy!r}; "
                             f"options: {sorted(STRATEGIES)}")
        if self.transport is not None:
            normalize_transport(self.transport)

    # -- node naming ---------------------------------------------------------
    def node_id(self, slot: int) -> str:
        return f"{self.node_prefix}{slot:04d}"

    def node_ids(self) -> list[str]:
        return [self.node_id(s) for s in range(self.num_nodes)]

    def target_of(self, slot: int) -> float:
        """Per-node consensus target for the synthetic quadratic clients —
        distinct but bounded, so the fleet's convex hull stays small."""
        return float(slot % 5)

    # -- wire ----------------------------------------------------------------
    def to_dict(self) -> dict:
        d = asdict(self)
        d["chaos"] = self.chaos.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "FleetSpec":
        d = dict(d)
        if "chaos" in d and isinstance(d["chaos"], dict):
            d["chaos"] = ChaosSpec.from_dict(d["chaos"])
        return cls(**d)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "FleetSpec":
        return cls.from_dict(json.loads(text))


# --------------------------------------------------------------------------
# Seeded chaos schedule
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ChaosEvent:
    node_id: str
    kind: str                  # "kill" | "stall"
    after_pushes: int          # trigger once the node has pushed this often
    restart_after: float = 0.0  # kill only: respawn delay
    duration: float = 0.0       # stall only: sleep length


def _node_rng(seed: int, node_id: str) -> np.random.Generator:
    """Per-node generator keyed on (seed, node_id) — the schedule is a pure
    function of the spec, independent of iteration order or host."""
    digest = hashlib.sha256(f"{seed}:{node_id}".encode()).digest()
    return np.random.default_rng(int.from_bytes(digest[:8], "big"))


def chaos_schedule(spec: FleetSpec) -> dict[str, list[ChaosEvent]]:
    """The concrete, deterministic chaos schedule for ``spec``: node id →
    events. Every host derives the same schedule from the spec alone, so the
    chaos engine needs no coordination either — each worker injects exactly
    the events of the nodes it claimed."""
    chaos = spec.chaos
    ids = spec.node_ids()
    rng = np.random.default_rng(chaos.seed)
    order = [ids[i] for i in rng.permutation(len(ids))]
    victims = order[:chaos.kills]
    stalled = order[chaos.kills:chaos.kills + chaos.stalls]
    out: dict[str, list[ChaosEvent]] = {}
    for nid in victims:
        r = _node_rng(chaos.seed, nid)
        lo, hi = chaos.park_after
        park = int(r.integers(min(lo, hi), max(lo, hi) + 1))
        # a victim must have pushed at least once (there must be a blob to
        # resume from) and must NOT have finished its rounds already
        park = max(1, min(park, spec.rounds - 1))
        out[nid] = [ChaosEvent(nid, "kill", park, restart_after=chaos.restart_after)]
    for nid in stalled:
        r = _node_rng(chaos.seed, nid)
        lo, hi = chaos.stall_after
        after = max(1, min(int(r.integers(min(lo, hi), max(lo, hi) + 1)), spec.rounds))
        out.setdefault(nid, []).append(
            ChaosEvent(nid, "stall", after, duration=chaos.stall_duration))
    return out


# --------------------------------------------------------------------------
# Control plane: spec + claims + heartbeats in the shared folder
# --------------------------------------------------------------------------


def fleet_control_uri(store_uri: str) -> str:
    """The control-plane folder URI for a data-plane store URI: the innermost
    base with every ``cache+`` / ``shard<G>+`` wrapper stripped. For a flat
    disk store, control and data share one folder (``fleet/`` keys are
    excluded from every state hash); for a sharded store the control blobs
    live in the base directory *above* the per-group folders."""
    _wrappers, base = parse_folder_uri(store_uri)
    if base.startswith("memory://"):
        raise ValueError(
            "the fleet control plane must be reachable by every host — "
            "use a shared mount (disk path) or s3://, not memory://")
    return base


def control_folder(store_uri: str) -> SharedFolder:
    return make_folder(fleet_control_uri(store_uri))


def write_spec(control: SharedFolder, spec: FleetSpec) -> None:
    control.put(SPEC_KEY, serialize_fleet_blob("spec", spec.to_dict()))


def read_spec(control: SharedFolder, *, timeout: float = 0.0,
              poll: float = 0.2) -> FleetSpec:
    """Read (polling up to ``timeout`` — a worker may come up before the
    launcher) the fleet spec from the control folder."""
    deadline = time.monotonic() + timeout
    while True:
        blob = control.get(SPEC_KEY)
        if blob is not None:
            kind, payload = deserialize_fleet_blob(blob)
            if kind == "spec":
                return FleetSpec.from_dict(payload)
        if time.monotonic() >= deadline:
            raise TimeoutError(f"no fleet spec at {SPEC_KEY!r} after {timeout}s")
        time.sleep(poll)


def claim_key(slot: int) -> str:
    return f"{_CLAIM_PREFIX}{slot:04d}"


def claim_slots(control: SharedFolder, spec: FleetSpec, worker_id: str, *,
                max_slots: int | None = None) -> list[int]:
    """Claim up to ``max_slots`` node slots for ``worker_id`` via atomic
    ``put_if_absent`` writes — concurrent workers partition the fleet with no
    messages between them. A worker restarting under the same id reclaims the
    slots it already owns (its previous claim blobs name it)."""
    mine: list[int] = []
    for slot in range(spec.num_nodes):
        if max_slots is not None and len(mine) >= max_slots:
            break
        key = claim_key(slot)
        blob = serialize_fleet_blob("claim", {
            "worker": worker_id, "slot": slot,
            "node_id": spec.node_id(slot), "time": time.time()})
        if control.put_if_absent(key, blob):
            mine.append(slot)
            continue
        existing = control.get(key)
        if existing is None:
            continue
        try:
            _kind, payload = deserialize_fleet_blob(existing)
        except (ValueError, KeyError):
            continue
        if payload.get("worker") == worker_id:
            mine.append(slot)  # our own claim, from a previous incarnation
    return mine


def _heartbeat(control: SharedFolder, node_id: str, payload: dict) -> None:
    control.put(f"{_HEARTBEAT_PREFIX}{node_id}", serialize_fleet_blob("heartbeat", payload))


def _read_fleet_blob(control: SharedFolder, key: str) -> dict | None:
    blob = control.get(key)
    if blob is None:
        return None
    try:
        _kind, payload = deserialize_fleet_blob(blob)
    except (ValueError, KeyError):
        return None
    return payload


# --------------------------------------------------------------------------
# The soak client (module-level: spawn must pickle it)
# --------------------------------------------------------------------------


class _SimulatedCrash(RuntimeError):
    """Thread-runner stand-in for a SIGKILL: the client dies mid-round
    without depositing a result; the worker restarts it with resume."""


def _soak_client(spec_dict: dict, slot: int, *, park_after_pushes: int | None = None,
                 stall_after: int | None = None, stall_duration: float = 0.0,
                 crash_mode: str = "sigkill") -> dict:
    """One fleet node: quadratic consensus training federated through the
    spec's store. Pushes a heartbeat every federation step (via the node's
    ``on_step`` hook), deposits its result blob itself on completion — the
    worker never relays data — and, as a chaos victim, parks mid-round after
    ``park_after_pushes`` pushes so the SIGKILL lands deterministically."""
    spec = FleetSpec.from_dict(spec_dict)
    node_id = spec.node_id(slot)
    control = control_folder(spec.store_uri)
    data = make_folder(spec.store_uri)
    t0 = time.time()
    state: dict[str, Any] = {"first_push": None}
    # Every soak node runs instrumented: the node flushes an obs/ snapshot
    # each round (flush_every=1 — soak rounds are few and blobs tiny), which
    # is what SoakReport's telemetry rollups and `repro.obs` read back.
    tel = Telemetry(node_id, enabled=True, flush_every=1)

    def on_step(node, _aggregated) -> None:
        if state["first_push"] is None:
            state["first_push"] = time.time()
        # heartbeats are thin telemetry deposits: liveness plus the brief
        # rollup (round count, staleness, phase means)
        _heartbeat(control, node_id, {
            "node_id": node_id, "slot": slot, "counter": node.counter,
            "pushes": node.num_pushes, "status": "running",
            "resumed": node.resumed is not None, "time": time.time(),
            "obs": tel.brief()})

    node = AsyncFederatedNode(
        strategy=get_strategy(spec.strategy), shared_folder=data,
        node_id=node_id, transport=spec.transport, on_step=on_step,
        telemetry=tel)
    resumed = node.resumed is not None
    start_counter = node.counter
    if resumed:
        w = np.asarray(node.resumed.params["w"], np.float32).copy()
    else:
        w = np.zeros((spec.param_size,), np.float32)
    target = np.float32(spec.target_of(slot))

    while node.counter < spec.rounds:
        w = w + np.float32(0.3) * (target - w)  # local "training"
        aggregated = node.update_parameters({"w": w}, num_examples=1 + slot % 5)
        if aggregated is not None:
            w = np.asarray(aggregated["w"], np.float32)
        if park_after_pushes is not None and node.num_pushes >= park_after_pushes:
            _heartbeat(control, node_id, {
                "node_id": node_id, "slot": slot, "counter": node.counter,
                "pushes": node.num_pushes, "status": "parked",
                "resumed": resumed, "time": time.time()})
            if crash_mode == "raise":
                raise _SimulatedCrash(node_id)
            while True:  # mid-round: hold still until the SIGKILL lands
                time.sleep(0.05)
        if stall_after is not None and node.num_pushes == stall_after:
            time.sleep(stall_duration)  # the slow-node stall
        time.sleep(spec.round_sleep)

    result = {
        "node_id": node_id, "slot": slot, "resumed": resumed,
        "start_counter": start_counter, "final_counter": node.counter,
        "pushes": node.num_pushes, "aggregations": node.num_aggregations,
        "skipped_pulls": node.num_skipped_pulls,
        "wall_seconds": time.time() - t0,
        "first_push_unix": state["first_push"],
        "finished_unix": time.time(),
        "params_l2": float(np.linalg.norm(w)),
        "transport_stats": dict(node.transport_stats()),
    }
    control.put(f"{_RESULT_PREFIX}{node_id}", serialize_fleet_blob("result", result))
    _heartbeat(control, node_id, {
        "node_id": node_id, "slot": slot, "counter": node.counter,
        "pushes": node.num_pushes, "status": "done", "resumed": resumed,
        "time": time.time()})
    return result


# --------------------------------------------------------------------------
# Workers
# --------------------------------------------------------------------------


@dataclass
class WorkerReport:
    worker_id: str
    slots: list[int]
    crashes_injected: int = 0
    restarts: int = 0
    fleet_state_hash: str | None = None
    all_results_seen: bool = False
    wall_seconds: float = 0.0
    recoveries: dict = field(default_factory=dict)  # node -> SIGKILL→first-push s
    results: dict = field(default_factory=dict)     # node -> result payload


def default_worker_timeout(spec: FleetSpec) -> float:
    """Generous bound on one worker's run phase: startup + rounds + chaos."""
    per_round = spec.round_sleep + 1.0
    chaos = spec.chaos.kill_grace + spec.chaos.restart_after if spec.chaos.kills else 0.0
    return 120.0 + spec.rounds * per_round + chaos + spec.chaos.stalls * spec.chaos.stall_duration


def fleet_state_hash(spec_or_uri: "FleetSpec | str") -> str:
    """The fleet-wide data-plane state hash every worker must agree on after
    quiescence. Built over the spec's store URI, so flat and sharded fleets
    hash exactly what their nodes federate through (fleet/ and state/ control
    blobs excluded)."""
    uri = spec_or_uri.store_uri if isinstance(spec_or_uri, FleetSpec) else spec_or_uri
    folder = make_folder(uri)
    from .gossip import ShardedFolders, ShardedWeightStore  # circular-import guard

    if isinstance(folder, ShardedFolders):
        return ShardedWeightStore(folder).state_hash()
    return WeightStore(folder).state_hash()


def wait_all_results(control: SharedFolder, spec: FleetSpec, *,
                     timeout: float, poll: float = 0.25) -> bool:
    """Block until every fleet node's result blob is present (global
    quiescence) or ``timeout`` passes; True on full coverage."""
    want = {f"{_RESULT_PREFIX}{nid}" for nid in spec.node_ids()}
    deadline = time.monotonic() + timeout
    while True:
        have = {k for k in control.keys() if k.startswith(_RESULT_PREFIX)}
        if want <= have:
            return True
        if time.monotonic() >= deadline:
            return False
        time.sleep(poll)


def run_worker(store_uri: str | None = None, *, spec: FleetSpec | None = None,
               worker_id: str | None = None, max_slots: int | None = None,
               timeout: float | None = None, spec_timeout: float = 60.0,
               control: SharedFolder | None = None) -> WorkerReport:
    """One host's whole contribution to the soak: read the spec, claim slots,
    run + chaos the claimed nodes, wait for fleet-wide quiescence, compute
    the fleet state hash independently, deposit the worker report. Run this
    once per host (``python -m repro.fleet worker``); no invocation is
    special — the fleet has no parent."""
    if control is None:
        if store_uri is None:
            if spec is None:
                raise ValueError("need store_uri, spec, or control")
            store_uri = spec.store_uri
        control = control_folder(store_uri)
    if spec is None:
        spec = read_spec(control, timeout=spec_timeout)
    worker_id = worker_id or f"worker-{uuid.uuid4().hex[:8]}"
    if timeout is None:
        timeout = default_worker_timeout(spec)
    t0 = time.time()
    slots = claim_slots(control, spec, worker_id, max_slots=max_slots)
    _log.info("worker %s: claimed slots %s of fleet %r (%s runner)",
              worker_id, slots, spec.name, spec.runner)
    schedule = chaos_schedule(spec)
    runner = _run_slots_threaded if spec.runner == "thread" else _run_slots_processes
    report = runner(control, spec, worker_id, slots, schedule, timeout)
    # Global quiescence, then the fleet-wide hash every worker must agree on.
    report.all_results_seen = wait_all_results(control, spec, timeout=spec.result_timeout)
    if not report.all_results_seen:
        _log.warning("worker %s: quiescence timeout — not every node deposited "
                     "a result within %.0fs", worker_id, spec.result_timeout)
    time.sleep(spec.settle)
    report.fleet_state_hash = fleet_state_hash(spec)
    report.wall_seconds = time.time() - t0
    control.put(f"{_WORKER_PREFIX}{worker_id}", serialize_fleet_blob("worker", {
        "worker": worker_id, "slots": list(slots),
        "crashes_injected": report.crashes_injected,
        "restarts": report.restarts,
        "fleet_state_hash": report.fleet_state_hash,
        "all_results_seen": report.all_results_seen,
        "wall_seconds": report.wall_seconds,
        "recoveries": dict(report.recoveries),
        "time": time.time()}))
    return report


def _chaos_kwargs(events: list[ChaosEvent]) -> dict:
    kwargs: dict[str, Any] = {}
    for ev in events:
        if ev.kind == "kill":
            kwargs["park_after_pushes"] = ev.after_pushes
        elif ev.kind == "stall":
            kwargs["stall_after"] = ev.after_pushes
            kwargs["stall_duration"] = ev.duration
    return kwargs


def _run_slots_processes(control: SharedFolder, spec: FleetSpec, worker_id: str,
                         slots: list[int], schedule: dict[str, list[ChaosEvent]],
                         timeout: float) -> WorkerReport:
    """Run the claimed slots as real OS processes under a ProcessSupervisor,
    injecting this worker's share of the chaos schedule: SIGKILL a victim the
    moment its parked heartbeat lands (backstop timer otherwise), respawn it
    after the scheduled delay — the respawn must resume, not restart."""
    report = WorkerReport(worker_id, list(slots))
    sup = ProcessSupervisor()
    spec_dict = spec.to_dict()
    slot_of = {spec.node_id(s): s for s in slots}
    kill_events: dict[str, ChaosEvent] = {}
    killed_at: dict[str, float] = {}
    restart_due: dict[str, float] = {}
    try:
        for slot in slots:
            nid = spec.node_id(slot)
            events = schedule.get(nid, [])
            sup.spawn(nid, _soak_client, (spec_dict, slot), _chaos_kwargs(events))
            kill = next((e for e in events if e.kind == "kill"), None)
            if kill is not None:
                kill_events[nid] = kill
                # backstop: if the parked heartbeat never shows (crashed some
                # other way, wedged before parking), SIGKILL anyway
                sup.schedule_kill(nid, spec.chaos.kill_grace)
        deadline = time.monotonic() + timeout
        while (sup.unsettled() or restart_due) and time.monotonic() < deadline:
            for nid in list(kill_events):
                hb = _read_fleet_blob(control, f"{_HEARTBEAT_PREFIX}{nid}")
                if hb is not None and hb.get("status") == "parked":
                    sup.kill(nid)  # mid-round, deterministically
            for nid in sup.poll():
                kill = kill_events.pop(nid, None)
                if kill is not None:  # the victim settled by dying
                    _log.info("worker %s: chaos SIGKILL landed on %s",
                              worker_id, nid)
                    killed_at[nid] = time.time()
                    report.crashes_injected += 1
                    restart_due[nid] = time.monotonic() + kill.restart_after
            now = time.monotonic()
            for nid, due in list(restart_due.items()):
                if now >= due:
                    del restart_due[nid]
                    # restart WITHOUT the park: the reborn node must resume
                    # from its own deposits and run to completion
                    _log.info("worker %s: restarting %s (must resume)",
                              worker_id, nid)
                    sup.spawn(nid, _soak_client, (spec_dict, slot_of[nid]), {})
                    report.restarts += 1
            time.sleep(0.05)
        sup.join(max(0.0, deadline - time.monotonic()))
    finally:
        sup.shutdown()
    for slot in slots:
        nid = spec.node_id(slot)
        res = sup.result(nid)
        if res.error is None and isinstance(res.result, dict):
            report.results[nid] = res.result
    for nid, t_kill in killed_at.items():
        first_push = (report.results.get(nid) or {}).get("first_push_unix")
        if first_push:
            report.recoveries[nid] = max(0.0, first_push - t_kill)
    return report


def _run_slots_threaded(control: SharedFolder, spec: FleetSpec, worker_id: str,
                        slots: list[int], schedule: dict[str, list[ChaosEvent]],
                        timeout: float) -> WorkerReport:
    """Thread runner for in-process soaks (the 10²-node benchmark regime,
    where an OS process per node would be interpreter-startup-bound). Chaos
    kills become mid-round exceptions that abort the client without a result
    deposit — same observable contract as a SIGKILL minus the signal — and
    the restarted client must resume exactly as in process mode."""
    report = WorkerReport(worker_id, list(slots))
    spec_dict = spec.to_dict()
    lock = threading.Lock()
    killed_at: dict[str, float] = {}

    def drive(slot: int) -> None:
        nid = spec.node_id(slot)
        events = schedule.get(nid, [])
        kwargs = _chaos_kwargs(events)
        kill = next((e for e in events if e.kind == "kill"), None)
        while True:
            try:
                result = _soak_client(spec_dict, slot, crash_mode="raise", **kwargs)
            except _SimulatedCrash:
                _log.info("worker %s: simulated crash of %s; restarting",
                          worker_id, nid)
                with lock:
                    report.crashes_injected += 1
                    killed_at[nid] = time.time()
                time.sleep(kill.restart_after if kill is not None else 0.0)
                kwargs = {}  # the restart runs clean — and must resume
                with lock:
                    report.restarts += 1
                continue
            with lock:
                report.results[nid] = result
            return

    threads = [threading.Thread(target=drive, args=(slot,), daemon=True,
                                name=f"fleet-{spec.node_id(slot)}")
               for slot in slots]
    for t in threads:
        t.start()
    deadline = time.monotonic() + timeout
    for t in threads:
        t.join(timeout=max(0.0, deadline - time.monotonic()))
    # Recoveries are derived AFTER the joins, only for drivers that delivered
    # a result — a straggler thread past the deadline can at worst add a
    # killed_at entry nobody reads, never a half-built latency.
    with lock:
        for nid, t_kill in killed_at.items():
            first_push = (report.results.get(nid) or {}).get("first_push_unix")
            if first_push:
                report.recoveries[nid] = max(0.0, first_push - t_kill)
    return report


# --------------------------------------------------------------------------
# Fleet-wide report (watch / any worker)
# --------------------------------------------------------------------------


@dataclass
class SoakReport:
    """Everything the soak acceptance needs, assembled purely from the shared
    folder — by ``repro.fleet watch``, by any worker, by anything that can
    read the mount."""

    name: str
    num_nodes: int
    rounds: int
    claims: dict            # slot -> worker id
    results: dict           # node -> result payload
    workers: dict           # worker id -> worker payload
    victims: list           # scheduled SIGKILL victims (from the seeded schedule)
    stalled: list           # scheduled slow nodes
    resumed: dict           # node -> bool
    rounds_completed: dict  # node -> final counter
    crashes_injected: int
    restarts: int
    recovery_latency: dict  # node -> seconds (SIGKILL → restarted node's first push)
    fleet_hashes: dict      # worker -> fleet state hash
    pipeline_stats: dict    # summed PipelineStats counters across all nodes
    telemetry: dict         # obs/ rollups: per-node staleness + phase latency
    total_pushes: int
    wall_seconds: float
    rounds_per_sec: float
    complete: bool          # every node deposited a result
    converged: bool         # complete AND all workers computed one hash
    recovered: bool         # every scheduled victim resumed
    passed: bool

    def to_dict(self) -> dict:
        return asdict(self)

    def summary(self) -> str:
        hashes = sorted(set(self.fleet_hashes.values()))
        lines = [
            f"fleet {self.name!r}: {len(self.results)}/{self.num_nodes} nodes "
            f"reported, {len(self.workers)} workers",
            f"  rounds/node: {self.rounds}  total pushes: {self.total_pushes}  "
            f"rounds/sec: {self.rounds_per_sec:.2f}",
            f"  crashes injected: {self.crashes_injected}  restarts: {self.restarts}  "
            f"victims resumed: {sum(bool(self.resumed.get(v)) for v in self.victims)}"
            f"/{len(self.victims)}",
            f"  fleet state hash: {hashes if len(hashes) != 1 else hashes[0]} "
            f"({'converged' if self.converged else 'NOT converged'})",
            self._telemetry_line(),
            f"  passed: {self.passed}",
        ]
        if self.recovery_latency:
            mean = sum(self.recovery_latency.values()) / len(self.recovery_latency)
            lines.insert(3, f"  recovery latency: mean {mean:.2f}s over "
                            f"{len(self.recovery_latency)} restarts")
        return "\n".join(lines)

    def _telemetry_line(self) -> str:
        fleet = (self.telemetry or {}).get("fleet") or {}
        if not fleet.get("nodes_reporting"):
            return "  telemetry: no obs/ blobs found"
        phases = fleet.get("phase_ms") or {}
        phase_txt = " ".join(
            f"{name} {phases[name]:.2f}ms"
            for name in ("pull", "push", "aggregate") if name in phases)
        return (
            f"  telemetry: {fleet['nodes_reporting']}/{self.num_nodes} nodes, "
            f"staleness mean {fleet.get('staleness_mean', 0.0):.2f} "
            f"p90 {fleet.get('staleness_p90_max', 0.0):.2f}, "
            f"phase means {phase_txt or 'n/a'}")


def assemble_report(control: SharedFolder, spec: FleetSpec | None = None) -> SoakReport:
    """Fold every ``fleet/`` blob in the control folder into one SoakReport.
    Read-only — safe to run concurrently with the fleet, from any host."""
    if spec is None:
        spec = read_spec(control)
    results: dict[str, dict] = {}
    workers: dict[str, dict] = {}
    claims: dict[int, str] = {}
    for key in control.keys():
        if not key.startswith(FLEET_PREFIX) or key == SPEC_KEY:
            continue
        payload = _read_fleet_blob(control, key)
        if payload is None:
            continue
        if key.startswith(_RESULT_PREFIX):
            results[str(payload.get("node_id"))] = payload
        elif key.startswith(_WORKER_PREFIX):
            workers[str(payload.get("worker"))] = payload
        elif key.startswith(_CLAIM_PREFIX):
            claims[int(payload.get("slot", -1))] = str(payload.get("worker"))
    schedule = chaos_schedule(spec)
    victims = sorted(n for n, evs in schedule.items()
                     if any(e.kind == "kill" for e in evs))
    stalled = sorted(n for n, evs in schedule.items()
                     if any(e.kind == "stall" for e in evs))
    resumed = {n: bool(r.get("resumed")) for n, r in results.items()}
    rounds_completed = {n: int(r.get("final_counter", 0)) for n, r in results.items()}
    crashes = sum(int(w.get("crashes_injected", 0)) for w in workers.values())
    restarts = sum(int(w.get("restarts", 0)) for w in workers.values())
    recovery: dict[str, float] = {}
    for w in workers.values():
        for nid, latency in (w.get("recoveries") or {}).items():
            recovery[str(nid)] = float(latency)
    hashes = {wid: str(w["fleet_state_hash"]) for wid, w in workers.items()
              if w.get("fleet_state_hash")}
    stats: dict[str, float] = {}
    for r in results.values():
        for k, v in (r.get("transport_stats") or {}).items():
            if isinstance(v, (int, float)):
                stats[k] = stats.get(k, 0) + v
    # Telemetry rollups come from the DATA plane's obs/ blobs alone — the
    # per-node staleness/latency picture survives even when a node died
    # before depositing its fleet/ result.
    try:
        telemetry = telemetry_rollups(collect_obs(spec.store_uri))
    except Exception:
        _log.debug("telemetry rollup failed for %s", spec.store_uri,
                   exc_info=True)
        telemetry = {"nodes": {}, "fleet": {"nodes_reporting": 0}}
    total_pushes = sum(int(r.get("pushes", 0)) for r in results.values())
    wall = max([float(w.get("wall_seconds", 0.0)) for w in workers.values()]
               + [float(r.get("wall_seconds", 0.0)) for r in results.values()]
               + [0.0])
    # Throughput over the *active* federation span (first push → last finish),
    # not the worker wall, which also counts quiescence waits and settle time.
    starts = [r.get("first_push_unix") for r in results.values() if r.get("first_push_unix")]
    ends = [r.get("finished_unix") for r in results.values() if r.get("finished_unix")]
    active = (max(ends) - min(starts)) if starts and ends else 0.0
    complete = set(results) >= set(spec.node_ids())
    converged = complete and len(hashes) >= 1 and len(set(hashes.values())) == 1
    recovered = all(resumed.get(v, False) for v in victims)
    passed = (
        complete and converged and recovered
        and crashes >= len(victims)
        and all(rounds_completed.get(n, 0) >= spec.rounds for n in spec.node_ids())
    )
    return SoakReport(
        name=spec.name, num_nodes=spec.num_nodes, rounds=spec.rounds,
        claims=claims, results=results, workers=workers,
        victims=victims, stalled=stalled, resumed=resumed,
        rounds_completed=rounds_completed, crashes_injected=crashes,
        restarts=restarts, recovery_latency=recovery, fleet_hashes=hashes,
        pipeline_stats=stats, telemetry=telemetry, total_pushes=total_pushes,
        wall_seconds=wall,
        rounds_per_sec=(total_pushes / active) if active > 0 else 0.0,
        complete=complete, converged=converged, recovered=recovered,
        passed=passed)


def watch(store_uri: str, *, interval: float = 2.0, timeout: float = 600.0,
          printer: Callable[[str], None] = print) -> SoakReport:
    """Poll the control folder until the soak completes (every node reported
    AND every claiming worker deposited its fleet hash) or ``timeout``
    passes; prints one progress line per poll. Pure reader — running it adds
    nothing to the data path."""
    control = control_folder(store_uri)
    spec = read_spec(control, timeout=timeout)
    deadline = time.monotonic() + timeout
    while True:
        report = assemble_report(control, spec)
        expected_workers = set(report.claims.values())
        printer(
            f"[fleet {spec.name}] nodes {len(report.results)}/{spec.num_nodes} "
            f"workers {len(report.fleet_hashes)}/{max(1, len(expected_workers))} "
            f"crashes {report.crashes_injected} restarts {report.restarts}")
        done = report.complete and expected_workers and (
            expected_workers <= set(report.fleet_hashes))
        if done or time.monotonic() >= deadline:
            return report
        time.sleep(interval)


def run_fleet_local(spec: FleetSpec, num_workers: int = 2, *,
                    timeout: float | None = None,
                    worker_prefix: str = "local") -> SoakReport:
    """Single-host convenience (and the benchmark harness): write the spec,
    run ``num_workers`` worker loops concurrently in this process — each
    claiming its share of slots exactly as separate hosts would — and
    assemble the fleet report. The multi-host flow is the same thing with
    ``repro.fleet worker`` once per machine."""
    if num_workers < 1:
        raise ValueError(f"num_workers must be >= 1, got {num_workers}")
    control = control_folder(spec.store_uri)
    write_spec(control, spec)
    per_worker = -(-spec.num_nodes // num_workers)  # ceil
    errors: list[BaseException] = []

    def run(i: int) -> None:
        try:
            run_worker(spec=spec, control=control,
                       worker_id=f"{worker_prefix}{i}", max_slots=per_worker,
                       timeout=timeout)
        except BaseException as e:  # noqa: BLE001 - surfaced after join
            errors.append(e)

    threads = [threading.Thread(target=run, args=(i,), daemon=True,
                                name=f"fleet-worker-{i}")
               for i in range(num_workers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        raise errors[0]
    return assemble_report(control, spec)
