"""Federated aggregation strategies, executed CLIENT-SIDE (serverless).

The paper's design makes the strategy a per-node object: every client owns a
strategy instance and aggregates whatever it pulls from the weight store with
its own weights inserted (Algorithm 1, ``WeightUpdate``). There is no server
state — "server-side" optimizers (FedAvgM / FedAdam / FedYogi / FedAdagrad,
Reddi et al. 2021) therefore keep their momentum/moment buffers *on the
client*, which is exactly the paper's "each client may implement its own
aggregation strategy" property.

Flat-vector hot path (this module's execution model): the store pulls
``FlatUpdate``s — contiguous f32 vectors sharing one interned ``LeafSpec``
per model structure — and every strategy aggregates them *vectorized over
stacked flats*. There is no per-leaf Python loop anywhere on the steady-state
path: peers' rows are copied into a reusable (K, N) stack only when their
flat actually changed (decode-cache hits contribute zero copies), every
combine is one BLAS matvec or one Pallas ``fed_agg`` kernel launch
(``use_kernel=True``, plumbed through the ``Strategy`` base so *every*
strategy honors it), and adaptive strategies keep their momentum/moment
buffers as flat vectors with a fused pseudo-gradient+moment kernel
(``fed_opt``). The aggregate is unflattened into the model's pytree exactly
once, at the trainer boundary. The per-leaf reference implementations live in
``strategies_ref.py`` (property-tested to match within 1e-6).

Aggregation arithmetic is float32 — the same contract as the Pallas kernels
and the wire transports (quantized/delta values are f32-centric). Models with
leaves that don't embed exactly in f32 (int, f64) still aggregate (cast in,
cast back out), matching the PR-2 ``use_kernel`` behavior; PartialFedAvg
additionally passes such *personal* leaves through untouched.

Beyond-paper extensions (paper §5 limitations #2, and future work):
  * ``FedAsync``   — staleness-discounted mixing (Xie et al. 2019), executed
    as a single linear combination (the per-peer lerp chain factorizes into
    per-client coefficients — one fused pass instead of K).
  * ``FedBuff``    — buffered aggregation (Nguyen et al. 2022).
  * ``PartialFedAvg`` — partial model updates (Pillutla et al. 2022): only a
    filtered subset of leaves federates; the rest stay personal (a cached
    boolean mask over the flat index space).
"""
from __future__ import annotations

import re
from abc import ABC, abstractmethod
from typing import Sequence

import numpy as np

from .serialize import NodeUpdate
from .tree import LeafSpec, PyTree


def _combine_flat(stacked: np.ndarray, coeffs: np.ndarray, *,
                  use_kernel: bool = False,
                  out: np.ndarray | None = None) -> np.ndarray:
    """Σ_k coeffs[k]·stacked[k] — THE aggregation primitive. One BLAS matvec
    (single pass over the (K, N) stack) or one generalized ``fed_agg`` kernel
    launch; the coefficients need not be normalized, which is what lets
    FedAsync's lerp chain and weighted means share this code. ``out`` (a warm
    buffer) skips the fresh-page allocation, which at 10^8 params costs more
    than the matvec itself."""
    if use_kernel and stacked.shape[0] > 1:
        from repro.kernels.fed_agg import ops as fed_agg_ops

        return np.asarray(fed_agg_ops.aggregate_flat(stacked, coeffs))
    if out is None:
        out = np.empty(stacked.shape[1], np.float32)
    return np.dot(coeffs, stacked, out=out)


class _StackCache:
    """Reusable (K, N) stacked-flats buffer. A row is recopied only when its
    source flat is a *different array object* than last round — the store's
    decode cache returns the same ndarray for an unchanged peer, so in steady
    state stacking costs zero copies (only the caller's own fresh row moves).
    Tree-only updates (no flat) are flattened straight into their row — the
    allocation-free trainer boundary."""

    def __init__(self):
        self._rows: list = []  # source ndarray per row (held → ids stay valid)
        self._buf: np.ndarray | None = None

    def stack(self, spec: LeafSpec, updates: Sequence[NodeUpdate]) -> np.ndarray:
        k, n = len(updates), spec.num_params
        buf = self._buf
        if buf is None or buf.shape != (k, n):
            buf = np.empty((k, n), np.float32)
            self._buf = buf
            self._rows = [None] * k
        for i, u in enumerate(updates):
            flat = getattr(u, "flat", None)
            if flat is not None and spec.compatible(u.spec):
                if self._rows[i] is not flat:
                    buf[i] = flat
                self._rows[i] = flat
            else:
                spec.flatten_into(u.params, buf[i])
                self._rows[i] = None  # trees are rewritten every round
        return buf


class Strategy(ABC):
    """Client-side aggregation strategy (flat-vector execution).

    ``use_kernel`` lives on the base class so every subclass — not just
    FedAvg — routes its linear combinations through the Pallas ``fed_agg`` /
    ``fed_opt`` kernels when asked.
    """

    name: str = "strategy"

    def __init__(self, *, use_kernel: bool = False, reuse_output: bool = False):
        self.use_kernel = use_kernel
        # reuse_output=True returns trees that VIEW a strategy-owned buffer,
        # valid only until the next aggregate() call — the steady-state fast
        # path for trainers that consume the aggregate immediately (e.g. copy
        # it to device). Default False: every aggregate returns fresh storage.
        self.reuse_output = reuse_output
        self._spec: LeafSpec | None = None
        self._stack = _StackCache()
        self._bufs: dict[str, np.ndarray] = {}

    # -- flat plumbing -------------------------------------------------------
    def _resolve_spec(self, own: NodeUpdate) -> LeafSpec:
        """The layout everything is aggregated in: own's spec when the store
        handed us a FlatUpdate, else a spec built once and reused while own's
        structure is stable."""
        spec = getattr(own, "spec", None)
        if spec is not None:
            self._spec = spec
            return spec
        spec = self._spec
        if spec is not None and spec.describes(own.params):
            return spec
        spec = LeafSpec.of(own.params)
        self._spec = spec
        return spec

    def _flat_of(self, u: NodeUpdate, spec: LeafSpec) -> np.ndarray:
        flat = getattr(u, "flat", None)
        if flat is not None and spec.compatible(u.spec):
            return flat
        return spec.flatten(u.params)

    def _stacked(self, spec: LeafSpec, updates: Sequence[NodeUpdate]) -> np.ndarray:
        return self._stack.stack(spec, updates)

    def _buffer(self, name: str, spec: LeafSpec) -> np.ndarray:
        """Named warm scratch vector (internal use — never escapes unless
        ``reuse_output`` opted in)."""
        buf = self._bufs.get(name)
        if buf is None or buf.size != spec.num_params:
            buf = np.empty(spec.num_params, np.float32)
            self._bufs[name] = buf
        return buf

    def _out_buf(self, spec: LeafSpec) -> np.ndarray | None:
        return self._buffer("out", spec) if self.reuse_output else None

    def _emit(self, spec: LeafSpec, state: np.ndarray) -> np.ndarray:
        """Detach internal state for the caller: a fresh copy by default, the
        reusable out buffer under ``reuse_output``."""
        if self.reuse_output:
            out = self._buffer("out", spec)
            np.copyto(out, state)
            return out
        return state.copy()

    def _mean_coeffs(self, updates: Sequence[NodeUpdate]) -> np.ndarray:
        weights = np.asarray([max(1, u.num_examples) for u in updates], np.float32)
        return weights / weights.sum()

    def _weighted_mean(self, spec: LeafSpec, updates: Sequence[NodeUpdate], *,
                       out: np.ndarray | None = None) -> np.ndarray:
        """Example-count weighted mean (FedAvg, eq. 1) over stacked flats."""
        return _combine_flat(self._stacked(spec, updates),
                             self._mean_coeffs(updates),
                             use_kernel=self.use_kernel, out=out)

    @abstractmethod
    def aggregate(self, own: NodeUpdate, peers: Sequence[NodeUpdate]) -> PyTree:
        """Combine own latest params with peer updates → new local params."""

    # -- recoverable optimizer state ------------------------------------------
    def state_dict(self) -> dict[str, np.ndarray] | None:
        """Named flat vectors a restarted node needs to resume this
        strategy's server-optimizer trajectory (momentum/moment buffers).
        ``None`` when stateless (nothing worth persisting); the node ships
        the dict as a ``state/<node>`` recovery blob through the transport
        pipeline. Stateful subclasses override both hooks."""
        return None

    def load_state_dict(self, state: dict) -> None:
        """Restore ``state_dict`` output (a best-effort no-op on mismatch:
        a recovered blob from an older structure must never crash a fresh
        node — it just starts cold)."""

    @staticmethod
    def _flat_state(state: dict, *names: str) -> "list[np.ndarray] | None":
        """Validate + normalize recovery arrays: all present, equal sizes."""
        try:
            vecs = [np.asarray(state[n], np.float32).reshape(-1).copy()
                    for n in names]
        except (KeyError, TypeError, ValueError):
            return None
        if len({v.size for v in vecs}) != 1:
            return None
        return vecs

    def reset(self) -> None:  # stateful subclasses extend
        self._spec = None
        self._stack = _StackCache()
        self._bufs.clear()


class FedAvg(Strategy):
    """Example-count weighted average (McMahan et al. 2016, eq. 1)."""

    name = "fedavg"

    def aggregate(self, own: NodeUpdate, peers: Sequence[NodeUpdate]) -> PyTree:
        spec = self._resolve_spec(own)
        return spec.unflatten(
            self._weighted_mean(spec, [own, *peers], out=self._out_buf(spec)))


class _FedOpt(Strategy):
    """Adaptive federated optimization base (Reddi et al. 2021).

    Maintains a client-local estimate x of the global model. Each aggregation
    computes the pseudo-gradient Δ = x − avg(updates) and applies a server
    optimizer step to x. ``x``/``m``/``v`` are flat f32 vectors, lazily
    initialized from the first own update; with ``use_kernel`` the whole
    avg→Δ→moments→step chain runs as the fused ``fed_opt`` Pallas kernel
    (one pass over the stack, no (K, N) temporaries).
    """

    variant: str = "adam"

    def __init__(self, server_lr: float = 1.0, beta1: float = 0.9,
                 beta2: float = 0.99, tau: float = 1e-3, *, use_kernel: bool = False):
        super().__init__(use_kernel=use_kernel)
        self.server_lr = server_lr
        self.beta1 = beta1
        self.beta2 = beta2
        self.tau = tau
        self.x: np.ndarray | None = None
        self.m: np.ndarray | None = None
        self.v: np.ndarray | None = None

    def reset(self) -> None:
        super().reset()
        self.x = self.m = self.v = None

    def state_dict(self) -> dict[str, np.ndarray] | None:
        if self.x is None:
            return None
        return {"x": self.x, "m": self.m, "v": self.v}

    def load_state_dict(self, state: dict) -> None:
        vecs = self._flat_state(state, "x", "m", "v")
        if vecs is not None:
            self.x, self.m, self.v = vecs

    def _update_v(self, v: np.ndarray, d2: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def aggregate(self, own: NodeUpdate, peers: Sequence[NodeUpdate]) -> PyTree:
        spec = self._resolve_spec(own)
        if self.x is not None and self.x.size != spec.num_params:
            self.x = self.m = self.v = None  # structure changed → reinit
        updates = [own, *peers]
        stacked = self._stacked(spec, updates)
        coeffs = self._mean_coeffs(updates)
        if self.x is None:
            self.x = np.array(self._flat_of(own, spec), np.float32, copy=True)
            self.m = np.zeros_like(self.x)
            self.v = np.zeros_like(self.x)
        if self.use_kernel:
            from repro.kernels.fed_agg import ops as fed_agg_ops

            self.x, self.m, self.v = fed_agg_ops.fed_opt_flat(
                stacked, coeffs, self.x, self.m, self.v,
                variant=self.variant, server_lr=self.server_lr,
                beta1=self.beta1, beta2=self.beta2, tau=self.tau,
            )
            # fed_opt_flat returned freshly allocated state nothing aliases,
            # and the kernel path replaces (never mutates) it next round — no
            # detach copy needed
            return spec.unflatten(self.x)
        else:
            avg = _combine_flat(stacked, coeffs, out=self._buffer("avg", spec))
            d = self.x - avg  # pseudo-gradient
            self.m *= self.beta1
            self.m += (1.0 - self.beta1) * d
            self.v = self._update_v(self.v, d * d)
            self.x -= self.server_lr * self.m / (np.sqrt(self.v) + self.tau)
        return spec.unflatten(self._emit(spec, self.x))  # in-place state: detach


class FedAvgM(Strategy):
    """FedAvg with server momentum (Hsu et al. 2019)."""

    name = "fedavgm"

    def __init__(self, server_lr: float = 1.0, momentum: float = 0.9, *,
                 use_kernel: bool = False):
        super().__init__(use_kernel=use_kernel)
        self.server_lr = server_lr
        self.momentum = momentum
        self.x: np.ndarray | None = None
        self.buf: np.ndarray | None = None

    def reset(self) -> None:
        super().reset()
        self.x = self.buf = None

    def state_dict(self) -> dict[str, np.ndarray] | None:
        if self.x is None:
            return None
        return {"x": self.x, "buf": self.buf}

    def load_state_dict(self, state: dict) -> None:
        vecs = self._flat_state(state, "x", "buf")
        if vecs is not None:
            self.x, self.buf = vecs

    def aggregate(self, own: NodeUpdate, peers: Sequence[NodeUpdate]) -> PyTree:
        spec = self._resolve_spec(own)
        if self.x is not None and self.x.size != spec.num_params:
            self.x = self.buf = None
        avg = self._weighted_mean(spec, [own, *peers], out=self._buffer("avg", spec))
        if self.x is None:
            self.x = np.array(self._flat_of(own, spec), np.float32, copy=True)
            self.buf = np.zeros_like(self.x)
        # buf = momentum·buf + (x − avg);  x -= lr·buf   (all in place)
        self.buf *= self.momentum
        self.buf += self.x
        self.buf -= avg
        self.x -= self.server_lr * self.buf
        return spec.unflatten(self._emit(spec, self.x))


class FedAdam(_FedOpt):
    name = "fedadam"
    variant = "adam"

    def _update_v(self, v, d2):
        return self.beta2 * v + (1 - self.beta2) * d2


class FedYogi(_FedOpt):
    name = "fedyogi"
    variant = "yogi"

    def _update_v(self, v, d2):
        return v - (1 - self.beta2) * d2 * np.sign(v - d2)


class FedAdagrad(_FedOpt):
    name = "fedadagrad"
    variant = "adagrad"

    def _update_v(self, v, d2):
        return v + d2


class FedAsync(Strategy):
    """Staleness-aware asynchronous mixing (Xie et al. 2019, FedAsync).

    new = (1 − α_k)·current + α_k·peer_k, applied per peer in arrival order,
    with α_k = alpha·s(staleness) and s a polynomial/hinge discount.
    Staleness is measured in counter lag (peer.counter vs own.counter).

    The sequential lerp chain factorizes exactly into one linear combination:
    c_own = Π_j (1 − α_j) and c_k = α_k·Π_{j>k} (1 − α_j), so the whole chain
    is a single fused pass over the stacked flats (per-*client* work stays a
    trivial K-length Python loop computing coefficients).

    Elastic-fleet churn adds a second discount axis: a peer whose
    ``lease_epoch`` is *ahead* of ours was adopted by a surviving worker and
    resumed from its stranded ``latest/`` blob — its params may encode a
    trajectory frozen long before its counter suggests. Each adoption hop
    multiplies that peer's mixing weight by ``(1 + epoch_gap)^(-epoch_a)``
    (one-sided: only peers *ahead* in epochs are damped, so the resurrected
    node itself still absorbs the live consensus at full strength instead of
    yanking it backwards). ``epoch_a = 0`` disables the term; updates without
    lease metadata (gap 0) aggregate bit-identically to before.
    """

    name = "fedasync"

    def __init__(self, alpha: float = 0.6, staleness_fn: str = "poly",
                 a: float = 0.5, b: int = 4, *, epoch_a: float = 1.0,
                 use_kernel: bool = False):
        super().__init__(use_kernel=use_kernel)
        self.alpha = alpha
        self.staleness_fn = staleness_fn
        self.a = a
        self.b = b
        self.epoch_a = epoch_a

    def _discount(self, staleness: float) -> float:
        s = max(0.0, staleness)
        if self.staleness_fn == "poly":
            return (1.0 + s) ** (-self.a)
        if self.staleness_fn == "hinge":
            return 1.0 if s <= self.b else 1.0 / (self.a * (s - self.b) + 1.0)
        if self.staleness_fn == "const":
            return 1.0
        raise ValueError(f"unknown staleness_fn {self.staleness_fn}")

    def aggregate(self, own: NodeUpdate, peers: Sequence[NodeUpdate]) -> PyTree:
        if not peers:
            return own.params
        spec = self._resolve_spec(own)
        own_epoch = int(getattr(own, "lease_epoch", 0))
        alphas = []
        for peer in peers:
            a_eff = self.alpha * self._discount(float(own.counter - peer.counter))
            gap = int(getattr(peer, "lease_epoch", 0)) - own_epoch
            if gap > 0 and self.epoch_a:
                a_eff *= (1.0 + gap) ** (-self.epoch_a)
            alphas.append(min(max(a_eff, 0.0), 1.0))
        coeffs = np.empty(len(peers) + 1, np.float32)
        suffix = 1.0  # Π_{j>k} (1 − α_j), built back to front
        for k in range(len(peers) - 1, -1, -1):
            coeffs[k + 1] = alphas[k] * suffix
            suffix *= 1.0 - alphas[k]
        coeffs[0] = suffix
        stacked = self._stacked(spec, [own, *peers])
        return spec.unflatten(
            _combine_flat(stacked, coeffs, use_kernel=self.use_kernel,
                          out=self._out_buf(spec)))


class FedBuff(Strategy):
    """Buffered asynchronous aggregation (Nguyen et al. 2022).

    Accumulates peer updates into a buffer; only aggregates once ≥ K distinct
    updates (incl. own) have been buffered, otherwise returns own params
    unchanged (client keeps training).
    """

    name = "fedbuff"

    def __init__(self, buffer_size: int = 3, *, use_kernel: bool = False):
        super().__init__(use_kernel=use_kernel)
        self.buffer_size = buffer_size
        self._pending: dict[str, NodeUpdate] = {}
        self._seen_counters: dict[str, int] = {}

    def reset(self) -> None:
        super().reset()
        self._pending.clear()
        self._seen_counters.clear()

    def aggregate(self, own: NodeUpdate, peers: Sequence[NodeUpdate]) -> PyTree:
        for peer in peers:
            if self._seen_counters.get(peer.node_id, -1) < peer.counter:
                self._pending[peer.node_id] = peer
                self._seen_counters[peer.node_id] = peer.counter
        self._pending[own.node_id] = own
        if len(self._pending) < self.buffer_size:
            return own.params
        updates = list(self._pending.values())
        self._pending.clear()
        spec = self._resolve_spec(own)
        return spec.unflatten(
            self._weighted_mean(spec, updates, out=self._out_buf(spec)))


class PartialFedAvg(Strategy):
    """Partial model personalization (Pillutla et al. 2022): only leaves whose
    path matches ``shared_pattern`` federate; everything else stays personal.

    ``families=`` selects shared leaves by *named leaf family* instead (a
    family name, a sequence of names, or a ``{name: path-regex}`` mapping —
    see ``tree.FAMILY_PATTERNS``), resolved through ``LeafSpec.family_view``:
    the exact subset the ``family(...)`` transport ships, so the aggregation
    mask and the wire selector can never diverge. It overrides
    ``shared_pattern`` when given.

    The leaf filter compiles once per spec into a boolean mask over the flat
    index space (per-leaf work at spec-construction time only); each aggregate
    is then the usual fused weighted mean plus one vectorized select.
    """

    name = "partial_fedavg"

    def __init__(self, shared_pattern: str = ".*", *, families=None,
                 use_kernel: bool = False, reuse_output: bool = False):
        super().__init__(use_kernel=use_kernel, reuse_output=reuse_output)
        self.families = families
        self.pattern = re.compile(shared_pattern)
        self._mask: np.ndarray | None = None
        self._leaf_mask: list[bool] | None = None
        self._mask_key: str | None = None

    def _mask_for(self, spec: LeafSpec) -> np.ndarray:
        if self._mask_key != spec.key:
            if self.families is not None:
                view = spec.family_view(self.families)
                mask = view.mask
                leaf_mask = list(view.leaf_mask)
            else:
                mask = np.zeros(spec.num_params, bool)
                leaf_mask = []
                for path, off, n in zip(spec.paths, spec.offsets, spec.sizes):
                    shared = bool(self.pattern.search(path))
                    leaf_mask.append(shared)
                    if shared:
                        mask[off:off + n] = True
            self._mask = mask
            self._leaf_mask = leaf_mask
            self._mask_key = spec.key
        return self._mask

    def aggregate(self, own: NodeUpdate, peers: Sequence[NodeUpdate]) -> PyTree:
        spec = self._resolve_spec(own)
        updates = [own, *peers]
        stacked = self._stacked(spec, updates)
        avg = _combine_flat(stacked, self._mean_coeffs(updates),
                            use_kernel=self.use_kernel,
                            out=self._buffer("avg", spec))
        # stacked[0] is own's flat (just written by the stack fill) — reuse it
        # for the personal entries instead of re-flattening own
        out = self._out_buf(spec)
        if out is None:
            out = np.empty(spec.num_params, np.float32)
        np.copyto(out, stacked[0])
        np.copyto(out, avg, where=self._mask_for(spec))
        out_tree = spec.unflatten(out)
        if spec.f32_exact:
            return out_tree
        # Personal leaves of non-f32-embeddable models (int/f64) must pass
        # through untouched — never rounded through the f32 flat. Swap own's
        # original leaf objects back in (per-leaf, but only on this exact-
        # dtype fallback, never on the f32 hot path).
        import jax

        agg_leaves = jax.tree.leaves(out_tree)
        own_leaves = jax.tree.leaves(own.params)
        return jax.tree.unflatten(spec.treedef, [
            a if shared else o
            for a, o, shared in zip(agg_leaves, own_leaves, self._leaf_mask)
        ])


STRATEGIES = {
    cls.name: cls
    for cls in [FedAvg, FedAvgM, FedAdam, FedYogi, FedAdagrad, FedAsync, FedBuff, PartialFedAvg]
}


def get_strategy(name: str, **kwargs) -> Strategy:
    if name not in STRATEGIES:
        raise KeyError(f"unknown strategy {name!r}; options: {sorted(STRATEGIES)}")
    return STRATEGIES[name](**kwargs)
