"""Per-leaf (tree-path) reference implementations of the aggregation
strategies — the PR-2 semantics, kept verbatim as the oracle.

``core/strategies.py`` now runs every strategy vectorized over flat parameter
vectors (the federation hot path). This module preserves the original
per-leaf ``jax.tree.map`` implementations so that

  * property tests can assert the flat path matches the tree path within
    1e-6 over multi-round stateful sequences (momentum/moment buffers,
    FedBuff buffering, FedAsync staleness), and
  * ``benchmarks/run.py --only agg`` can measure the speedup of the flat
    path against exactly the code it replaced.

Do not grow this module: it is a frozen reference, not a second backend.
"""
from __future__ import annotations

import re
from abc import ABC, abstractmethod
from typing import Sequence

import jax
import numpy as np

from .serialize import NodeUpdate
from .tree import (
    PyTree,
    tree_sub,
    tree_weighted_mean,
    tree_zeros_like,
)


def _weighted_mean_updates(updates: Sequence[NodeUpdate], *, use_kernel: bool = False) -> PyTree:
    trees = [u.params for u in updates]
    weights = [max(1, u.num_examples) for u in updates]
    if use_kernel and len(trees) > 1:
        # PR-2 kernel hot path: re-flattens every tree on every call.
        from repro.kernels.fed_agg import ops as fed_agg_ops

        return fed_agg_ops.aggregate_pytrees(trees, weights)
    return tree_weighted_mean(trees, weights)


class RefStrategy(ABC):
    """Client-side aggregation strategy (per-leaf reference)."""

    name: str = "strategy"

    @abstractmethod
    def aggregate(self, own: NodeUpdate, peers: Sequence[NodeUpdate]) -> PyTree:
        """Combine own latest params with peer updates → new local params."""

    def reset(self) -> None:  # stateful subclasses override
        pass


class FedAvgRef(RefStrategy):
    name = "fedavg"

    def __init__(self, *, use_kernel: bool = False):
        self.use_kernel = use_kernel

    def aggregate(self, own: NodeUpdate, peers: Sequence[NodeUpdate]) -> PyTree:
        return _weighted_mean_updates([own, *peers], use_kernel=self.use_kernel)


class _FedOptRef(RefStrategy):
    def __init__(self, server_lr: float = 1.0, beta1: float = 0.9, beta2: float = 0.99, tau: float = 1e-3):
        self.server_lr = server_lr
        self.beta1 = beta1
        self.beta2 = beta2
        self.tau = tau
        self.x: PyTree | None = None
        self.m: PyTree | None = None
        self.v: PyTree | None = None

    def reset(self) -> None:
        self.x = self.m = self.v = None

    def _update_v(self, v: np.ndarray, d2: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def aggregate(self, own: NodeUpdate, peers: Sequence[NodeUpdate]) -> PyTree:
        avg = _weighted_mean_updates([own, *peers])
        if self.x is None:
            self.x = jax.tree.map(np.asarray, own.params)
            self.m = tree_zeros_like(self.x)
            self.v = tree_zeros_like(self.x)
        delta = tree_sub(self.x, avg)  # pseudo-gradient
        self.m = jax.tree.map(lambda m, d: self.beta1 * m + (1 - self.beta1) * d, self.m, delta)
        self.v = jax.tree.map(lambda v, d: self._update_v(v, d * d), self.v, delta)
        self.x = jax.tree.map(
            lambda x, m, v: x - self.server_lr * m / (np.sqrt(v) + self.tau),
            self.x, self.m, self.v,
        )
        return jax.tree.map(np.copy, self.x)


class FedAvgMRef(RefStrategy):
    name = "fedavgm"

    def __init__(self, server_lr: float = 1.0, momentum: float = 0.9):
        self.server_lr = server_lr
        self.momentum = momentum
        self.x: PyTree | None = None
        self.buf: PyTree | None = None

    def reset(self) -> None:
        self.x = self.buf = None

    def aggregate(self, own: NodeUpdate, peers: Sequence[NodeUpdate]) -> PyTree:
        avg = _weighted_mean_updates([own, *peers])
        if self.x is None:
            self.x = jax.tree.map(np.asarray, own.params)
            self.buf = tree_zeros_like(self.x)
        delta = tree_sub(self.x, avg)
        self.buf = jax.tree.map(lambda b, d: self.momentum * b + d, self.buf, delta)
        self.x = jax.tree.map(lambda x, b: x - self.server_lr * b, self.x, self.buf)
        return jax.tree.map(np.copy, self.x)


class FedAdamRef(_FedOptRef):
    name = "fedadam"

    def _update_v(self, v, d2):
        return self.beta2 * v + (1 - self.beta2) * d2


class FedYogiRef(_FedOptRef):
    name = "fedyogi"

    def _update_v(self, v, d2):
        return v - (1 - self.beta2) * d2 * np.sign(v - d2)


class FedAdagradRef(_FedOptRef):
    name = "fedadagrad"

    def _update_v(self, v, d2):
        return v + d2


class FedAsyncRef(RefStrategy):
    name = "fedasync"

    def __init__(self, alpha: float = 0.6, staleness_fn: str = "poly", a: float = 0.5, b: int = 4):
        self.alpha = alpha
        self.staleness_fn = staleness_fn
        self.a = a
        self.b = b

    def _discount(self, staleness: float) -> float:
        s = max(0.0, staleness)
        if self.staleness_fn == "poly":
            return (1.0 + s) ** (-self.a)
        if self.staleness_fn == "hinge":
            return 1.0 if s <= self.b else 1.0 / (self.a * (s - self.b) + 1.0)
        if self.staleness_fn == "const":
            return 1.0
        raise ValueError(f"unknown staleness_fn {self.staleness_fn}")

    def aggregate(self, own: NodeUpdate, peers: Sequence[NodeUpdate]) -> PyTree:
        current = own.params
        for peer in peers:
            staleness = float(own.counter - peer.counter)
            a_eff = self.alpha * self._discount(staleness)
            a_eff = min(max(a_eff, 0.0), 1.0)
            current = jax.tree.map(
                lambda c, p, a=a_eff: (1.0 - a) * c + a * p, current, peer.params
            )
        return current


class FedBuffRef(RefStrategy):
    name = "fedbuff"

    def __init__(self, buffer_size: int = 3):
        self.buffer_size = buffer_size
        self._buffer: dict[str, NodeUpdate] = {}
        self._seen_counters: dict[str, int] = {}

    def reset(self) -> None:
        self._buffer.clear()
        self._seen_counters.clear()

    def aggregate(self, own: NodeUpdate, peers: Sequence[NodeUpdate]) -> PyTree:
        for peer in peers:
            if self._seen_counters.get(peer.node_id, -1) < peer.counter:
                self._buffer[peer.node_id] = peer
                self._seen_counters[peer.node_id] = peer.counter
        self._buffer[own.node_id] = own
        if len(self._buffer) < self.buffer_size:
            return own.params
        updates = list(self._buffer.values())
        self._buffer.clear()
        return _weighted_mean_updates(updates)


class PartialFedAvgRef(RefStrategy):
    name = "partial_fedavg"

    def __init__(self, shared_pattern: str = ".*", *, use_kernel: bool = False):
        self.pattern = re.compile(shared_pattern)
        self.base = FedAvgRef(use_kernel=use_kernel)

    def aggregate(self, own: NodeUpdate, peers: Sequence[NodeUpdate]) -> PyTree:
        avg = self.base.aggregate(own, peers)
        flat_own = jax.tree_util.tree_flatten_with_path(own.params)
        flat_avg = jax.tree.flatten(avg)[0]
        out_leaves = []
        from .tree import path_str

        for (path, own_leaf), avg_leaf in zip(flat_own[0], flat_avg):
            if self.pattern.search(path_str(path)):
                out_leaves.append(avg_leaf)
            else:
                out_leaves.append(own_leaf)
        return jax.tree.unflatten(flat_own[1], out_leaves)


REF_STRATEGIES = {
    cls.name: cls
    for cls in [FedAvgRef, FedAvgMRef, FedAdamRef, FedYogiRef, FedAdagradRef,
                FedAsyncRef, FedBuffRef, PartialFedAvgRef]
}


def get_ref_strategy(name: str, **kwargs) -> RefStrategy:
    if name not in REF_STRATEGIES:
        raise KeyError(f"unknown strategy {name!r}; options: {sorted(REF_STRATEGIES)}")
    return REF_STRATEGIES[name](**kwargs)
