"""Federated callback — the paper's `FlwrFederatedCallback` equivalent.

The paper hooks federation into the ML framework's callback mechanism
(Keras `on_epoch_end`). Our JAX trainer (`repro.training.Trainer`) exposes the
same hook; this callback pushes/pulls/aggregates via the node and, when the
node returns aggregated weights, swaps them into the training loop.

A callback-based design keeps the paper's "minimal modification" principle:
federation is one line added to an existing training script.
"""
from __future__ import annotations

from collections import deque
from typing import Any

from .node import AsyncFederatedNode, SyncFederatedNode
from .tree import PyTree


class Callback:
    """Trainer callback protocol (duck-typed; see repro.training.Trainer)."""

    def on_train_begin(self, trainer) -> None: ...

    def on_epoch_begin(self, trainer, epoch: int) -> None: ...

    def on_epoch_end(self, trainer, epoch: int, logs: dict[str, Any]) -> None: ...

    def on_train_end(self, trainer) -> None: ...


class FederatedCallback(Callback):
    """Federate at the end of every local epoch (paper: 'model federation
    happened at the end of each epoch')."""

    def __init__(
        self,
        node: AsyncFederatedNode | SyncFederatedNode,
        *,
        num_examples_per_epoch: int,
        federate_every: int = 1,
        sample_prob: float = 1.0,
        history_limit: int | None = 10_000,
    ):
        self.node = node
        self.num_examples_per_epoch = num_examples_per_epoch
        self.federate_every = federate_every  # paper limitation #4: frequency knob
        self.sample_prob = sample_prob  # Algorithm 1's C: client sampling prob
        # Bounded: a million-epoch soak must not grow memory linearly. The
        # deque keeps the most recent entries; None means unbounded (legacy).
        self.history: "deque[dict[str, Any]]" = deque(maxlen=history_limit)

    def on_epoch_end(self, trainer, epoch: int, logs: dict[str, Any]) -> None:
        if (epoch + 1) % self.federate_every != 0:
            return
        if self.sample_prob < 1.0 and trainer.rng_py.random() >= self.sample_prob:
            # Non-sampled clients keep training without the WeightUpdate step
            # (one of the two sampling semantics described in the paper).
            self.history.append({"epoch": epoch, "federated": False, "sampled": False})
            return
        new_params: PyTree | None = self.node.update_parameters(
            trainer.host_params(), num_examples=self.num_examples_per_epoch, metrics=dict(logs)
        )
        if new_params is not None:
            trainer.set_params(new_params)
        self.history.append(
            {"epoch": epoch, "federated": new_params is not None, "sampled": True}
        )

    def on_train_end(self, trainer) -> None:
        # Trainer.fit runs this via try/finally, so a crashed fit cannot leak
        # the store's background prefetcher thread.
        self.node.store.stop_prefetch()
