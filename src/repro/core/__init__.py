"""repro.core — the paper's contribution: serverless (a)sync federated learning.

Public API mirrors the paper's usage snippet:

    from repro.core import AsyncFederatedNode, FederatedCallback, make_folder
    from repro.core.strategies import FedAvg

    node = AsyncFederatedNode(strategy=FedAvg(), shared_folder=make_folder("/mnt/shared/exp1"))
    callback = FederatedCallback(node, num_examples_per_epoch=...)
    trainer.fit(..., callbacks=[callback])
"""
from .callback import Callback, FederatedCallback
from .node import AsyncFederatedNode, FederationTimeout, SyncFederatedNode
from .partition import partition_dataset, partition_sequence_dataset, skewed_assignment
from .serialize import NodeUpdate, deserialize_update, serialize_update
from .simulation import run_threaded, simulate_timeline, straggler_speedup
from .store import DiskFolder, InMemoryFolder, S3Folder, SharedFolder, WeightStore, make_folder
from .strategies import (
    STRATEGIES,
    FedAdagrad,
    FedAdam,
    FedAsync,
    FedAvg,
    FedAvgM,
    FedBuff,
    FedYogi,
    PartialFedAvg,
    Strategy,
    get_strategy,
)

__all__ = [
    "AsyncFederatedNode",
    "SyncFederatedNode",
    "FederationTimeout",
    "Callback",
    "FederatedCallback",
    "NodeUpdate",
    "serialize_update",
    "deserialize_update",
    "SharedFolder",
    "InMemoryFolder",
    "DiskFolder",
    "S3Folder",
    "WeightStore",
    "make_folder",
    "Strategy",
    "FedAvg",
    "FedAvgM",
    "FedAdam",
    "FedYogi",
    "FedAdagrad",
    "FedAsync",
    "FedBuff",
    "PartialFedAvg",
    "STRATEGIES",
    "get_strategy",
    "skewed_assignment",
    "partition_dataset",
    "partition_sequence_dataset",
    "run_threaded",
    "simulate_timeline",
    "straggler_speedup",
]
