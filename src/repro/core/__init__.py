"""repro.core — the paper's contribution: serverless (a)sync federated learning.

Public API mirrors the paper's usage snippet:

    from repro.core import AsyncFederatedNode, FederatedCallback, make_folder
    from repro.core.strategies import FedAvg

    node = AsyncFederatedNode(strategy=FedAvg(), shared_folder=make_folder("/mnt/shared/exp1"))
    callback = FederatedCallback(node, num_examples_per_epoch=...)
    trainer.fit(..., callbacks=[callback])
"""
from .callback import Callback, FederatedCallback
from .gossip import (
    ShardedFolders,
    ShardedWeightStore,
    balanced_groups,
    default_group_of,
)
from .fleet import (
    ChaosEvent,
    ChaosSpec,
    FleetSpec,
    SoakReport,
    WorkerReport,
    assemble_report,
    chaos_schedule,
    claim_slots,
    fleet_state_hash,
    run_fleet_local,
    run_worker,
)
from .node import AsyncFederatedNode, FederationTimeout, SyncFederatedNode
from .partition import partition_dataset, partition_sequence_dataset, skewed_assignment
from .serialize import (
    FlatUpdate,
    GroupSummary,
    NodeUpdate,
    deserialize_group_summary,
    deserialize_update,
    deserialize_update_delta,
    peek_meta,
    serialize_group_summary,
    serialize_update,
    serialize_update_delta,
)
from .tree import (
    FAMILY_PATTERNS,
    FamilyView,
    LeafSpec,
    register_family,
    resolve_family_patterns,
)
from .simulation import (
    ClientResult,
    ProcessCrashed,
    ProcessSupervisor,
    run_multiprocess,
    run_threaded,
    simulate_timeline,
    straggler_speedup,
)
from .store import (
    TRANSPORTS,
    CachingFolder,
    DiskFolder,
    InMemoryFolder,
    S3Folder,
    SharedFolder,
    WeightStore,
    make_folder,
)
from .transport import (
    PipelineStats,
    Prefetcher,
    TransportPipeline,
    family_transport_spec,
    normalize_transport,
    parse_folder_uri,
    parse_pipeline_spec,
)
from .strategies import (
    STRATEGIES,
    FedAdagrad,
    FedAdam,
    FedAsync,
    FedAvg,
    FedAvgM,
    FedBuff,
    FedYogi,
    PartialFedAvg,
    Strategy,
    get_strategy,
)

__all__ = [
    "AsyncFederatedNode",
    "SyncFederatedNode",
    "FederationTimeout",
    "Callback",
    "FederatedCallback",
    "NodeUpdate",
    "FlatUpdate",
    "LeafSpec",
    "FamilyView",
    "FAMILY_PATTERNS",
    "register_family",
    "resolve_family_patterns",
    "GroupSummary",
    "serialize_update",
    "deserialize_update",
    "serialize_update_delta",
    "deserialize_update_delta",
    "serialize_group_summary",
    "deserialize_group_summary",
    "peek_meta",
    "ShardedFolders",
    "ShardedWeightStore",
    "default_group_of",
    "balanced_groups",
    "SharedFolder",
    "InMemoryFolder",
    "DiskFolder",
    "S3Folder",
    "CachingFolder",
    "WeightStore",
    "TRANSPORTS",
    "make_folder",
    "TransportPipeline",
    "PipelineStats",
    "Prefetcher",
    "family_transport_spec",
    "normalize_transport",
    "parse_pipeline_spec",
    "parse_folder_uri",
    "Strategy",
    "FedAvg",
    "FedAvgM",
    "FedAdam",
    "FedYogi",
    "FedAdagrad",
    "FedAsync",
    "FedBuff",
    "PartialFedAvg",
    "STRATEGIES",
    "get_strategy",
    "skewed_assignment",
    "partition_dataset",
    "partition_sequence_dataset",
    "run_threaded",
    "run_multiprocess",
    "ClientResult",
    "ProcessCrashed",
    "ProcessSupervisor",
    "simulate_timeline",
    "straggler_speedup",
    "FleetSpec",
    "ChaosSpec",
    "ChaosEvent",
    "SoakReport",
    "WorkerReport",
    "chaos_schedule",
    "claim_slots",
    "fleet_state_hash",
    "run_worker",
    "run_fleet_local",
    "assemble_report",
]
