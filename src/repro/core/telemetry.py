"""Store-native observability: spans, counters, and ``obs/`` blob rollups.

There is no server to scrape, so there is no server to hold metrics either —
telemetry rides the shared folder as its own blob family (``obs/<node>/<seq>``,
the ``obs_of`` envelope in serialize.py), excluded from ``state_hash`` exactly
like ``fleet/`` control blobs, and any peer can assemble the fleet-wide
picture read-only (``python -m repro.obs watch``/``trace``).

Two layers, no dependencies beyond the stdlib:

  * ``SpanRecorder`` — a monotonic-clock flight recorder: ``with rec.span("pull")``
    records ``(name, t0, dur)`` into a bounded ring (old events drop, a counter
    remembers how many) and folds every span into cumulative per-phase
    aggregates (count/total/min/max) that never grow.
  * ``Telemetry`` — the per-node aggregator. Nodes, the store context, codecs,
    the trainer, and gossip all call ``tel.span(...)`` / ``tel.observe_staleness``
    through it; every ``flush_every`` rounds ``snapshot()`` packages phase
    latencies, the staleness distribution (the FedAsync signal), bytes-per-round
    and chain depth deltas from ``PipelineStats``, prefetch hit rate, trainer
    throughput, and the drained span ring into one JSON-safe payload for
    ``WeightStore.push_obs``.

When disabled, ``span()`` returns a shared no-op context manager and every
hook is a single attribute check — instrumented code stays on the hot path
unconditionally (``BENCH_obs.json`` holds the measured overhead).

Timestamps: spans are recorded on the monotonic clock (immune to NTP steps);
each ``Telemetry`` notes one ``(time.time(), clock())`` anchor pair at birth
so ``snapshot()`` can export wall-clock-aligned microseconds, which is what
lets ``chrome_trace`` merge rings from different nodes onto one timeline.
"""
from __future__ import annotations

import os
import threading
import time
from collections import deque
from typing import Any, Callable

__all__ = [
    "SpanRecorder",
    "Telemetry",
    "chrome_trace",
    "collect_obs",
    "env_enabled",
    "telemetry_rollups",
]


def env_enabled(default: bool = False) -> bool:
    """True when ``REPRO_OBS`` opts this process into telemetry."""
    raw = os.environ.get("REPRO_OBS", "").strip().lower()
    if not raw:
        return default
    return raw not in ("0", "off", "false", "no")


class _NullSpan:
    """Shared no-op context manager — the disabled-path span."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: object) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_recorder", "_name", "_t0")

    def __init__(self, recorder: "SpanRecorder", name: str):
        self._recorder = recorder
        self._name = name

    def __enter__(self) -> "_Span":
        self._t0 = self._recorder.clock()
        return self

    def __exit__(self, *exc: object) -> bool:
        rec = self._recorder
        t0 = self._t0
        rec.record(self._name, t0, rec.clock() - t0)
        return False


class SpanRecorder:
    """Bounded ring of timed spans + cumulative per-phase aggregates.

    The ring holds the most recent ``capacity`` events for trace export (old
    ones drop; ``dropped`` counts them), while the per-phase aggregates fold
    every span ever recorded — so latency breakdowns stay exact even when the
    flight recorder wraps. Thread-safe: the node thread, prefetcher thread,
    and trainer all record into one instance.
    """

    def __init__(self, capacity: int = 2048, *, clock: Callable[[], float] = time.perf_counter):
        self.capacity = max(1, int(capacity))
        self.clock = clock
        self.dropped = 0
        self.total_recorded = 0
        self._lock = threading.Lock()
        self._events: deque[tuple[str, float, float]] = deque(maxlen=self.capacity)
        self._phases: dict[str, list[float]] = {}

    def span(self, name: str) -> _Span:
        return _Span(self, name)

    def record(self, name: str, t0: float, dur: float) -> None:
        with self._lock:
            if len(self._events) == self.capacity:
                self.dropped += 1
            self._events.append((name, t0, dur))
            self.total_recorded += 1
            agg = self._phases.get(name)
            if agg is None:
                self._phases[name] = [1, dur, dur, dur]
            else:
                agg[0] += 1
                agg[1] += dur
                if dur < agg[2]:
                    agg[2] = dur
                if dur > agg[3]:
                    agg[3] = dur

    def drain(self) -> list[tuple[str, float, float]]:
        """Pop and return the ring's events (aggregates are untouched)."""
        with self._lock:
            events = list(self._events)
            self._events.clear()
        return events

    def phase_stats(self) -> dict[str, dict[str, float]]:
        with self._lock:
            return {
                name: {
                    "count": int(count),
                    "total_s": total,
                    "mean_s": total / count,
                    "min_s": lo,
                    "max_s": hi,
                }
                for name, (count, total, lo, hi) in self._phases.items()
            }

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)


class Telemetry:
    """Per-node telemetry aggregator feeding ``obs/<node>/<seq>`` blobs."""

    def __init__(
        self,
        node_id: str = "",
        *,
        enabled: bool | None = None,
        ring_capacity: int = 2048,
        flush_every: int = 10,
        obs_keep: int = 16,
        staleness_window: int = 256,
        clock: Callable[[], float] = time.perf_counter,
    ):
        self.node_id = node_id
        self.enabled = env_enabled() if enabled is None else bool(enabled)
        self.flush_every = max(1, int(flush_every))
        self.obs_keep = max(1, int(obs_keep))
        self.recorder = SpanRecorder(ring_capacity, clock=clock)
        self.clock = clock
        # Wall/monotonic anchor: spans live on the monotonic clock; exported
        # timestamps are anchor_unix + (t - anchor_mono), comparable across
        # nodes (to wall-clock skew, which Perfetto tolerates per-process).
        self.anchor_unix = time.time()
        self.anchor_mono = clock()
        self.seq = 0
        self.rounds = 0
        self.aggregations = 0
        self._lock = threading.Lock()
        self._counters: dict[str, float] = {}
        self._stale_count = 0
        self._stale_sum = 0.0
        self._stale_max = 0.0
        self._stale_recent: deque[float] = deque(maxlen=max(1, int(staleness_window)))
        self._train_steps = 0
        self._train_seconds = 0.0
        self._last_transport: dict[str, float] = {}
        self._rounds_at_flush = 0
        self._time_at_flush = time.time()

    # -- recording hooks (hot path) ------------------------------------

    def span(self, name: str):
        """Context manager timing one phase; shared no-op when disabled."""
        if not self.enabled:
            return _NULL_SPAN
        return self.recorder.span(name)

    def count(self, name: str, n: float = 1) -> None:
        if not self.enabled:
            return
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + n

    def observe_staleness(self, value: float) -> None:
        """Record one peer-update staleness sample (own counter − peer counter)."""
        if not self.enabled:
            return
        value = float(value)
        with self._lock:
            self._stale_count += 1
            self._stale_sum += value
            if value > self._stale_max:
                self._stale_max = value
            self._stale_recent.append(value)

    def note_train(self, steps: int, seconds: float) -> None:
        if not self.enabled:
            return
        with self._lock:
            self._train_steps += int(steps)
            self._train_seconds += float(seconds)

    def end_round(self, *, aggregated: bool) -> None:
        self.rounds += 1
        if aggregated:
            self.aggregations += 1

    def should_flush(self) -> bool:
        return self.enabled and self.rounds > 0 and self.rounds % self.flush_every == 0

    # -- snapshots ------------------------------------------------------

    def _to_unix_us(self, t_mono: float) -> int:
        return int(round((self.anchor_unix + (t_mono - self.anchor_mono)) * 1e6))

    def staleness_stats(self) -> dict[str, float]:
        with self._lock:
            recent = sorted(self._stale_recent)
            count, total, peak = self._stale_count, self._stale_sum, self._stale_max
        out = {
            "count": count,
            "mean": (total / count) if count else 0.0,
            "max": peak,
        }
        if recent:
            out["p50"] = recent[len(recent) // 2]
            out["p90"] = recent[min(len(recent) - 1, int(len(recent) * 0.9))]
        else:
            out["p50"] = out["p90"] = 0.0
        return out

    def brief(self) -> dict[str, float]:
        """Tiny rollup for heartbeat payloads (thin telemetry deposits)."""
        stale = self.staleness_stats()
        phases = self.recorder.phase_stats()

        def mean_ms(name: str) -> float:
            agg = phases.get(name)
            return round(agg["mean_s"] * 1e3, 3) if agg else 0.0

        return {
            "rounds": self.rounds,
            "staleness_mean": round(stale["mean"], 3),
            "staleness_p90": round(stale["p90"], 3),
            "pull_ms": mean_ms("pull"),
            "push_ms": mean_ms("push"),
            "aggregate_ms": mean_ms("aggregate"),
        }

    def snapshot(self, transport_stats: dict[str, float] | None = None) -> dict[str, Any]:
        """Package current state into one ``obs/`` payload and advance ``seq``.

        Cumulative signals (phase aggregates, staleness, counters, transport
        stats) carry the full history — readers only need each node's latest
        blob. The span ring drains here; ``transport_delta`` and the derived
        bytes-per-round / round rate cover just the window since last flush.
        """
        now_unix = time.time()
        transport = dict(transport_stats or {})
        events = self.recorder.drain()
        spans = [
            [name, self._to_unix_us(t0), int(round(dur * 1e6))]
            for name, t0, dur in events
        ]
        with self._lock:
            counters = dict(self._counters)
            train_steps, train_seconds = self._train_steps, self._train_seconds
            last_transport = self._last_transport
            rounds_at_flush = self._rounds_at_flush
            time_at_flush = self._time_at_flush
        delta = {
            k: v - last_transport.get(k, 0)
            for k, v in transport.items()
            if isinstance(v, (int, float)) and not isinstance(v, bool)
        }
        window_rounds = max(0, self.rounds - rounds_at_flush)
        window_seconds = max(1e-9, now_unix - time_at_flush)
        hits = transport.get("decode_hits", 0)
        misses = transport.get("decode_misses", 0)
        payload: dict[str, Any] = {
            "node_id": self.node_id,
            "seq": self.seq,
            "time_unix": now_unix,
            "rounds": self.rounds,
            "aggregations": self.aggregations,
            "phases": self.recorder.phase_stats(),
            "staleness": self.staleness_stats(),
            "counters": counters,
            "train": {
                "steps": train_steps,
                "seconds": train_seconds,
                "steps_per_sec": train_steps / train_seconds if train_seconds > 0 else 0.0,
            },
            "transport": transport,
            "transport_delta": delta,
            "window": {
                "rounds": window_rounds,
                "seconds": window_seconds,
                "rounds_per_sec": window_rounds / window_seconds,
                "bytes_written_per_round": (
                    delta.get("bytes_written", 0) / window_rounds if window_rounds else 0.0
                ),
            },
            "chain_depth": transport.get("chain_depth", 0),
            "prefetch_hit_rate": hits / (hits + misses) if (hits + misses) else 0.0,
            "spans": spans,
            "dropped_spans": self.recorder.dropped,
        }
        with self._lock:
            self.seq += 1
            self._last_transport = {
                k: v
                for k, v in transport.items()
                if isinstance(v, (int, float)) and not isinstance(v, bool)
            }
            self._rounds_at_flush = self.rounds
            self._time_at_flush = now_unix
        return payload


# -- fleet-side assembly (read-only, coordinator-free) ------------------


def collect_obs(store_uri_or_folder: Any) -> dict[str, list[dict[str, Any]]]:
    """Gather every ``obs/`` payload in a store, node → payloads by seq.

    Accepts a ``make_folder`` URI, a ``SharedFolder``, or a ``ShardedFolders``
    (all groups scanned). Pure reads — never writes, never aggregates weights.
    """
    from .gossip import ShardedFolders
    from .serialize import deserialize_obs_blob
    from .store import make_folder

    folder = store_uri_or_folder
    if isinstance(folder, str):
        folder = make_folder(folder)
    folders = (
        [folder.group_folder(g) for g in range(folder.num_groups)]
        if isinstance(folder, ShardedFolders)
        else [folder]
    )
    by_node: dict[str, list[tuple[int, dict[str, Any]]]] = {}
    for f in folders:
        for key in f.keys():
            if not key.startswith("obs/"):
                continue
            blob = f.get(key)
            if blob is None:
                continue
            try:
                node_id, seq, payload = deserialize_obs_blob(blob)
            except (ValueError, KeyError):
                continue
            by_node.setdefault(node_id, []).append((seq, payload))
    return {
        node: [payload for _seq, payload in sorted(pairs, key=lambda p: p[0])]
        for node, pairs in sorted(by_node.items())
    }


def telemetry_rollups(obs_by_node: dict[str, list[dict[str, Any]]]) -> dict[str, Any]:
    """Fold collected ``obs/`` payloads into per-node + fleet rollups.

    Cumulative fields come from each node's latest payload; round rate spans
    first→last payload when a node deposited more than one.
    """
    nodes: dict[str, Any] = {}
    for node_id, payloads in obs_by_node.items():
        if not payloads:
            continue
        last = payloads[-1]
        phases = last.get("phases") or {}
        phase_ms = {
            name: round(agg.get("mean_s", 0.0) * 1e3, 3) for name, agg in phases.items()
        }
        stale = last.get("staleness") or {}
        rate = (last.get("window") or {}).get("rounds_per_sec", 0.0)
        if len(payloads) > 1:
            dt = last.get("time_unix", 0) - payloads[0].get("time_unix", 0)
            dr = last.get("rounds", 0) - payloads[0].get("rounds", 0)
            if dt > 0:
                rate = dr / dt
        transport = last.get("transport") or {}
        counters = last.get("counters") or {}
        nodes[node_id] = {
            # Serving nodes enrich their payloads with a "serve" dict (SLOs:
            # swap latency, rounds-behind-store staleness, throughput); its
            # presence is what distinguishes the serving tier in rollups.
            "role": "serve" if last.get("serve") else "train",
            "rounds": last.get("rounds", 0),
            # Elastic-fleet churn markers: a node counts node.adopted once
            # when a surviving worker resumes it from a lapsed lease.
            "adopted": bool(counters.get("node.adopted", 0)),
            "lease_epoch": int(counters.get("node.lease_epoch", 0)),
            "aggregations": last.get("aggregations", 0),
            "rounds_per_sec": round(float(rate), 4),
            "staleness_mean": round(float(stale.get("mean", 0.0)), 4),
            "staleness_p90": round(float(stale.get("p90", 0.0)), 4),
            "staleness_max": float(stale.get("max", 0.0)),
            "phase_ms": phase_ms,
            "bytes_written": transport.get("bytes_written", 0),
            "bytes_read": transport.get("bytes_read", 0),
            "chain_depth": last.get("chain_depth", 0),
            "prefetch_hit_rate": round(float(last.get("prefetch_hit_rate", 0.0)), 4),
            "train_steps_per_sec": round(
                float((last.get("train") or {}).get("steps_per_sec", 0.0)), 3
            ),
            "dropped_spans": last.get("dropped_spans", 0),
        }
        if last.get("serve"):
            nodes[node_id]["serve"] = last["serve"]
    fleet: dict[str, Any] = {"nodes_reporting": len(nodes)}
    if nodes:
        vals = list(nodes.values())
        fleet["rounds_total"] = sum(v["rounds"] for v in vals)
        fleet["staleness_mean"] = round(
            sum(v["staleness_mean"] for v in vals) / len(vals), 4
        )
        fleet["staleness_p90_max"] = max(v["staleness_p90"] for v in vals)
        fleet["bytes_written"] = sum(v["bytes_written"] for v in vals)
        fleet["adoptions"] = sum(1 for v in vals if v["adopted"])
        fleet["serving_nodes"] = sum(1 for v in vals if v["role"] == "serve")
        phase_names = sorted({name for v in vals for name in v["phase_ms"]})
        fleet["phase_ms"] = {
            name: round(
                sum(v["phase_ms"].get(name, 0.0) for v in vals)
                / max(1, sum(1 for v in vals if name in v["phase_ms"])),
                3,
            )
            for name in phase_names
        }
    return {"nodes": nodes, "fleet": fleet}


def chrome_trace(obs_by_node: dict[str, list[dict[str, Any]]]) -> dict[str, Any]:
    """Merge per-node span rings into one Chrome trace-event JSON document.

    Each node becomes a process (integer pid + a ``process_name`` metadata
    event); spans become ``ph: "X"`` complete events with wall-clock-anchored
    microsecond timestamps, so Perfetto / chrome://tracing lays the whole
    fleet on one timeline.
    """
    events: list[dict[str, Any]] = []
    for pid, (node_id, payloads) in enumerate(sorted(obs_by_node.items())):
        events.append(
            {
                "name": "process_name",
                "ph": "M",
                "pid": pid,
                "tid": 0,
                "args": {"name": node_id or f"node{pid}"},
            }
        )
        for payload in payloads:
            for span in payload.get("spans") or []:
                name, ts_us, dur_us = span[0], int(span[1]), int(span[2])
                events.append(
                    {
                        "name": str(name),
                        "cat": "repro",
                        "ph": "X",
                        "ts": ts_us,
                        "dur": max(0, dur_us),
                        "pid": pid,
                        "tid": 0,
                    }
                )
    return {"traceEvents": events, "displayTimeUnit": "ms"}
