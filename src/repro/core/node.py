"""Federated nodes: the paper's client-side federation objects.

``AsyncFederatedNode`` implements Algorithm 1 (FedAvgAsync) generalized over
strategies: push own weights → state-hash check → pull peers' latest →
client-side aggregate → continue training. If the store is unchanged or empty
(no peers yet), the client keeps its own weights — no waiting, ever.

``SyncFederatedNode`` implements the paper's synchronous *serverless* mode:
after pushing round-t weights the client blocks until all K participants have
deposited round-t weights, then everybody aggregates the identical set
locally. A ``timeout`` makes single-node failure observable instead of a
deadlock (the paper's operational criticism of synchronous FL).

Nodes are transparent to the flat-vector hot path: the store pulls
``FlatUpdate``s (contiguous f32 vectors sharing an interned ``LeafSpec``),
the strategies aggregate them vectorized, and the pytree the trainer receives
back is materialized exactly once at this boundary.
"""
from __future__ import annotations

import time
import uuid
import warnings
from typing import Callable

from repro.logs import get_logger

from .gossip import ShardedFolders, ShardedWeightStore
from .serialize import NodeUpdate
from .store import SharedFolder, WeightStore
from .strategies import FedAvg, PartialFedAvg, Strategy
from .telemetry import Telemetry
from .transport import family_transport_spec, normalize_transport
from .tree import PyTree, tree_to_numpy

_log = get_logger("node")


class FederationTimeout(RuntimeError):
    """Raised by SyncFederatedNode when peers never arrive (straggler/crash)."""


class _BaseNode:
    def __init__(
        self,
        *,
        strategy: Strategy | None = None,
        shared_folder: SharedFolder | ShardedFolders | None = None,
        store: WeightStore | ShardedWeightStore | None = None,
        node_id: str | None = None,
        transport: str | None = None,
        families=None,
        resume: bool = True,
        persist_strategy_state: bool = False,
        prefetch_interval: float | None = None,
        clock: Callable[[], float] = time.monotonic,
        on_step: "Callable[[_BaseNode, PyTree | None], None] | None" = None,
        telemetry: "Telemetry | bool | None" = None,
        lease_epoch: int = 0,
    ):
        # Elastic-fleet provenance: 0 for a node on its original slot claim,
        # >0 when a surviving worker adopted this slot at that lease epoch.
        # Rides every pushed update's wire meta so staleness-aware strategies
        # (FedAsync's epoch-gap discount) can damp resurrected stragglers.
        self.lease_epoch = int(lease_epoch)
        # Leaf-family selector (LoRA-style adapter federation): one kwarg
        # configures both halves of subset federation. When the node builds
        # its own store it ships only the selected families (``family(...)``
        # transport); and unless the caller passed an explicit strategy, it
        # aggregates only those families too (non-federated leaves stay
        # personal, bit-exact). A name, a sequence of names, or a mapping
        # name → sub-policy (full | quantized | delta) — see
        # ``tree.FAMILY_PATTERNS`` / ``register_family``.
        self.families = families
        if families is not None:
            if transport is None and store is None:
                transport = family_transport_spec(families)
            if strategy is None:
                # a mapping selector maps name → *sub-policy* (a transport
                # concern); the aggregation mask only needs the names
                names = tuple(families) if not isinstance(families, str) else families
                strategy = PartialFedAvg(families=names)
        self._owns_store = store is None
        if store is None:
            if shared_folder is None:
                raise ValueError("need shared_folder or store")
            if isinstance(shared_folder, ShardedFolders):
                store = ShardedWeightStore(shared_folder, transport=transport)
            else:
                store = WeightStore(shared_folder, transport=transport)
        elif transport is not None:
            # store.transport is the canonical pipeline spec; compare specs,
            # not raw strings, so "delta_q" matches a "delta(q)" store. A
            # node spec with no envelope also matches a store that added one
            # via compress= — the node is asserting the wire policy, and the
            # envelope is a store-construction detail.
            want = normalize_transport(transport)
            have = store.transport
            if want not in (have, have.rpartition("|")[0] or have):
                raise ValueError(
                    f"store already configured with transport {have!r}; "
                    "pass transport= only together with shared_folder"
                )
        self.store = store
        self.strategy = strategy or FedAvg()
        self.node_id = node_id or uuid.uuid4().hex[:8]
        self.clock = clock
        # Soak/observability hook: called once per federation step (after the
        # push and any aggregation) with (node, aggregated-or-None). The fleet
        # harness hangs heartbeat deposits on it; exceptions propagate — a
        # broken hook is a caller bug, not something to swallow mid-soak.
        self.on_step = on_step
        self.persist_strategy_state = persist_strategy_state
        # Telemetry: an instance wires in as-is; True/False forces on/off;
        # None defers to the REPRO_OBS env var (default off — span() then
        # returns a shared no-op and every hook is one attribute check).
        if isinstance(telemetry, Telemetry):
            self.telemetry = telemetry
        else:
            self.telemetry = Telemetry(enabled=telemetry)
        if not self.telemetry.node_id:
            self.telemetry.node_id = self.node_id
        if self.telemetry.enabled and self._owns_store:
            # Only a store this node built is exclusively its own traffic; a
            # caller-provided store may be shared, and its spans would
            # conflate nodes.
            store.attach_telemetry(self.telemetry)
        self.counter = 0  # local epoch counter; there is no global round
        self._last_state_hash: str | None = None
        # Restart/recovery (read-your-own-writes bootstrap): a node that comes
        # back under an id it deposited under before — a SIGKILL'd client
        # restarting — resumes its counter after its own ``latest/`` blob, and
        # exposes that blob so the caller can restore params instead of
        # restarting training from scratch. A fresh (generated) id has nothing
        # to recover, so only explicit ids pay the one lookup.
        self.resumed: NodeUpdate | None = None
        if resume and node_id is not None:
            previous = store.pull_node(node_id)
            if previous is not None:
                self.counter = previous.counter + 1
                self.resumed = previous
            # Strategy-state recovery: a resumed FedAvgM/FedAdam node
            # restores its momentum/moment vectors from the state/ blob it
            # (or its previous incarnation) deposited, so the server-
            # optimizer trajectory survives a crash — not just the params.
            if persist_strategy_state and previous is not None:
                recovered = store.pull_strategy_state(node_id)
                if (recovered is not None
                        and recovered[1].get("strategy") == self.strategy.name):
                    self.strategy.load_state_dict(recovered[0])
        # Background prefetch: warm the decoded-update cache between
        # federation steps so the step's pull is all cache hits.
        if prefetch_interval is not None:
            store.start_prefetch(prefetch_interval, exclude=self.node_id)
        # instrumentation
        self.num_pushes = 0
        self.num_pulls = 0
        self.num_skipped_pulls = 0
        self.num_aggregations = 0

    def transport_stats(self) -> dict[str, int]:
        """Wire-level counters from the underlying store — the pipeline's
        full stats dict (bytes written/read, decode-cache hits/misses, chain
        depths, residual norms, prefetch activity) — in one shape regardless
        of store kind, so transport experiments read a single dict per
        node."""
        store = self.store
        if hasattr(store, "cache_stats"):  # ShardedWeightStore aggregates
            return store.cache_stats()
        return store.transport_stats()

    def _finish_step(self, aggregated: PyTree | None) -> PyTree | None:
        """Every return path of update_parameters funnels through here so the
        ``on_step`` hook fires exactly once per federation step — including
        skipped-pull and no-peers steps, which a heartbeat must still count.
        Telemetry rounds tick here too, and every ``flush_every`` rounds the
        aggregator snapshots into an ``obs/<node>/<seq>`` blob."""
        tel = self.telemetry
        if tel.enabled:
            tel.end_round(aggregated=aggregated is not None)
            if tel.should_flush():
                try:
                    payload = tel.snapshot(self.transport_stats())
                    self.store.push_obs(self.node_id, payload["seq"], payload,
                                        keep=tel.obs_keep)
                except Exception:
                    # observability must never take down federation
                    _log.debug("node %s: obs flush failed", self.node_id,
                               exc_info=True)
        if self.on_step is not None:
            self.on_step(self, aggregated)
        return aggregated

    def _persist_strategy_state(self) -> None:
        state = self.strategy.state_dict()
        if state:
            self.store.push_strategy_state(
                self.node_id, self.strategy.name, self.counter, state)

    def _push(self, params: PyTree, num_examples: int, metrics: dict | None = None) -> NodeUpdate:
        update = NodeUpdate(
            params=tree_to_numpy(params),
            num_examples=num_examples,
            node_id=self.node_id,
            counter=self.counter,
            timestamp=self.clock(),
            metrics=metrics or {},
            lease_epoch=self.lease_epoch,
        )
        self.store.push(update)
        self.num_pushes += 1
        return update


class AsyncFederatedNode(_BaseNode):
    """Asynchronous serverless federation (paper Figure 2 / Algorithm 1)."""

    def update_parameters(
        self, params: PyTree, num_examples: int, metrics: dict | None = None
    ) -> PyTree | None:
        """Push-then-pull federation step; returns aggregated params, or
        ``None`` when no peer weights are available / store unchanged (the
        caller keeps training on its current weights — Algorithm 1's 'resume
        training' branch)."""
        tel = self.telemetry
        with tel.span("push"):
            own = self._push(params, num_examples, metrics)
        self.counter += 1

        with tel.span("pull"):
            state = self.store.state_hash(exclude_node=self.node_id)
            if state == self._last_state_hash:
                # Only our own deposit changed nothing relative to what we
                # already aggregated → skip the download entirely (paper's
                # hash check).
                peers = None
            else:
                peers = self.store.pull(exclude=self.node_id)
        if peers is None:
            self.num_skipped_pulls += 1
            return self._finish_step(None)
        self.num_pulls += 1
        # Record the PRE-pull hash: a peer depositing while we were pulling
        # must show up as a change next round. Re-hashing here would mark that
        # unseen blob as already-aggregated and drop it permanently; the
        # pre-pull hash only risks one redundant re-pull.
        self._last_state_hash = state
        if not peers:
            return self._finish_step(None)
        if tel.enabled:
            # Update staleness in local-epoch units (the FedAsync signal): how
            # far behind our own counter each pulled peer update is.
            for u in peers:
                tel.observe_staleness(own.counter - u.counter)
        with tel.span("aggregate"):
            aggregated = self.strategy.aggregate(own, peers)
        self.num_aggregations += 1
        if self.persist_strategy_state:
            self._persist_strategy_state()
        return self._finish_step(aggregated)


class SyncFederatedNode(_BaseNode):
    """Synchronous serverless federation: barrier on the weight store."""

    def __init__(self, *, num_nodes: int, timeout: float = 60.0, poll_interval: float = 0.02,
                 resume: bool = False, **kwargs):
        # resume defaults OFF here (unlike async): a node that bootstraps its
        # counter past its peers would wait on a round they will never reach,
        # while the peers aggregate their stale history blobs. Sync recovery
        # needs all participants restarted together — opt in explicitly.
        super().__init__(resume=resume, **kwargs)
        # Round-exact blobs are required so every client aggregates the same
        # set even when a fast peer has already deposited round t+1. Flipping
        # keep_history on a store the CALLER constructed (and may share with
        # async nodes) is a side effect they must hear about: every node using
        # that store starts writing per-round history blobs.
        if not self.store.keep_history:
            if not self._owns_store:
                warnings.warn(
                    "SyncFederatedNode is enabling keep_history on a caller-"
                    "provided store; all nodes sharing it will now write "
                    "history/ blobs. Construct the store with "
                    "keep_history=True (or give sync nodes their own store) "
                    "to make this explicit.",
                    stacklevel=2,
                )
            self.store.keep_history = True
        self.num_nodes = num_nodes
        self.timeout = timeout
        self.poll_interval = poll_interval

    def update_parameters(
        self, params: PyTree, num_examples: int, metrics: dict | None = None
    ) -> PyTree:
        tel = self.telemetry
        with tel.span("push"):
            own = self._push(params, num_examples, metrics)
        round_id = self.counter
        self.counter += 1

        # The injected clock drives the deadline, not time.monotonic():
        # simulated-clock tests of timeout behavior (and virtual-time
        # harnesses) must be able to age the barrier without real sleeping.
        deadline = self.clock() + self.timeout
        with tel.span("pull"):
            while True:
                peers = self.store.pull_round(round_id, exclude=self.node_id)
                self.num_pulls += 1
                if len(peers) >= self.num_nodes - 1:
                    break
                if self.clock() > deadline:
                    raise FederationTimeout(
                        f"node {self.node_id}: only {len(peers) + 1}/{self.num_nodes} "
                        f"nodes reached round {round_id} within {self.timeout}s"
                    )
                time.sleep(self.poll_interval)
        if tel.enabled:
            for u in peers:
                tel.observe_staleness(own.counter - u.counter)
        # Deterministic aggregation order across clients → identical results.
        peers.sort(key=lambda u: u.node_id)
        with tel.span("aggregate"):
            aggregated = self.strategy.aggregate(own, peers)
        self.num_aggregations += 1
        if self.persist_strategy_state:
            self._persist_strategy_state()
        self._finish_step(aggregated)
        return aggregated
