"""Pytree utilities used across the federated core.

All federated aggregation ultimately reduces to weighted sums over pytrees of
arrays. These helpers keep that logic in one place and let the Pallas
``fed_agg`` kernel slot in as the hot path for the flattened representation.

``LeafSpec`` is the contract of the flat-vector federation hot path: the
paths/shapes/dtypes/offsets of a model's leaves, computed once per structure
and shared (content-hashed) by every flat vector that layout describes. In
steady state a federation step touches parameters only as contiguous f32
vectors — per-leaf Python work happens exactly twice: when a spec is first
built, and at the trainer boundary where a flat aggregate is unflattened back
into the model's pytree.
"""
from __future__ import annotations

import hashlib
import re
from typing import Any, Callable, Mapping, Sequence

import jax
import numpy as np

PyTree = Any

# Dtypes whose every value survives a float32 round-trip: the store may decode
# such leaves straight into a flat f32 vector and still reconstruct the exact
# tree a per-leaf reader would (bf16/f8 ship as f32 on the wire already).
_F32_EXACT = frozenset(
    {"float32", "float16", "bfloat16", "float8_e4m3fn", "float8_e5m2"}
)


class LeafSpec:
    """Flat layout of one pytree structure: paths, shapes, dtypes, offsets.

    A spec is immutable once built and content-hashed (``key``), so two specs
    with equal keys describe byte-compatible flat vectors even when built in
    different stores or processes. All ``FlatUpdate``s pulled from one store
    share a single spec instance per structure, which makes the compatibility
    check on the aggregation hot path an identity comparison.
    """

    def __init__(self, paths, shapes, dtypes, treedef):
        self.paths: tuple[str, ...] = tuple(paths)
        self.shapes: tuple[tuple[int, ...], ...] = tuple(tuple(s) for s in shapes)
        self.dtypes: tuple[np.dtype, ...] = tuple(np.dtype(d) for d in dtypes)
        self.treedef = treedef
        self.sizes: tuple[int, ...] = tuple(int(np.prod(s)) for s in self.shapes)
        offsets = np.zeros(len(self.sizes) + 1, np.int64)
        np.cumsum(self.sizes, out=offsets[1:])
        self.offsets: np.ndarray = offsets[:-1]
        self.bounds: np.ndarray = offsets  # offsets plus the total, for searchsorted
        self.num_params: int = int(offsets[-1])
        self.index: dict[str, int] = {p: i for i, p in enumerate(self.paths)}
        self._family_views: dict[tuple, "FamilyView"] = {}
        # True when flatten→unflatten is value-exact (every leaf f32-embeddable)
        self.f32_exact: bool = all(d.name in _F32_EXACT for d in self.dtypes)
        self.key: str = hashlib.sha256(
            repr((self.paths, self.shapes, tuple(d.name for d in self.dtypes))).encode()
        ).hexdigest()[:16]

    @classmethod
    def of(cls, tree: PyTree) -> "LeafSpec":
        leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
        paths, shapes, dtypes = [], [], []
        for path, leaf in leaves_with_paths:
            # shape/dtype attributes cover arrays AND abstract values
            # (jax.eval_shape output) without forcing a device transfer
            shape, dtype = getattr(leaf, "shape", None), getattr(leaf, "dtype", None)
            if shape is None or dtype is None:
                arr = np.asarray(leaf)
                shape, dtype = arr.shape, arr.dtype
            paths.append(path_str(path))
            shapes.append(shape)
            dtypes.append(dtype)
        return cls(paths, shapes, dtypes, treedef)

    def compatible(self, other: "LeafSpec | None") -> bool:
        return other is not None and (other is self or other.key == self.key)

    def describes(self, tree: PyTree) -> bool:
        """Cheap steady-state check: same treedef (C-level compare); shape
        drift under an identical treedef is caught by ``flatten``'s size
        check."""
        return jax.tree.structure(tree) == self.treedef

    def flatten(self, tree: PyTree) -> np.ndarray:
        """One contiguous f32 vector in spec order (single concatenate pass).
        Per-leaf sizes are validated, so a shape permutation under the same
        treedef cannot silently produce a mislaid vector."""
        leaves = jax.tree.leaves(tree)
        if len(leaves) != len(self.sizes):
            raise ValueError(f"{len(leaves)} leaves vs spec's {len(self.sizes)}")
        parts = []
        for n, leaf in zip(self.sizes, leaves):
            arr = np.asarray(leaf, np.float32)
            if arr.size != n:
                raise ValueError(f"leaf size {arr.size} vs spec's {n}")
            parts.append(arr.reshape(-1))
        return np.concatenate(parts) if parts else np.zeros((0,), np.float32)

    def flatten_into(self, tree: PyTree, out: np.ndarray) -> np.ndarray:
        """Flatten ``tree`` into a caller-provided (warm) f32 buffer — the
        allocation-free boundary for fresh trainer params entering the flat
        hot path (fresh 10^8-element allocations cost more in page faults
        than the aggregation itself)."""
        leaves = jax.tree.leaves(tree)
        if len(leaves) != len(self.sizes):
            raise ValueError(f"{len(leaves)} leaves vs spec's {len(self.sizes)}")
        if out.shape != (self.num_params,):
            raise ValueError(f"out shape {out.shape} vs ({self.num_params},)")
        for o, n, leaf in zip(self.offsets, self.sizes, leaves):
            arr = np.asarray(leaf)
            if arr.size != n:
                raise ValueError(f"leaf size {arr.size} vs spec's {n}")
            out[o:o + n] = arr.reshape(-1)
        return out

    def unflatten(self, vec: np.ndarray) -> PyTree:
        """Flat vector → pytree with original shapes/dtypes. Float32 leaves are
        *views* into ``vec`` (zero copy); treat them as read-only."""
        vec = np.asarray(vec).reshape(-1)
        if vec.size != self.num_params:
            raise ValueError(f"{vec.size} params vs spec's {self.num_params}")
        leaves = [
            np.asarray(vec[o:o + n], dtype=d).reshape(s)
            for o, n, d, s in zip(self.offsets, self.sizes, self.dtypes, self.shapes)
        ]
        return jax.tree.unflatten(self.treedef, leaves)

    def empty_flat(self) -> np.ndarray:
        return np.empty((self.num_params,), np.float32)

    def family_view(self, families: "str | Sequence[str] | Mapping[str, str]") -> "FamilyView":
        """Sub-vector view of the named leaf families (cached per selector).

        ``families`` is a registered family name, a sequence of names, or an
        explicit ``{name: path-regex}`` mapping (see ``FAMILY_PATTERNS``).
        """
        resolved = resolve_family_patterns(families)
        cache_key = tuple(resolved.items())
        view = self._family_views.get(cache_key)
        if view is None:
            view = self._family_views[cache_key] = FamilyView(self, resolved)
        return view

    def __repr__(self) -> str:
        return (f"LeafSpec(leaves={len(self.paths)}, params={self.num_params}, "
                f"key={self.key})")


# --------------------------------------------------------------------------
# Leaf families: named subsets of a model's leaves, selected by path pattern
# --------------------------------------------------------------------------

# Registry of well-known families. Patterns match path *segments* of the
# 'a/b/c' strings a LeafSpec stores; ``register_family`` adds project-specific
# ones. The names are the vocabulary of the ``family(...)`` transport stage
# and of PartialFedAvg's ``families=`` selector.
FAMILY_PATTERNS: dict[str, str] = {
    "adapters": r"(^|/)(lora_[ab]|adapter[^/]*)(/|$)",
    "embeddings": r"(^|/)(embed|unembed)(/|$)",
    "norms": r"(^|/)[a-z_]*norm[0-9]*(/|$)",
}


def register_family(name: str, pattern: str) -> None:
    """Register (or override) a named leaf family pattern."""
    re.compile(pattern)  # fail fast on a malformed regex
    FAMILY_PATTERNS[name] = pattern


def resolve_family_patterns(
    families: str | Sequence[str] | Mapping[str, str],
) -> dict[str, str]:
    """Normalize a family selector into an ordered ``{name: pattern}`` dict."""
    if isinstance(families, str):
        families = (families,)
    if isinstance(families, Mapping):
        return {str(n): str(p) for n, p in families.items()}
    out: dict[str, str] = {}
    for name in families:
        if name not in FAMILY_PATTERNS:
            raise KeyError(
                f"unknown leaf family {name!r}; registered: {sorted(FAMILY_PATTERNS)} "
                "(register_family adds more)")
        out[name] = FAMILY_PATTERNS[name]
    return out


class FamilyView:
    """Flat sub-vector view of a LeafSpec restricted to named leaf families.

    A leaf belongs to the first selected family whose pattern matches its
    path; unmatched leaves are outside the view. The view exposes the flat
    bool ``mask`` / sorted ``indices`` over the spec's vector, per-family
    index subsets for codec routing, and ``extract``/``scatter`` as the
    gather/scatter-back pair. ``pattern`` is the single equivalent regex, so
    the same selector can drive ``PartialFedAvg(shared_pattern=...)`` and the
    per-leaf reference oracle.
    """

    def __init__(self, spec: LeafSpec, patterns: Mapping[str, str]):
        if not patterns:
            raise ValueError("family selector is empty")
        self.spec = spec
        self.names: tuple[str, ...] = tuple(patterns)
        compiled = {n: re.compile(p) for n, p in patterns.items()}
        leaf_names = []
        for path in spec.paths:
            fam = next((n for n, rx in compiled.items() if rx.search(path)), None)
            leaf_names.append(fam)
        self.leaf_names: tuple[str | None, ...] = tuple(leaf_names)
        self.leaf_mask: tuple[bool, ...] = tuple(f is not None for f in leaf_names)
        self.paths: tuple[str, ...] = tuple(
            p for p, f in zip(spec.paths, leaf_names) if f is not None)
        mask = np.zeros(spec.num_params, bool)
        fam_spans: dict[str, list[tuple[int, int]]] = {n: [] for n in self.names}
        for fam, off, size in zip(leaf_names, spec.offsets, spec.sizes):
            if fam is not None:
                mask[off:off + size] = True
                fam_spans[fam].append((int(off), int(size)))
        empty = [n for n, spans in fam_spans.items() if not spans]
        if empty:
            raise ValueError(
                f"leaf families {empty} match no leaf of {spec!r}; "
                f"paths: {list(spec.paths)[:8]}...")
        self.mask: np.ndarray = mask
        self.indices: np.ndarray = np.flatnonzero(mask).astype(np.int64)
        self.num_params: int = int(self.indices.size)
        self._fam_spans = fam_spans
        self._fam_indices: dict[str, np.ndarray] = {}
        self.pattern: str = "|".join(f"(?:{p})" for p in patterns.values())
        self.key: str = hashlib.sha256(
            repr((spec.key, tuple(patterns.items()))).encode()).hexdigest()[:16]

    def indices_of(self, name: str) -> np.ndarray:
        """Sorted flat indices of one family's parameters."""
        idx = self._fam_indices.get(name)
        if idx is None:
            spans = self._fam_spans[name]
            idx = (np.concatenate([np.arange(o, o + s, dtype=np.int64) for o, s in spans])
                   if spans else np.zeros((0,), np.int64))
            idx.sort()
            self._fam_indices[name] = idx
        return idx

    def extract(self, flat: np.ndarray) -> np.ndarray:
        """Gather the view's sub-vector out of a full flat vector (copy)."""
        return np.asarray(flat)[self.indices]

    def scatter(self, sub: np.ndarray, out: np.ndarray) -> np.ndarray:
        """Scatter a sub-vector back into a full flat vector, in place."""
        if sub.shape != (self.num_params,):
            raise ValueError(f"sub shape {sub.shape} vs ({self.num_params},)")
        out[self.indices] = sub
        return out

    def __repr__(self) -> str:
        return (f"FamilyView({'+'.join(self.names)}, leaves={len(self.paths)}, "
                f"params={self.num_params}/{self.spec.num_params})")


def tree_zeros_like(tree: PyTree) -> PyTree:
    return jax.tree.map(np.zeros_like, tree)


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(lambda x, y: x + y, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(lambda x, y: x - y, a, b)


def tree_scale(tree: PyTree, s: float) -> PyTree:
    return jax.tree.map(lambda x: x * s, tree)


def tree_weighted_sum(trees: Sequence[PyTree], weights: Sequence[float]) -> PyTree:
    """sum_i weights[i] * trees[i], leafwise. Host-side (numpy) friendly."""
    if len(trees) != len(weights):
        raise ValueError(f"{len(trees)} trees vs {len(weights)} weights")
    if not trees:
        raise ValueError("empty aggregation")

    def _leaf(*leaves):
        acc = leaves[0] * weights[0]
        for leaf, w in zip(leaves[1:], weights[1:]):
            acc = acc + leaf * w
        return acc

    return jax.tree.map(_leaf, *trees)


def tree_mean(trees: Sequence[PyTree]) -> PyTree:
    n = len(trees)
    return tree_weighted_sum(trees, [1.0 / n] * n)


def tree_weighted_mean(trees: Sequence[PyTree], weights: Sequence[float]) -> PyTree:
    """Weighted mean with weights normalized to sum to 1 (FedAvg, eq. 1)."""
    total = float(sum(weights))
    if total <= 0:
        raise ValueError(f"non-positive total weight {total}")
    return tree_weighted_sum(trees, [float(w) / total for w in weights])


def tree_map_with_path(fn: Callable, tree: PyTree) -> PyTree:
    """Map fn(path_str, leaf) over the tree."""

    def _fn(path, leaf):
        return fn(path_str(path), leaf)

    return jax.tree_util.tree_map_with_path(_fn, tree)


def path_str(path) -> str:
    """Render a jax key path as 'a/b/0/c'."""
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def tree_paths(tree: PyTree) -> list[str]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [path_str(p) for p, _ in leaves]


def tree_to_numpy(tree: PyTree) -> PyTree:
    """Device→host copy; aggregation and the weight store live on host."""
    return jax.tree.map(lambda x: np.asarray(x), tree)


def tree_size_bytes(tree: PyTree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_num_params(tree: PyTree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_allclose(a: PyTree, b: PyTree, rtol: float = 1e-5, atol: float = 1e-6) -> bool:
    flat_a, treedef_a = jax.tree.flatten(a)
    flat_b, treedef_b = jax.tree.flatten(b)
    if treedef_a != treedef_b:
        return False
    return all(np.allclose(x, y, rtol=rtol, atol=atol) for x, y in zip(flat_a, flat_b))


def tree_l2_distance(a: PyTree, b: PyTree) -> float:
    sq = jax.tree.map(lambda x, y: float(np.sum((np.asarray(x, np.float64) - np.asarray(y, np.float64)) ** 2)), a, b)
    return float(np.sqrt(sum(jax.tree.leaves(sq))))


def tree_flatten_to_vector(tree: PyTree) -> tuple[np.ndarray, Callable[[np.ndarray], PyTree]]:
    """Flatten a pytree to a single 1-D float vector + an unflatten closure.

    Convenience wrapper over ``LeafSpec`` for one-shot callers; code on the
    federation hot path should build the spec once and reuse it.
    """
    spec = LeafSpec.of(tree)
    return spec.flatten(tree), spec.unflatten
