"""Pytree utilities used across the federated core.

All federated aggregation ultimately reduces to weighted sums over pytrees of
arrays. These helpers keep that logic in one place and let the Pallas
``fed_agg`` kernel slot in as the hot path for the flattened representation.

``LeafSpec`` is the contract of the flat-vector federation hot path: the
paths/shapes/dtypes/offsets of a model's leaves, computed once per structure
and shared (content-hashed) by every flat vector that layout describes. In
steady state a federation step touches parameters only as contiguous f32
vectors — per-leaf Python work happens exactly twice: when a spec is first
built, and at the trainer boundary where a flat aggregate is unflattened back
into the model's pytree.
"""
from __future__ import annotations

import hashlib
from typing import Any, Callable, Sequence

import jax
import numpy as np

PyTree = Any

# Dtypes whose every value survives a float32 round-trip: the store may decode
# such leaves straight into a flat f32 vector and still reconstruct the exact
# tree a per-leaf reader would (bf16/f8 ship as f32 on the wire already).
_F32_EXACT = frozenset(
    {"float32", "float16", "bfloat16", "float8_e4m3fn", "float8_e5m2"}
)


class LeafSpec:
    """Flat layout of one pytree structure: paths, shapes, dtypes, offsets.

    A spec is immutable once built and content-hashed (``key``), so two specs
    with equal keys describe byte-compatible flat vectors even when built in
    different stores or processes. All ``FlatUpdate``s pulled from one store
    share a single spec instance per structure, which makes the compatibility
    check on the aggregation hot path an identity comparison.
    """

    def __init__(self, paths, shapes, dtypes, treedef):
        self.paths: tuple[str, ...] = tuple(paths)
        self.shapes: tuple[tuple[int, ...], ...] = tuple(tuple(s) for s in shapes)
        self.dtypes: tuple[np.dtype, ...] = tuple(np.dtype(d) for d in dtypes)
        self.treedef = treedef
        self.sizes: tuple[int, ...] = tuple(int(np.prod(s)) for s in self.shapes)
        offsets = np.zeros(len(self.sizes) + 1, np.int64)
        np.cumsum(self.sizes, out=offsets[1:])
        self.offsets: np.ndarray = offsets[:-1]
        self.bounds: np.ndarray = offsets  # offsets plus the total, for searchsorted
        self.num_params: int = int(offsets[-1])
        self.index: dict[str, int] = {p: i for i, p in enumerate(self.paths)}
        # True when flatten→unflatten is value-exact (every leaf f32-embeddable)
        self.f32_exact: bool = all(d.name in _F32_EXACT for d in self.dtypes)
        self.key: str = hashlib.sha256(
            repr((self.paths, self.shapes, tuple(d.name for d in self.dtypes))).encode()
        ).hexdigest()[:16]

    @classmethod
    def of(cls, tree: PyTree) -> "LeafSpec":
        leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
        paths, shapes, dtypes = [], [], []
        for path, leaf in leaves_with_paths:
            arr = np.asarray(leaf)
            paths.append(path_str(path))
            shapes.append(arr.shape)
            dtypes.append(arr.dtype)
        return cls(paths, shapes, dtypes, treedef)

    def compatible(self, other: "LeafSpec | None") -> bool:
        return other is not None and (other is self or other.key == self.key)

    def describes(self, tree: PyTree) -> bool:
        """Cheap steady-state check: same treedef (C-level compare); shape
        drift under an identical treedef is caught by ``flatten``'s size
        check."""
        return jax.tree.structure(tree) == self.treedef

    def flatten(self, tree: PyTree) -> np.ndarray:
        """One contiguous f32 vector in spec order (single concatenate pass).
        Per-leaf sizes are validated, so a shape permutation under the same
        treedef cannot silently produce a mislaid vector."""
        leaves = jax.tree.leaves(tree)
        if len(leaves) != len(self.sizes):
            raise ValueError(f"{len(leaves)} leaves vs spec's {len(self.sizes)}")
        parts = []
        for n, leaf in zip(self.sizes, leaves):
            arr = np.asarray(leaf, np.float32)
            if arr.size != n:
                raise ValueError(f"leaf size {arr.size} vs spec's {n}")
            parts.append(arr.reshape(-1))
        return np.concatenate(parts) if parts else np.zeros((0,), np.float32)

    def flatten_into(self, tree: PyTree, out: np.ndarray) -> np.ndarray:
        """Flatten ``tree`` into a caller-provided (warm) f32 buffer — the
        allocation-free boundary for fresh trainer params entering the flat
        hot path (fresh 10^8-element allocations cost more in page faults
        than the aggregation itself)."""
        leaves = jax.tree.leaves(tree)
        if len(leaves) != len(self.sizes):
            raise ValueError(f"{len(leaves)} leaves vs spec's {len(self.sizes)}")
        if out.shape != (self.num_params,):
            raise ValueError(f"out shape {out.shape} vs ({self.num_params},)")
        for o, n, leaf in zip(self.offsets, self.sizes, leaves):
            arr = np.asarray(leaf)
            if arr.size != n:
                raise ValueError(f"leaf size {arr.size} vs spec's {n}")
            out[o:o + n] = arr.reshape(-1)
        return out

    def unflatten(self, vec: np.ndarray) -> PyTree:
        """Flat vector → pytree with original shapes/dtypes. Float32 leaves are
        *views* into ``vec`` (zero copy); treat them as read-only."""
        vec = np.asarray(vec).reshape(-1)
        if vec.size != self.num_params:
            raise ValueError(f"{vec.size} params vs spec's {self.num_params}")
        leaves = [
            np.asarray(vec[o:o + n], dtype=d).reshape(s)
            for o, n, d, s in zip(self.offsets, self.sizes, self.dtypes, self.shapes)
        ]
        return jax.tree.unflatten(self.treedef, leaves)

    def empty_flat(self) -> np.ndarray:
        return np.empty((self.num_params,), np.float32)

    def __repr__(self) -> str:
        return (f"LeafSpec(leaves={len(self.paths)}, params={self.num_params}, "
                f"key={self.key})")


def tree_zeros_like(tree: PyTree) -> PyTree:
    return jax.tree.map(np.zeros_like, tree)


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(lambda x, y: x + y, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(lambda x, y: x - y, a, b)


def tree_scale(tree: PyTree, s: float) -> PyTree:
    return jax.tree.map(lambda x: x * s, tree)


def tree_weighted_sum(trees: Sequence[PyTree], weights: Sequence[float]) -> PyTree:
    """sum_i weights[i] * trees[i], leafwise. Host-side (numpy) friendly."""
    if len(trees) != len(weights):
        raise ValueError(f"{len(trees)} trees vs {len(weights)} weights")
    if not trees:
        raise ValueError("empty aggregation")

    def _leaf(*leaves):
        acc = leaves[0] * weights[0]
        for leaf, w in zip(leaves[1:], weights[1:]):
            acc = acc + leaf * w
        return acc

    return jax.tree.map(_leaf, *trees)


def tree_mean(trees: Sequence[PyTree]) -> PyTree:
    n = len(trees)
    return tree_weighted_sum(trees, [1.0 / n] * n)


def tree_weighted_mean(trees: Sequence[PyTree], weights: Sequence[float]) -> PyTree:
    """Weighted mean with weights normalized to sum to 1 (FedAvg, eq. 1)."""
    total = float(sum(weights))
    if total <= 0:
        raise ValueError(f"non-positive total weight {total}")
    return tree_weighted_sum(trees, [float(w) / total for w in weights])


def tree_map_with_path(fn: Callable, tree: PyTree) -> PyTree:
    """Map fn(path_str, leaf) over the tree."""

    def _fn(path, leaf):
        return fn(path_str(path), leaf)

    return jax.tree_util.tree_map_with_path(_fn, tree)


def path_str(path) -> str:
    """Render a jax key path as 'a/b/0/c'."""
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def tree_paths(tree: PyTree) -> list[str]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [path_str(p) for p, _ in leaves]


def tree_to_numpy(tree: PyTree) -> PyTree:
    """Device→host copy; aggregation and the weight store live on host."""
    return jax.tree.map(lambda x: np.asarray(x), tree)


def tree_size_bytes(tree: PyTree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_num_params(tree: PyTree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_allclose(a: PyTree, b: PyTree, rtol: float = 1e-5, atol: float = 1e-6) -> bool:
    flat_a, treedef_a = jax.tree.flatten(a)
    flat_b, treedef_b = jax.tree.flatten(b)
    if treedef_a != treedef_b:
        return False
    return all(np.allclose(x, y, rtol=rtol, atol=atol) for x, y in zip(flat_a, flat_b))


def tree_l2_distance(a: PyTree, b: PyTree) -> float:
    sq = jax.tree.map(lambda x, y: float(np.sum((np.asarray(x, np.float64) - np.asarray(y, np.float64)) ** 2)), a, b)
    return float(np.sqrt(sum(jax.tree.leaves(sq))))


def tree_flatten_to_vector(tree: PyTree) -> tuple[np.ndarray, Callable[[np.ndarray], PyTree]]:
    """Flatten a pytree to a single 1-D float vector + an unflatten closure.

    Convenience wrapper over ``LeafSpec`` for one-shot callers; code on the
    federation hot path should build the spec once and reuse it.
    """
    spec = LeafSpec.of(tree)
    return spec.flatten(tree), spec.unflatten
