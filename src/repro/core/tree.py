"""Pytree utilities used across the federated core.

All federated aggregation ultimately reduces to weighted sums over pytrees of
arrays. These helpers keep that logic in one place and let the Pallas
``fed_agg`` kernel slot in as the hot path for the flattened representation.
"""
from __future__ import annotations

from typing import Any, Callable, Sequence

import jax
import numpy as np

PyTree = Any


def tree_zeros_like(tree: PyTree) -> PyTree:
    return jax.tree.map(np.zeros_like, tree)


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(lambda x, y: x + y, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(lambda x, y: x - y, a, b)


def tree_scale(tree: PyTree, s: float) -> PyTree:
    return jax.tree.map(lambda x: x * s, tree)


def tree_weighted_sum(trees: Sequence[PyTree], weights: Sequence[float]) -> PyTree:
    """sum_i weights[i] * trees[i], leafwise. Host-side (numpy) friendly."""
    if len(trees) != len(weights):
        raise ValueError(f"{len(trees)} trees vs {len(weights)} weights")
    if not trees:
        raise ValueError("empty aggregation")

    def _leaf(*leaves):
        acc = leaves[0] * weights[0]
        for leaf, w in zip(leaves[1:], weights[1:]):
            acc = acc + leaf * w
        return acc

    return jax.tree.map(_leaf, *trees)


def tree_mean(trees: Sequence[PyTree]) -> PyTree:
    n = len(trees)
    return tree_weighted_sum(trees, [1.0 / n] * n)


def tree_weighted_mean(trees: Sequence[PyTree], weights: Sequence[float]) -> PyTree:
    """Weighted mean with weights normalized to sum to 1 (FedAvg, eq. 1)."""
    total = float(sum(weights))
    if total <= 0:
        raise ValueError(f"non-positive total weight {total}")
    return tree_weighted_sum(trees, [float(w) / total for w in weights])


def tree_map_with_path(fn: Callable, tree: PyTree) -> PyTree:
    """Map fn(path_str, leaf) over the tree."""

    def _fn(path, leaf):
        return fn(path_str(path), leaf)

    return jax.tree_util.tree_map_with_path(_fn, tree)


def path_str(path) -> str:
    """Render a jax key path as 'a/b/0/c'."""
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def tree_paths(tree: PyTree) -> list[str]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [path_str(p) for p, _ in leaves]


def tree_to_numpy(tree: PyTree) -> PyTree:
    """Device→host copy; aggregation and the weight store live on host."""
    return jax.tree.map(lambda x: np.asarray(x), tree)


def tree_size_bytes(tree: PyTree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_num_params(tree: PyTree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_allclose(a: PyTree, b: PyTree, rtol: float = 1e-5, atol: float = 1e-6) -> bool:
    flat_a, treedef_a = jax.tree.flatten(a)
    flat_b, treedef_b = jax.tree.flatten(b)
    if treedef_a != treedef_b:
        return False
    return all(np.allclose(x, y, rtol=rtol, atol=atol) for x, y in zip(flat_a, flat_b))


def tree_l2_distance(a: PyTree, b: PyTree) -> float:
    sq = jax.tree.map(lambda x, y: float(np.sum((np.asarray(x, np.float64) - np.asarray(y, np.float64)) ** 2)), a, b)
    return float(np.sqrt(sum(jax.tree.leaves(sq))))


def tree_flatten_to_vector(tree: PyTree) -> tuple[np.ndarray, Callable[[np.ndarray], PyTree]]:
    """Flatten a pytree to a single 1-D float vector + an unflatten closure.

    Used to hand aggregation to the Pallas fed_agg kernel, which operates on
    (num_clients, num_params) stacked flats.
    """
    leaves, treedef = jax.tree.flatten(tree)
    shapes = [np.shape(l) for l in leaves]
    dtypes = [np.asarray(l).dtype for l in leaves]
    sizes = [int(np.prod(s)) for s in shapes]
    flat = np.concatenate([np.asarray(l, np.float32).reshape(-1) for l in leaves]) if leaves else np.zeros((0,), np.float32)

    def unflatten(vec: np.ndarray) -> PyTree:
        out, off = [], 0
        for shape, dtype, size in zip(shapes, dtypes, sizes):
            out.append(np.asarray(vec[off : off + size], dtype=dtype).reshape(shape))
            off += size
        return jax.tree.unflatten(treedef, out)

    return flat, unflatten
