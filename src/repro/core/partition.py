"""Label-skew data partitioning (paper §4.1).

Procedure, verbatim from the paper:
  1. partition training examples into n mutually exclusive subsets by label
     (n = number of federated nodes); e.g. n=2 on MNIST → digits 0-4 vs 5-9.
  2. with probability ``s`` (the skew) an example is assigned to the node
     owning its label partition; with probability 1-s it goes to a uniformly
     random node.

s=0 → random split (iid); s=1 → full skew (no label overlap across nodes).
"""
from __future__ import annotations

import numpy as np


def label_partitions(labels: np.ndarray, num_nodes: int, num_classes: int) -> np.ndarray:
    """Map each class to its owning node: contiguous blocks of classes."""
    classes_per_node = num_classes / num_nodes
    owners = np.minimum((np.arange(num_classes) / classes_per_node).astype(np.int64), num_nodes - 1)
    return owners[labels]


def skewed_assignment(
    labels: np.ndarray,
    num_nodes: int,
    skew: float,
    *,
    num_classes: int | None = None,
    seed: int = 0,
) -> np.ndarray:
    """Node index per example under the paper's skew-s sampling."""
    if not 0.0 <= skew <= 1.0:
        raise ValueError(f"skew must be in [0,1], got {skew}")
    labels = np.asarray(labels)
    if num_classes is None:
        num_classes = int(labels.max()) + 1
    rng = np.random.default_rng(seed)
    owner = label_partitions(labels, num_nodes, num_classes)
    random_node = rng.integers(0, num_nodes, size=labels.shape[0])
    use_owner = rng.random(labels.shape[0]) < skew
    return np.where(use_owner, owner, random_node)


def partition_dataset(
    inputs: np.ndarray,
    labels: np.ndarray,
    num_nodes: int,
    skew: float,
    *,
    num_classes: int | None = None,
    seed: int = 0,
) -> list[tuple[np.ndarray, np.ndarray]]:
    """Split (inputs, labels) into per-node shards under label skew."""
    assign = skewed_assignment(labels, num_nodes, skew, num_classes=num_classes, seed=seed)
    shards = []
    for node in range(num_nodes):
        idx = np.nonzero(assign == node)[0]
        shards.append((inputs[idx], labels[idx]))
    return shards


def partition_sequence_dataset(
    token_stream: np.ndarray, num_nodes: int, *, seed: int = 0
) -> list[np.ndarray]:
    """Contiguous document-level split for LM data (paper §4.4 splits the
    WikiText training set across nodes)."""
    n = token_stream.shape[0]
    bounds = np.linspace(0, n, num_nodes + 1).astype(np.int64)
    return [token_stream[bounds[i] : bounds[i + 1]] for i in range(num_nodes)]
