"""Sharded gossip weight store — O(group) federation for 10⁴-node fleets.

The flat ``WeightStore`` scans every fleet member on each ``state_hash`` /
``pull``, so the store itself becomes the bottleneck long before the ROADMAP's
"millions of users": per-step cost is O(fleet). This module partitions the
fleet into *node groups*, each owning its own ``SharedFolder`` (any existing
backend — memory, disk, s3, cache-wrapped), so a node's per-step ``push`` /
``state_hash`` / ``pull`` touch only its home group's folder: O(group).

Cross-group information flows by gossip instead of scanning:

* Every push refreshes the pushing node's *group summary* — the
  example-weighted mean of the group's latest params, carrying the total
  ``num_examples`` behind it and a version vector (node → counter). The
  summary is deposited under a versioned key
  ``summary/<origin>/<version>-<content hash>`` in the group's own folder;
  the zero-padded version scalar (sum of counters + 1) makes freshness
  comparable from a key listing alone — no blob reads — and the hash makes
  version-scalar ties between racing writers resolve deterministically.

* Groups form a ring. After pushing, a node *forwards* every summary its home
  folder holds (its own group's and any it previously received) to the next
  ``gossip_fanout`` **populated** groups on the ring, skipping-but-seeding
  empty groups so holes in a hash-assigned fleet never partition the ring.
  A forward is a cheap key-listing comparison plus a blob copy only when the
  target's copy is missing or older — steady state writes nothing.

* ``pull`` returns the home group's real peer updates plus a bounded sample
  of foreign-group summaries as pseudo-peers (node id ``group:<origin>``,
  weighted by the group's total example count), so the existing client-side
  strategies fold remote groups into aggregation unchanged.

An update therefore propagates fleet-wide within at most one populated-group
hop per gossip round: every group hears about it within ``num_groups`` rounds
(the ring diameter) — the property test in ``tests/test_gossip.py`` proves the
bound under adversarial push orderings.

**Hierarchical tiers** (``shard<G>x<L>+<uri>``): one flat ring still makes
every group index every other group's summary — O(num_groups) per pull. With
``L > 1`` the groups form a *summary tree* instead (``GossipHierarchy``):
level-0 rings are confined to segments of ``branching ≈ G**(1/L)`` groups;
each segment deterministically elects (stable hash — no coordinator, no
messages) an *aggregator* group whose folder collects the segment's summaries
and holds their fold — one level-1 ``SuperSummary`` blob under
``summary1/<origin>/…`` — forwarded on a shorter ring of aggregators,
recursively, until the top tier is a single ring. Any segment member's push
performs the aggregator duties by writing into the elected folder, so the
election never needs the aggregator group to have live members. A push then
touches O(branching · levels) = O(G**(1/L) · L) folders and a pull indexes one
summary chain — own segment at level 0 plus one sibling set per tier — instead
of N/G summaries; the per-tier sibling sets partition the fleet, so nothing is
double-counted. Information crosses the fleet within ``levels ×
per-ring-diameter`` pushes (property-tested at ≥2 levels).

Consistency model: the summary layer is eventually consistent. Two same-group
writers racing a refresh can leave one contribution out of the summary until
either pushes again (last-writer-wins per version scalar); real ``latest/``
deposits are never involved in the race, so within-group federation stays
exactly as strong as the flat store.

``ShardedWeightStore`` presents the ``WeightStore`` interface, so
``AsyncFederatedNode`` / ``SyncFederatedNode`` work unchanged on top;
``make_folder("shard<G>+<uri>")`` routes URIs here (see ``ShardedFolders``).
"""
from __future__ import annotations

import contextlib
import hashlib
import math
import threading
import time
from typing import Callable, Iterable, Mapping, Sequence

import numpy as np

from .serialize import (
    FlatDecodeUnsupported,
    FlatUpdate,
    GroupSummary,
    NodeUpdate,
    SuperSummary,
    content_hash,
    decode_params_flat,
    deserialize_fleet_blob,
    deserialize_group_summary,
    deserialize_super_summary,
    serialize_fleet_blob,
)
from .store import SharedFolder, WeightStore
from .transport import TransportPipeline, _LruCache
from .tree import tree_weighted_mean
from repro.logs import get_logger

_log = get_logger("gossip")

_SUMMARY_PREFIX = "summary/"
GROUP_PEER_PREFIX = "group:"  # node_id prefix of summary pseudo-peers in pull()
_NULL_SPAN = contextlib.nullcontext()


def _summary_prefix(level: int) -> str:
    """Key prefix of one summary tier: level 0 keeps the flat-ring layout
    (``summary/``) so single-tier stores are the L=1 degenerate case on disk
    too; tiers deposit under ``summary<level>/``."""
    return _SUMMARY_PREFIX if level == 0 else f"summary{level}/"


def group_peer_id(origin: int, level: int = 0) -> str:
    """Pseudo-peer node id a (super-)summary decodes to: ``group:<origin>``
    at level 0 (unchanged from the flat ring), ``group:L<level>.<origin>``
    for tiers."""
    if level == 0:
        return f"{GROUP_PEER_PREFIX}{origin}"
    return f"{GROUP_PEER_PREFIX}L{level}.{origin}"

# one grammar owns all routing: the shard-wrapper syntax is defined once, in
# transport.py, next to the rest of the folder-URI/pipeline grammar
from .transport import _SHARD_RE as SHARD_URI_RE  # noqa: E402


# --------------------------------------------------------------------------
# Group assignment
# --------------------------------------------------------------------------


def default_group_of(node_id: str, num_groups: int) -> int:
    """Stable hash assignment: the same node id maps to the same group on any
    machine, any process, any fleet composition — a node can compute its home
    group knowing nothing but its own id."""
    if num_groups < 1:
        raise ValueError(f"num_groups must be >= 1, got {num_groups}")
    h = int.from_bytes(hashlib.sha256(node_id.encode()).digest()[:8], "big")
    return h % num_groups


def balanced_groups(node_ids: Iterable[str], num_groups: int) -> dict[str, int]:
    """Explicit balanced assignment for a *known* fleet: deterministic in the
    node **set** (any iteration order), group sizes differ by at most one, so
    no group is empty once ``len(node_ids) >= num_groups``. Use as the
    ``group_of`` override when the fleet roster is known up front; the default
    hash assignment needs no roster but only balances in expectation."""
    if num_groups < 1:
        raise ValueError(f"num_groups must be >= 1, got {num_groups}")
    ordered = sorted(set(node_ids), key=lambda n: (hashlib.sha256(n.encode()).hexdigest(), n))
    return {n: i % num_groups for i, n in enumerate(ordered)}


# --------------------------------------------------------------------------
# Roster blobs — epoch-versioned fleet membership, in the store itself
# --------------------------------------------------------------------------
#
# Elastic fleets change composition mid-soak: workers die, their nodes get
# adopted, new workers join. The membership truth lives where everything else
# does — as blobs. ``fleet/roster/<epoch>`` holds the sorted node set at that
# epoch; epochs are write-once (``put_if_absent``), so concurrent publishers
# race on the *next* key and exactly one wins (same CAS-by-key discipline as
# slot leases). Readers take the highest epoch present. The ``fleet/`` prefix
# keeps rosters out of every state hash, like all launcher control traffic.

ROSTER_PREFIX = "fleet/roster/"


def _roster_key(epoch: int) -> str:
    return f"{ROSTER_PREFIX}{epoch:06d}"


def read_roster(folder: SharedFolder) -> tuple[int, list[str]] | None:
    """Freshest roster in ``folder`` -> (epoch, sorted node ids), or None."""
    best = -1
    for key in folder.keys():
        if key.startswith(ROSTER_PREFIX):
            tail = key[len(ROSTER_PREFIX):]
            if tail.isdigit():
                best = max(best, int(tail))
    # walk downward: the freshest key could lose a race with a concurrent
    # delete/GC, and an older epoch is a valid (just stale) answer
    while best >= 0:
        blob = folder.get(_roster_key(best))
        if blob is not None:
            try:
                kind, payload = deserialize_fleet_blob(blob)
                if kind == "roster":
                    return int(payload.get("epoch", best)), [
                        str(n) for n in payload.get("nodes", [])]
            except (ValueError, KeyError):
                pass
        best -= 1
    return None


def write_roster(folder: SharedFolder, node_ids: Iterable[str], *,
                 retries: int = 8) -> int:
    """Publish ``node_ids`` as the current roster; returns the epoch it lives
    at. No-op (returns the current epoch) when the membership set is unchanged;
    otherwise CAS-bumps to the next epoch, retrying through concurrent
    publishers until one epoch holds this exact set."""
    nodes = sorted(set(str(n) for n in node_ids))
    for _ in range(retries):
        cur = read_roster(folder)
        if cur is not None and cur[1] == nodes:
            return cur[0]
        epoch = 0 if cur is None else cur[0] + 1
        blob = serialize_fleet_blob(
            "roster", {"epoch": epoch, "nodes": nodes, "time": time.time()})
        if folder.put_if_absent(_roster_key(epoch), blob):
            return epoch
    raise RuntimeError(
        f"roster write lost {retries} consecutive epoch races; giving up")


# --------------------------------------------------------------------------
# Hierarchical topology — a pure function of (num_groups, levels)
# --------------------------------------------------------------------------


def _elect(level: int, origin: int, size: int) -> int:
    """Stable-hash aggregator election: which of the ``size`` children of
    (level, origin) carries the segment's super-summary. Every participant
    computes the same answer from the tuple alone — no coordinator, no
    messages, no dependence on who is alive."""
    h = int.from_bytes(
        hashlib.sha256(f"agg:{level}:{origin}".encode()).digest()[:8], "big")
    return h % size


class GossipHierarchy:
    """Static summary-tree topology over ``num_groups`` level-0 groups.

    Everything here is arithmetic on origin indices — deterministic in
    (num_groups, levels), so every node (and every fresh store instance)
    derives the identical tree with zero communication. Level-t *origins*
    (0..counts[t]) name summary blobs: a level-0 origin is a group, a level-t
    origin is one segment of level-(t-1) origins, folded into a single
    ``SuperSummary`` held in the folder of its hash-elected aggregator group
    (``holder``). Rings at every non-top level are confined to one parent
    segment; the top level is a single global ring. ``levels=1`` degenerates
    exactly to the flat ring (one level-0 ring over all groups, no tiers).
    """

    def __init__(self, num_groups: int, levels: int = 1):
        if num_groups < 1:
            raise ValueError(f"num_groups must be >= 1, got {num_groups}")
        if levels < 1:
            raise ValueError(f"levels must be >= 1, got {levels}")
        self.num_groups = num_groups
        self.levels = levels
        # L-th root of G: every tier's rings end up comparably sized, which is
        # what makes the per-push folder count O(G**(1/L) * L) = O(log G)
        self.branching = (
            max(2, math.ceil(num_groups ** (1.0 / levels))) if levels > 1
            else num_groups
        )
        counts = [num_groups]
        for _ in range(1, levels):
            counts.append(max(1, math.ceil(counts[-1] / self.branching)))
        self.counts = counts  # counts[t] = number of level-t origins
        self._holders: dict[tuple[int, int], int] = {}
        self._scopes: dict[int, dict[int, frozenset[int]]] = {}

    def children(self, level: int, origin: int) -> range:
        """Level-(level-1) origins folded into (level, origin)."""
        s = self.branching
        lo = origin * s
        return range(lo, min(lo + s, self.counts[level - 1]))

    def holder(self, level: int, origin: int) -> int:
        """The group whose folder holds (level, origin)'s summary blob. Level
        0: the group itself. Tiers: the elected child's holder, recursively —
        distinct origins at one level have disjoint subtrees, so their holders
        never collide."""
        if level == 0:
            return origin
        key = (level, origin)
        g = self._holders.get(key)
        if g is None:
            kids = self.children(level, origin)
            g = self.holder(level - 1, kids[_elect(level, origin, len(kids))])
            self._holders[key] = g
        return g

    def path(self, group: int) -> list[int]:
        """``group``'s ancestor origin at each level: path[0] is the group,
        path[t] the level-t segment covering it (contiguous chunking makes
        this a plain integer division)."""
        p = [group]
        for _ in range(1, self.levels):
            p.append(p[-1] // self.branching)
        return p

    def ring(self, level: int, origin: int) -> range:
        """Origins of the level-``level`` ring containing ``origin``: the
        sibling chunk under one parent, except the top level — one global
        ring (its origins have no parent to confine them)."""
        if level >= self.levels - 1:
            return range(self.counts[level])
        s = self.branching
        lo = (origin // s) * s
        return range(lo, min(lo + s, self.counts[level]))

    def scope(self, group: int) -> dict[int, frozenset[int]]:
        """Pull admissibility: level -> origins whose (super-)summaries
        ``group``'s pulls fold in as pseudo-peers. Level 0 covers the own
        segment's other groups; each tier covers exactly the leaf groups no
        lower level reaches (the own-path origin is excluded at every level —
        it covers the puller itself). The per-level sets therefore partition
        the foreign fleet: nothing is double-counted."""
        sc = self._scopes.get(group)
        if sc is None:
            p = self.path(group)
            sc = {
                t: frozenset(o for o in self.ring(t, p[t]) if o != p[t])
                for t in range(self.levels)
            }
            self._scopes[group] = sc
        return sc

    def diameter(self) -> int:
        """Worst-case push count for information to cross the fleet:
        ``levels × max per-ring diameter`` (the property-tested bound)."""
        per_ring = max(
            len(self.ring(t, 0)) for t in range(self.levels)
        )
        return self.levels * per_ring

    def __repr__(self) -> str:
        return (f"GossipHierarchy(num_groups={self.num_groups}, "
                f"levels={self.levels}, branching={self.branching}, "
                f"counts={self.counts})")


# --------------------------------------------------------------------------
# Per-group folder routing
# --------------------------------------------------------------------------


def _append_group(uri: str, group: int) -> str:
    """Derive group ``group``'s folder URI from the base URI, preserving any
    ``cache+``/``retry+`` wrapping ('shard4+cache+/mnt/x' caches each group
    folder; 'shard4+retry+/mnt/x' retries each group folder's I/O)."""
    if uri.startswith("cache+"):
        return "cache+" + _append_group(uri[len("cache+"):], group)
    if uri.startswith("retry+"):
        return "retry+" + _append_group(uri[len("retry+"):], group)
    if uri == "memory://":
        # anonymous memory:// mints a fresh in-process folder per make_folder
        # call; ShardedFolders caches one instance per group, which is the
        # identity that matters. Named memory://<name> URIs fall through to
        # the path-suffix branch so each group shares one registry entry.
        return "memory://"
    return uri.rstrip("/") + f"/group{group:04d}"


class ShardedFolders:
    """Handle to a family of per-group folders (lazily created, cached).

    Built from a base URI (``make_folder("shard<G>+<uri>")`` returns one) or
    an explicit ``factory``. Not itself a ``SharedFolder`` — it is the routing
    table a ``ShardedWeightStore`` shards over, and ``_BaseNode`` accepts it
    wherever ``shared_folder=`` is taken.
    """

    def __init__(
        self,
        num_groups: int,
        uri: str | None = None,
        *,
        levels: int = 1,
        factory: Callable[[int], SharedFolder] | None = None,
    ):
        if num_groups < 1:
            raise ValueError(f"num_groups must be >= 1, got {num_groups}")
        if levels < 1:
            raise ValueError(f"levels must be >= 1, got {levels}")
        if (uri is None) == (factory is None):
            raise ValueError("pass exactly one of uri= or factory=")
        self.num_groups = num_groups
        self.levels = levels  # summary tiers the store gossips over (1 = flat ring)
        self.uri = uri
        self._factory = factory
        self._folders: dict[int, SharedFolder] = {}
        self._lock = threading.Lock()

    @classmethod
    def from_uri(cls, uri: str) -> "ShardedFolders":
        m = SHARD_URI_RE.match(uri)
        if not m:
            raise ValueError(
                f"not a shard URI: {uri!r} (expected 'shard<G>[x<L>]+<uri>')")
        levels = int(m.group(2)) if m.group(2) is not None else 1
        if levels < 1:
            raise ValueError(f"shard<G>x<L>+ needs L >= 1, got {uri!r}")
        return cls(int(m.group(1)), m.group(3), levels=levels)

    def group_uri(self, group: int) -> str | None:
        if self.uri is None:
            return None
        return _append_group(self.uri, group)

    def group_folder(self, group: int) -> SharedFolder:
        if not 0 <= group < self.num_groups:
            raise ValueError(f"group {group} out of range [0, {self.num_groups})")
        with self._lock:
            folder = self._folders.get(group)
            if folder is None:
                if self._factory is not None:
                    folder = self._factory(group)
                else:
                    from .store import make_folder  # lazy: store routes shard URIs here

                    folder = make_folder(self.group_uri(group))
                self._folders[group] = folder
            return folder

    @classmethod
    def from_folders(cls, folders: Sequence[SharedFolder], *,
                     levels: int = 1) -> "ShardedFolders":
        folders = list(folders)
        return cls(len(folders), levels=levels, factory=lambda g: folders[g])

    def __len__(self) -> int:
        return self.num_groups

    def __repr__(self) -> str:
        src = self.uri if self.uri is not None else "<factory>"
        return (f"ShardedFolders(num_groups={self.num_groups}, "
                f"levels={self.levels}, uri={src!r})")


# --------------------------------------------------------------------------
# The sharded store
# --------------------------------------------------------------------------


def _summary_key(origin: int, version: int, blob_hash: str, *,
                 level: int = 0) -> str:
    """``summary[<level>]/<origin>/<version>-<hash>``: the zero-padded version
    makes freshness a plain string comparison from a key listing, and the
    content hash makes the key name its exact bytes — two racing refreshes
    that land on the same version scalar produce *distinct* keys, every folder
    picks the same (lexically largest) winner, and decoded-summary caches
    keyed on the key can never alias different params."""
    return f"{_summary_prefix(level)}{origin:04d}/{version:012d}-{blob_hash}"


def _parse_summary_key(key: str) -> tuple[int, str, str] | None:
    """-> (level, zero-padded origin string, 'version-hash'). Origin and
    version stay strings on the scan path — zero-padding makes lexical order
    numeric, and skipping int conversions matters when a pull re-indexes every
    summary key; the composite version orders by scalar first, content hash as
    the deterministic tie-break."""
    if not key.startswith("summary"):
        return None
    tier, _, tail = key[len("summary"):].partition("/")
    if tier == "":
        level = 0  # flat 'summary/' prefix — the level-0 layout
    elif tier.isdigit():
        level = int(tier)
    else:
        return None
    origin, _, version = tail.partition("/")
    if not (origin.isdigit() and version):
        return None
    return level, origin, version


def _version_scalar(composite: str) -> int:
    return int(composite.partition("-")[0])


class ShardedWeightStore:
    """``WeightStore``-compatible facade over per-group stores + gossip.

    ``folders`` is a ``ShardedFolders`` handle, a ``shard<G>+<uri>`` string,
    or an explicit sequence of ``SharedFolder`` (one per group).

    ``group_of`` overrides the stable-hash assignment: a mapping
    (node → group, e.g. from ``balanced_groups``) or a callable
    ``node_id -> group``; unmapped nodes fall back to the hash.

    ``gossip_fanout`` is how many *populated* downstream ring neighbors each
    push forwards summaries to; ``summary_sample`` bounds how many foreign
    summaries one ``pull`` folds in (rotating deterministically through all
    origins across successive pulls, so every group is eventually sampled).

    Operations that identify the acting node (``push`` via ``update.node_id``,
    ``state_hash(exclude_node=...)``, ``pull(exclude=...)``,
    ``pull_round(..., exclude=...)``) route to that node's home group and stay
    O(group). Fleet-wide calls with no node context (``node_ids()``,
    ``pull()`` with no exclude, ``clear()``) scan every group — diagnostics,
    not the hot path.
    """

    def __init__(
        self,
        folders: "ShardedFolders | str | Sequence[SharedFolder]",
        *,
        group_of: Mapping[str, int] | Callable[[str], int] | None = None,
        gossip_fanout: int = 1,
        summary_sample: int = 16,
        transport: str | None = None,
        keep_history: bool = False,
        rebase_every: int = 10,
        delta_density_threshold: float = 0.5,
        topk_fraction: float = 0.01,
        compress: str = "none",
        decode_cache_entries: int = 256,
        roster_folder: SharedFolder | None = None,
        roster_check_every: int = 8,
    ):
        if isinstance(folders, str):
            folders = ShardedFolders.from_uri(folders)
        elif not isinstance(folders, ShardedFolders):
            folders = ShardedFolders.from_folders(folders)
        self.folders = folders
        self.num_groups = folders.num_groups
        # summary-tree depth rides on the folder handle ('shard<G>x<L>+');
        # levels=1 is the flat ring — one global level-0 ring, no tiers
        self.levels = max(1, int(getattr(folders, "levels", 1)))
        self.hierarchy = GossipHierarchy(self.num_groups, self.levels)
        # fail fast, like WeightStore: per-group stores are built lazily on
        # first push, far too late to learn transport= or compress= was
        # misspelled (or zstd unavailable). The throwaway pipeline runs the
        # full spec-grammar validation; per-group stores build their own.
        probe = TransportPipeline.from_spec(
            transport, compress=compress, topk_fraction=topk_fraction)
        self.transport = probe.spec
        if gossip_fanout < 1:
            raise ValueError(f"gossip_fanout must be >= 1, got {gossip_fanout}")
        self.gossip_fanout = gossip_fanout
        if summary_sample < 1:
            raise ValueError(f"summary_sample must be >= 1, got {summary_sample}")
        self.summary_sample = summary_sample
        self._group_of = group_of
        self._keep_history = keep_history
        self._store_kwargs = dict(
            rebase_every=rebase_every,
            delta_density_threshold=delta_density_threshold,
            topk_fraction=topk_fraction,
            compress=compress,
            decode_cache_entries=decode_cache_entries,
        )
        # interned LeafSpecs for summary decode (shared across group folders —
        # a summary key names its exact bytes, so layouts interned here are
        # valid wherever the blob was copied by gossip)
        self._specs: dict = {}
        self._stores: dict[int, WeightStore] = {}
        # Memoized summary indexes, group -> (listing token, index, populated).
        # ``SharedFolder.list_version()`` is a folder-level listing-change
        # token: while it holds still, the parsed index is reused verbatim and
        # steady-state pulls/forwards skip the O(keys) re-split entirely
        # (hits/misses surface via PipelineStats). Entries are only ever
        # replaced whole (atomic under the GIL) and the cached index is
        # treated as read-only by every consumer.
        self._index_memo: dict[int, tuple] = {}
        self._lock = threading.Lock()
        self._push_seq = 0  # paces the empty-group rechecks in _forward
        self._assumed_empty: set[int] = set()  # groups last seen memberless
        # Decoded-summary cache. A summary key names its exact content
        # (origin + version + content hash; forwarded copies are
        # byte-identical), so a decoded pseudo-update can be reused across
        # pulls AND across group folders with no version-token dance — the key
        # is the identity. Held one-per-origin plus rotation slack: a smaller
        # bound would evict inside the rotating sample window and re-pay an
        # O(num_groups) decode stream every cycle.
        self._summary_cache = _LruCache(
            max(4 * max(summary_sample, 16), self.num_groups)
        )
        # Rotation bookkeeping per pulling node: its own window counter (a
        # store-global counter would stride past some origins forever when the
        # instance is shared by several nodes), which (origin, version-hash)
        # pairs its pulls have already been handed, and whether unseen pairs
        # remain (drives the state-hash nudge that keeps rotation alive when
        # the folder itself is quiet). Keyed per node, so concurrent pulls by
        # different nodes touch different entries.
        self._window: dict[str, int] = {}
        self._served: dict[str, set] = {}
        self._rotation_pending: dict[str, bool] = {}
        # Elastic membership: group assignment re-resolves against the
        # freshest ``fleet/roster/<epoch>`` blob (see write_roster). The
        # roster folder is passed explicitly or lazily derived from the base
        # URI; URI-less factory stores opt in via roster_folder=. Epoch bumps
        # are checked every ``roster_check_every`` pushes plus on explicit
        # refresh_roster() calls; all roster state mutates under self._lock.
        self._roster_folder = roster_folder
        self._roster_probed = roster_folder is not None
        self._roster_check_every = max(1, int(roster_check_every))
        self._roster_epoch = -1
        self._roster_groups: dict[str, int] | None = None
        self._home: dict[str, int] = {}  # last-seen home, for push migration
        # instrumentation — bumped under _stats_lock: a shared instance
        # serves many threaded nodes, and bare += would lose updates
        self._stats_lock = threading.Lock()
        self.num_summary_refreshes = 0
        self.num_summary_forwards = 0  # ring copies + tier down-copies
        self.num_super_folds = 0  # SuperSummary deposits (levels > 1)
        self.num_regroups = 0  # roster epoch bumps absorbed
        # summary-layer wire traffic (refresh deposits + ring-forward copies);
        # per-group latest/base/history bytes live on the per-group stores
        self.summary_bytes_written = 0
        # attached per-node Telemetry (attach_telemetry); per-group stores
        # created later inherit it
        self._telemetry = None

    # -- routing -------------------------------------------------------------
    def group_of(self, node_id: str) -> int:
        # roster assignment wins when a roster has been absorbed: it is the
        # dynamic-membership truth. Nodes the roster has not (yet) heard of
        # fall through to the static override / stable hash, so a node can
        # always push before its membership propagates.
        roster = self._roster_groups
        if roster is not None:
            g = roster.get(node_id)
            if g is not None:
                return g
        if self._group_of is not None:
            if callable(self._group_of):
                g = int(self._group_of(node_id))
                if not 0 <= g < self.num_groups:
                    raise ValueError(f"group_of({node_id!r}) = {g} out of range")
                return g
            g = self._group_of.get(node_id)
            if g is not None:
                return int(g)
        return default_group_of(node_id, self.num_groups)

    # -- dynamic membership ---------------------------------------------------
    def _ensure_roster_folder(self) -> SharedFolder | None:
        """The folder roster blobs live in: explicit ``roster_folder=``, else
        (for URI-built shards) the wrapper-stripped base URI's folder — the
        same place ``repro.fleet`` keeps its control plane. Factory-built
        stores without an explicit folder never probe (there is no base)."""
        if not self._roster_probed:
            with self._lock:
                if not self._roster_probed:
                    self._roster_probed = True
                    uri = self.folders.uri
                    if uri is not None:
                        from .store import make_folder
                        from .transport import parse_folder_uri

                        _wrappers, base = parse_folder_uri(uri)
                        # anonymous memory:// has no cross-store identity to
                        # anchor a roster; named memory://<name> does (shared
                        # registry), as do disk/s3 bases.
                        if base != "memory://":
                            self._roster_folder = make_folder(base)
        return self._roster_folder

    def refresh_roster(self) -> bool:
        """Absorb the freshest roster epoch, recomputing ``balanced_groups``
        over its membership. True when the epoch advanced (a regroup)."""
        folder = self._ensure_roster_folder()
        if folder is None:
            return False
        cur = read_roster(folder)
        if cur is None:
            return False
        epoch, nodes = cur
        with self._lock:
            if epoch <= self._roster_epoch:
                return False
            self._roster_groups = balanced_groups(nodes, self.num_groups) \
                if nodes else None
            self._roster_epoch = epoch
        # A regroup dissolves the old grouping: summaries (and supers folded
        # from them) computed under the previous epoch still credit departed
        # members, so cached decodes must not satisfy post-epoch pulls — drop
        # them along with the listing memo and the empty-group assumptions,
        # and let the folders' own refresh cycle rebuild the fresh view.
        self._summary_cache.clear()
        self._index_memo.clear()
        self._assumed_empty.clear()
        with self._stats_lock:
            self.num_regroups += 1
        _log.info("roster epoch %d absorbed: %d members regrouped over %d groups",
                  epoch, len(nodes), self.num_groups)
        return True

    @property
    def roster_epoch(self) -> int:
        """Freshest roster epoch absorbed so far (-1: none)."""
        return self._roster_epoch

    def _migrate_node(self, node_id: str, old_group: int, new_group: int) -> None:
        """A regrouped node's deposits move home: drop its keys from the old
        group's folder so the next push to the new home is the single copy.
        The old group's summary drains the departed contribution on its next
        member refresh; readers racing this delete fall back to pull_node's
        cross-group scan."""
        folder = self._folder(old_group)
        prefixes = (f"base/{node_id}/", f"chain/{node_id}/",
                    f"history/{node_id}/", f"state/{node_id}")
        for key in folder.keys():
            if key == f"latest/{node_id}" or key.startswith(prefixes):
                folder.delete(key)
        _log.info("node %s migrated group %d -> %d", node_id, old_group, new_group)

    def _store(self, group: int) -> WeightStore:
        with self._lock:
            store = self._stores.get(group)
            if store is None:
                store = WeightStore(
                    self.folders.group_folder(group),
                    transport=self.transport,
                    keep_history=self._keep_history,
                    **self._store_kwargs,
                )
                if self._telemetry is not None:
                    store.attach_telemetry(self._telemetry)
                self._stores[group] = store
            return store

    def _folder(self, group: int) -> SharedFolder:
        return self._store(group).folder

    # keep_history must fan out to every per-group store, present and future
    # (SyncFederatedNode flips it post-construction).
    @property
    def keep_history(self) -> bool:
        return self._keep_history

    @keep_history.setter
    def keep_history(self, value: bool) -> None:
        self._keep_history = value
        with self._lock:
            stores = list(self._stores.values())
        for store in stores:
            store.keep_history = value

    # -- summary plumbing -----------------------------------------------------
    @staticmethod
    def _summary_index(keys: Iterable[str]) -> dict[tuple[int, str], list]:
        """(level, zero-padded origin string) -> [freshest 'version-hash', its
        key, stale keys], from a key listing alone — freshness comparisons AND
        garbage collection need no blob reads and no relisting (stale keys a
        racing writer adds after this listing are caught by the next pass)."""
        index: dict[tuple[int, str], list] = {}
        for key in keys:
            parsed = _parse_summary_key(key)
            if parsed is None:
                continue
            level, origin, version = parsed
            have = index.get((level, origin))
            if have is None:
                index[(level, origin)] = [version, key, []]
            elif version > have[0]:
                have[2].append(have[1])
                have[0], have[1] = version, key
            else:
                have[2].append(key)
        return index

    def _indexed(self, group: int) -> tuple[dict[tuple[int, str], list], bool]:
        """``group``'s folder summary index plus its populated flag (any
        ``latest/`` key), memoized on the folder's listing-change token.
        While ``list_version()`` holds still the parsed index is reused —
        steady-state pulls and no-op forwards skip the O(keys) re-split.
        Backends without a token (None) re-index every call; a missed
        DiskFolder invalidation self-heals on the next listing change, and
        the returned index must be treated as read-only (it is shared)."""
        folder = self._folder(group)
        stats = self._store(group).pipeline.stats
        token = folder.list_version()
        if token is not None:
            memo = self._index_memo.get(group)
            if memo is not None and memo[0] == token:
                stats.incr("summary_index_hits")
                return memo[1], memo[2]
        stats.incr("summary_index_misses")
        keys = folder.keys()
        index = self._summary_index(keys)
        populated = any(k.startswith("latest/") for k in keys)
        if token is not None:
            self._index_memo[group] = (token, index, populated)
        return index, populated

    @staticmethod
    def _replace_summaries(folder: SharedFolder, stale: list | None) -> None:
        """GC an origin's superseded summary keys after a fresher put."""
        if stale is None:
            return
        for key in stale[2]:
            folder.delete(key)
        folder.delete(stale[1])

    def load_summary(self, group: int, origin: int,
                     level: int = 0) -> GroupSummary | SuperSummary | None:
        """Freshest readable level-``level`` summary of ``origin`` held in
        ``group``'s folder (diagnostics + tests; pull() uses the same
        resolution)."""
        folder = self._folder(group)
        entry = self._summary_index(folder.keys()).get((level, f"{origin:04d}"))
        if entry is None:
            return None
        _vtag, freshest, stale = entry
        loads = deserialize_group_summary if level == 0 else deserialize_super_summary
        # freshest first, stale fallbacks next — tolerates a racing GC
        for key in [freshest, *sorted(stale, reverse=True)]:
            blob = folder.get(key)
            if blob is not None:
                try:
                    return loads(blob)
                except (ValueError, KeyError):
                    continue
        return None

    @staticmethod
    def _group_mean(updates: list[NodeUpdate], weights: list[int]):
        """Example-weighted mean of the group's latest params. When the store
        pulled spec-sharing FlatUpdates (the steady state), this is one
        vectorized matvec over stacked flats; mixed structures fall back to
        the per-leaf tree mean."""
        first = updates[0]
        spec = getattr(first, "spec", None)
        if spec is not None and all(
            getattr(u, "spec", None) is not None and spec.compatible(u.spec)
            for u in updates
        ):
            coeffs = np.asarray(weights, np.float64)
            coeffs = (coeffs / coeffs.sum()).astype(np.float32)
            # in-place accumulation: no (K, N) stack transient on the push path
            out = np.multiply(updates[0].flat, coeffs[0])
            scratch = np.empty_like(out)
            for c, u in zip(coeffs[1:], updates[1:]):
                np.multiply(u.flat, c, out=scratch)
                out += scratch
            return spec.unflatten(out)
        return tree_weighted_mean([u.params for u in updates], weights)

    def _refresh_summary(self, group: int) -> None:
        """Recompute ``group``'s own summary from its latest set and deposit it
        if fresher than what the folder already holds. Every pushing node runs
        this — the 'election' is simply that a stale folder gets refreshed by
        whichever member pushes next, and version-ordered keys make the race
        last-writer-wins without blob reads."""
        store = self._store(group)
        updates = store.pull()
        if not updates:
            return
        vv = {u.node_id: int(u.counter) for u in updates}
        version = sum(c + 1 for c in vv.values())
        folder = store.folder
        current = self._indexed(group)[0].get((0, f"{group:04d}"))
        if current is not None and _version_scalar(current[0]) >= version:
            return
        weights = [max(1, u.num_examples) for u in updates]
        summary = GroupSummary(
            params=self._group_mean(updates, weights),
            num_examples=sum(weights),
            origin=group,
            version=version,
            version_vector=vv,
            timestamp=max(u.timestamp for u in updates),
        )
        # summaries ride the same pipeline envelope as every other deposit
        blob = store.pipeline.encode_summary(summary)
        folder.put(_summary_key(group, version, content_hash(blob)), blob)
        with self._stats_lock:
            self.summary_bytes_written += len(blob)
            self.num_summary_refreshes += 1
        self._replace_summaries(folder, current)
        _log.debug("group %d: refreshed summary v%d (%d members, %d bytes)",
                   group, version, len(updates), len(blob))

    def _forward(self, group: int) -> None:
        """Forward the level-0 summaries ``group``'s folder holds to the next
        ``gossip_fanout`` populated groups on its level-0 ring (the whole
        fleet at ``levels=1``; the group's own segment under a hierarchy —
        cross-segment flow is the tiers' job). Empty groups en route don't
        count toward the fanout — so hash-assignment holes never cut the
        ring — and are *seeded once* per origin rather than kept fresh (their
        folder is read only by a node that later joins, whose own pushes then
        pull the group into the live ring); between periodic rechecks they
        don't even cost a listing. A populated target that is already as
        fresh costs one key listing, no writes."""
        ring = self.hierarchy.ring(0, group)
        if len(ring) <= 1:
            return
        index, _populated = self._indexed(group)
        ringset = set(ring)
        held = [
            (k, e) for k, e in index.items()
            if k[0] == 0 and int(k[1]) in ringset
        ]
        if not held:
            return
        folder = self._folder(group)
        blobs: dict[str, bytes | None] = {}  # one home-folder read per origin,
        relayed = 0                          # however many targets need it
        # every 16th push, re-list groups assumed empty: one that gained its
        # first member starts receiving forwards within bounded delay
        recheck = self._push_seq % 16 == 0
        pos = group - ring[0]
        for step in range(1, len(ring)):
            target = ring[(pos + step) % len(ring)]
            if target in self._assumed_empty and not recheck:
                continue
            target_folder = self._folder(target)
            target_index, populated = self._indexed(target)
            for origin, (vtag, key, _stale) in held:
                have = target_index.get(origin)
                if have is not None and (not populated or have[0] >= vtag):
                    continue  # empty targets: seed once, don't keep fresh
                if key not in blobs:
                    blobs[key] = folder.get(key)
                blob = blobs[key]
                if blob is None:  # GC'd under us — a racing writer is fresher
                    continue
                target_folder.put(key, blob)
                with self._stats_lock:
                    self.summary_bytes_written += len(blob)
                    self.num_summary_forwards += 1
                self._replace_summaries(target_folder, have)
            if populated:
                self._assumed_empty.discard(target)
                relayed += 1
                if relayed >= self.gossip_fanout:
                    break
            else:
                self._assumed_empty.add(target)

    # -- summary tiers (levels > 1) -------------------------------------------
    def _fold_super(self, level: int, origin: int, holder_group: int) -> None:
        """Fold (level, origin)'s child summaries — gathered in the holder
        group's folder by the level-(level-1) ring — into one ``SuperSummary``
        deposit, if any child is fresher than the current super. The version
        scalar is the sum of folded child version scalars, so it is monotone
        in child freshness and comparable from key listings alone; the
        freshness check therefore reads no blobs in the steady state."""
        hier = self.hierarchy
        folder = self._folder(holder_group)
        index, _pop = self._indexed(holder_group)
        child_entries = []
        for child in hier.children(level, origin):
            e = index.get((level - 1, f"{child:04d}"))
            if e is not None:
                child_entries.append((child, e))
        if not child_entries:
            return
        version = sum(_version_scalar(e[0]) for _, e in child_entries)
        cur = index.get((level, f"{origin:04d}"))
        if cur is not None and _version_scalar(cur[0]) >= version:
            return
        updates, weights = [], []
        child_versions: dict[str, int] = {}
        vv: dict[str, int] = {}
        for child, (vtag, key, _stale) in child_entries:
            update = self._summary_cache.get(key)
            if update is None:
                blob = folder.get(key)
                if blob is None:
                    continue  # GC'd under us — a racing folder is fresher
                update = self._decode_summary(blob)
                if update is None:
                    continue
                self._summary_cache.put(key, update)
            updates.append(update)
            weights.append(max(1, update.num_examples))
            child_versions[str(child)] = _version_scalar(vtag)
            # per-child counter maxima, NOT a fleet-wide node vector: the
            # propagated counter (max over children) stays exact at every
            # level while blob metadata stays O(branching), and the per-node
            # truth remains one level-0 hop away via child_versions
            vv[update.node_id] = int(update.counter)
        if not updates:
            return
        version = sum(child_versions.values())
        if cur is not None and _version_scalar(cur[0]) >= version:
            return  # undecodable stragglers dropped us below the held super
        summary = SuperSummary(
            params=self._group_mean(updates, weights),
            num_examples=sum(weights),
            origin=origin,
            level=level,
            version=version,
            child_versions=child_versions,
            version_vector=vv,
            timestamp=max(u.timestamp for u in updates),
        )
        blob = self._store(holder_group).pipeline.encode_super_summary(summary)
        folder.put(_summary_key(origin, version, content_hash(blob),
                                level=level), blob)
        with self._stats_lock:
            self.summary_bytes_written += len(blob)
            self.num_super_folds += 1
        self._replace_summaries(folder, cur)
        _log.debug("super L%d.%d folded v%d (%d children, %d bytes) -> group %d",
                   level, origin, version, len(updates), len(blob), holder_group)

    def _forward_super(self, level: int, origin: int, holder_group: int) -> None:
        """Forward the level-``level`` supers the holder's folder carries to
        the next ``gossip_fanout`` aggregators on the level-``level`` ring.
        Unlike level 0 there is no populated check and no seeding: ring
        positions are origins, their holder folders are structurally active
        whether or not the holder group has live members (any descendant's
        push writes into them)."""
        hier = self.hierarchy
        ring = hier.ring(level, origin)
        if len(ring) <= 1:
            return
        index, _pop = self._indexed(holder_group)
        ringset = set(ring)
        held = [
            (k, e) for k, e in index.items()
            if k[0] == level and int(k[1]) in ringset
        ]
        if not held:
            return
        folder = self._folder(holder_group)
        blobs: dict[str, bytes | None] = {}
        pos = origin - ring[0]
        for step in range(1, min(len(ring), self.gossip_fanout + 1)):
            target_origin = ring[(pos + step) % len(ring)]
            target_group = hier.holder(level, target_origin)
            if target_group == holder_group:
                continue
            target_folder = self._folder(target_group)
            target_index, _tp = self._indexed(target_group)
            for key2, (vtag, key, _stale) in held:
                have = target_index.get(key2)
                if have is not None and have[0] >= vtag:
                    continue
                if key not in blobs:
                    blobs[key] = folder.get(key)
                blob = blobs[key]
                if blob is None:
                    continue
                target_folder.put(key, blob)
                with self._stats_lock:
                    self.summary_bytes_written += len(blob)
                    self.num_summary_forwards += 1
                self._replace_summaries(target_folder, have)

    def _down_copy(self, group: int, level: int, holder_group: int) -> None:
        """Copy the sibling supers ``group``'s pulls are scoped to from its
        level-``level`` chain folder into its own folder, so a pull touches
        exactly one folder no matter how deep the tree. Own-path origins are
        skipped (they cover the puller itself); fresh copies land under the
        same content-addressed keys, so decoded-summary caching is unaffected
        by which folder a blob was read from."""
        if holder_group == group:
            return
        allowed = self.hierarchy.scope(group).get(level)
        if not allowed:
            return
        index, _pop = self._indexed(holder_group)
        held = [
            (k, e) for k, e in index.items()
            if k[0] == level and int(k[1]) in allowed
        ]
        if not held:
            return
        own_index, _op = self._indexed(group)
        folder = self._folder(holder_group)
        own_folder = self._folder(group)
        for key2, (vtag, key, _stale) in held:
            have = own_index.get(key2)
            if have is not None and have[0] >= vtag:
                continue
            blob = folder.get(key)
            if blob is None:
                continue
            own_folder.put(key, blob)
            with self._stats_lock:
                self.summary_bytes_written += len(blob)
                self.num_summary_forwards += 1
            self._replace_summaries(own_folder, have)

    def _tier_work(self, group: int) -> None:
        """One push's tier duties along ``group``'s ancestor chain: fold the
        covering super at each level, forward it on that level's ring, and
        down-copy sibling supers into the home folder for the next pull.
        O(branching) key work per level — O(branching × levels) per push."""
        hier = self.hierarchy
        path = hier.path(group)
        for t in range(1, self.levels):
            origin = path[t]
            holder_group = hier.holder(t, origin)
            with self._span(f"gossip.l{t}.fold"):
                self._fold_super(t, origin, holder_group)
            with self._span(f"gossip.l{t}.forward"):
                self._forward_super(t, origin, holder_group)
            with self._span("gossip.down"):
                self._down_copy(group, t, holder_group)

    def _decode_summary(self, blob: bytes) -> NodeUpdate | None:
        """(Super-)summary blob → pseudo-peer update, decoded straight into a
        flat vector (a ``FlatUpdate`` sharing this store's interned specs) so
        that downstream client-side aggregation stays on the flat hot path;
        falls back to the tree decode for non-f32-embeddable params."""
        try:
            spec, flat, meta = decode_params_flat(blob, self._specs)
            if "summary_of" in meta:
                origin, level = int(meta["summary_of"]), 0
            elif "super_summary_of" in meta:
                origin = int(meta["super_summary_of"])
                level = int(meta.get("level", 1))
            else:
                return None
            version_vector = meta.get("version_vector", {})
            return FlatUpdate(
                flat, spec,
                num_examples=int(meta["num_examples"]),
                node_id=group_peer_id(origin, level),
                # Node-counter units (freshest covered member's counter), NOT
                # the version scalar: staleness-aware strategies (FedAsync)
                # compare this against their own epoch counter. For tiers the
                # max over per-child maxima IS the max over covered nodes.
                counter=max((int(v) for v in version_vector.values()), default=0),
                timestamp=float(meta.get("timestamp", 0.0)),
                metrics={"summary_of": origin, "summary_level": level,
                         "summary_version": int(meta["version"])},
            )
        except FlatDecodeUnsupported:
            pass
        except (ValueError, KeyError, ImportError):
            # ImportError: a zstd-wrapped summary forwarded from a group whose
            # writer has a zstd module, read by a node without one — skip it
            # (eventual consistency), never crash the pull.
            return None
        try:
            summary = deserialize_group_summary(blob)
            level = 0
        except (ValueError, KeyError, ImportError):
            try:
                summary = deserialize_super_summary(blob)
                level = summary.level
            except (ValueError, KeyError, ImportError):
                return None
        return NodeUpdate(
            params=summary.params,
            num_examples=summary.num_examples,
            node_id=group_peer_id(summary.origin, level),
            counter=max(summary.version_vector.values(), default=0),
            timestamp=summary.timestamp,
            metrics={"summary_of": summary.origin, "summary_level": level,
                     "summary_version": summary.version},
        )

    def _peer_summaries(self, group: int, exclude: str) -> list[NodeUpdate]:
        """Foreign (super-)summaries in ``group``'s folder as pseudo-peer
        updates, bounded to ``summary_sample`` per pull (rotating through all
        admissible entries across successive pulls). Under a hierarchy only
        the scope partition is admissible — own level-0 segment plus one
        sibling set per tier — so a leaked or stale out-of-scope blob can
        never double-count a subtree. Tracks which ((level, origin), version)
        pairs ``exclude``'s pulls have been handed so ``state_hash`` can keep
        nudging the node until the rotation has covered everything."""
        folder = self._folder(group)
        index, _pop = self._indexed(group)
        scope = self.hierarchy.scope(group)
        # (level, zero-padded origin) pairs sort level-major, numeric within a
        # level — a deterministic rotation order shared by every node
        admissible = sorted(
            k for k in index
            if k[0] < self.levels and int(k[1]) in scope[k[0]]
        )
        current = {(k, index[k][0]) for k in admissible}
        served = self._served.get(exclude, set()) & current  # drop superseded pairs
        seq = self._window.get(exclude, 0)
        self._window[exclude] = seq + 1
        window = admissible
        if self.summary_sample and len(admissible) > self.summary_sample:
            # Tile the entry space per pulling node: ITS successive pulls see
            # disjoint sample windows, so all entries are covered in
            # ceil(n/sample) of its pulls and the decoded-summary cache
            # reaches steady state just as fast.
            start = (seq * self.summary_sample) % len(admissible)
            window = (admissible + admissible)[start:start + self.summary_sample]
        out = []
        for key2 in window:
            vtag, key, _stale = index[key2]
            served.add((key2, vtag))  # handed to this pull, readable or not
            cached = self._summary_cache.get(key)  # refreshes LRU position
            if cached is not None:
                out.append(cached)
                continue
            blob = folder.get(key)
            if blob is None:
                continue
            update = self._decode_summary(blob)
            if update is None:
                continue
            self._summary_cache.put(key, update)
            out.append(update)
        self._served[exclude] = served
        self._rotation_pending[exclude] = len(served) < len(current)
        return out

    def _span(self, name: str):
        """Telemetry span when attached and enabled, shared no-op otherwise —
        lets the per-level gossip phases nest without branching at each site."""
        tel = self._telemetry
        if tel is not None and tel.enabled:
            return tel.span(name)
        return _NULL_SPAN

    # -- the WeightStore interface -------------------------------------------
    def push(self, update: NodeUpdate) -> None:
        self._push_seq += 1
        # paced roster check: one base-folder key listing every
        # _roster_check_every pushes keeps regrouping live without putting a
        # scan on every hot-path push
        if (self._push_seq - 1) % self._roster_check_every == 0:
            self.refresh_roster()
        group = self.group_of(update.node_id)
        old = self._home.get(update.node_id)
        self._home[update.node_id] = group
        if old is not None and old != group:
            self._migrate_node(update.node_id, old, group)
        # this push populates ``group`` — never skip it as an empty hole again
        # (an instance shared by many nodes learns this for every group it
        # routes; per-node instances rely on the periodic recheck instead)
        self._assumed_empty.discard(group)
        self._store(group).push(update)
        # the outer "gossip" span keeps the PR-7 dashboard phase; the l<k>
        # sub-spans show where summary time goes per level ('repro.obs watch')
        with self._span("gossip"):
            with self._span("gossip.l0.refresh"):
                self._refresh_summary(group)
            with self._span("gossip.l0.forward"):
                self._forward(group)
            if self.levels > 1:
                self._tier_work(group)

    def state_hash(self, exclude_node: str | None = None) -> str:
        """O(group-folder keys): only the caller's home folder is hashed. The
        caller's own deposits AND its own group's summary (which its push just
        refreshed) are excluded, so Algorithm 1's skip check survives; foreign
        summaries forwarded in by upstream groups are included — their arrival
        is precisely the cross-group change a node must react to."""
        if exclude_node is None:
            h = hashlib.sha256()
            for g in range(self.num_groups):
                # state/ blobs are optimizer recovery data, fleet/ blobs are
                # launcher control traffic, obs/ blobs are telemetry — none
                # is federation signal, excluded exactly as the flat store does
                h.update(self._folder(g).state_hash(
                    exclude=("state/", "fleet/", "obs/")).encode())
            return h.hexdigest()[:16]
        group = self.group_of(exclude_node)
        # own-path summary prefixes at every level: the node's own push
        # refreshes its group summary AND (when it is on an aggregator's
        # folder) re-folds the covering supers — self-inflicted churn that
        # must not defeat Algorithm 1's skip check. Sibling entries at each
        # level stay included: their arrival IS the cross-group signal.
        path = self.hierarchy.path(group)
        exclude = (
            f"latest/{exclude_node}",
            f"base/{exclude_node}/",
            f"chain/{exclude_node}/",
            f"history/{exclude_node}/",
            *(f"{_summary_prefix(t)}{path[t]:04d}/" for t in range(self.levels)),
            "state/",
            "fleet/",
            "obs/",
        )
        base = self._folder(group).state_hash(exclude=exclude)
        if self._rotation_pending.get(exclude_node):
            # The folder may be quiet, but this node's pulls have not yet
            # been handed every foreign summary (origins > summary_sample):
            # without this nudge the node's skip check would freeze the
            # rotation and some groups would never be folded in. Mixing in
            # the node's own window counter keeps the hash moving until
            # coverage is complete, then it settles back to the folder hash.
            seq = self._window.get(exclude_node, 0)
            return hashlib.sha256(
                f"{base}:rotation:{seq}".encode()
            ).hexdigest()[:16]
        return base

    def node_ids(self) -> list[str]:
        out: set[str] = set()
        for g in range(self.num_groups):
            out.update(self._store(g).node_ids())
        return sorted(out)

    def pull(self, exclude: str | None = None) -> list[NodeUpdate]:
        """With ``exclude`` (the caller): home-group peers as real updates plus
        a bounded sample of foreign-group summaries as pseudo-peers. Without:
        a fleet-wide scan of real updates (no summaries — they would double
        count), for diagnostics."""
        if exclude is None:
            out = []
            for g in range(self.num_groups):
                out.extend(self._store(g).pull())
            return out
        group = self.group_of(exclude)
        return self._store(group).pull(exclude=exclude) + self._peer_summaries(group, exclude)

    def pull_node(self, node_id: str) -> NodeUpdate | None:
        home = self.group_of(node_id)
        update = self._store(home).pull_node(node_id)
        if update is None and self._roster_groups is not None:
            # Regroup race: a roster bump moved the node's home before its
            # deposits migrated (migration happens on its next push). A
            # resuming node must still find its latest blob, so fall back to
            # an O(groups) sweep — miss path only, never the steady state.
            for g in range(self.num_groups):
                if g == home:
                    continue
                update = self._store(g).pull_node(node_id)
                if update is not None:
                    break
        return update

    # -- strategy-state recovery + prefetch: route to the home group ----------
    def push_strategy_state(self, node_id: str, strategy: str, counter: int,
                            state: dict) -> None:
        self._store(self.group_of(node_id)).push_strategy_state(
            node_id, strategy, counter, state)

    def pull_strategy_state(self, node_id: str) -> tuple[dict, dict] | None:
        return self._store(self.group_of(node_id)).pull_strategy_state(node_id)

    # -- observability blobs: deposit to the home group, read fleet-wide ------
    def attach_telemetry(self, telemetry) -> None:
        self._telemetry = telemetry
        with self._lock:
            stores = list(self._stores.values())
        for store in stores:
            store.attach_telemetry(telemetry)

    def push_obs(self, node_id: str, seq: int, payload: dict, *,
                 keep: int | None = None) -> None:
        self._store(self.group_of(node_id)).push_obs(
            node_id, seq, payload, keep=keep)

    def pull_obs(self, node_id: str | None = None) -> list[tuple[str, int, dict]]:
        if node_id is not None:
            return self._store(self.group_of(node_id)).pull_obs(node_id)
        out = []
        for g in range(self.num_groups):
            out.extend(self._store(g).pull_obs())
        return out

    def start_prefetch(self, interval: float = 0.1, *, exclude: str):
        """Background-warm the decoded-update cache of ``exclude``'s home
        group (the only folder its pulls touch). Requires the node id —
        sharded prefetch has no meaning without a home group."""
        return self._store(self.group_of(exclude)).start_prefetch(
            interval, exclude=exclude)

    def stop_prefetch(self) -> None:
        with self._lock:
            stores = list(self._stores.values())
        for store in stores:
            store.stop_prefetch()

    def pull_round(self, counter: int, exclude: str | None = None) -> list[NodeUpdate]:
        """Sync-mode barrier set. With ``exclude`` this is the caller's home
        group only: synchronous federation is per-group under sharding (set
        ``SyncFederatedNode(num_nodes=<group size>)``); cross-group state still
        arrives via async gossip summaries on ``pull``."""
        if exclude is None:
            out = []
            for g in range(self.num_groups):
                out.extend(self._store(g).pull_round(counter))
            return out
        return self._store(self.group_of(exclude)).pull_round(counter, exclude=exclude)

    def clear(self) -> None:
        for g in range(self.num_groups):
            self._store(g).clear()
        # Version scalars restart after a clear, so cached decodes and the
        # populated/seeded/served memos are all invalid — drop every bit of
        # derived state along with the blobs.
        self._summary_cache.clear()
        self._index_memo.clear()
        self._assumed_empty.clear()
        self._window.clear()
        self._served.clear()
        self._rotation_pending.clear()
        self._specs.clear()

    def cache_stats(self) -> dict[str, int]:
        """Aggregate decode-cache + byte counters across the per-group stores,
        including the gossip summary traffic (refreshes + ring forwards) —
        often the dominant wire cost at fleet scale."""
        hits = misses = read = 0
        index_hits = index_misses = 0
        written = self.summary_bytes_written
        with self._lock:
            stores = list(self._stores.values())
        for store in stores:
            hits += store.decode_hits
            misses += store.decode_misses
            written += store.bytes_written
            read += store.bytes_read
            pstats = store.pipeline.stats.as_dict()
            index_hits += pstats.get("summary_index_hits", 0)
            index_misses += pstats.get("summary_index_misses", 0)
        return {"decode_hits": hits, "decode_misses": misses,
                "bytes_written": written, "bytes_read": read,
                "summary_bytes_written": self.summary_bytes_written,
                "summary_index_hits": index_hits,
                "summary_index_misses": index_misses}
