"""Sharded gossip weight store — O(group) federation for 10⁴-node fleets.

The flat ``WeightStore`` scans every fleet member on each ``state_hash`` /
``pull``, so the store itself becomes the bottleneck long before the ROADMAP's
"millions of users": per-step cost is O(fleet). This module partitions the
fleet into *node groups*, each owning its own ``SharedFolder`` (any existing
backend — memory, disk, s3, cache-wrapped), so a node's per-step ``push`` /
``state_hash`` / ``pull`` touch only its home group's folder: O(group).

Cross-group information flows by gossip instead of scanning:

* Every push refreshes the pushing node's *group summary* — the
  example-weighted mean of the group's latest params, carrying the total
  ``num_examples`` behind it and a version vector (node → counter). The
  summary is deposited under a versioned key
  ``summary/<origin>/<version>-<content hash>`` in the group's own folder;
  the zero-padded version scalar (sum of counters + 1) makes freshness
  comparable from a key listing alone — no blob reads — and the hash makes
  version-scalar ties between racing writers resolve deterministically.

* Groups form a ring. After pushing, a node *forwards* every summary its home
  folder holds (its own group's and any it previously received) to the next
  ``gossip_fanout`` **populated** groups on the ring, skipping-but-seeding
  empty groups so holes in a hash-assigned fleet never partition the ring.
  A forward is a cheap key-listing comparison plus a blob copy only when the
  target's copy is missing or older — steady state writes nothing.

* ``pull`` returns the home group's real peer updates plus a bounded sample
  of foreign-group summaries as pseudo-peers (node id ``group:<origin>``,
  weighted by the group's total example count), so the existing client-side
  strategies fold remote groups into aggregation unchanged.

An update therefore propagates fleet-wide within at most one populated-group
hop per gossip round: every group hears about it within ``num_groups`` rounds
(the ring diameter) — the property test in ``tests/test_gossip.py`` proves the
bound under adversarial push orderings.

Consistency model: the summary layer is eventually consistent. Two same-group
writers racing a refresh can leave one contribution out of the summary until
either pushes again (last-writer-wins per version scalar); real ``latest/``
deposits are never involved in the race, so within-group federation stays
exactly as strong as the flat store.

``ShardedWeightStore`` presents the ``WeightStore`` interface, so
``AsyncFederatedNode`` / ``SyncFederatedNode`` work unchanged on top;
``make_folder("shard<G>+<uri>")`` routes URIs here (see ``ShardedFolders``).
"""
from __future__ import annotations

import hashlib
import threading
import time
from typing import Callable, Iterable, Mapping, Sequence

import numpy as np

from .serialize import (
    FlatDecodeUnsupported,
    FlatUpdate,
    GroupSummary,
    NodeUpdate,
    content_hash,
    decode_params_flat,
    deserialize_fleet_blob,
    deserialize_group_summary,
    serialize_fleet_blob,
)
from .store import SharedFolder, WeightStore
from .transport import TransportPipeline, _LruCache
from .tree import tree_weighted_mean
from repro.logs import get_logger

_log = get_logger("gossip")

_SUMMARY_PREFIX = "summary/"
GROUP_PEER_PREFIX = "group:"  # node_id prefix of summary pseudo-peers in pull()

# one grammar owns all routing: the shard-wrapper syntax is defined once, in
# transport.py, next to the rest of the folder-URI/pipeline grammar
from .transport import _SHARD_RE as SHARD_URI_RE  # noqa: E402


# --------------------------------------------------------------------------
# Group assignment
# --------------------------------------------------------------------------


def default_group_of(node_id: str, num_groups: int) -> int:
    """Stable hash assignment: the same node id maps to the same group on any
    machine, any process, any fleet composition — a node can compute its home
    group knowing nothing but its own id."""
    if num_groups < 1:
        raise ValueError(f"num_groups must be >= 1, got {num_groups}")
    h = int.from_bytes(hashlib.sha256(node_id.encode()).digest()[:8], "big")
    return h % num_groups


def balanced_groups(node_ids: Iterable[str], num_groups: int) -> dict[str, int]:
    """Explicit balanced assignment for a *known* fleet: deterministic in the
    node **set** (any iteration order), group sizes differ by at most one, so
    no group is empty once ``len(node_ids) >= num_groups``. Use as the
    ``group_of`` override when the fleet roster is known up front; the default
    hash assignment needs no roster but only balances in expectation."""
    if num_groups < 1:
        raise ValueError(f"num_groups must be >= 1, got {num_groups}")
    ordered = sorted(set(node_ids), key=lambda n: (hashlib.sha256(n.encode()).hexdigest(), n))
    return {n: i % num_groups for i, n in enumerate(ordered)}


# --------------------------------------------------------------------------
# Roster blobs — epoch-versioned fleet membership, in the store itself
# --------------------------------------------------------------------------
#
# Elastic fleets change composition mid-soak: workers die, their nodes get
# adopted, new workers join. The membership truth lives where everything else
# does — as blobs. ``fleet/roster/<epoch>`` holds the sorted node set at that
# epoch; epochs are write-once (``put_if_absent``), so concurrent publishers
# race on the *next* key and exactly one wins (same CAS-by-key discipline as
# slot leases). Readers take the highest epoch present. The ``fleet/`` prefix
# keeps rosters out of every state hash, like all launcher control traffic.

ROSTER_PREFIX = "fleet/roster/"


def _roster_key(epoch: int) -> str:
    return f"{ROSTER_PREFIX}{epoch:06d}"


def read_roster(folder: SharedFolder) -> tuple[int, list[str]] | None:
    """Freshest roster in ``folder`` -> (epoch, sorted node ids), or None."""
    best = -1
    for key in folder.keys():
        if key.startswith(ROSTER_PREFIX):
            tail = key[len(ROSTER_PREFIX):]
            if tail.isdigit():
                best = max(best, int(tail))
    # walk downward: the freshest key could lose a race with a concurrent
    # delete/GC, and an older epoch is a valid (just stale) answer
    while best >= 0:
        blob = folder.get(_roster_key(best))
        if blob is not None:
            try:
                kind, payload = deserialize_fleet_blob(blob)
                if kind == "roster":
                    return int(payload.get("epoch", best)), [
                        str(n) for n in payload.get("nodes", [])]
            except (ValueError, KeyError):
                pass
        best -= 1
    return None


def write_roster(folder: SharedFolder, node_ids: Iterable[str], *,
                 retries: int = 8) -> int:
    """Publish ``node_ids`` as the current roster; returns the epoch it lives
    at. No-op (returns the current epoch) when the membership set is unchanged;
    otherwise CAS-bumps to the next epoch, retrying through concurrent
    publishers until one epoch holds this exact set."""
    nodes = sorted(set(str(n) for n in node_ids))
    for _ in range(retries):
        cur = read_roster(folder)
        if cur is not None and cur[1] == nodes:
            return cur[0]
        epoch = 0 if cur is None else cur[0] + 1
        blob = serialize_fleet_blob(
            "roster", {"epoch": epoch, "nodes": nodes, "time": time.time()})
        if folder.put_if_absent(_roster_key(epoch), blob):
            return epoch
    raise RuntimeError(
        f"roster write lost {retries} consecutive epoch races; giving up")


# --------------------------------------------------------------------------
# Per-group folder routing
# --------------------------------------------------------------------------


def _append_group(uri: str, group: int) -> str:
    """Derive group ``group``'s folder URI from the base URI, preserving any
    ``cache+``/``retry+`` wrapping ('shard4+cache+/mnt/x' caches each group
    folder; 'shard4+retry+/mnt/x' retries each group folder's I/O)."""
    if uri.startswith("cache+"):
        return "cache+" + _append_group(uri[len("cache+"):], group)
    if uri.startswith("retry+"):
        return "retry+" + _append_group(uri[len("retry+"):], group)
    if uri.startswith("memory://"):
        # memory:// mints a fresh in-process folder per make_folder call;
        # ShardedFolders caches one instance per group, which is the identity
        # that matters.
        return "memory://"
    return uri.rstrip("/") + f"/group{group:04d}"


class ShardedFolders:
    """Handle to a family of per-group folders (lazily created, cached).

    Built from a base URI (``make_folder("shard<G>+<uri>")`` returns one) or
    an explicit ``factory``. Not itself a ``SharedFolder`` — it is the routing
    table a ``ShardedWeightStore`` shards over, and ``_BaseNode`` accepts it
    wherever ``shared_folder=`` is taken.
    """

    def __init__(
        self,
        num_groups: int,
        uri: str | None = None,
        *,
        factory: Callable[[int], SharedFolder] | None = None,
    ):
        if num_groups < 1:
            raise ValueError(f"num_groups must be >= 1, got {num_groups}")
        if (uri is None) == (factory is None):
            raise ValueError("pass exactly one of uri= or factory=")
        self.num_groups = num_groups
        self.uri = uri
        self._factory = factory
        self._folders: dict[int, SharedFolder] = {}
        self._lock = threading.Lock()

    @classmethod
    def from_uri(cls, uri: str) -> "ShardedFolders":
        m = SHARD_URI_RE.match(uri)
        if not m:
            raise ValueError(f"not a shard URI: {uri!r} (expected 'shard<G>+<uri>')")
        return cls(int(m.group(1)), m.group(2))

    def group_uri(self, group: int) -> str | None:
        if self.uri is None:
            return None
        return _append_group(self.uri, group)

    def group_folder(self, group: int) -> SharedFolder:
        if not 0 <= group < self.num_groups:
            raise ValueError(f"group {group} out of range [0, {self.num_groups})")
        with self._lock:
            folder = self._folders.get(group)
            if folder is None:
                if self._factory is not None:
                    folder = self._factory(group)
                else:
                    from .store import make_folder  # lazy: store routes shard URIs here

                    folder = make_folder(self.group_uri(group))
                self._folders[group] = folder
            return folder

    @classmethod
    def from_folders(cls, folders: Sequence[SharedFolder]) -> "ShardedFolders":
        folders = list(folders)
        return cls(len(folders), factory=lambda g: folders[g])

    def __len__(self) -> int:
        return self.num_groups

    def __repr__(self) -> str:
        src = self.uri if self.uri is not None else "<factory>"
        return f"ShardedFolders(num_groups={self.num_groups}, uri={src!r})"


# --------------------------------------------------------------------------
# The sharded store
# --------------------------------------------------------------------------


def _summary_key(origin: int, version: int, blob_hash: str) -> str:
    """``summary/<origin>/<version>-<hash>``: the zero-padded version makes
    freshness a plain string comparison from a key listing, and the content
    hash makes the key name its exact bytes — two racing refreshes that land
    on the same version scalar produce *distinct* keys, every folder picks the
    same (lexically largest) winner, and decoded-summary caches keyed on the
    key can never alias different params."""
    return f"{_SUMMARY_PREFIX}{origin:04d}/{version:012d}-{blob_hash}"


def _parse_summary_key(key: str) -> tuple[str, str] | None:
    """-> (zero-padded origin string, 'version-hash'). Both components stay
    strings on the scan path — zero-padding makes lexical order numeric, and
    skipping int conversions matters when every pull re-indexes every summary
    key; the composite version orders by scalar first, content hash as the
    deterministic tie-break."""
    if not key.startswith(_SUMMARY_PREFIX):
        return None
    origin, _, version = key[len(_SUMMARY_PREFIX):].partition("/")
    if not (origin.isdigit() and version):
        return None
    return origin, version


def _version_scalar(composite: str) -> int:
    return int(composite.partition("-")[0])


class ShardedWeightStore:
    """``WeightStore``-compatible facade over per-group stores + gossip.

    ``folders`` is a ``ShardedFolders`` handle, a ``shard<G>+<uri>`` string,
    or an explicit sequence of ``SharedFolder`` (one per group).

    ``group_of`` overrides the stable-hash assignment: a mapping
    (node → group, e.g. from ``balanced_groups``) or a callable
    ``node_id -> group``; unmapped nodes fall back to the hash.

    ``gossip_fanout`` is how many *populated* downstream ring neighbors each
    push forwards summaries to; ``summary_sample`` bounds how many foreign
    summaries one ``pull`` folds in (rotating deterministically through all
    origins across successive pulls, so every group is eventually sampled).

    Operations that identify the acting node (``push`` via ``update.node_id``,
    ``state_hash(exclude_node=...)``, ``pull(exclude=...)``,
    ``pull_round(..., exclude=...)``) route to that node's home group and stay
    O(group). Fleet-wide calls with no node context (``node_ids()``,
    ``pull()`` with no exclude, ``clear()``) scan every group — diagnostics,
    not the hot path.
    """

    def __init__(
        self,
        folders: "ShardedFolders | str | Sequence[SharedFolder]",
        *,
        group_of: Mapping[str, int] | Callable[[str], int] | None = None,
        gossip_fanout: int = 1,
        summary_sample: int = 16,
        transport: str | None = None,
        keep_history: bool = False,
        rebase_every: int = 10,
        delta_density_threshold: float = 0.5,
        topk_fraction: float = 0.01,
        compress: str = "none",
        decode_cache_entries: int = 256,
        roster_folder: SharedFolder | None = None,
        roster_check_every: int = 8,
    ):
        if isinstance(folders, str):
            folders = ShardedFolders.from_uri(folders)
        elif not isinstance(folders, ShardedFolders):
            folders = ShardedFolders.from_folders(folders)
        self.folders = folders
        self.num_groups = folders.num_groups
        # fail fast, like WeightStore: per-group stores are built lazily on
        # first push, far too late to learn transport= or compress= was
        # misspelled (or zstd unavailable). The throwaway pipeline runs the
        # full spec-grammar validation; per-group stores build their own.
        probe = TransportPipeline.from_spec(
            transport, compress=compress, topk_fraction=topk_fraction)
        self.transport = probe.spec
        if gossip_fanout < 1:
            raise ValueError(f"gossip_fanout must be >= 1, got {gossip_fanout}")
        self.gossip_fanout = gossip_fanout
        if summary_sample < 1:
            raise ValueError(f"summary_sample must be >= 1, got {summary_sample}")
        self.summary_sample = summary_sample
        self._group_of = group_of
        self._keep_history = keep_history
        self._store_kwargs = dict(
            rebase_every=rebase_every,
            delta_density_threshold=delta_density_threshold,
            topk_fraction=topk_fraction,
            compress=compress,
            decode_cache_entries=decode_cache_entries,
        )
        # interned LeafSpecs for summary decode (shared across group folders —
        # a summary key names its exact bytes, so layouts interned here are
        # valid wherever the blob was copied by gossip)
        self._specs: dict = {}
        self._stores: dict[int, WeightStore] = {}
        self._lock = threading.Lock()
        self._push_seq = 0  # paces the empty-group rechecks in _forward
        self._assumed_empty: set[int] = set()  # groups last seen memberless
        # Decoded-summary cache. A summary key names its exact content
        # (origin + version + content hash; forwarded copies are
        # byte-identical), so a decoded pseudo-update can be reused across
        # pulls AND across group folders with no version-token dance — the key
        # is the identity. Held one-per-origin plus rotation slack: a smaller
        # bound would evict inside the rotating sample window and re-pay an
        # O(num_groups) decode stream every cycle.
        self._summary_cache = _LruCache(
            max(4 * max(summary_sample, 16), self.num_groups)
        )
        # Rotation bookkeeping per pulling node: its own window counter (a
        # store-global counter would stride past some origins forever when the
        # instance is shared by several nodes), which (origin, version-hash)
        # pairs its pulls have already been handed, and whether unseen pairs
        # remain (drives the state-hash nudge that keeps rotation alive when
        # the folder itself is quiet). Keyed per node, so concurrent pulls by
        # different nodes touch different entries.
        self._window: dict[str, int] = {}
        self._served: dict[str, set] = {}
        self._rotation_pending: dict[str, bool] = {}
        # Elastic membership: group assignment re-resolves against the
        # freshest ``fleet/roster/<epoch>`` blob (see write_roster). The
        # roster folder is passed explicitly or lazily derived from the base
        # URI; URI-less factory stores opt in via roster_folder=. Epoch bumps
        # are checked every ``roster_check_every`` pushes plus on explicit
        # refresh_roster() calls; all roster state mutates under self._lock.
        self._roster_folder = roster_folder
        self._roster_probed = roster_folder is not None
        self._roster_check_every = max(1, int(roster_check_every))
        self._roster_epoch = -1
        self._roster_groups: dict[str, int] | None = None
        self._home: dict[str, int] = {}  # last-seen home, for push migration
        # instrumentation — bumped under _stats_lock: a shared instance
        # serves many threaded nodes, and bare += would lose updates
        self._stats_lock = threading.Lock()
        self.num_summary_refreshes = 0
        self.num_summary_forwards = 0
        self.num_regroups = 0  # roster epoch bumps absorbed
        # summary-layer wire traffic (refresh deposits + ring-forward copies);
        # per-group latest/base/history bytes live on the per-group stores
        self.summary_bytes_written = 0
        # attached per-node Telemetry (attach_telemetry); per-group stores
        # created later inherit it
        self._telemetry = None

    # -- routing -------------------------------------------------------------
    def group_of(self, node_id: str) -> int:
        # roster assignment wins when a roster has been absorbed: it is the
        # dynamic-membership truth. Nodes the roster has not (yet) heard of
        # fall through to the static override / stable hash, so a node can
        # always push before its membership propagates.
        roster = self._roster_groups
        if roster is not None:
            g = roster.get(node_id)
            if g is not None:
                return g
        if self._group_of is not None:
            if callable(self._group_of):
                g = int(self._group_of(node_id))
                if not 0 <= g < self.num_groups:
                    raise ValueError(f"group_of({node_id!r}) = {g} out of range")
                return g
            g = self._group_of.get(node_id)
            if g is not None:
                return int(g)
        return default_group_of(node_id, self.num_groups)

    # -- dynamic membership ---------------------------------------------------
    def _ensure_roster_folder(self) -> SharedFolder | None:
        """The folder roster blobs live in: explicit ``roster_folder=``, else
        (for URI-built shards) the wrapper-stripped base URI's folder — the
        same place ``repro.fleet`` keeps its control plane. Factory-built
        stores without an explicit folder never probe (there is no base)."""
        if not self._roster_probed:
            with self._lock:
                if not self._roster_probed:
                    self._roster_probed = True
                    uri = self.folders.uri
                    if uri is not None:
                        from .store import make_folder
                        from .transport import parse_folder_uri

                        _wrappers, base = parse_folder_uri(uri)
                        if not base.startswith("memory://"):
                            self._roster_folder = make_folder(base)
        return self._roster_folder

    def refresh_roster(self) -> bool:
        """Absorb the freshest roster epoch, recomputing ``balanced_groups``
        over its membership. True when the epoch advanced (a regroup)."""
        folder = self._ensure_roster_folder()
        if folder is None:
            return False
        cur = read_roster(folder)
        if cur is None:
            return False
        epoch, nodes = cur
        with self._lock:
            if epoch <= self._roster_epoch:
                return False
            self._roster_groups = balanced_groups(nodes, self.num_groups) \
                if nodes else None
            self._roster_epoch = epoch
        with self._stats_lock:
            self.num_regroups += 1
        _log.info("roster epoch %d absorbed: %d members regrouped over %d groups",
                  epoch, len(nodes), self.num_groups)
        return True

    @property
    def roster_epoch(self) -> int:
        """Freshest roster epoch absorbed so far (-1: none)."""
        return self._roster_epoch

    def _migrate_node(self, node_id: str, old_group: int, new_group: int) -> None:
        """A regrouped node's deposits move home: drop its keys from the old
        group's folder so the next push to the new home is the single copy.
        The old group's summary drains the departed contribution on its next
        member refresh; readers racing this delete fall back to pull_node's
        cross-group scan."""
        folder = self._folder(old_group)
        prefixes = (f"base/{node_id}/", f"chain/{node_id}/",
                    f"history/{node_id}/", f"state/{node_id}")
        for key in folder.keys():
            if key == f"latest/{node_id}" or key.startswith(prefixes):
                folder.delete(key)
        _log.info("node %s migrated group %d -> %d", node_id, old_group, new_group)

    def _store(self, group: int) -> WeightStore:
        with self._lock:
            store = self._stores.get(group)
            if store is None:
                store = WeightStore(
                    self.folders.group_folder(group),
                    transport=self.transport,
                    keep_history=self._keep_history,
                    **self._store_kwargs,
                )
                if self._telemetry is not None:
                    store.attach_telemetry(self._telemetry)
                self._stores[group] = store
            return store

    def _folder(self, group: int) -> SharedFolder:
        return self._store(group).folder

    # keep_history must fan out to every per-group store, present and future
    # (SyncFederatedNode flips it post-construction).
    @property
    def keep_history(self) -> bool:
        return self._keep_history

    @keep_history.setter
    def keep_history(self, value: bool) -> None:
        self._keep_history = value
        with self._lock:
            stores = list(self._stores.values())
        for store in stores:
            store.keep_history = value

    # -- summary plumbing -----------------------------------------------------
    @staticmethod
    def _summary_index(keys: Iterable[str]) -> dict[str, list]:
        """zero-padded origin string -> [freshest 'version-hash', its key,
        stale keys], from a key listing alone — freshness comparisons AND
        garbage collection need no blob reads and no relisting (stale keys a
        racing writer adds after this listing are caught by the next pass)."""
        index: dict[str, list] = {}
        for key in keys:
            parsed = _parse_summary_key(key)
            if parsed is None:
                continue
            origin, version = parsed
            have = index.get(origin)
            if have is None:
                index[origin] = [version, key, []]
            elif version > have[0]:
                have[2].append(have[1])
                have[0], have[1] = version, key
            else:
                have[2].append(key)
        return index

    @staticmethod
    def _replace_summaries(folder: SharedFolder, stale: list | None) -> None:
        """GC an origin's superseded summary keys after a fresher put."""
        if stale is None:
            return
        for key in stale[2]:
            folder.delete(key)
        folder.delete(stale[1])

    def load_summary(self, group: int, origin: int) -> GroupSummary | None:
        """Freshest readable summary of ``origin`` held in ``group``'s folder
        (diagnostics + tests; pull() uses the same resolution)."""
        folder = self._folder(group)
        entry = self._summary_index(folder.keys()).get(f"{origin:04d}")
        if entry is None:
            return None
        _vtag, freshest, stale = entry
        # freshest first, stale fallbacks next — tolerates a racing GC
        for key in [freshest, *sorted(stale, reverse=True)]:
            blob = folder.get(key)
            if blob is not None:
                try:
                    return deserialize_group_summary(blob)
                except (ValueError, KeyError):
                    continue
        return None

    @staticmethod
    def _group_mean(updates: list[NodeUpdate], weights: list[int]):
        """Example-weighted mean of the group's latest params. When the store
        pulled spec-sharing FlatUpdates (the steady state), this is one
        vectorized matvec over stacked flats; mixed structures fall back to
        the per-leaf tree mean."""
        first = updates[0]
        spec = getattr(first, "spec", None)
        if spec is not None and all(
            getattr(u, "spec", None) is not None and spec.compatible(u.spec)
            for u in updates
        ):
            coeffs = np.asarray(weights, np.float64)
            coeffs = (coeffs / coeffs.sum()).astype(np.float32)
            # in-place accumulation: no (K, N) stack transient on the push path
            out = np.multiply(updates[0].flat, coeffs[0])
            scratch = np.empty_like(out)
            for c, u in zip(coeffs[1:], updates[1:]):
                np.multiply(u.flat, c, out=scratch)
                out += scratch
            return spec.unflatten(out)
        return tree_weighted_mean([u.params for u in updates], weights)

    def _refresh_summary(self, group: int) -> None:
        """Recompute ``group``'s own summary from its latest set and deposit it
        if fresher than what the folder already holds. Every pushing node runs
        this — the 'election' is simply that a stale folder gets refreshed by
        whichever member pushes next, and version-ordered keys make the race
        last-writer-wins without blob reads."""
        store = self._store(group)
        updates = store.pull()
        if not updates:
            return
        vv = {u.node_id: int(u.counter) for u in updates}
        version = sum(c + 1 for c in vv.values())
        folder = store.folder
        keys = folder.keys()
        current = self._summary_index(keys).get(f"{group:04d}")
        if current is not None and _version_scalar(current[0]) >= version:
            return
        weights = [max(1, u.num_examples) for u in updates]
        summary = GroupSummary(
            params=self._group_mean(updates, weights),
            num_examples=sum(weights),
            origin=group,
            version=version,
            version_vector=vv,
            timestamp=max(u.timestamp for u in updates),
        )
        # summaries ride the same pipeline envelope as every other deposit
        blob = store.pipeline.encode_summary(summary)
        folder.put(_summary_key(group, version, content_hash(blob)), blob)
        with self._stats_lock:
            self.summary_bytes_written += len(blob)
            self.num_summary_refreshes += 1
        self._replace_summaries(folder, current)
        _log.debug("group %d: refreshed summary v%d (%d members, %d bytes)",
                   group, version, len(updates), len(blob))

    def _forward(self, group: int) -> None:
        """Forward every summary ``group``'s folder holds to the next
        ``gossip_fanout`` populated groups on the ring. Empty groups en route
        don't count toward the fanout — so hash-assignment holes never cut the
        ring — and are *seeded once* per origin rather than kept fresh (their
        folder is read only by a node that later joins, whose own pushes then
        pull the group into the live ring); between periodic rechecks they
        don't even cost a listing. A populated target that is already as
        fresh costs one key listing, no writes."""
        if self.num_groups <= 1:
            return
        folder = self._folder(group)
        held = self._summary_index(folder.keys())
        if not held:
            return
        blobs: dict[str, bytes | None] = {}  # one home-folder read per origin,
        relayed = 0                          # however many targets need it
        # every 16th push, re-list groups assumed empty: one that gained its
        # first member starts receiving forwards within bounded delay
        recheck = self._push_seq % 16 == 0
        for step in range(1, self.num_groups):
            target = (group + step) % self.num_groups
            if target in self._assumed_empty and not recheck:
                continue
            target_folder = self._folder(target)
            target_keys = target_folder.keys()
            target_index = self._summary_index(target_keys)
            populated = any(k.startswith("latest/") for k in target_keys)
            for origin, (vtag, key, _stale) in held.items():
                have = target_index.get(origin)
                if have is not None and (not populated or have[0] >= vtag):
                    continue  # empty targets: seed once, don't keep fresh
                if key not in blobs:
                    blobs[key] = folder.get(key)
                blob = blobs[key]
                if blob is None:  # GC'd under us — a racing writer is fresher
                    continue
                target_folder.put(key, blob)
                with self._stats_lock:
                    self.summary_bytes_written += len(blob)
                    self.num_summary_forwards += 1
                self._replace_summaries(target_folder, have)
            if populated:
                self._assumed_empty.discard(target)
                relayed += 1
                if relayed >= self.gossip_fanout:
                    break
            else:
                self._assumed_empty.add(target)

    def _decode_summary(self, blob: bytes) -> NodeUpdate | None:
        """Summary blob → pseudo-peer update, decoded straight into a flat
        vector (a ``FlatUpdate`` sharing this store's interned specs) so that
        downstream client-side aggregation stays on the flat hot path; falls
        back to the tree decode for non-f32-embeddable params."""
        try:
            spec, flat, meta = decode_params_flat(blob, self._specs)
            if "summary_of" not in meta:
                return None
            origin = int(meta["summary_of"])
            version_vector = meta.get("version_vector", {})
            return FlatUpdate(
                flat, spec,
                num_examples=int(meta["num_examples"]),
                node_id=f"{GROUP_PEER_PREFIX}{origin}",
                # Node-counter units (freshest member's counter), NOT the
                # version scalar: staleness-aware strategies (FedAsync)
                # compare this against their own epoch counter.
                counter=max((int(v) for v in version_vector.values()), default=0),
                timestamp=float(meta.get("timestamp", 0.0)),
                metrics={"summary_of": origin,
                         "summary_version": int(meta["version"])},
            )
        except FlatDecodeUnsupported:
            pass
        except (ValueError, KeyError, ImportError):
            # ImportError: a zstd-wrapped summary forwarded from a group whose
            # writer has a zstd module, read by a node without one — skip it
            # (eventual consistency), never crash the pull.
            return None
        try:
            summary = deserialize_group_summary(blob)
        except (ValueError, KeyError, ImportError):
            return None
        return NodeUpdate(
            params=summary.params,
            num_examples=summary.num_examples,
            node_id=f"{GROUP_PEER_PREFIX}{summary.origin}",
            counter=max(summary.version_vector.values(), default=0),
            timestamp=summary.timestamp,
            metrics={"summary_of": summary.origin,
                     "summary_version": summary.version},
        )

    def _peer_summaries(self, group: int, exclude: str) -> list[NodeUpdate]:
        """Foreign-group summaries in ``group``'s folder as pseudo-peer
        updates, bounded to ``summary_sample`` per pull (rotating through all
        origins across successive pulls). Tracks which (origin, version)
        pairs ``exclude``'s pulls have been handed so ``state_hash`` can keep
        nudging the node until the rotation has covered everything."""
        folder = self._folder(group)
        index = self._summary_index(folder.keys())
        index.pop(f"{group:04d}", None)  # own group's members arrive as real updates
        origins = sorted(index)  # zero-padded strings: lexical order IS numeric
        current = {(o, index[o][0]) for o in origins}
        served = self._served.get(exclude, set()) & current  # drop superseded pairs
        seq = self._window.get(exclude, 0)
        self._window[exclude] = seq + 1
        window = origins
        if self.summary_sample and len(origins) > self.summary_sample:
            # Tile the origin space per pulling node: ITS successive pulls see
            # disjoint sample windows, so all groups are covered in
            # ceil(n/sample) of its pulls and the decoded-summary cache
            # reaches steady state just as fast.
            start = (seq * self.summary_sample) % len(origins)
            window = (origins + origins)[start:start + self.summary_sample]
        out = []
        for origin in window:
            vtag, key, _stale = index[origin]
            served.add((origin, vtag))  # handed to this pull, readable or not
            cached = self._summary_cache.get(key)  # refreshes LRU position
            if cached is not None:
                out.append(cached)
                continue
            blob = folder.get(key)
            if blob is None:
                continue
            update = self._decode_summary(blob)
            if update is None:
                continue
            self._summary_cache.put(key, update)
            out.append(update)
        self._served[exclude] = served
        self._rotation_pending[exclude] = len(served) < len(current)
        return out

    # -- the WeightStore interface -------------------------------------------
    def push(self, update: NodeUpdate) -> None:
        self._push_seq += 1
        # paced roster check: one base-folder key listing every
        # _roster_check_every pushes keeps regrouping live without putting a
        # scan on every hot-path push
        if (self._push_seq - 1) % self._roster_check_every == 0:
            self.refresh_roster()
        group = self.group_of(update.node_id)
        old = self._home.get(update.node_id)
        self._home[update.node_id] = group
        if old is not None and old != group:
            self._migrate_node(update.node_id, old, group)
        # this push populates ``group`` — never skip it as an empty hole again
        # (an instance shared by many nodes learns this for every group it
        # routes; per-node instances rely on the periodic recheck instead)
        self._assumed_empty.discard(group)
        self._store(group).push(update)
        tel = self._telemetry
        if tel is not None and tel.enabled:
            with tel.span("gossip"):
                self._refresh_summary(group)
                self._forward(group)
        else:
            self._refresh_summary(group)
            self._forward(group)

    def state_hash(self, exclude_node: str | None = None) -> str:
        """O(group-folder keys): only the caller's home folder is hashed. The
        caller's own deposits AND its own group's summary (which its push just
        refreshed) are excluded, so Algorithm 1's skip check survives; foreign
        summaries forwarded in by upstream groups are included — their arrival
        is precisely the cross-group change a node must react to."""
        if exclude_node is None:
            h = hashlib.sha256()
            for g in range(self.num_groups):
                # state/ blobs are optimizer recovery data, fleet/ blobs are
                # launcher control traffic, obs/ blobs are telemetry — none
                # is federation signal, excluded exactly as the flat store does
                h.update(self._folder(g).state_hash(
                    exclude=("state/", "fleet/", "obs/")).encode())
            return h.hexdigest()[:16]
        group = self.group_of(exclude_node)
        exclude = (
            f"latest/{exclude_node}",
            f"base/{exclude_node}/",
            f"chain/{exclude_node}/",
            f"history/{exclude_node}/",
            f"{_SUMMARY_PREFIX}{group:04d}/",
            "state/",
            "fleet/",
            "obs/",
        )
        base = self._folder(group).state_hash(exclude=exclude)
        if self._rotation_pending.get(exclude_node):
            # The folder may be quiet, but this node's pulls have not yet
            # been handed every foreign summary (origins > summary_sample):
            # without this nudge the node's skip check would freeze the
            # rotation and some groups would never be folded in. Mixing in
            # the node's own window counter keeps the hash moving until
            # coverage is complete, then it settles back to the folder hash.
            seq = self._window.get(exclude_node, 0)
            return hashlib.sha256(
                f"{base}:rotation:{seq}".encode()
            ).hexdigest()[:16]
        return base

    def node_ids(self) -> list[str]:
        out: set[str] = set()
        for g in range(self.num_groups):
            out.update(self._store(g).node_ids())
        return sorted(out)

    def pull(self, exclude: str | None = None) -> list[NodeUpdate]:
        """With ``exclude`` (the caller): home-group peers as real updates plus
        a bounded sample of foreign-group summaries as pseudo-peers. Without:
        a fleet-wide scan of real updates (no summaries — they would double
        count), for diagnostics."""
        if exclude is None:
            out = []
            for g in range(self.num_groups):
                out.extend(self._store(g).pull())
            return out
        group = self.group_of(exclude)
        return self._store(group).pull(exclude=exclude) + self._peer_summaries(group, exclude)

    def pull_node(self, node_id: str) -> NodeUpdate | None:
        home = self.group_of(node_id)
        update = self._store(home).pull_node(node_id)
        if update is None and self._roster_groups is not None:
            # Regroup race: a roster bump moved the node's home before its
            # deposits migrated (migration happens on its next push). A
            # resuming node must still find its latest blob, so fall back to
            # an O(groups) sweep — miss path only, never the steady state.
            for g in range(self.num_groups):
                if g == home:
                    continue
                update = self._store(g).pull_node(node_id)
                if update is not None:
                    break
        return update

    # -- strategy-state recovery + prefetch: route to the home group ----------
    def push_strategy_state(self, node_id: str, strategy: str, counter: int,
                            state: dict) -> None:
        self._store(self.group_of(node_id)).push_strategy_state(
            node_id, strategy, counter, state)

    def pull_strategy_state(self, node_id: str) -> tuple[dict, dict] | None:
        return self._store(self.group_of(node_id)).pull_strategy_state(node_id)

    # -- observability blobs: deposit to the home group, read fleet-wide ------
    def attach_telemetry(self, telemetry) -> None:
        self._telemetry = telemetry
        with self._lock:
            stores = list(self._stores.values())
        for store in stores:
            store.attach_telemetry(telemetry)

    def push_obs(self, node_id: str, seq: int, payload: dict, *,
                 keep: int | None = None) -> None:
        self._store(self.group_of(node_id)).push_obs(
            node_id, seq, payload, keep=keep)

    def pull_obs(self, node_id: str | None = None) -> list[tuple[str, int, dict]]:
        if node_id is not None:
            return self._store(self.group_of(node_id)).pull_obs(node_id)
        out = []
        for g in range(self.num_groups):
            out.extend(self._store(g).pull_obs())
        return out

    def start_prefetch(self, interval: float = 0.1, *, exclude: str):
        """Background-warm the decoded-update cache of ``exclude``'s home
        group (the only folder its pulls touch). Requires the node id —
        sharded prefetch has no meaning without a home group."""
        return self._store(self.group_of(exclude)).start_prefetch(
            interval, exclude=exclude)

    def stop_prefetch(self) -> None:
        with self._lock:
            stores = list(self._stores.values())
        for store in stores:
            store.stop_prefetch()

    def pull_round(self, counter: int, exclude: str | None = None) -> list[NodeUpdate]:
        """Sync-mode barrier set. With ``exclude`` this is the caller's home
        group only: synchronous federation is per-group under sharding (set
        ``SyncFederatedNode(num_nodes=<group size>)``); cross-group state still
        arrives via async gossip summaries on ``pull``."""
        if exclude is None:
            out = []
            for g in range(self.num_groups):
                out.extend(self._store(g).pull_round(counter))
            return out
        return self._store(self.group_of(exclude)).pull_round(counter, exclude=exclude)

    def clear(self) -> None:
        for g in range(self.num_groups):
            self._store(g).clear()
        # Version scalars restart after a clear, so cached decodes and the
        # populated/seeded/served memos are all invalid — drop every bit of
        # derived state along with the blobs.
        self._summary_cache.clear()
        self._assumed_empty.clear()
        self._window.clear()
        self._served.clear()
        self._rotation_pending.clear()
        self._specs.clear()

    def cache_stats(self) -> dict[str, int]:
        """Aggregate decode-cache + byte counters across the per-group stores,
        including the gossip summary traffic (refreshes + ring forwards) —
        often the dominant wire cost at fleet scale."""
        hits = misses = read = 0
        written = self.summary_bytes_written
        with self._lock:
            stores = list(self._stores.values())
        for store in stores:
            hits += store.decode_hits
            misses += store.decode_misses
            written += store.bytes_written
            read += store.bytes_read
        return {"decode_hits": hits, "decode_misses": misses,
                "bytes_written": written, "bytes_read": read,
                "summary_bytes_written": self.summary_bytes_written}
