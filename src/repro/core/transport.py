"""Composable transport pipeline — the weight store's wire layer.

Everything that turns a ``NodeUpdate`` into deposited bytes (and back) lives
here, behind one small seam:

  * A ``Codec`` owns one wire *policy* — how an update is encoded against the
    folder's existing contents. The five policies (``full``, ``quantized``,
    ``delta``, ``delta(q)``, ``topk``) plus the compressed envelope
    (``npz`` / ``zstd``) are all stages of one pipeline. Wire blobs stay
    self-describing (``delta_of`` / ``quantized`` / ``chain_depth`` meta), so
    *readers never need to know the writer's policy* — decode dispatches on
    the blob, not on the local codec stack, and heterogeneous fleets can mix
    pipelines freely.
  * A ``TransportPipeline`` is built from a single registry-parsed spec
    string, e.g. ``"topk(adaptive)|delta(chain=4)|zstd"``. The same grammar
    drives folder-URI routing (``cache+``, ``shard<G>+``) via
    ``parse_folder_uri`` — one parser owns all routing decisions.
  * ``PipelineStats`` carries every wire counter (bytes written/read, chain
    depths, residual norms, rebases, prefetch activity) — the per-pipeline
    replacement for the ad-hoc counters ``WeightStore`` used to grow.

Spec grammar::

    pipeline  := stage ("|" stage)*
    stage     := name | name "(" args ")"
    args      := arg ("," arg)*
    arg       := key "=" value | flag          # e.g. chain=4, q, adaptive

    policy stages : full | quantized | delta(chain=<int>, q, rebase=<int>)
                    | topk(adaptive, fraction=<float>)
                    | family(<name>=<sub-policy>, ...)
    envelope      : npz | zstd                 # at most one, always last

    family sub-policies are full | quantized | delta (bare ``<name>`` means
    full); a per-family envelope token (``embeddings=quantized|zstd``) hoists
    to the whole-blob envelope. ``|`` and ``,`` split at paren depth 0 only,
    so sub-specs nest inside ``family(...)`` without escaping.

    folder URIs share the stage idea with "+" as the separator:
    uri       := (wrapper "+")* base   # wrapper: cache | retry | shard<G>[x<L>]
    base      := path | memory:// | s3://bucket/prefix

Legacy ``transport=`` strings map onto the grammar (``delta_q`` →
``delta(q)``); their wire output is byte-identical to what the pre-pipeline
store produced.

New capabilities shipped on the clean seam:

  * **Delta chains** (``delta(chain=K)``) — each push encodes against the
    *previous pushed state* instead of the anchor base, so per-push bytes
    track one local step's sparsity rather than the accumulated drift since
    the last rebase. Chain links are content-addressed under
    ``chain/<node>/<hash>``; reconstruction depth is bounded by ``K``: when a
    link would exceed it, the writer *re-anchors* with a depth-1 delta
    against the content-hashed base (and a full rebase still fires every
    ``rebase_every`` pushes). A steady reader reconstructs each pull with a
    single delta application (the previous state is cached by blob hash); a
    fresh reader walks at most ``K`` hops.
  * **Background prefetch** (``Prefetcher``) — a thread that warms the
    decoded-update cache from cheap ``version()`` listings between
    federation steps, so the federation-step pull finds peers pre-decoded.
  * **Adaptive top-k** (``topk(adaptive)``) — scales the shipped ``k`` to
    the measured error-feedback residual norm: bursts of change ship more
    entries, quiet stretches ship fewer.
  * **Leaf-family subset transport** (``family(adapters=full, ...)``) —
    exploits model structure the flat path can't see: every push after the
    anchor ships only the leaves of named *families* (``tree.FAMILY_PATTERNS``
    path patterns: adapters, embeddings, norms, ...), each under its own
    sub-policy. LoRA-style adapter federation ships orders of magnitude fewer
    bytes than a full model; pairs with ``PartialFedAvg(families=...)`` so
    non-federated leaves stay personal, bit-exact.
"""
from __future__ import annotations

import re
import threading
from typing import Any

import numpy as np

from .serialize import (
    FlatDecodeUnsupported,
    NodeUpdate,
    apply_update_delta_flat,
    canonicalize_params,
    content_hash,
    decode_params_flat,
    deserialize_update,
    deserialize_update_delta,
    deserialize_update_delta_flat,
    deserialize_update_quantized,
    flat_update_from_meta,
    maybe_decompress,
    peek_meta,
    serialize_group_summary,
    serialize_super_summary,
    serialize_update,
    serialize_update_delta,
    serialize_update_delta_from_flat,
    serialize_update_quantized,
)
from .tree import LeafSpec, tree_size_bytes
from repro.logs import get_logger

_log = get_logger("transport")

# cycle/corruption guard on the reader's chain walk; far above any real
# ``chain=`` bound (writers re-anchor long before this)
_MAX_RESOLVE_HOPS = 64


class _LruCache:
    """Tiny insertion-ordered LRU (dict-backed) shared by the read-side
    caches: CachingFolder's blob cache, WeightStore's decoded-update cache,
    the pipeline's decoded-base/chain-state cache, and ShardedWeightStore's
    decoded-summary cache. Internally locked: stores are shared across
    threads (one ShardedWeightStore serving many threaded nodes is an
    endorsed usage, and the prefetch thread races the pulling thread by
    design), and an unlocked eviction loop racing a get()'s pop/reinsert
    would crash with 'dict changed size during iteration'."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self._data: dict = {}
        self._lock = threading.Lock()

    def get(self, key):
        """Value for ``key`` (refreshing its LRU position), else None."""
        with self._lock:
            hit = self._data.get(key)
            if hit is not None:
                self._data.pop(key, None)
                self._data[key] = hit
            return hit

    def put(self, key, value) -> None:
        with self._lock:
            self._data.pop(key, None)
            self._data[key] = value
            while len(self._data) > self.capacity:
                self._data.pop(next(iter(self._data)))

    def pop(self, key) -> None:
        with self._lock:
            self._data.pop(key, None)

    def clear(self) -> None:
        with self._lock:
            self._data.clear()

    def __len__(self) -> int:
        return len(self._data)


# --------------------------------------------------------------------------
# Spec grammar — one parser for transport pipelines AND folder URIs
# --------------------------------------------------------------------------

_STAGE_RE = re.compile(r"^([A-Za-z_][\w]*)\s*(?:\((.*)\))?$", re.DOTALL)
# ``shard<G>+<uri>`` — G node groups, single gossip ring (level 1);
# ``shard<G>x<L>+<uri>`` — G groups federated through an L-level summary tree
# (hierarchical gossip: rings of rings, push cost O(fanout·levels))
_SHARD_RE = re.compile(r"^shard(\d+)(?:x(\d+))?\+(.+)$", re.DOTALL)

_POLICIES = ("full", "quantized", "delta", "topk", "family")
_ENVELOPES = ("npz", "zstd")

# legacy transport names → pipeline specs (wire output byte-identical)
LEGACY_TRANSPORTS = {
    "full": "full",
    "quantized": "quantized",
    "delta": "delta",
    "delta_q": "delta(q)",
    "topk": "topk",
}


def _split_top(text: str, sep: str) -> list[str]:
    """Split on ``sep`` at paren depth 0 only — ``family(a=x|zstd)`` is one
    pipeline stage, and ``family(a=full, b=quantized)`` has two args whose
    values may themselves carry commas/pipes inside nested parens."""
    parts: list[str] = []
    depth = 0
    cur: list[str] = []
    for ch in text:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth < 0:
                raise ValueError(f"unbalanced parentheses in {text!r}")
        if ch == sep and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if depth != 0:
        raise ValueError(f"unbalanced parentheses in {text!r}")
    parts.append("".join(cur))
    return parts


def parse_stage(text: str) -> tuple[str, dict]:
    """``"delta(chain=4,q)"`` → ``("delta", {"chain": "4", "q": True})``."""
    m = _STAGE_RE.match(text.strip())
    if not m:
        raise ValueError(f"malformed transport stage {text!r}")
    name = m.group(1).lower()
    args: dict = {}
    body = m.group(2)
    if body is not None and body.strip():
        for part in _split_top(body, ","):
            part = part.strip()
            if not part:
                raise ValueError(f"malformed arguments in stage {text!r}")
            if "=" in part:
                k, _, v = part.partition("=")
                args[k.strip()] = v.strip()
            else:
                args[part] = True
    return name, args


def parse_pipeline_spec(spec: str) -> list[tuple[str, dict]]:
    """Split a pipeline spec into ``(stage name, args)`` tuples."""
    if not isinstance(spec, str) or not spec.strip():
        raise ValueError(f"empty transport spec {spec!r}")
    return [parse_stage(part) for part in _split_top(spec, "|")]


def _int_arg(args: dict, key: str, default: int | None, stage: str) -> int | None:
    v = args.get(key)
    if v is None:
        return default
    try:
        out = int(v)
    except (TypeError, ValueError):
        raise ValueError(f"{stage}: {key}= wants an integer, got {v!r}") from None
    return out


def _validate_stages(stages: list[tuple[str, dict]]) -> tuple[tuple[str, dict], str]:
    """-> ((policy name, normalized policy args), envelope name or 'none').
    Raises ValueError on anything the registry does not know."""
    policy: list[tuple[str, dict]] = []
    envelope = "none"
    for i, (name, args) in enumerate(stages):
        if name in _ENVELOPES:
            if i != len(stages) - 1:
                raise ValueError(
                    f"envelope stage {name!r} must be the last pipeline stage")
            envelope = name
        elif name in _POLICIES:
            policy.append((name, dict(args)))
        else:
            known = ", ".join(sorted(_POLICIES + _ENVELOPES))
            raise ValueError(f"unknown transport stage {name!r}; known: {known}")
    if not policy:
        raise ValueError("transport spec needs a policy stage "
                         f"(one of {', '.join(_POLICIES)})")
    # ``topk|delta`` is the explicit form of ``topk`` (top-k selection always
    # ships ordinary delta blobs); any other policy stacking is an error.
    if policy[0][0] == "topk" and len(policy) == 2:
        dn, dargs = policy[1]
        if dn != "delta" or dargs:
            raise ValueError(
                "topk implies its own delta encoding; only a bare '|delta' "
                f"may follow it (got {dn!r} with args {dargs})")
        policy = policy[:1]
    if len(policy) > 1:
        raise ValueError("at most one policy stage per pipeline "
                         "(topk|delta being the one legal stack)")
    name, args = policy[0]
    if name in ("full", "quantized"):
        if args:
            raise ValueError(f"{name} takes no arguments (got {args})")
        return (name, {}), envelope
    if name == "family":
        if not args:
            raise ValueError(
                "family(...) needs at least one <name>=<sub-policy> argument")
        fams: dict[str, str] = {}
        for fam, sub in args.items():
            sub_spec = "full" if sub is True else str(sub)
            try:
                (sub_name, sub_args), sub_env = _validate_stages(
                    parse_pipeline_spec(sub_spec))
            except ValueError as e:
                raise ValueError(
                    f"family: bad sub-spec for {fam!r}: {e}") from None
            if sub_name not in ("full", "quantized", "delta"):
                raise ValueError(
                    f"family: {fam!r} sub-policy must be full, quantized or "
                    f"delta (got {sub_name!r})")
            if sub_name == "delta" and (sub_args.get("chain", 1) != 1
                                        or sub_args.get("q")
                                        or "rebase" in sub_args):
                raise ValueError(
                    f"family: {fam!r} takes a bare 'delta' (chain/q/rebase "
                    "are whole-pipeline knobs, not per-family ones)")
            if sub_env != "none":
                # the envelope wraps the whole blob — a per-family envelope
                # token (``embeddings=quantized|zstd``) hoists up, and every
                # such token must agree
                if envelope not in ("none", sub_env):
                    raise ValueError(
                        f"family: {fam!r} asks for envelope {sub_env!r} but "
                        f"the pipeline already carries {envelope!r}")
                envelope = sub_env
            fams[fam] = sub_name
        return ("family", {"families": fams}), envelope
    if name == "delta":
        unknown = set(args) - {"chain", "q", "rebase"}
        if unknown:
            raise ValueError(f"delta: unknown arguments {sorted(unknown)}")
        chain = _int_arg(args, "chain", 1, "delta")
        if chain < 1:
            raise ValueError(f"delta: chain must be >= 1, got {chain}")
        rebase = _int_arg(args, "rebase", None, "delta")
        if rebase is not None and rebase < 1:
            raise ValueError(f"delta: rebase must be >= 1, got {rebase}")
        quantize = bool(args.get("q", False))
        if quantize and chain > 1:
            raise ValueError(
                "delta: chains require lossless reconstruction — q (int8 "
                "values) cannot be combined with chain > 1")
        out = {"chain": chain, "q": quantize}
        if rebase is not None:
            out["rebase"] = rebase
        return (name, out), envelope
    # topk
    out = {"adaptive": False, "fraction": None}
    for k, v in args.items():
        if k == "adaptive" and v is True:
            out["adaptive"] = True
        elif k == "fraction":
            out["fraction"] = float(v)
        else:
            # a bare float flag is shorthand for fraction=
            try:
                out["fraction"] = float(k) if v is True else float("nan")
            except ValueError:
                out["fraction"] = float("nan")
            if not np.isfinite(out["fraction"]):
                raise ValueError(f"topk: unknown argument {k!r}") from None
    if out["fraction"] is not None and not 0.0 < out["fraction"] <= 1.0:
        raise ValueError(f"topk: fraction must be in (0, 1], got {out['fraction']}")
    return ("topk", out), envelope


def _canonical(policy: tuple[str, dict], envelope: str) -> str:
    name, args = policy
    rendered = []
    if name == "delta":
        if args.get("chain", 1) != 1:
            rendered.append(f"chain={args['chain']}")
        if args.get("q"):
            rendered.append("q")
        if "rebase" in args:
            rendered.append(f"rebase={args['rebase']}")
    elif name == "topk":
        if args.get("adaptive"):
            rendered.append("adaptive")
        if args.get("fraction") is not None:
            rendered.append(f"fraction={args['fraction']:g}")
    elif name == "family":
        rendered.extend(
            f"{fam}={sub}" for fam, sub in sorted(args["families"].items()))
    spec = f"{name}({','.join(rendered)})" if rendered else name
    return spec if envelope == "none" else f"{spec}|{envelope}"


def normalize_transport(transport: str | None = None, *, quantized: bool = False,
                        compress: str = "none") -> str:
    """Legacy name or pipeline spec → canonical pipeline spec. The canonical
    form is what two specs are compared by (node vs store agreement), so it is
    deterministic: sorted-free single policy stage + optional envelope."""
    if transport is None:
        transport = "quantized" if quantized else "full"
    transport = LEGACY_TRANSPORTS.get(transport, transport)
    policy, envelope = _validate_stages(parse_pipeline_spec(transport))
    if compress not in ("none", "npz", "zstd"):
        raise ValueError(f"unknown compress {compress!r}; options: "
                         "('none', 'npz', 'zstd')")
    if compress != "none":
        if envelope != "none" and envelope != compress:
            raise ValueError(
                f"spec {transport!r} already carries envelope {envelope!r}; "
                f"conflicting compress={compress!r}")
        envelope = compress
    return _canonical(policy, envelope)


def family_transport_spec(families, default: str = "full") -> str:
    """Leaf-family selector → canonical ``family(...)`` spec string. Accepts
    one family name, a sequence of names (each shipped under ``default``), or
    a mapping name → sub-policy. The node/store ``families=`` convenience
    kwargs funnel through here so the selector and the wire spec can never
    disagree."""
    if isinstance(families, str):
        families = (families,)
    if hasattr(families, "items"):
        fams = {str(k): str(v) for k, v in families.items()}
    else:
        fams = {str(name): default for name in families}
    if not fams:
        raise ValueError("family selector needs at least one family name")
    return normalize_transport(
        "family(" + ",".join(f"{k}={v}" for k, v in sorted(fams.items())) + ")")


def parse_folder_uri(uri: str) -> tuple[list[tuple[str, dict]], str]:
    """Folder-URI side of the grammar: ``"shard8+cache+/mnt/x"`` →
    ``([("shard", {"groups": 8, "levels": 1}), ("cache", {})], "/mnt/x")``.
    ``shard8x2+...`` parses to ``{"groups": 8, "levels": 2}`` — an 8-group
    store gossiping through a 2-level summary tree. Wrappers apply
    outermost-first; the base URI is whatever remains (path / memory:// /
    s3://). ``retry+`` wraps the folder beneath it with capped
    exponential-backoff retries on transient I/O errors (flaky NFS /
    object-store reads)."""
    wrappers: list[tuple[str, dict]] = []
    while True:
        m = _SHARD_RE.match(uri)
        if m:
            levels = int(m.group(2)) if m.group(2) is not None else 1
            if levels < 1:
                raise ValueError(
                    f"shard<G>x<L>+ needs L >= 1, got {levels} in {uri!r}")
            wrappers.append(("shard", {"groups": int(m.group(1)),
                                       "levels": levels}))
            uri = m.group(3)
            continue
        if uri.startswith("cache+"):
            wrappers.append(("cache", {}))
            uri = uri[len("cache+"):]
            continue
        if uri.startswith("retry+"):
            wrappers.append(("retry", {}))
            uri = uri[len("retry+"):]
            continue
        return wrappers, uri


# --------------------------------------------------------------------------
# Per-pipeline stats
# --------------------------------------------------------------------------


class PipelineStats:
    """Every wire counter one transport pipeline accumulates. Replaces the
    ad-hoc counters that used to live directly on ``WeightStore`` — one stats
    object per pipeline, shared by its codecs, readable as one dict.

    Mutations go through ``incr``/``set_value``/``record_max``, all guarded by
    one lock: the node thread, the background ``Prefetcher`` thread, and
    in-process soak peers sharing a store all bump these concurrently, and a
    bare ``+=`` on an instance attribute is a load/add/store race in CPython
    (tests/test_telemetry.py has the stress case that loses updates without
    the lock). Fields stay plain attributes for cheap, racy-but-safe reads.
    """

    _INT_FIELDS = (
        "bytes_written", "bytes_read", "encodes", "decodes",
        "decode_hits", "decode_misses", "rebases", "reanchors",
        "chain_depth", "max_chain_depth", "resolve_hops", "max_resolve_hops",
        "topk_k", "prefetch_cycles", "prefetched", "folder_retries",
        # gossip summary-listing memo (ShardedWeightStore): a hit means the
        # folder's listing token was unchanged and the parsed summary index
        # was reused without re-splitting every key
        "summary_index_hits", "summary_index_misses",
    )
    _FLOAT_FIELDS = ("residual_norm", "topk_fraction_effective")

    def __init__(self):
        self._lock = threading.Lock()
        self._zero()

    def _zero(self) -> None:
        for f in self._INT_FIELDS:
            setattr(self, f, 0)
        for f in self._FLOAT_FIELDS:
            setattr(self, f, 0.0)

    def incr(self, field: str, n: int | float = 1) -> None:
        with self._lock:
            setattr(self, field, getattr(self, field) + n)

    def set_value(self, field: str, value: int | float) -> None:
        with self._lock:
            setattr(self, field, value)

    def record_max(self, field: str, value: int | float) -> None:
        with self._lock:
            if value > getattr(self, field):
                setattr(self, field, value)

    def as_dict(self) -> dict[str, int | float]:
        with self._lock:
            return {f: getattr(self, f)
                    for f in self._INT_FIELDS + self._FLOAT_FIELDS}

    def reset(self) -> None:
        # zero in place under the existing lock — re-running __init__ would
        # swap the lock out from under a concurrent writer
        with self._lock:
            self._zero()


class StoreContext:
    """The folder handle codecs read and write through: every byte that
    crosses it is counted on the pipeline's stats, and the shared reader
    caches (interned LeafSpecs, decoded base/chain states) live here so the
    write side, the read side, and the prefetch thread all see one view."""

    def __init__(self, folder, stats: PipelineStats, *,
                 decoded_base_entries: int = 32):
        self.folder = folder
        self.stats = stats
        # attached by the owning store (``attach_telemetry``): when set and
        # enabled, folder round-trips and codec work record latency spans
        self.telemetry = None
        # interned LeafSpecs: one per decoded structure, shared by every
        # FlatUpdate decoded through this context
        self.specs: dict = {}
        # blob-content-hash -> (spec, flat) | (None, tree params): decoded
        # full bases AND reconstructed chain states (a chain link's hash
        # names the exact state it reconstructs to)
        self.decoded_bases = _LruCache(decoded_base_entries)

    def put(self, key: str, blob: bytes) -> None:
        tel = self.telemetry
        if tel is not None and tel.enabled:
            with tel.span("put"):
                self.folder.put(key, blob)
        else:
            self.folder.put(key, blob)
        self.stats.incr("bytes_written", len(blob))

    def get(self, key: str) -> bytes | None:
        tel = self.telemetry
        if tel is not None and tel.enabled:
            with tel.span("get"):
                blob = self.folder.get(key)
        else:
            blob = self.folder.get(key)
        if blob is not None:
            self.stats.incr("bytes_read", len(blob))
        return blob

    def delete(self, key: str) -> None:
        self.folder.delete(key)

    def keys(self) -> list[str]:
        return self.folder.keys()

    def clear(self) -> None:
        self.specs.clear()
        self.decoded_bases.clear()


# --------------------------------------------------------------------------
# Codecs
# --------------------------------------------------------------------------


def _deposit_base(update: NodeUpdate, ctx: StoreContext, *, compress: str,
                  old_hash: str | None, old_chain_keys: list[str],
                  stats: PipelineStats) -> tuple[bytes, str]:
    """Rebase: deposit a full blob under base/<node>/<hash> AND latest/, GC
    superseded bases + chain links. Shared by the delta and topk codecs."""
    node = update.node_id
    full = serialize_update(update, compress=compress)
    h = content_hash(full)
    # Base first, then latest: a reader that sees the new latest can always
    # resolve its base. Old bases/links are GC'd only after the new full
    # latest is in place (readers of the old delta retry into the new blob).
    ctx.put(f"base/{node}/{h}", full)
    ctx.put(f"latest/{node}", full)
    if old_hash is not None:
        # common case: we know exactly what we deposited — delete it directly
        # instead of listing the whole folder
        if old_hash != h:
            ctx.delete(f"base/{node}/{old_hash}")
        for key in old_chain_keys:
            ctx.delete(key)
    else:
        # first rebase in this process: sweep leftovers from a previous
        # incarnation (e.g. a crashed client restarting under its id).
        # match on (prefix, hash) split from the right: node ids may contain
        # '/', so a plain startswith would cross node borders.
        for key in ctx.keys():
            prefix = key.rpartition("/")[0]
            if prefix == f"base/{node}" and key != f"base/{node}/{h}":
                ctx.delete(key)
            elif prefix == f"chain/{node}":
                ctx.delete(key)
    stats.incr("rebases")
    return full, h


class Codec:
    """One wire policy. ``encode`` owns the write side (including any side
    deposits — bases, chain links — and their GC); the read side is the
    static ``decode_wire`` hooks, dispatched on the blob's self-describing
    meta by ``TransportPipeline.decode`` so readers never consult the local
    codec stack."""

    name = "codec"

    def __init__(self, *, compress: str = "none", stats: PipelineStats | None = None,
                 rebase_every: int = 10, density_threshold: float = 0.5,
                 topk_fraction: float = 0.01):
        self.compress = compress
        self.stats = stats if stats is not None else PipelineStats()
        self.rebase_every = rebase_every
        self.density_threshold = density_threshold
        self.topk_fraction = topk_fraction

    def encode(self, update: NodeUpdate, ctx: StoreContext) -> tuple[bytes, bool]:
        """Deposit ``update`` under latest/<node>; -> (blob, is_delta)."""
        raise NotImplementedError

    def reset(self) -> None:
        """Drop per-node writer state (store.clear)."""


class FullCodec(Codec):
    name = "full"

    def encode(self, update: NodeUpdate, ctx: StoreContext) -> tuple[bytes, bool]:
        blob = serialize_update(update, compress=self.compress)
        ctx.put(f"latest/{update.node_id}", blob)
        return blob, False

    @staticmethod
    def decode_wire(blob: bytes, meta: dict, ctx: StoreContext) -> NodeUpdate:
        try:
            spec, flat, m = decode_params_flat(blob, ctx.specs)
            return flat_update_from_meta(spec, flat, m)
        except FlatDecodeUnsupported:
            return deserialize_update(blob)


class QuantizedCodec(Codec):
    name = "quantized"

    def encode(self, update: NodeUpdate, ctx: StoreContext) -> tuple[bytes, bool]:
        blob = serialize_update_quantized(update, compress=self.compress)
        ctx.put(f"latest/{update.node_id}", blob)
        return blob, False

    @staticmethod
    def decode_wire(blob: bytes, meta: dict, ctx: StoreContext) -> NodeUpdate:
        try:
            spec, flat, m = decode_params_flat(blob, ctx.specs)
            return flat_update_from_meta(spec, flat, m)
        except FlatDecodeUnsupported:
            return deserialize_update_quantized(blob)


class _ChainState:
    """Writer-side view of one node's delta chain. ``prev_flat`` is the state
    a reader reconstructs from the current latest blob (hash ``prev_hash``);
    ``anchor_*`` is the content-hashed full base the chain re-anchors to.
    ``depth`` counts delta applications a fresh reader needs (0 = latest IS
    the anchor); ``segment_keys`` are the chain/ links deposited since the
    last re-anchor (GC'd when the next re-anchor supersedes them)."""

    __slots__ = ("anchor_hash", "spec", "anchor_flat", "prev_hash", "prev_flat",
                 "depth", "age", "segment_keys")

    def __init__(self, anchor_hash: str, spec: LeafSpec, anchor_flat: np.ndarray):
        self.anchor_hash = anchor_hash
        self.spec = spec
        self.anchor_flat = anchor_flat
        self.prev_hash = anchor_hash
        self.prev_flat = anchor_flat
        self.depth = 0
        self.age = 0
        self.segment_keys: list[str] = []


class DeltaCodec(Codec):
    """Sparse deltas against a content-hashed base, with optional
    delta-against-delta *chains* (``chain > 1``).

    chain == 1 reproduces the classic transport byte-for-byte: every push
    diffs against the anchor base. chain == K lets each push diff against the
    *previous pushed state* — per-push bytes track one step's sparsity, not
    the drift accumulated since the base — while bounding what a fresh reader
    must reconstruct: a link that would reach depth K+1 instead re-anchors
    with a depth-1 delta against the base. Links are content-addressed under
    ``chain/<node>/<hash>`` so readers can walk ``delta_of`` references; a
    link that will never be referenced again (depth == K, or superseded by a
    re-anchor) is deleted.

    ``q`` (int8-quantized changed values) and non-f32-embeddable models
    (int/f64 leaves) use the per-leaf tree path, which never chains (depth is
    always 1)."""

    name = "delta"

    def __init__(self, *, chain: int = 1, quantize: bool = False, **kw):
        super().__init__(**kw)
        if chain < 1:
            raise ValueError(f"chain must be >= 1, got {chain}")
        if quantize and chain > 1:
            raise ValueError("chained deltas require lossless values (no q)")
        self.chain = chain
        self.quantize = quantize
        # flat-path chain state and tree-path base state, per node; a node
        # lives in exactly one of the two (structure changes migrate it)
        self._chains: dict[str, _ChainState] = {}
        self._tree_bases: dict[str, tuple[str, Any, int]] = {}

    def reset(self) -> None:
        self._chains.clear()
        self._tree_bases.clear()

    # -- write side ----------------------------------------------------------
    def encode(self, update: NodeUpdate, ctx: StoreContext) -> tuple[bytes, bool]:
        node = update.node_id
        if self.quantize:
            return self._encode_tree(update, ctx)
        st = self._chains.get(node)
        spec = st.spec if st is not None else None
        if spec is not None and not spec.describes(update.params):
            spec, st = None, None
        if spec is None:
            spec = LeafSpec.of(update.params)
        if not spec.f32_exact:
            return self._encode_tree(update, ctx)
        self._tree_bases.pop(node, None)
        new_flat = None
        if st is not None and st.age < self.rebase_every:
            try:
                new_flat = spec.flatten(update.params)
            except ValueError:  # shape drift under the same treedef → rebase
                new_flat = None
            if new_flat is not None:
                blob, depth = self._encode_link(update, spec, new_flat, st)
                # One scan decides: if the encoded delta is not actually
                # smaller than a full deposit, rebase instead of shipping a
                # delta that saves nothing.
                if len(blob) < tree_size_bytes(update.params):
                    self._deposit_link(node, blob, depth, st, new_flat, ctx)
                    return blob, True
        full, h = _deposit_base(
            update, ctx, compress=self.compress,
            old_hash=st.anchor_hash if st is not None else None,
            old_chain_keys=st.segment_keys if st is not None else [],
            stats=self.stats)
        if new_flat is None:  # dense-guard rebases already flattened once
            new_flat = spec.flatten(update.params)
        self._chains[node] = _ChainState(h, spec, new_flat)
        self.stats.set_value("chain_depth", 0)
        return full, False

    def _encode_link(self, update, spec, new_flat, st) -> tuple[bytes, int]:
        if st.depth < self.chain:
            ref_hash, ref_flat, depth = st.prev_hash, st.prev_flat, st.depth + 1
        else:  # bound hit: re-anchor against the content-hashed base
            ref_hash, ref_flat, depth = st.anchor_hash, st.anchor_flat, 1
        extra = {"chain_depth": depth} if self.chain > 1 else None
        blob = serialize_update_delta_from_flat(
            update, spec, new_flat, ref_flat, ref_hash,
            density_threshold=self.density_threshold,
            compress=self.compress, extra_meta=extra)
        return blob, depth

    def _deposit_link(self, node, blob, depth, st, new_flat, ctx) -> None:
        bh = content_hash(blob)
        retire: list[str] = []
        if depth == 1 and st.segment_keys:
            # re-anchor: the previous segment's links are unreachable from
            # the new latest — retire them once it is in place
            retire, st.segment_keys = st.segment_keys, []
            self.stats.incr("reanchors")
        if self.chain > 1 and depth < self.chain:
            # the next link will reference this blob by hash — make it
            # addressable BEFORE latest/ points at it. A blob at the depth
            # bound is never referenced (its successor re-anchors): skip it.
            key = f"chain/{node}/{bh}"
            ctx.put(key, blob)
            st.segment_keys.append(key)
        ctx.put(f"latest/{node}", blob)
        for key in retire:
            ctx.delete(key)
        st.prev_hash, st.prev_flat, st.depth = bh, new_flat, depth
        st.age += 1
        self.stats.set_value("chain_depth", depth)
        self.stats.record_max("max_chain_depth", depth)

    def _encode_tree(self, update: NodeUpdate, ctx: StoreContext) -> tuple[bytes, bool]:
        """Per-leaf lossless/quantized path (the pre-chain transport)."""
        node = update.node_id
        self._chains.pop(node, None)
        base = self._tree_bases.get(node)
        if base is not None and base[2] < self.rebase_every:
            h, base_params, age = base
            try:
                blob = serialize_update_delta(
                    update, base_params, h, quantize=self.quantize,
                    density_threshold=self.density_threshold,
                    compress=self.compress)
            except ValueError:  # tree structure changed vs the base → rebase
                blob = None
            if blob is not None and len(blob) < tree_size_bytes(update.params):
                ctx.put(f"latest/{node}", blob)
                self._tree_bases[node] = (h, base_params, age + 1)
                return blob, True
        full, h = _deposit_base(
            update, ctx, compress=self.compress,
            old_hash=base[0] if base is not None else None,
            old_chain_keys=[], stats=self.stats)
        self._tree_bases[node] = (h, canonicalize_params(update.params), 0)
        return full, False

    # -- read side -----------------------------------------------------------
    @staticmethod
    def resolve_state(node_id: str, base_hash: str, ctx: StoreContext):
        """Reconstruct the state a ``delta_of`` reference names: the full
        base blob, or a chain link applied on its own recursively-resolved
        predecessor. -> (spec, flat) | (None, tree params) | None when any
        hop is unresolvable (writer mid-rebase / mid-GC: caller refetches).
        Every reconstructed state is cached by its blob hash, so a steady
        reader resolves each new link in one application, zero extra
        fetches."""
        pending: list[tuple[str, bytes]] = []
        cur = base_hash
        state = None
        while True:
            state = ctx.decoded_bases.get(cur)
            if state is not None:
                break
            raw = ctx.get(f"base/{node_id}/{cur}")
            # hash the RAW fetched bytes — writers hash what they deposit
            if raw is not None and content_hash(raw) == cur:
                blob = maybe_decompress(raw)
                try:
                    spec, flat, _ = decode_params_flat(blob, ctx.specs)
                    state = (spec, flat)
                except FlatDecodeUnsupported:
                    state = (None, deserialize_update(blob).params)
                ctx.decoded_bases.put(cur, state)
                break
            raw = ctx.get(f"chain/{node_id}/{cur}")
            if raw is None or content_hash(raw) != cur:
                return None
            blob = maybe_decompress(raw)
            prev = peek_meta(blob).get("delta_of")
            if not prev or len(pending) >= _MAX_RESOLVE_HOPS:
                return None
            pending.append((cur, blob))
            cur = prev
        hops = len(pending)
        if hops:
            spec, base_state = state
            resolved = None
            if spec is not None:
                # fast path: ONE base copy, every link applied in place —
                # a K-hop walk costs one memcpy plus K sparse scatters
                flat = np.array(base_state, dtype=np.float32, copy=True)
                try:
                    for _bh, blob in reversed(pending):
                        apply_update_delta_flat(blob, spec, flat)
                    resolved = (spec, flat)
                except (FlatDecodeUnsupported, ValueError):
                    resolved = None  # odd dtypes / drift: per-hop fallback
            if resolved is None:
                for _bh, blob in reversed(pending):
                    state = DeltaCodec._apply(blob, state)
                    if state is None:
                        return None
                resolved = state
            # cache only the walked-to state: intermediate hops are never
            # referenced again (writers only ever chain forward)
            ctx.decoded_bases.put(pending[0][0], resolved)
            state = resolved
        ctx.stats.set_value("resolve_hops", hops)
        ctx.stats.record_max("max_resolve_hops", hops)
        return state

    @staticmethod
    def _apply(blob: bytes, state):
        """Apply one (decompressed) delta blob on a resolved state."""
        spec, base_state = state
        if spec is not None:
            try:
                upd = deserialize_update_delta_flat(blob, spec, base_state)
                return (spec, upd.flat)
            except FlatDecodeUnsupported:
                pass  # odd-dtype delta values: fall through to tree path
            except ValueError:
                pass  # structure drift vs the base spec: tree path
            base_state = spec.unflatten(base_state)
        try:
            return (None, deserialize_update_delta(blob, base_state).params)
        except Exception:
            return None

    @staticmethod
    def decode_wire(blob: bytes, meta: dict, ctx: StoreContext,
                    node_id: str, raw_hash: str | None = None) -> NodeUpdate | None:
        state = DeltaCodec.resolve_state(node_id, meta["delta_of"], ctx)
        if state is None:
            return None
        spec, base_state = state
        if spec is not None:
            try:
                upd = deserialize_update_delta_flat(blob, spec, base_state)
                if raw_hash is not None:
                    # seed the chain cache: the writer's next link may
                    # reference this very blob's reconstructed state
                    ctx.decoded_bases.put(raw_hash, (spec, upd.flat))
                return upd
            except FlatDecodeUnsupported:
                pass
            except ValueError:
                pass
            base_state = spec.unflatten(base_state)
        return deserialize_update_delta(blob, base_state)


class TopKCodec(Codec):
    """Error-feedback top-k on flat vectors. The writer tracks ``acc`` — the
    state readers reconstruct (base + every shipped change). Each push ships
    only the top-k largest entries of ``new - acc``; the rest stays in the
    implicit residual and is drained by later pushes. Wire format: ordinary
    delta blobs against the content-hashed base, so readers are oblivious to
    the selection policy.

    ``adaptive=True`` scales k to the *measured residual norm*: the shipped
    fraction is ``fraction * (r / ema(r))`` clipped to ``[fraction/8,
    8*fraction]`` with r = ‖new − acc‖₂ relative to ‖new‖₂ — bursts of
    change (residual spiking above its running mean) ship more entries,
    quiet stretches ship fewer. Non-f32-embeddable models (int/f64 leaves)
    rebase on every push (lossless, just not sparse)."""

    name = "topk"

    def __init__(self, *, adaptive: bool = False, **kw):
        super().__init__(**kw)
        self.adaptive = adaptive
        # node -> (base_hash, spec, base_flat, acc_flat, age)
        self._state: dict[str, tuple] = {}
        self._ema: dict[str, float] = {}  # residual-norm EMA (adaptive mode)

    def reset(self) -> None:
        self._state.clear()
        self._ema.clear()

    def _fraction_for(self, node: str, new_flat: np.ndarray,
                      v: np.ndarray) -> float:
        rn = float(np.linalg.norm(v))
        self.stats.set_value("residual_norm", rn)
        if not self.adaptive:
            self.stats.set_value("topk_fraction_effective", self.topk_fraction)
            return self.topk_fraction
        rel = rn / (float(np.linalg.norm(new_flat)) + 1e-12)
        ema = self._ema.get(node, rel)
        frac = self.topk_fraction * rel / max(ema, 1e-12)
        frac = min(max(frac, self.topk_fraction / 8.0),
                   min(1.0, 8.0 * self.topk_fraction))
        self._ema[node] = 0.7 * ema + 0.3 * rel
        self.stats.set_value("topk_fraction_effective", frac)
        return frac

    def encode(self, update: NodeUpdate, ctx: StoreContext) -> tuple[bytes, bool]:
        node = update.node_id
        state = self._state.get(node)
        spec = None
        if state is not None:
            spec = state[1]
            if not spec.describes(update.params):
                spec, state = None, None
        if spec is None:
            spec = LeafSpec.of(update.params)
        if state is not None and state[4] < self.rebase_every and spec.f32_exact:
            h, _, base_flat, acc, age = state
            try:
                new_flat = spec.flatten(update.params)
            except ValueError:  # shape drift under the same treedef → rebase
                new_flat = None
            if new_flat is not None:
                v = new_flat - acc
                frac = self._fraction_for(node, new_flat, v)
                k = max(1, int(frac * v.size))
                self.stats.set_value("topk_k", k)
                nz = int(np.count_nonzero(v))
                if nz > k:
                    keep = np.argpartition(np.abs(v), v.size - k)[v.size - k:]
                    acc[keep] = new_flat[keep]
                else:
                    # all changes fit the budget: ship everything (where
                    # v == 0, acc already equals new_flat — one flat copy)
                    np.copyto(acc, new_flat)
                changed = np.flatnonzero(acc != base_flat)
                blob = serialize_update_delta_from_flat(
                    update, spec, acc, base_flat, h,
                    changed=changed,
                    density_threshold=self.density_threshold,
                    compress=self.compress,
                )
                if len(blob) < tree_size_bytes(update.params):
                    ctx.put(f"latest/{node}", blob)
                    self._state[node] = (h, spec, base_flat, acc, age + 1)
                    return blob, True
        full, h = _deposit_base(
            update, ctx, compress=self.compress,
            old_hash=state[0] if state is not None else None,
            old_chain_keys=[], stats=self.stats)
        if spec.f32_exact:
            # acc starts at the wire view of the params — exactly what a
            # reader decodes from the base blob (f32-exact dtypes guarantee
            # spec.flatten == the decoded wire values).
            flat = spec.flatten(update.params)
            self._state[node] = (h, spec, flat, flat.copy(), 0)
        else:
            self._state[node] = (h, spec, None, None, self.rebase_every)
        return full, False


class FamilyCodec(Codec):
    """Leaf-family subset transport (LoRA-style adapter federation).

    The writer anchors a content-hashed full base, then every push ships only
    the leaves of the *selected families* (names resolved through
    ``tree.FAMILY_PATTERNS`` → path patterns) as an ordinary delta blob
    against that base — readers reconstruct through the stock delta path and
    never learn the selection policy. Per-family sub-policies route the wire
    encoding: ``full`` ships every member entry each push, ``delta`` only the
    members that changed since the anchor, ``quantized`` ships members
    int8-quantized per leaf segment.

    Reconstructed NON-family leaves equal the anchor's values — a peer's
    local-only leaves are intentionally not shipped. Pair this transport with
    ``PartialFedAvg(families=...)``, which masks them out of aggregation
    anyway: each node keeps its personal leaves bit-exact. Trees whose leaves
    don't embed exactly in f32 (int/f64) rebase on every push (lossless, just
    not sparse)."""

    name = "family"

    def __init__(self, *, families: dict[str, str], **kw):
        super().__init__(**kw)
        self.families = dict(families)
        # node -> (base_hash, spec, base_flat, age)
        self._state: dict[str, tuple] = {}

    def reset(self) -> None:
        self._state.clear()

    def _changed_indices(self, view, new_flat: np.ndarray,
                         base_flat: np.ndarray) -> np.ndarray:
        segs = []
        for fam, sub in self.families.items():
            idx = view.indices_of(fam)
            if sub == "delta":
                idx = idx[new_flat[idx] != base_flat[idx]]
            segs.append(idx)
        if len(segs) == 1:
            return segs[0]
        # families are disjoint (first-match-wins leaf assignment), so a
        # plain sort of the concatenation is already duplicate-free
        changed = np.concatenate(segs)
        changed.sort()
        return changed

    def encode(self, update: NodeUpdate, ctx: StoreContext) -> tuple[bytes, bool]:
        node = update.node_id
        state = self._state.get(node)
        spec = None
        if state is not None:
            spec = state[1]
            if not spec.describes(update.params):
                spec, state = None, None
        if spec is None:
            spec = LeafSpec.of(update.params)
        # Resolve the selector against this structure up front: an unknown
        # family name or one matching no leaf must fail on the first push,
        # not silently ship nothing.
        view = spec.family_view(tuple(self.families))
        if state is not None and state[3] < self.rebase_every and spec.f32_exact:
            h, _, base_flat, age = state
            try:
                new_flat = spec.flatten(update.params)
            except ValueError:  # shape drift under the same treedef → rebase
                new_flat = None
            if new_flat is not None:
                changed = self._changed_indices(view, new_flat, base_flat)
                quantize_leaves = frozenset(
                    i for i, fam in enumerate(view.leaf_names)
                    if fam is not None and self.families[fam] == "quantized")
                blob = serialize_update_delta_from_flat(
                    update, spec, new_flat, base_flat, h,
                    changed=changed,
                    density_threshold=self.density_threshold,
                    compress=self.compress,
                    quantize_leaves=quantize_leaves,
                    extra_meta={"families": dict(sorted(self.families.items()))},
                )
                if len(blob) < tree_size_bytes(update.params):
                    ctx.put(f"latest/{node}", blob)
                    self._state[node] = (h, spec, base_flat, age + 1)
                    return blob, True
        full, h = _deposit_base(
            update, ctx, compress=self.compress,
            old_hash=state[0] if state is not None else None,
            old_chain_keys=[], stats=self.stats)
        if spec.f32_exact:
            # base_flat is exactly what a reader decodes from the base blob
            # (f32-exact dtypes guarantee spec.flatten == the wire values)
            self._state[node] = (h, spec, spec.flatten(update.params), 0)
        else:
            self._state[node] = (h, spec, None, self.rebase_every)
        return full, False


# --------------------------------------------------------------------------
# The pipeline
# --------------------------------------------------------------------------


_CODECS = {"full": FullCodec, "quantized": QuantizedCodec,
           "delta": DeltaCodec, "topk": TopKCodec, "family": FamilyCodec}


class TransportPipeline:
    """One parsed wire pipeline: a policy codec + an optional compressed
    envelope + the stats they share. ``WeightStore`` delegates its entire
    push/decode wire path here; summaries and strategy-state blobs ride the
    same envelope via ``encode_summary`` / the ``compress`` attribute."""

    def __init__(self, spec: str, *, rebase_every: int = 10,
                 delta_density_threshold: float = 0.5,
                 topk_fraction: float = 0.01):
        policy, envelope = _validate_stages(parse_pipeline_spec(
            LEGACY_TRANSPORTS.get(spec, spec)))
        self.spec = _canonical(policy, envelope)
        self.compress = envelope
        if envelope == "zstd":
            from .serialize import _zstd_module

            if _zstd_module() is None:
                raise ImportError(
                    "compress='zstd' requires a zstd module (zstandard)")
        name, args = policy
        kw: dict[str, Any] = dict(
            compress=envelope if envelope != "none" else "none",
            rebase_every=rebase_every,
            density_threshold=delta_density_threshold,
            topk_fraction=topk_fraction,
        )
        if name == "delta":
            kw["chain"] = args["chain"]
            kw["quantize"] = args["q"]
            if "rebase" in args:
                kw["rebase_every"] = args["rebase"]
        elif name == "topk":
            kw["adaptive"] = args["adaptive"]
            if args["fraction"] is not None:
                kw["topk_fraction"] = args["fraction"]
        elif name == "family":
            kw["families"] = args["families"]
        if not 0.0 < kw["topk_fraction"] <= 1.0:
            raise ValueError(
                f"topk_fraction must be in (0, 1], got {kw['topk_fraction']}")
        self.stats = PipelineStats()
        kw["stats"] = self.stats
        self.policy: Codec = _CODECS[name](**kw)

    @classmethod
    def from_spec(cls, transport: str | None = None, *, quantized: bool = False,
                  compress: str = "none", **kw) -> "TransportPipeline":
        return cls(normalize_transport(transport, quantized=quantized,
                                       compress=compress), **kw)

    # -- write side ----------------------------------------------------------
    def push(self, update: NodeUpdate, ctx: StoreContext) -> tuple[bytes, bool]:
        self.stats.incr("encodes")
        tel = ctx.telemetry
        if tel is not None and tel.enabled:
            with tel.span("encode"):
                return self.policy.encode(update, ctx)
        return self.policy.encode(update, ctx)

    def encode_history(self, update: NodeUpdate) -> bytes:
        """Self-contained (and, for lossy policies, exact) history blob."""
        return serialize_update(update, compress=self.compress_arg)

    def encode_summary(self, summary) -> bytes:
        """Gossip group summaries ride the pipeline's envelope."""
        return serialize_group_summary(summary, compress=self.compress_arg)

    def encode_super_summary(self, summary) -> bytes:
        """Hierarchical-gossip tier folds ride the same envelope."""
        return serialize_super_summary(summary, compress=self.compress_arg)

    @property
    def compress_arg(self) -> str:
        return self.compress if self.compress != "none" else "none"

    # -- read side (policy-oblivious: dispatches on wire meta) ----------------
    def decode(self, blob: bytes, node_id: str, ctx: StoreContext) -> NodeUpdate | None:
        """Decode a self-describing blob; None when a delta's reference chain
        cannot be resolved yet (caller refetches — the writer is mid-rebase
        or mid-GC)."""
        self.stats.incr("decodes")
        tel = ctx.telemetry
        if tel is not None and tel.enabled:
            with tel.span("decode"):
                return self._decode(blob, node_id, ctx)
        return self._decode(blob, node_id, ctx)

    def _decode(self, blob: bytes, node_id: str, ctx: StoreContext) -> NodeUpdate | None:
        raw = blob
        # Decompress exactly once up front: peek_meta and every decode below
        # call maybe_decompress themselves, which is a no-op on raw npz bytes
        # but a full second (or third) zstd pass on a still-wrapped blob.
        blob = maybe_decompress(blob)
        meta = peek_meta(blob)
        if meta.get("delta_of"):
            # content-hash the raw bytes only for deltas: a chain link's
            # successor references this blob's hash (full blobs are big and
            # never referenced by latest-hash — their identity is base/<h>)
            return DeltaCodec.decode_wire(blob, meta, ctx, node_id,
                                          raw_hash=content_hash(raw))
        if meta.get("quantized"):
            return QuantizedCodec.decode_wire(blob, meta, ctx)
        return FullCodec.decode_wire(blob, meta, ctx)

    def reset(self) -> None:
        self.policy.reset()


# --------------------------------------------------------------------------
# Background prefetch
# --------------------------------------------------------------------------


class Prefetcher:
    """Warms a store's decoded-update cache between federation steps.

    A daemon thread periodically calls ``store.warm_cache()``, which walks
    the folder's ``latest/`` listing, compares each key's cheap ``version()``
    token against the decoded-update cache, and decodes only the stale
    entries — so by the time the training loop reaches its federation step,
    ``pull`` is all cache hits and the step pays neither download nor npz
    decode. Exceptions are swallowed (a mid-rebase writer or a vanished key
    is routine); the next cycle retries.

    The thread holds only a *weak* reference to the store: a short-lived
    store that was never explicitly ``stop_prefetch()``-ed is still
    collectable (its caches hold full decoded flat vectors — pinning them
    from an immortal poller would leak a model-sized cache per store), and
    the thread exits on its own once the store is gone."""

    def __init__(self, store, *, interval: float = 0.1,
                 exclude: str | None = None):
        import weakref

        self._store_ref = weakref.ref(store)
        self.interval = interval
        self.exclude = exclude
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._loop, name="weightstore-prefetch", daemon=True)
        self._thread.start()

    def _loop(self) -> None:
        while not self._stop.wait(self.interval):
            store = self._store_ref()
            if store is None:
                return  # store collected: nothing left to warm
            try:
                store.warm_cache(exclude=self.exclude)
            except Exception:
                # routine during rebases/GC; next cycle retries — but leave a
                # debug trail instead of vanishing the error entirely
                _log.debug("prefetch sweep failed", exc_info=True)
            del store  # don't pin the store across the sleep

    def stop(self) -> None:
        self._stop.set()
        self._thread.join(timeout=5.0)

    @property
    def running(self) -> bool:
        return self._thread.is_alive()
