"""Federated simulation harnesses.

Two complementary simulators:

* ``run_threaded`` — real concurrency with Python threads sharing one weight
  store, mirroring the paper's own experimental setup ("we simulated
  concurrent training jobs with python multi-threading"). Supports injected
  per-node failures to reproduce the paper's robustness claims.

* ``simulate_timeline`` — deterministic event-driven virtual-clock model of
  sync vs async federation. The paper's timing claims (async avoids straggler
  idle time) are functions of per-node epoch durations only, so we compute
  them exactly instead of sleeping: sync wall-clock = Σ_rounds max_k(t_k),
  async wall-clock per node = Σ its own epochs; federation events are replayed
  in virtual-time order to count aggregations and idle time.
"""
from __future__ import annotations

import threading
import traceback
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence


# --------------------------------------------------------------------------
# Thread-based simulation (paper-faithful)
# --------------------------------------------------------------------------


@dataclass
class ClientResult:
    node_id: str
    result: Any = None
    error: BaseException | None = None
    traceback: str = ""


def run_threaded(client_fns: Sequence[Callable[[], Any]], *, names: Sequence[str] | None = None,
                 join_timeout: float = 600.0) -> list[ClientResult]:
    """Run client closures concurrently; never lets one crash kill the rest
    (that is precisely the async-robustness story)."""
    names = list(names or [f"node{i}" for i in range(len(client_fns))])
    results = [ClientResult(node_id=n) for n in names]

    def _wrap(i: int, fn: Callable[[], Any]) -> None:
        try:
            results[i].result = fn()
        except BaseException as e:  # noqa: BLE001 - captured for the caller
            results[i].error = e
            results[i].traceback = traceback.format_exc()

    threads = [
        threading.Thread(target=_wrap, args=(i, fn), name=names[i], daemon=True)
        for i, fn in enumerate(client_fns)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=join_timeout)
    return results


# --------------------------------------------------------------------------
# Event-driven virtual-clock timing model
# --------------------------------------------------------------------------


@dataclass
class TimelineResult:
    mode: str
    wall_clock: float                      # time until ALL nodes finish E epochs
    per_node_finish: list[float]
    per_node_idle: list[float]             # barrier wait (sync) — async is 0
    federation_events: list[tuple[float, int, int]] = field(default_factory=list)
    # (virtual time, node, number of peer updates visible at that moment)


def simulate_timeline(
    epoch_durations: Sequence[Sequence[float]],
    *,
    mode: str = "async",
    comm_time: float = 0.0,
    failures: dict[int, int] | None = None,
) -> TimelineResult:
    """Replay a federation schedule in virtual time.

    epoch_durations[k][i] = duration of node k's epoch i.
    failures maps node → epoch index at which the node dies.
    sync: every epoch ends with a barrier across *alive* nodes... except that
    the paper's (and real Flower's) semantics are that a dead node blocks the
    round forever — we model that: if any node dies, sync wall_clock = inf for
    the remaining nodes' work.
    """
    failures = failures or {}
    num_nodes = len(epoch_durations)
    num_epochs = len(epoch_durations[0])
    if any(len(d) != num_epochs for d in epoch_durations):
        raise ValueError("all nodes need the same number of planned epochs")

    if mode == "sync":
        t = 0.0
        idle = [0.0] * num_nodes
        finish = [0.0] * num_nodes
        events: list[tuple[float, int, int]] = []
        dead: set[int] = set()
        for e in range(num_epochs):
            for k in list(failures):
                if failures[k] == e:
                    dead.add(k)
            if dead:
                # a dead client never deposits round-e weights: barrier hangs.
                return TimelineResult(
                    mode="sync",
                    wall_clock=float("inf"),
                    per_node_finish=[float("inf")] * num_nodes,
                    per_node_idle=idle,
                    federation_events=events,
                )
            ends = [t + epoch_durations[k][e] for k in range(num_nodes)]
            barrier = max(ends) + comm_time
            for k in range(num_nodes):
                idle[k] += barrier - ends[k]
                finish[k] = barrier
                events.append((barrier, k, num_nodes - 1))
            t = barrier
        return TimelineResult("sync", t, finish, idle, events)

    if mode == "async":
        # Each node runs its own timeline; at each epoch end it sees whichever
        # peers have already deposited (push at epoch end, pull immediately).
        deposit_times: list[list[float]] = []
        for k in range(num_nodes):
            t, deps = 0.0, []
            die_at = failures.get(k, num_epochs + 1)
            for e in range(num_epochs):
                if e >= die_at:
                    break
                t += epoch_durations[k][e] + comm_time
                deps.append(t)
            deposit_times.append(deps)
        events = []
        finish = []
        for k in range(num_nodes):
            deps = deposit_times[k]
            finish.append(deps[-1] if deps else 0.0)
            for t_dep in deps:
                visible = sum(
                    1
                    for j in range(num_nodes)
                    if j != k and any(dj <= t_dep for dj in deposit_times[j])
                )
                events.append((t_dep, k, visible))
        events.sort()
        alive_finish = [f for k, f in enumerate(finish) if failures.get(k, num_epochs + 1) > num_epochs]
        wall = max(alive_finish) if alive_finish else max(finish)
        return TimelineResult("async", wall, finish, [0.0] * num_nodes, events)

    raise ValueError(f"unknown mode {mode!r}")


def straggler_speedup(epoch_durations: Sequence[Sequence[float]], comm_time: float = 0.0) -> float:
    """wall_clock(sync) / wall_clock(async) for the same schedule."""
    sync = simulate_timeline(epoch_durations, mode="sync", comm_time=comm_time)
    asyn = simulate_timeline(epoch_durations, mode="async", comm_time=comm_time)
    return sync.wall_clock / asyn.wall_clock
