"""Federated simulation harnesses.

Three complementary simulators:

* ``run_threaded`` — real concurrency with Python threads sharing one weight
  store, mirroring the paper's own experimental setup ("we simulated
  concurrent training jobs with python multi-threading"). Supports injected
  per-node failures to reproduce the paper's robustness claims.

* ``run_multiprocess`` — the same contract across real OS processes sharing a
  ``DiskFolder`` (or any mountable backend). This is the honest version of
  the paper's serverless claim: no shared Python objects, no GIL, crash
  injection is a real SIGKILL mid-round, and survivors must make progress on
  the strength of the shared folder alone.

* ``simulate_timeline`` — deterministic event-driven virtual-clock model of
  sync vs async federation. The paper's timing claims (async avoids straggler
  idle time) are functions of per-node epoch durations only, so we compute
  them exactly instead of sleeping: sync wall-clock = Σ_rounds max_k(t_k),
  async wall-clock per node = Σ its own epochs; federation events are replayed
  in virtual-time order to count aggregations and idle time.
"""
from __future__ import annotations

import multiprocessing
import os
import signal
import threading
import time
import traceback
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence


# --------------------------------------------------------------------------
# Thread-based simulation (paper-faithful)
# --------------------------------------------------------------------------


@dataclass
class ClientResult:
    node_id: str
    result: Any = None
    error: BaseException | None = None
    traceback: str = ""
    exitcode: int | None = None  # set by run_multiprocess; None for threads


def run_threaded(client_fns: Sequence[Callable[[], Any]], *, names: Sequence[str] | None = None,
                 join_timeout: float = 600.0) -> list[ClientResult]:
    """Run client closures concurrently; never lets one crash kill the rest
    (that is precisely the async-robustness story)."""
    names = list(names or [f"node{i}" for i in range(len(client_fns))])
    results = [ClientResult(node_id=n) for n in names]

    def _wrap(i: int, fn: Callable[[], Any]) -> None:
        try:
            results[i].result = fn()
        except BaseException as e:  # noqa: BLE001 - captured for the caller
            results[i].error = e
            results[i].traceback = traceback.format_exc()

    threads = [
        threading.Thread(target=_wrap, args=(i, fn), name=names[i], daemon=True)
        for i, fn in enumerate(client_fns)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=join_timeout)
    return results


# --------------------------------------------------------------------------
# Process-based federation runtime
# --------------------------------------------------------------------------


class ProcessCrashed(RuntimeError):
    """A client process exited without reporting a result (crash / SIGKILL)."""


def _mp_entry(target: Callable[..., Any], args: tuple, kwargs: dict, conn) -> None:
    """Child entrypoint: run the client and ship (ok, result, tb) back over the
    child's private pipe (one channel per process — a SIGKILL mid-send can only
    corrupt the victim's own channel, never a survivor's)."""
    try:
        result = target(*args, **kwargs)
        conn.send((True, result, ""))
    except BaseException:  # noqa: BLE001 - reported to the parent, never raised
        conn.send((False, None, traceback.format_exc()))
    finally:
        conn.close()


class _Supervised:
    """One client slot under a ProcessSupervisor: the (re)spawnable target
    spec, the live process + pipe of the current incarnation, its result, and
    any armed kill timers."""

    __slots__ = ("name", "target", "args", "kwargs", "proc", "conn", "result",
                 "settled", "received", "timers", "history")

    def __init__(self, name: str, target: Callable[..., Any], args: tuple, kwargs: dict):
        self.name = name
        self.target = target
        self.args = args
        self.kwargs = kwargs
        self.proc = None
        self.conn = None
        self.result = ClientResult(node_id=name)
        self.settled = False
        self.received = False
        self.timers: list[threading.Timer] = []
        self.history: list[ClientResult] = []  # earlier incarnations' results

    def cancel_timers(self) -> None:
        """A settled client's scheduled kills must die with it: an unfired
        Timer is a live thread, and a long-running supervisor (the fleet
        worker) would otherwise accumulate one per finished client until its
        own shutdown."""
        for t in self.timers:
            t.cancel()
        for t in self.timers:
            t.join(timeout=1.0)
        self.timers.clear()


class ProcessSupervisor:
    """Owns a set of client OS processes: spawn, poll, kill, restart, reap.

    The process-supervision core of ``run_multiprocess`` (which remains the
    one-shot convenience wrapper), exposed as an incremental object so
    long-lived harnesses — the fleet chaos worker — can SIGKILL a client
    mid-round and respawn it under the same name without tearing the whole
    cohort down. Clients run under the ``spawn`` start method by default
    (clean interpreters; the only fork-safe choice once JAX threads exist in
    the parent) and are daemonic: a dying supervisor never strands children.

    Lifecycle of one client: ``spawn(name, target, args)`` → the child runs
    and ships ``(ok, result, tb)`` over a private pipe → ``poll()`` absorbs
    the message (or notices a silent death) and marks the client *settled* →
    ``result(name)`` carries the outcome. ``spawn`` on a settled name is a
    restart: the previous incarnation's result moves to ``history(name)``.
    Kill timers armed via ``schedule_kill`` are cancelled the moment their
    client settles (no leaked timer threads) and on ``shutdown()``.
    """

    def __init__(self, *, start_method: str = "spawn"):
        self._ctx = multiprocessing.get_context(start_method)
        self._clients: dict[str, _Supervised] = {}

    # -- lifecycle ------------------------------------------------------------
    def spawn(self, name: str, target: Callable[..., Any], args: tuple = (),
              kwargs: dict | None = None) -> None:
        """Launch ``target(*args, **kwargs)`` as a supervised process. A name
        already present must be settled (then this is a restart); anything
        else is two live processes under one identity — a caller bug."""
        c = self._clients.get(name)
        if c is not None:
            if not c.settled:
                raise ValueError(f"client {name!r} is still running")
            self._reap(c, timeout=5.0)
            c.cancel_timers()
            c.history.append(c.result)
            c.target, c.args, c.kwargs = target, args, dict(kwargs or {})
            c.result = ClientResult(node_id=name)
            c.settled = c.received = False
        else:
            c = _Supervised(name, target, args, dict(kwargs or {}))
            self._clients[name] = c
        parent_conn, child_conn = self._ctx.Pipe(duplex=False)
        c.proc = self._ctx.Process(
            target=_mp_entry, args=(c.target, c.args, c.kwargs, child_conn),
            name=name, daemon=True)
        c.conn = parent_conn
        c.proc.start()
        child_conn.close()  # parent's copy; lets recv see EOF when a child dies

    def respawn(self, name: str) -> None:
        """Restart a settled client with its previous target spec (the chaos
        engine's restart-with-resume step)."""
        c = self._client(name)
        self.spawn(name, c.target, c.args, c.kwargs)

    def kill(self, name: str) -> None:
        """SIGKILL the client's current process: no cleanup, no goodbye
        deposit — the crash the serverless robustness claim must survive."""
        c = self._client(name)
        if c.proc is not None:
            _sigkill(c.proc)

    def schedule_kill(self, name: str, delay: float) -> None:
        """Arm a SIGKILL ``delay`` seconds from now. The timer targets the
        process object alive *now* — a client that settles (or restarts)
        first has the timer cancelled, never a stale kill on a reused pid."""
        c = self._client(name)
        timer = threading.Timer(delay, _sigkill, args=(c.proc,))
        timer.daemon = True
        timer.start()
        c.timers.append(timer)

    def cancel_scheduled_kills(self, name: str) -> None:
        """Disarm every pending ``schedule_kill`` timer for ``name`` without
        touching the process. The chaos engine's clean-finish path: a kill
        victim that deposited its result between the parked heartbeat and the
        backstop must not eat a spurious SIGKILL (or be counted as a crash)."""
        self._client(name).cancel_timers()

    # -- observation ----------------------------------------------------------
    def poll(self) -> list[str]:
        """Absorb whatever the clients have reported; returns the names that
        settled during this call. Non-blocking (modulo a 50 ms drain grant to
        freshly-dead channels)."""
        newly = []
        for c in self._clients.values():
            if not c.settled and self._try_settle(c):
                newly.append(c.name)
        return newly

    def unsettled(self) -> list[str]:
        return [c.name for c in self._clients.values() if not c.settled]

    def names(self) -> list[str]:
        return list(self._clients)

    def result(self, name: str) -> ClientResult:
        """The current (latest-incarnation) result of ``name``."""
        return self._client(name).result

    def history(self, name: str) -> list[ClientResult]:
        """Results of earlier incarnations (oldest first), excluding the
        current one."""
        return list(self._client(name).history)

    def incarnation(self, name: str) -> int:
        """0 for the first launch, +1 per restart."""
        return len(self._client(name).history)

    # -- collective waits -----------------------------------------------------
    def join(self, timeout: float) -> None:
        """Wait (bounded) for every client to settle; clients still alive at
        the deadline are reaped (SIGKILL) and report ``ProcessCrashed``."""
        deadline = time.monotonic() + timeout
        while self.unsettled() and time.monotonic() < deadline:
            if not self.poll():
                time.sleep(0.05)
        # Final sweep: a result delivered right at the deadline is already
        # sitting in our end of the pipe — recover it, don't report a crash.
        for c in self._clients.values():
            if not c.settled:
                self._try_settle(c)
        for c in self._clients.values():
            if not c.settled:  # hung past the deadline: reap it
                self._reap(c, timeout=0.0)
                self._settle(c)
            else:
                self._reap(c, timeout=max(0.0, deadline - time.monotonic()) + 1.0)
            c.cancel_timers()

    def shutdown(self) -> None:
        """Cancel every armed timer, reap every process. Idempotent; safe
        after an exception mid-flight (run_multiprocess calls it in a
        ``finally``)."""
        for c in self._clients.values():
            c.cancel_timers()
            self._reap(c, timeout=0.0)
            if not c.settled:
                self._try_settle(c)
            if not c.settled:
                self._settle(c)

    # -- internals ------------------------------------------------------------
    def _client(self, name: str) -> _Supervised:
        c = self._clients.get(name)
        if c is None:
            raise KeyError(f"no supervised client {name!r}")
        return c

    def _try_settle(self, c: _Supervised) -> bool:
        """Absorb the client's message if available; True when it settled
        (reported, channel dead, or process gone without reporting)."""
        alive = c.proc.is_alive()  # check BEFORE polling: a message landing
        # between poll and liveness check must not be mistaken for a crash
        try:
            if not c.conn.poll(0 if alive else 0.05):
                if alive:
                    return False
                # dead + channel empty ⇒ will never report
                self._settle(c)
                return True
            ok, result, tb = c.conn.recv()
        except (EOFError, OSError):  # killed mid-send: only its own channel dies
            self._settle(c)
            return True
        c.received = True
        if ok:
            c.result.result = result
        else:
            c.result.error = ProcessCrashed(f"client {c.name} raised")
            c.result.traceback = tb
        self._settle(c)
        return True

    def _settle(self, c: _Supervised) -> None:
        c.settled = True
        c.cancel_timers()
        self._reap(c, timeout=5.0)
        if not c.received and c.result.error is None:
            c.result.error = ProcessCrashed(
                f"client {c.name} exited with code {c.result.exitcode} "
                "before reporting"
            )

    @staticmethod
    def _reap(c: _Supervised, timeout: float) -> None:
        if c.proc is None:
            return
        c.proc.join(timeout=timeout)
        if c.proc.is_alive():
            _sigkill(c.proc)
            c.proc.join(timeout=5.0)
        c.result.exitcode = c.proc.exitcode


def _sigkill(proc) -> None:
    if proc is not None and proc.is_alive() and proc.pid is not None:
        try:
            os.kill(proc.pid, signal.SIGKILL)
        except ProcessLookupError:
            pass


def run_multiprocess(
    clients: Sequence[Callable[[], Any] | tuple],
    *,
    names: Sequence[str] | None = None,
    start_method: str = "spawn",
    join_timeout: float = 600.0,
    kill_after: dict[int, float] | None = None,
) -> list[ClientResult]:
    """Run clients as real OS processes; a crashed process never kills the rest.

    Each entry of ``clients`` is either a zero-arg callable or a
    ``(target, args)`` / ``(target, args, kwargs)`` tuple. Targets and their
    return values cross a process boundary, so both must be picklable —
    module-level functions, not closures (the default ``spawn`` start method
    gives every client a clean interpreter, which is what a real serverless
    deployment looks like and is the only fork-safe choice once JAX threads
    exist in the parent).

    ``kill_after`` maps client index → seconds after launch at which the
    process is SIGKILLed (crash injection mid-round: no cleanup, no goodbye
    deposit — exactly what the async-robustness claim must survive). Killed or
    timed-out clients report a ``ProcessCrashed`` error in their
    ``ClientResult``; survivors are unaffected. Kill timers are cancelled as
    soon as their client settles — a client finishing before its scheduled
    kill leaves no timer thread behind.

    One-shot wrapper over ``ProcessSupervisor`` (use that directly for
    incremental spawn/kill/restart — the fleet chaos harness does).
    """
    specs: list[tuple[Callable[..., Any], tuple, dict]] = []
    for entry in clients:
        if callable(entry):
            specs.append((entry, (), {}))
        else:
            target = entry[0]
            args = tuple(entry[1]) if len(entry) > 1 else ()
            kwargs = dict(entry[2]) if len(entry) > 2 else {}
            specs.append((target, args, kwargs))
    for i in kill_after or {}:
        # validate BEFORE launching anything: failing mid-setup would leave
        # already-started children running unsupervised
        if not 0 <= i < len(specs):
            raise ValueError(f"kill_after index {i} out of range for {len(specs)} clients")
    names = list(names or [f"node{i}" for i in range(len(specs))])
    if len(names) != len(specs):
        raise ValueError(f"{len(names)} names for {len(specs)} clients")
    if len(set(names)) != len(names):
        raise ValueError(f"client names must be unique, got {names}")

    sup = ProcessSupervisor(start_method=start_method)
    try:
        for name, (t, a, kw) in zip(names, specs):
            sup.spawn(name, t, a, kw)
        for i, delay in (kill_after or {}).items():
            sup.schedule_kill(names[i], delay)
        sup.join(join_timeout)
    finally:
        sup.shutdown()
    return [sup.result(n) for n in names]


# --------------------------------------------------------------------------
# Event-driven virtual-clock timing model
# --------------------------------------------------------------------------


@dataclass
class TimelineResult:
    mode: str
    wall_clock: float                      # time until ALL nodes finish E epochs
    per_node_finish: list[float]
    per_node_idle: list[float]             # barrier wait (sync) — async is 0
    federation_events: list[tuple[float, int, int]] = field(default_factory=list)
    # (virtual time, node, number of peer updates visible at that moment)


def simulate_timeline(
    epoch_durations: Sequence[Sequence[float]],
    *,
    mode: str = "async",
    comm_time: float = 0.0,
    failures: dict[int, int] | None = None,
) -> TimelineResult:
    """Replay a federation schedule in virtual time.

    epoch_durations[k][i] = duration of node k's epoch i.
    failures maps node → epoch index at which the node dies.
    sync: every epoch ends with a barrier across *alive* nodes... except that
    the paper's (and real Flower's) semantics are that a dead node blocks the
    round forever — we model that: if any node dies, sync wall_clock = inf for
    the remaining nodes' work.
    """
    failures = failures or {}
    num_nodes = len(epoch_durations)
    num_epochs = len(epoch_durations[0])
    if any(len(d) != num_epochs for d in epoch_durations):
        raise ValueError("all nodes need the same number of planned epochs")

    if mode == "sync":
        t = 0.0
        idle = [0.0] * num_nodes
        finish = [0.0] * num_nodes
        events: list[tuple[float, int, int]] = []
        dead: set[int] = set()
        for e in range(num_epochs):
            for k in list(failures):
                if failures[k] == e:
                    dead.add(k)
            if dead:
                # a dead client never deposits round-e weights: barrier hangs.
                return TimelineResult(
                    mode="sync",
                    wall_clock=float("inf"),
                    per_node_finish=[float("inf")] * num_nodes,
                    per_node_idle=idle,
                    federation_events=events,
                )
            ends = [t + epoch_durations[k][e] for k in range(num_nodes)]
            barrier = max(ends) + comm_time
            for k in range(num_nodes):
                idle[k] += barrier - ends[k]
                finish[k] = barrier
                events.append((barrier, k, num_nodes - 1))
            t = barrier
        return TimelineResult("sync", t, finish, idle, events)

    if mode == "async":
        # Each node runs its own timeline; at each epoch end it sees whichever
        # peers have already deposited (push at epoch end, pull immediately).
        deposit_times: list[list[float]] = []
        for k in range(num_nodes):
            t, deps = 0.0, []
            die_at = failures.get(k, num_epochs + 1)
            for e in range(num_epochs):
                if e >= die_at:
                    break
                t += epoch_durations[k][e] + comm_time
                deps.append(t)
            deposit_times.append(deps)
        events = []
        finish = []
        for k in range(num_nodes):
            deps = deposit_times[k]
            finish.append(deps[-1] if deps else 0.0)
            for t_dep in deps:
                visible = sum(
                    1
                    for j in range(num_nodes)
                    if j != k and any(dj <= t_dep for dj in deposit_times[j])
                )
                events.append((t_dep, k, visible))
        events.sort()
        alive_finish = [f for k, f in enumerate(finish) if failures.get(k, num_epochs + 1) > num_epochs]
        wall = max(alive_finish) if alive_finish else max(finish)
        return TimelineResult("async", wall, finish, [0.0] * num_nodes, events)

    raise ValueError(f"unknown mode {mode!r}")


def straggler_speedup(epoch_durations: Sequence[Sequence[float]], comm_time: float = 0.0) -> float:
    """wall_clock(sync) / wall_clock(async) for the same schedule."""
    sync = simulate_timeline(epoch_durations, mode="sync", comm_time=comm_time)
    asyn = simulate_timeline(epoch_durations, mode="async", comm_time=comm_time)
    return sync.wall_clock / asyn.wall_clock
