"""Federated simulation harnesses.

Three complementary simulators:

* ``run_threaded`` — real concurrency with Python threads sharing one weight
  store, mirroring the paper's own experimental setup ("we simulated
  concurrent training jobs with python multi-threading"). Supports injected
  per-node failures to reproduce the paper's robustness claims.

* ``run_multiprocess`` — the same contract across real OS processes sharing a
  ``DiskFolder`` (or any mountable backend). This is the honest version of
  the paper's serverless claim: no shared Python objects, no GIL, crash
  injection is a real SIGKILL mid-round, and survivors must make progress on
  the strength of the shared folder alone.

* ``simulate_timeline`` — deterministic event-driven virtual-clock model of
  sync vs async federation. The paper's timing claims (async avoids straggler
  idle time) are functions of per-node epoch durations only, so we compute
  them exactly instead of sleeping: sync wall-clock = Σ_rounds max_k(t_k),
  async wall-clock per node = Σ its own epochs; federation events are replayed
  in virtual-time order to count aggregations and idle time.
"""
from __future__ import annotations

import multiprocessing
import os
import signal
import threading
import time
import traceback
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence


# --------------------------------------------------------------------------
# Thread-based simulation (paper-faithful)
# --------------------------------------------------------------------------


@dataclass
class ClientResult:
    node_id: str
    result: Any = None
    error: BaseException | None = None
    traceback: str = ""
    exitcode: int | None = None  # set by run_multiprocess; None for threads


def run_threaded(client_fns: Sequence[Callable[[], Any]], *, names: Sequence[str] | None = None,
                 join_timeout: float = 600.0) -> list[ClientResult]:
    """Run client closures concurrently; never lets one crash kill the rest
    (that is precisely the async-robustness story)."""
    names = list(names or [f"node{i}" for i in range(len(client_fns))])
    results = [ClientResult(node_id=n) for n in names]

    def _wrap(i: int, fn: Callable[[], Any]) -> None:
        try:
            results[i].result = fn()
        except BaseException as e:  # noqa: BLE001 - captured for the caller
            results[i].error = e
            results[i].traceback = traceback.format_exc()

    threads = [
        threading.Thread(target=_wrap, args=(i, fn), name=names[i], daemon=True)
        for i, fn in enumerate(client_fns)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=join_timeout)
    return results


# --------------------------------------------------------------------------
# Process-based federation runtime
# --------------------------------------------------------------------------


class ProcessCrashed(RuntimeError):
    """A client process exited without reporting a result (crash / SIGKILL)."""


def _mp_entry(target: Callable[..., Any], args: tuple, kwargs: dict, conn) -> None:
    """Child entrypoint: run the client and ship (ok, result, tb) back over the
    child's private pipe (one channel per process — a SIGKILL mid-send can only
    corrupt the victim's own channel, never a survivor's)."""
    try:
        result = target(*args, **kwargs)
        conn.send((True, result, ""))
    except BaseException:  # noqa: BLE001 - reported to the parent, never raised
        conn.send((False, None, traceback.format_exc()))
    finally:
        conn.close()


def run_multiprocess(
    clients: Sequence[Callable[[], Any] | tuple],
    *,
    names: Sequence[str] | None = None,
    start_method: str = "spawn",
    join_timeout: float = 600.0,
    kill_after: dict[int, float] | None = None,
) -> list[ClientResult]:
    """Run clients as real OS processes; a crashed process never kills the rest.

    Each entry of ``clients`` is either a zero-arg callable or a
    ``(target, args)`` / ``(target, args, kwargs)`` tuple. Targets and their
    return values cross a process boundary, so both must be picklable —
    module-level functions, not closures (the default ``spawn`` start method
    gives every client a clean interpreter, which is what a real serverless
    deployment looks like and is the only fork-safe choice once JAX threads
    exist in the parent).

    ``kill_after`` maps client index → seconds after launch at which the
    process is SIGKILLed (crash injection mid-round: no cleanup, no goodbye
    deposit — exactly what the async-robustness claim must survive). Killed or
    timed-out clients report a ``ProcessCrashed`` error in their
    ``ClientResult``; survivors are unaffected.
    """
    specs: list[tuple[Callable[..., Any], tuple, dict]] = []
    for entry in clients:
        if callable(entry):
            specs.append((entry, (), {}))
        else:
            target = entry[0]
            args = tuple(entry[1]) if len(entry) > 1 else ()
            kwargs = dict(entry[2]) if len(entry) > 2 else {}
            specs.append((target, args, kwargs))
    for i in kill_after or {}:
        # validate BEFORE launching anything: failing mid-setup would leave
        # already-started children running unsupervised
        if not 0 <= i < len(specs):
            raise ValueError(f"kill_after index {i} out of range for {len(specs)} clients")
    names = list(names or [f"node{i}" for i in range(len(specs))])
    if len(names) != len(specs):
        raise ValueError(f"{len(names)} names for {len(specs)} clients")
    results = [ClientResult(node_id=n) for n in names]

    ctx = multiprocessing.get_context(start_method)
    procs = []
    conns = []
    for i, (t, a, kw) in enumerate(specs):
        parent_conn, child_conn = ctx.Pipe(duplex=False)
        procs.append(ctx.Process(target=_mp_entry, args=(t, a, kw, child_conn),
                                 name=names[i], daemon=True))
        conns.append((parent_conn, child_conn))
    for p in procs:
        p.start()
    for _, child_conn in conns:
        child_conn.close()  # parent's copy; lets recv see EOF when a child dies

    timers: list[threading.Timer] = []

    def _kill(proc) -> None:
        if proc.is_alive() and proc.pid is not None:
            try:
                os.kill(proc.pid, signal.SIGKILL)
            except ProcessLookupError:
                pass

    for i, delay in (kill_after or {}).items():
        timer = threading.Timer(delay, _kill, args=(procs[i],))
        timer.daemon = True
        timer.start()
        timers.append(timer)

    received: set[int] = set()

    def _try_recv(i: int) -> bool:
        """Absorb client i's message if available; True when i is settled
        (reported, channel dead, or process gone without reporting)."""
        conn = conns[i][0]
        alive = procs[i].is_alive()  # check BEFORE polling: a message landing
        # between poll and liveness check must not be mistaken for a crash
        try:
            if not conn.poll(0 if alive else 0.05):
                return not alive  # dead + channel empty ⇒ will never report
            ok, result, tb = conn.recv()
        except (EOFError, OSError):  # killed mid-send: only its own channel dies
            return True
        received.add(i)
        if ok:
            results[i].result = result
        else:
            results[i].error = ProcessCrashed(f"client {names[i]} raised")
            results[i].traceback = tb
        return True

    deadline = time.monotonic() + join_timeout
    pending = set(range(len(specs)))
    while pending and time.monotonic() < deadline:
        settled = {i for i in pending if _try_recv(i)}
        pending -= settled
        if not settled:
            time.sleep(0.05)
    # Final sweep: a result delivered right at the deadline is already sitting
    # in our end of the pipe — recover it instead of reporting a crash.
    for i in list(pending):
        _try_recv(i)

    for timer in timers:
        timer.cancel()
    for i, p in enumerate(procs):
        p.join(timeout=max(0.0, deadline - time.monotonic()) + 1.0)
        if p.is_alive():  # hung past the deadline: reap it
            _kill(p)
            p.join(timeout=5.0)
        results[i].exitcode = p.exitcode
        if i not in received and results[i].error is None:
            results[i].error = ProcessCrashed(
                f"client {names[i]} exited with code {p.exitcode} before reporting"
            )
    return results


# --------------------------------------------------------------------------
# Event-driven virtual-clock timing model
# --------------------------------------------------------------------------


@dataclass
class TimelineResult:
    mode: str
    wall_clock: float                      # time until ALL nodes finish E epochs
    per_node_finish: list[float]
    per_node_idle: list[float]             # barrier wait (sync) — async is 0
    federation_events: list[tuple[float, int, int]] = field(default_factory=list)
    # (virtual time, node, number of peer updates visible at that moment)


def simulate_timeline(
    epoch_durations: Sequence[Sequence[float]],
    *,
    mode: str = "async",
    comm_time: float = 0.0,
    failures: dict[int, int] | None = None,
) -> TimelineResult:
    """Replay a federation schedule in virtual time.

    epoch_durations[k][i] = duration of node k's epoch i.
    failures maps node → epoch index at which the node dies.
    sync: every epoch ends with a barrier across *alive* nodes... except that
    the paper's (and real Flower's) semantics are that a dead node blocks the
    round forever — we model that: if any node dies, sync wall_clock = inf for
    the remaining nodes' work.
    """
    failures = failures or {}
    num_nodes = len(epoch_durations)
    num_epochs = len(epoch_durations[0])
    if any(len(d) != num_epochs for d in epoch_durations):
        raise ValueError("all nodes need the same number of planned epochs")

    if mode == "sync":
        t = 0.0
        idle = [0.0] * num_nodes
        finish = [0.0] * num_nodes
        events: list[tuple[float, int, int]] = []
        dead: set[int] = set()
        for e in range(num_epochs):
            for k in list(failures):
                if failures[k] == e:
                    dead.add(k)
            if dead:
                # a dead client never deposits round-e weights: barrier hangs.
                return TimelineResult(
                    mode="sync",
                    wall_clock=float("inf"),
                    per_node_finish=[float("inf")] * num_nodes,
                    per_node_idle=idle,
                    federation_events=events,
                )
            ends = [t + epoch_durations[k][e] for k in range(num_nodes)]
            barrier = max(ends) + comm_time
            for k in range(num_nodes):
                idle[k] += barrier - ends[k]
                finish[k] = barrier
                events.append((barrier, k, num_nodes - 1))
            t = barrier
        return TimelineResult("sync", t, finish, idle, events)

    if mode == "async":
        # Each node runs its own timeline; at each epoch end it sees whichever
        # peers have already deposited (push at epoch end, pull immediately).
        deposit_times: list[list[float]] = []
        for k in range(num_nodes):
            t, deps = 0.0, []
            die_at = failures.get(k, num_epochs + 1)
            for e in range(num_epochs):
                if e >= die_at:
                    break
                t += epoch_durations[k][e] + comm_time
                deps.append(t)
            deposit_times.append(deps)
        events = []
        finish = []
        for k in range(num_nodes):
            deps = deposit_times[k]
            finish.append(deps[-1] if deps else 0.0)
            for t_dep in deps:
                visible = sum(
                    1
                    for j in range(num_nodes)
                    if j != k and any(dj <= t_dep for dj in deposit_times[j])
                )
                events.append((t_dep, k, visible))
        events.sort()
        alive_finish = [f for k, f in enumerate(finish) if failures.get(k, num_epochs + 1) > num_epochs]
        wall = max(alive_finish) if alive_finish else max(finish)
        return TimelineResult("async", wall, finish, [0.0] * num_nodes, events)

    raise ValueError(f"unknown mode {mode!r}")


def straggler_speedup(epoch_durations: Sequence[Sequence[float]], comm_time: float = 0.0) -> float:
    """wall_clock(sync) / wall_clock(async) for the same schedule."""
    sync = simulate_timeline(epoch_durations, mode="sync", comm_time=comm_time)
    asyn = simulate_timeline(epoch_durations, mode="async", comm_time=comm_time)
    return sync.wall_clock / asyn.wall_clock
