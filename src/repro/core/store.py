"""The serverless weight store.

The paper's central abstraction: "any remote folder accessible by the client
machine" (S3 bucket, blob container, NFS mount). A client *pushes* its update
blob under its node-id key, reads the folder *state hash* to detect change,
and *pulls* the latest blob per peer.

Backends:
  * ``InMemoryFolder`` — thread-safe shared dict; mirrors the paper's
    python-multithreading simulation setup.
  * ``DiskFolder``    — a filesystem directory with atomic writes; this is the
    production backend (point it at an NFS/gcsfuse/s3fs mount).
  * ``S3Folder``      — thin boto3 adapter, import-guarded (the container is
    offline; the class exists so the public API matches the paper's usage
    snippet `S3Folder(directory="mybucket/experiment1")`).
  * ``CachingFolder`` — read-through wrapper over any backend: skips
    re-downloading blobs whose per-key ``version`` metadata is unchanged
    (the Algorithm 1 state-hash fast path at per-peer granularity).

All backends implement the tiny ``SharedFolder`` byte-blob protocol; the
``WeightStore`` wrapper above them speaks ``NodeUpdate`` pytrees, keeps one
*latest* blob per node (plus optional history), and exposes the state-hash
fast path from Algorithm 1. ``WeightStore`` also owns the wire *transport*:
full blobs, int8-quantized blobs, or sparse deltas against a content-hashed
per-node base blob.
"""
from __future__ import annotations

import hashlib
import os
import tempfile
import threading
import time
import urllib.parse
from abc import ABC, abstractmethod
from typing import Any

import numpy as np

from .serialize import (
    COMPRESSIONS,
    FlatDecodeUnsupported,
    NodeUpdate,
    canonicalize_params,
    content_hash,
    decode_params_flat,
    deserialize_update,
    deserialize_update_delta,
    deserialize_update_delta_flat,
    deserialize_update_quantized,
    flat_update_from_meta,
    maybe_decompress,
    peek_meta,
    serialize_update,
    serialize_update_delta,
    serialize_update_delta_from_flat,
    serialize_update_quantized,
)
from .tree import LeafSpec, tree_size_bytes

def _exclusion(exclude: "str | tuple[str, ...] | None"):
    """Normalize a state_hash exclusion — None, one exact key, or a tuple of
    exact keys / prefixes (trailing '/') — into a fast per-key predicate:
    one set lookup plus one C-level tuple-startswith, hoisted out of the
    per-key loop (state_hash runs this over every key in the folder)."""
    if exclude is None:
        return None
    if isinstance(exclude, str):
        exclude = (exclude,)
    exact = frozenset(e for e in exclude if not e.endswith("/"))
    prefixes = tuple(e for e in exclude if e.endswith("/"))
    if prefixes:
        return lambda key: key in exact or key.startswith(prefixes)
    return exact.__contains__


class _LruCache:
    """Tiny insertion-ordered LRU (dict-backed) shared by the read-side
    caches: CachingFolder's blob cache, WeightStore's decoded-update cache,
    and ShardedWeightStore's decoded-summary cache. Internally locked: stores
    are shared across threads (one ShardedWeightStore serving many threaded
    nodes is an endorsed usage), and an unlocked eviction loop racing a
    get()'s pop/reinsert would crash with 'dict changed size during
    iteration'."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self._data: dict = {}
        self._lock = threading.Lock()

    def get(self, key):
        """Value for ``key`` (refreshing its LRU position), else None."""
        with self._lock:
            hit = self._data.get(key)
            if hit is not None:
                self._data.pop(key, None)
                self._data[key] = hit
            return hit

    def put(self, key, value) -> None:
        with self._lock:
            self._data.pop(key, None)
            self._data[key] = value
            while len(self._data) > self.capacity:
                self._data.pop(next(iter(self._data)))

    def pop(self, key) -> None:
        with self._lock:
            self._data.pop(key, None)

    def clear(self) -> None:
        with self._lock:
            self._data.clear()

    def __len__(self) -> int:
        return len(self._data)


class SharedFolder(ABC):
    """Byte-blob folder: the minimal contract a 'remote folder' must satisfy."""

    @abstractmethod
    def put(self, key: str, blob: bytes) -> None: ...

    @abstractmethod
    def get(self, key: str) -> bytes | None: ...

    @abstractmethod
    def keys(self) -> list[str]: ...

    @abstractmethod
    def delete(self, key: str) -> None: ...

    def version(self, key: str) -> Any | None:
        """Cheap per-key change token (vclock, stat tuple, etag). Two calls
        returning equal non-None values imply the blob content is unchanged.
        ``None`` means the backend cannot answer cheaply (or the key is
        missing) — callers must fetch."""
        return None

    def state_hash(self, exclude: str | tuple[str, ...] | None = None) -> str:
        """Hash of (key, version) pairs — cheap change detection. ``exclude``
        drops keys (the caller's own deposits: exact keys, or prefixes ending
        in '/') so a client's push does not defeat its own skip check
        (Algorithm 1's hash comparison).

        Default derives versions from blob hashes; backends override with
        cheaper metadata (mtime, etag) when available.
        """
        skip = _exclusion(exclude)
        h = hashlib.sha256()
        for key in sorted(self.keys()):
            if skip is not None and skip(key):
                continue
            blob = self.get(key)
            if blob is not None:
                h.update(key.encode())
                h.update(hashlib.sha256(blob).digest())
        return h.hexdigest()[:16]


class InMemoryFolder(SharedFolder):
    """Thread-safe in-process folder (the paper's simulation backend)."""

    def __init__(self):
        self._blobs: dict[str, bytes] = {}
        self._versions: dict[str, int] = {}
        self._vclock = 0
        self._lock = threading.RLock()

    def put(self, key: str, blob: bytes) -> None:
        with self._lock:
            self._vclock += 1
            self._blobs[key] = blob
            self._versions[key] = self._vclock

    def get(self, key: str) -> bytes | None:
        with self._lock:
            return self._blobs.get(key)

    def keys(self) -> list[str]:
        with self._lock:
            return list(self._blobs.keys())

    def delete(self, key: str) -> None:
        with self._lock:
            self._blobs.pop(key, None)
            self._versions.pop(key, None)

    def version(self, key: str) -> int | None:
        with self._lock:
            return self._versions.get(key)

    def state_hash(self, exclude: str | tuple[str, ...] | None = None) -> str:
        skip = _exclusion(exclude)
        with self._lock:
            items = sorted(
                (k, v) for k, v in self._versions.items()
                if skip is None or not skip(k)
            )
        h = hashlib.sha256(repr(items).encode())
        return h.hexdigest()[:16]


class DiskFolder(SharedFolder):
    """Filesystem-backed folder with atomic writes (tmp + rename).

    Safe for multiple processes on a shared mount: readers never observe a
    torn write because rename is atomic on POSIX.
    """

    def __init__(self, directory: str):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)

    def _path(self, key: str) -> str:
        # Percent-encoding is reversible even when the key (a node id, say)
        # itself contains '/', '__', or '%' — '.replace("/", "__")' was not.
        safe = urllib.parse.quote(key, safe="")
        return os.path.join(self.directory, safe + ".npz")

    def put(self, key: str, blob: bytes) -> None:
        fd, tmp = tempfile.mkstemp(dir=self.directory, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(blob)
            # Stamp an explicit nanosecond mtime: the filesystem clock can be
            # coarse (1s on NFS), and inode numbers recycle, so without this a
            # rapid same-size rewrite could repeat a version() token and let a
            # CachingFolder serve stale bytes as a hit.
            now = time.time_ns()
            os.utime(tmp, ns=(now, now))
            os.replace(tmp, self._path(key))
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)

    def get(self, key: str) -> bytes | None:
        path = self._path(key)
        for _ in range(3):  # retry: concurrent replace() can race open()
            try:
                with open(path, "rb") as f:
                    return f.read()
            except FileNotFoundError:
                return None
            except OSError:
                time.sleep(0.01)
        return None

    def keys(self) -> list[str]:
        out = []
        for name in os.listdir(self.directory):
            if name.endswith(".npz"):
                out.append(urllib.parse.unquote(name[: -len(".npz")]))
        return out

    def delete(self, key: str) -> None:
        try:
            os.unlink(self._path(key))
        except FileNotFoundError:
            pass

    def version(self, key: str) -> tuple[int, int, int] | None:
        try:
            st = os.stat(self._path(key))
        except FileNotFoundError:
            return None
        # put() always replaces via a fresh temp file, so the inode changes on
        # every write — (inode, mtime, size) survives coarse mtime clocks.
        return (st.st_ino, st.st_mtime_ns, st.st_size)

    def state_hash(self, exclude: str | tuple[str, ...] | None = None) -> str:
        skip = _exclusion(exclude)
        items = []
        for name in sorted(os.listdir(self.directory)):
            if not name.endswith(".npz"):
                continue
            if skip is not None and skip(urllib.parse.unquote(name[: -len(".npz")])):
                continue
            path = os.path.join(self.directory, name)
            try:
                st = os.stat(path)
            except FileNotFoundError:
                continue
            # include the inode: a same-size rewrite within one mtime tick on a
            # coarse-timestamp mount must still change the hash (put() always
            # replaces via a fresh temp file)
            items.append((name, st.st_ino, st.st_mtime_ns, st.st_size))
        return hashlib.sha256(repr(items).encode()).hexdigest()[:16]


class S3Folder(SharedFolder):
    """S3-backed folder (paper's production backend). Requires boto3.

    Offline containers can still import this module; instantiation raises if
    boto3 is unavailable.
    """

    def __init__(self, directory: str):
        try:
            import boto3  # type: ignore
        except ImportError as e:  # pragma: no cover - offline container
            raise ImportError("S3Folder requires boto3") from e
        bucket, _, prefix = directory.partition("/")
        self._s3 = boto3.client("s3")
        self.bucket, self.prefix = bucket, prefix.rstrip("/")

    def _key(self, key: str) -> str:
        return f"{self.prefix}/{key}.npz" if self.prefix else f"{key}.npz"

    def put(self, key: str, blob: bytes) -> None:  # pragma: no cover
        self._s3.put_object(Bucket=self.bucket, Key=self._key(key), Body=blob)

    def get(self, key: str) -> bytes | None:  # pragma: no cover
        try:
            resp = self._s3.get_object(Bucket=self.bucket, Key=self._key(key))
            return resp["Body"].read()
        except self._s3.exceptions.NoSuchKey:
            return None

    def keys(self) -> list[str]:  # pragma: no cover
        prefix = f"{self.prefix}/" if self.prefix else ""
        resp = self._s3.list_objects_v2(Bucket=self.bucket, Prefix=prefix)
        out = []
        for obj in resp.get("Contents", []):
            name = obj["Key"][len(prefix):]
            if name.endswith(".npz"):
                out.append(name[: -len(".npz")])
        return out

    def delete(self, key: str) -> None:  # pragma: no cover
        self._s3.delete_object(Bucket=self.bucket, Key=self._key(key))

    def version(self, key: str) -> str | None:  # pragma: no cover
        try:
            resp = self._s3.head_object(Bucket=self.bucket, Key=self._key(key))
        except Exception:
            return None
        return resp.get("ETag")

    def state_hash(self, exclude: str | tuple[str, ...] | None = None) -> str:  # pragma: no cover
        prefix = f"{self.prefix}/" if self.prefix else ""
        skip = _exclusion(exclude)
        resp = self._s3.list_objects_v2(Bucket=self.bucket, Prefix=prefix)
        items = sorted(
            (o["Key"], o["ETag"])
            for o in resp.get("Contents", [])
            if o["Key"].endswith(".npz")
            and not (skip is not None and skip(o["Key"][len(prefix): -len(".npz")]))
        )
        return hashlib.sha256(repr(items).encode()).hexdigest()[:16]


class CachingFolder(SharedFolder):
    """Read-through cache over any SharedFolder.

    ``get`` first asks the inner backend for the key's cheap ``version`` token
    and returns the locally cached blob when it matches — so a peer whose
    deposit has not changed since the last pull costs one metadata lookup
    instead of a full download. This extends Algorithm 1's whole-store
    state-hash fast path to per-peer granularity, which matters once one slow
    peer would otherwise force re-downloading every fast peer's blob.

    Byte counters (``bytes_fetched`` / ``bytes_saved``) make transport
    experiments measurable. The cache holds at most ``max_entries`` blobs
    (LRU): a long sync federation with ``keep_history`` mints a new
    ``history/...`` key every round, and an unbounded cache would grow with
    the full federation trace.
    """

    def __init__(self, inner: SharedFolder, *, max_entries: int = 64):
        self.inner = inner
        self._cache: "_LruCache" = _LruCache(max_entries)  # key -> (version, blob)
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.bytes_fetched = 0
        self.bytes_saved = 0

    @property
    def max_entries(self) -> int:
        return self._cache.capacity

    @max_entries.setter
    def max_entries(self, value: int) -> None:
        self._cache.capacity = value

    def put(self, key: str, blob: bytes) -> None:
        self.inner.put(key, blob)
        # Invalidate rather than cache: version(key) here could already belong
        # to a concurrent writer's blob, and pairing their token with our bytes
        # would be a *persistent* stale hit. The next get refetches once.
        with self._lock:
            self._cache.pop(key)

    def get(self, key: str) -> bytes | None:
        # Read the version token *before* the blob: if a writer lands between
        # the two reads we may cache a fresh blob under a stale token, which
        # only costs one redundant refetch next time — never a stale hit.
        v = self.inner.version(key)
        if v is not None:
            with self._lock:
                hit = self._cache.get(key)  # refreshes LRU position
                if hit is not None and hit[0] == v:
                    self.hits += 1
                    self.bytes_saved += len(hit[1])
                    return hit[1]
        blob = self.inner.get(key)
        with self._lock:
            self.misses += 1
            if blob is not None:
                self.bytes_fetched += len(blob)
                if v is not None:
                    self._cache.put(key, (v, blob))
        return blob

    def keys(self) -> list[str]:
        return self.inner.keys()

    def delete(self, key: str) -> None:
        self.inner.delete(key)
        with self._lock:
            self._cache.pop(key)

    def version(self, key: str) -> Any | None:
        return self.inner.version(key)

    def state_hash(self, exclude: str | tuple[str, ...] | None = None) -> str:
        return self.inner.state_hash(exclude=exclude)

    def cache_stats(self) -> dict[str, int]:
        with self._lock:
            return {
                "hits": self.hits,
                "misses": self.misses,
                "bytes_fetched": self.bytes_fetched,
                "bytes_saved": self.bytes_saved,
            }


TRANSPORTS = ("full", "quantized", "delta", "delta_q", "topk")


class WeightStore:
    """Typed view over a SharedFolder: one latest NodeUpdate per node.

    Implements the push / state-hash-check / pull triad from Algorithm 1.
    ``keep_history`` additionally retains per-counter blobs so experiments can
    audit the full federation trace.

    ``transport`` selects the wire format for ``latest/`` deposits:

      * ``"full"``      — one complete npz blob per push (the default).
      * ``"quantized"`` — int8-quantized blob (lossy, ~4x smaller).
      * ``"delta"``     — sparse diff against a per-node content-hashed base
        blob stored under ``base/<node>/<hash>``; lossless (bitwise-equal
        reconstruction). The node re-deposits a full base every
        ``rebase_every`` pushes, or whenever the encoded delta would not be
        smaller than a full deposit (``delta_density_threshold`` governs the
        per-leaf dense fallback inside the wire format).
      * ``"delta_q"``   — delta with int8-quantized changed values (lossy).
      * ``"topk"``      — writer-side top-k sparsification with client-side
        error feedback, computed on flat vectors (one ``argpartition`` per
        push): only the ``topk_fraction`` largest-magnitude entry changes ship
        each push, and everything unsent accumulates in a residual that is
        flushed by later pushes / the periodic rebase. On the wire these are
        ordinary delta blobs — readers need no top-k awareness.

    ``compress`` wraps every deposited blob: ``"none"`` (stored npz, the
    default), ``"npz"`` (deflate), or ``"zstd"`` (whole-blob zstd frame,
    requires a zstd module). Readers sniff the format, so heterogeneous
    compression settings coexist in one folder. ``bytes_written`` counts every
    blob this store deposited (the write-side twin of ``CachingFolder``'s
    ``bytes_fetched``).

    ``pull``/``pull_node`` keep a bounded decoded-update cache keyed on the
    folder's per-key ``version`` token, so a peer whose deposit is unchanged
    costs one metadata lookup instead of an npz decode (the decode-side twin
    of ``CachingFolder``'s download skip). Decodes land *directly in flat
    f32 vectors* (``FlatUpdate`` with a shared per-structure ``LeafSpec``):
    no nested-dict rebuild, and the vectorized strategies aggregate the
    pulled flats without any per-leaf hop. Blobs whose leaves cannot embed
    losslessly in f32 (int/f64) fall back to the per-leaf tree decode.
    Cached update objects are returned by reference — treat pulled params
    as read-only, as every caller in this repo already does.
    """

    def __init__(
        self,
        folder: SharedFolder,
        *,
        quantized: bool = False,
        keep_history: bool = False,
        transport: str | None = None,
        rebase_every: int = 10,
        delta_density_threshold: float = 0.5,
        topk_fraction: float = 0.01,
        compress: str = "none",
        decode_cache_entries: int = 64,
    ):
        if transport is None:
            transport = "quantized" if quantized else "full"
        if transport not in TRANSPORTS:
            raise ValueError(f"unknown transport {transport!r}; options: {TRANSPORTS}")
        if compress not in COMPRESSIONS:
            raise ValueError(f"unknown compress {compress!r}; options: {COMPRESSIONS}")
        if compress == "zstd":
            from .serialize import _zstd_module

            if _zstd_module() is None:
                raise ImportError("compress='zstd' requires a zstd module (zstandard)")
        if not 0.0 < topk_fraction <= 1.0:
            raise ValueError(f"topk_fraction must be in (0, 1], got {topk_fraction}")
        self.folder = folder
        self.transport = transport
        self.quantized = transport == "quantized"
        self.keep_history = keep_history
        self.rebase_every = rebase_every
        self.delta_density_threshold = delta_density_threshold
        self.topk_fraction = topk_fraction
        self.compress = compress
        # writer state: node -> (base_hash, base_params, pushes since rebase)
        self._bases: dict[str, tuple[str, Any, int]] = {}
        # topk writer state: node -> (base_hash, spec, base_flat, acc_flat, age)
        # where acc is the error-feedback accumulator = what readers see.
        self._topk: dict[str, tuple] = {}
        # reader state: base_hash -> (spec, base_flat) | (None, base_params)
        self._decoded_bases: dict[str, Any] = {}
        # interned LeafSpecs: one per decoded structure, shared by every
        # FlatUpdate this store returns (spec identity == layout identity)
        self._specs: dict = {}
        # decoded-update cache: latest/<node> key -> (version token, update).
        # Companion to CachingFolder: that layer skips the *download* of an
        # unchanged blob, this one skips the npz *decode* — keyed on the same
        # cheap folder.version() token. 0 disables.
        self.decode_cache_entries = decode_cache_entries
        self._decoded_latest = _LruCache(decode_cache_entries)  # key -> (version, update)
        self.decode_hits = 0
        self.decode_misses = 0
        self.bytes_written = 0

    def _put(self, key: str, blob: bytes) -> None:
        self.folder.put(key, blob)
        self.bytes_written += len(blob)

    # -- push ---------------------------------------------------------------
    def push(self, update: NodeUpdate) -> None:
        is_delta = False
        if self.transport == "topk":
            blob, is_delta = self._push_topk(update)
        elif self.transport in ("delta", "delta_q"):
            blob, is_delta = self._push_delta(update)
        else:
            ser = serialize_update_quantized if self.quantized else serialize_update
            blob = ser(update, compress=self.compress)
            self._put(f"latest/{update.node_id}", blob)
        if self.keep_history:
            if is_delta:
                # history stays self-contained (and, for topk, exact)
                blob = serialize_update(update, compress=self.compress)
            self._put(f"history/{update.node_id}/{update.counter:06d}", blob)

    def _push_delta(self, update: NodeUpdate) -> tuple[bytes, bool]:
        """Deposit a delta when worthwhile, else rebase with a full blob;
        returns (deposited blob, whether it is a delta)."""
        node = update.node_id
        base = self._bases.get(node)
        if base is not None and base[2] < self.rebase_every:
            h, base_params, age = base
            try:
                blob = serialize_update_delta(
                    update,
                    base_params,
                    h,
                    quantize=self.transport == "delta_q",
                    density_threshold=self.delta_density_threshold,
                    compress=self.compress,
                )
            except ValueError:  # tree structure changed vs the base → rebase
                blob = None
            # One scan decides: if the encoded delta is not actually smaller
            # than a full deposit (dense drift — e.g. aggregated params were
            # adopted), rebase instead of shipping a delta that saves nothing.
            if blob is not None and len(blob) < tree_size_bytes(update.params):
                self._put(f"latest/{node}", blob)
                self._bases[node] = (h, base_params, age + 1)
                return blob, True
        full, h = self._deposit_base(node, update, base[0] if base is not None else None)
        self._bases[node] = (h, canonicalize_params(update.params), 0)
        return full, False

    def _deposit_base(self, node: str, update: NodeUpdate,
                      old_hash: str | None) -> tuple[bytes, str]:
        """Rebase: deposit a full blob under base/<node>/<hash> AND latest/,
        GC superseded bases. Shared by the delta and topk writers."""
        full = serialize_update(update, compress=self.compress)
        h = content_hash(full)
        # Base first, then latest: a reader that sees the new latest can
        # always resolve its base. Old bases are GC'd only after the new
        # full latest is in place (readers of the old delta retry into
        # the new full blob).
        self._put(f"base/{node}/{h}", full)
        self._put(f"latest/{node}", full)
        if old_hash is not None:
            # common case: we know the one base we deposited — delete it
            # directly instead of listing the whole folder
            if old_hash != h:
                self.folder.delete(f"base/{node}/{old_hash}")
        else:
            # first rebase in this process: sweep leftovers from a previous
            # incarnation (e.g. a crashed client restarting under its id)
            for key in self.folder.keys():
                # match on (prefix, hash) split from the right: node ids may
                # contain '/', so a plain startswith would cross node borders
                if key.rpartition("/")[0] == f"base/{node}" and key != f"base/{node}/{h}":
                    self.folder.delete(key)
        return full, h

    def _push_topk(self, update: NodeUpdate) -> tuple[bytes, bool]:
        """Error-feedback top-k on flat vectors. The writer tracks ``acc`` —
        the state readers reconstruct (base + every shipped change). Each push
        ships only the ``topk_fraction`` largest entries of ``new - acc``; the
        rest stays in the implicit residual and is drained by later pushes.
        Wire format: ordinary delta blobs against the content-hashed base, so
        readers are oblivious to the selection policy. Non-f32-embeddable
        models (int/f64 leaves) rebase on every push (lossless, just not
        sparse)."""
        node = update.node_id
        state = self._topk.get(node)
        spec = None
        if state is not None:
            spec = state[1]
            if not spec.describes(update.params):
                spec, state = None, None
        if spec is None:
            spec = LeafSpec.of(update.params)
        if state is not None and state[4] < self.rebase_every and spec.f32_exact:
            h, _, base_flat, acc, age = state
            try:
                new_flat = spec.flatten(update.params)
            except ValueError:  # shape drift under the same treedef → rebase
                new_flat = None
            if new_flat is not None:
                v = new_flat - acc
                k = max(1, int(self.topk_fraction * v.size))
                nz = int(np.count_nonzero(v))
                if nz > k:
                    keep = np.argpartition(np.abs(v), v.size - k)[v.size - k:]
                    acc[keep] = new_flat[keep]
                else:
                    # all changes fit the budget: ship everything (where
                    # v == 0, acc already equals new_flat — one flat copy)
                    np.copyto(acc, new_flat)
                changed = np.flatnonzero(acc != base_flat)
                blob = serialize_update_delta_from_flat(
                    update, spec, acc, base_flat, h,
                    changed=changed,
                    density_threshold=self.delta_density_threshold,
                    compress=self.compress,
                )
                if len(blob) < tree_size_bytes(update.params):
                    self._put(f"latest/{node}", blob)
                    self._topk[node] = (h, spec, base_flat, acc, age + 1)
                    return blob, True
        full, h = self._deposit_base(node, update,
                                     state[0] if state is not None else None)
        if spec.f32_exact:
            # acc starts at the wire view of the params — exactly what a
            # reader decodes from the base blob (f32-exact dtypes guarantee
            # spec.flatten == the decoded wire values).
            flat = spec.flatten(update.params)
            self._topk[node] = (h, spec, flat, flat.copy(), 0)
        else:
            self._topk[node] = (h, spec, None, None, self.rebase_every)
        return full, False

    # -- state hash fast path -------------------------------------------------
    def state_hash(self, exclude_node: str | None = None) -> str:
        # A node's deposits span latest/, base/ (delta rebases) and history/;
        # all of them must be excluded or the node's own push would defeat its
        # own skip check.
        exclude = None
        if exclude_node:
            exclude = (
                f"latest/{exclude_node}",
                f"base/{exclude_node}/",
                f"history/{exclude_node}/",
            )
        return self.folder.state_hash(exclude=exclude)

    # -- pull ---------------------------------------------------------------
    def node_ids(self) -> list[str]:
        return sorted(
            key[len("latest/"):] for key in self.folder.keys() if key.startswith("latest/")
        )

    def _decode(self, blob: bytes, node_id: str) -> NodeUpdate | None:
        """Decode a self-describing blob; None when a delta's base cannot be
        resolved yet (caller refetches — the writer is mid-rebase).

        The hot path lands in a flat f32 vector (``FlatUpdate`` sharing an
        interned ``LeafSpec``); blobs that cannot embed losslessly in f32
        (int/f64 leaves) take the per-leaf tree decode instead."""
        # Decompress exactly once up front: peek_meta and every decode below
        # call maybe_decompress themselves, which is a no-op on raw npz bytes
        # but a full second (or third) zstd pass on a still-wrapped blob.
        blob = maybe_decompress(blob)
        meta = peek_meta(blob)
        base_hash = meta.get("delta_of")
        if base_hash:
            base = self._decoded_bases.get(base_hash)
            if base is None:
                base_blob = self.folder.get(f"base/{node_id}/{base_hash}")
                # hash the RAW fetched bytes — writers hash what they deposit
                if base_blob is None or content_hash(base_blob) != base_hash:
                    return None
                base_blob = maybe_decompress(base_blob)
                try:
                    spec, base_flat, _ = decode_params_flat(base_blob, self._specs)
                    base = (spec, base_flat)
                except FlatDecodeUnsupported:
                    base = (None, deserialize_update(base_blob).params)
                if len(self._decoded_bases) > 16:
                    self._decoded_bases.pop(next(iter(self._decoded_bases)))
                self._decoded_bases[base_hash] = base
            spec, base_state = base
            if spec is not None:
                try:
                    return deserialize_update_delta_flat(blob, spec, base_state)
                except FlatDecodeUnsupported:
                    pass  # odd-dtype delta values: fall through to tree path
                except ValueError:
                    pass  # structure drift vs the base spec: tree path
                return deserialize_update_delta(blob, spec.unflatten(base_state))
            return deserialize_update_delta(blob, base_state)
        try:
            spec, flat, meta = decode_params_flat(blob, self._specs)
            return flat_update_from_meta(spec, flat, meta)
        except FlatDecodeUnsupported:
            pass
        if meta.get("quantized"):
            return deserialize_update_quantized(blob)
        return deserialize_update(blob)

    def _pull_latest(self, node_id: str) -> NodeUpdate | None:
        key = f"latest/{node_id}"
        # Version token read BEFORE the blob (same ordering as CachingFolder):
        # a writer landing in between can only cache a fresh update under a
        # stale token — one redundant decode next time, never a stale hit.
        v = self.folder.version(key) if self.decode_cache_entries else None
        if v is not None:
            hit = self._decoded_latest.get(key)  # refreshes LRU position
            if hit is not None and hit[0] == v:
                self.decode_hits += 1
                return hit[1]
        for _ in range(3):
            blob = self.folder.get(key)
            if blob is None:
                return None
            update = self._decode(blob, node_id)
            if update is not None:
                self.decode_misses += 1
                if v is not None:
                    self._decoded_latest.put(key, (v, update))
                return update
            time.sleep(0.01)  # writer mid-rebase; refetch latest + base
        return None

    def pull(self, exclude: str | None = None) -> list[NodeUpdate]:
        """Latest update per node (optionally excluding the caller's own)."""
        out = []
        for node_id in self.node_ids():
            if node_id == exclude:
                continue
            update = self._pull_latest(node_id)
            if update is not None:
                out.append(update)
        return out

    def pull_node(self, node_id: str) -> NodeUpdate | None:
        return self._pull_latest(node_id)

    def pull_round(self, counter: int, exclude: str | None = None) -> list[NodeUpdate]:
        """Exact-round blobs (requires keep_history=True) — used by the
        synchronous barrier so every client aggregates the identical set even
        if a fast peer has already deposited round t+1."""
        prefix = "history/"
        out = []
        for key in sorted(self.folder.keys()):
            if not key.startswith(prefix):
                continue
            # node ids may themselves contain '/' — split the counter off the
            # right instead of assuming exactly three segments.
            node_id, _, ctr = key[len(prefix):].rpartition("/")
            if not ctr.isdigit() or int(ctr) != counter or node_id == exclude:
                continue
            blob = self.folder.get(key)
            if blob is not None:
                out.append(self._decode(blob, node_id))
        return [u for u in out if u is not None]

    def clear(self) -> None:
        for key in self.folder.keys():
            self.folder.delete(key)
        self._bases.clear()
        self._topk.clear()
        self._decoded_bases.clear()
        self._decoded_latest.clear()
        self._specs.clear()


def make_folder(uri: str):
    """Folder factory: 'memory://', 's3://bucket/prefix', a local path, or any
    of those behind a read-through cache via a 'cache+' prefix
    (e.g. 'cache+/mnt/shared/exp1', 'cache+s3://bucket/exp1').

    A 'shard<G>+<uri>' prefix returns a ``ShardedFolders`` handle — G
    per-group folders of the inner kind (e.g. 'shard16+/mnt/shared/exp1',
    'shard8+cache+s3://bucket/exp1') — which the federated nodes turn into a
    gossip-sharded ``ShardedWeightStore`` instead of a flat ``WeightStore``.
    """
    if uri.startswith("shard"):
        from .gossip import SHARD_URI_RE, ShardedFolders  # circular-import guard

        if SHARD_URI_RE.match(uri):
            return ShardedFolders.from_uri(uri)
    if uri.startswith("cache+"):
        return CachingFolder(make_folder(uri[len("cache+"):]))
    if uri.startswith("memory://"):
        return InMemoryFolder()
    if uri.startswith("s3://"):
        return S3Folder(uri[len("s3://"):])
    return DiskFolder(uri)
