"""The serverless weight store.

The paper's central abstraction: "any remote folder accessible by the client
machine" (S3 bucket, blob container, NFS mount). A client *pushes* its update
blob under its node-id key, reads the folder *state hash* to detect change,
and *pulls* the latest blob per peer.

Backends:
  * ``InMemoryFolder`` — thread-safe shared dict; mirrors the paper's
    python-multithreading simulation setup.
  * ``DiskFolder``    — a filesystem directory with atomic writes; this is the
    production backend (point it at an NFS/gcsfuse/s3fs mount).
  * ``S3Folder``      — thin boto3 adapter, import-guarded (the container is
    offline; the class exists so the public API matches the paper's usage
    snippet `S3Folder(directory="mybucket/experiment1")`).

All backends implement the tiny ``SharedFolder`` byte-blob protocol; the
``WeightStore`` wrapper above them speaks ``NodeUpdate`` pytrees, keeps one
*latest* blob per node (plus optional history), and exposes the state-hash
fast path from Algorithm 1.
"""
from __future__ import annotations

import hashlib
import os
import tempfile
import threading
import time
from abc import ABC, abstractmethod
from typing import Iterable

from .serialize import (
    NodeUpdate,
    deserialize_update,
    deserialize_update_quantized,
    serialize_update,
    serialize_update_quantized,
)


class SharedFolder(ABC):
    """Byte-blob folder: the minimal contract a 'remote folder' must satisfy."""

    @abstractmethod
    def put(self, key: str, blob: bytes) -> None: ...

    @abstractmethod
    def get(self, key: str) -> bytes | None: ...

    @abstractmethod
    def keys(self) -> list[str]: ...

    @abstractmethod
    def delete(self, key: str) -> None: ...

    def state_hash(self, exclude: str | None = None) -> str:
        """Hash of (key, version) pairs — cheap change detection. ``exclude``
        drops one key (the caller's own deposit) so a client's push does not
        defeat its own skip check (Algorithm 1's hash comparison).

        Default derives versions from blob hashes; backends override with
        cheaper metadata (mtime, etag) when available.
        """
        h = hashlib.sha256()
        for key in sorted(self.keys()):
            if key == exclude:
                continue
            blob = self.get(key)
            if blob is not None:
                h.update(key.encode())
                h.update(hashlib.sha256(blob).digest())
        return h.hexdigest()[:16]


class InMemoryFolder(SharedFolder):
    """Thread-safe in-process folder (the paper's simulation backend)."""

    def __init__(self):
        self._blobs: dict[str, bytes] = {}
        self._versions: dict[str, int] = {}
        self._vclock = 0
        self._lock = threading.RLock()

    def put(self, key: str, blob: bytes) -> None:
        with self._lock:
            self._vclock += 1
            self._blobs[key] = blob
            self._versions[key] = self._vclock

    def get(self, key: str) -> bytes | None:
        with self._lock:
            return self._blobs.get(key)

    def keys(self) -> list[str]:
        with self._lock:
            return list(self._blobs.keys())

    def delete(self, key: str) -> None:
        with self._lock:
            self._blobs.pop(key, None)
            self._versions.pop(key, None)

    def state_hash(self, exclude: str | None = None) -> str:
        with self._lock:
            items = sorted((k, v) for k, v in self._versions.items() if k != exclude)
        h = hashlib.sha256(repr(items).encode())
        return h.hexdigest()[:16]


class DiskFolder(SharedFolder):
    """Filesystem-backed folder with atomic writes (tmp + rename).

    Safe for multiple processes on a shared mount: readers never observe a
    torn write because rename is atomic on POSIX.
    """

    def __init__(self, directory: str):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)

    def _path(self, key: str) -> str:
        safe = key.replace("/", "__")
        return os.path.join(self.directory, safe + ".npz")

    def put(self, key: str, blob: bytes) -> None:
        fd, tmp = tempfile.mkstemp(dir=self.directory, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(blob)
            os.replace(tmp, self._path(key))
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)

    def get(self, key: str) -> bytes | None:
        path = self._path(key)
        for _ in range(3):  # retry: concurrent replace() can race open()
            try:
                with open(path, "rb") as f:
                    return f.read()
            except FileNotFoundError:
                return None
            except OSError:
                time.sleep(0.01)
        return None

    def keys(self) -> list[str]:
        out = []
        for name in os.listdir(self.directory):
            if name.endswith(".npz"):
                out.append(name[: -len(".npz")].replace("__", "/"))
        return out

    def delete(self, key: str) -> None:
        try:
            os.unlink(self._path(key))
        except FileNotFoundError:
            pass

    def state_hash(self, exclude: str | None = None) -> str:
        items = []
        skip = exclude.replace("/", "__") + ".npz" if exclude else None
        for name in sorted(os.listdir(self.directory)):
            if not name.endswith(".npz") or name == skip:
                continue
            path = os.path.join(self.directory, name)
            try:
                st = os.stat(path)
            except FileNotFoundError:
                continue
            items.append((name, st.st_mtime_ns, st.st_size))
        return hashlib.sha256(repr(items).encode()).hexdigest()[:16]


class S3Folder(SharedFolder):
    """S3-backed folder (paper's production backend). Requires boto3.

    Offline containers can still import this module; instantiation raises if
    boto3 is unavailable.
    """

    def __init__(self, directory: str):
        try:
            import boto3  # type: ignore
        except ImportError as e:  # pragma: no cover - offline container
            raise ImportError("S3Folder requires boto3") from e
        bucket, _, prefix = directory.partition("/")
        self._s3 = boto3.client("s3")
        self.bucket, self.prefix = bucket, prefix.rstrip("/")

    def _key(self, key: str) -> str:
        return f"{self.prefix}/{key}.npz" if self.prefix else f"{key}.npz"

    def put(self, key: str, blob: bytes) -> None:  # pragma: no cover
        self._s3.put_object(Bucket=self.bucket, Key=self._key(key), Body=blob)

    def get(self, key: str) -> bytes | None:  # pragma: no cover
        try:
            resp = self._s3.get_object(Bucket=self.bucket, Key=self._key(key))
            return resp["Body"].read()
        except self._s3.exceptions.NoSuchKey:
            return None

    def keys(self) -> list[str]:  # pragma: no cover
        prefix = f"{self.prefix}/" if self.prefix else ""
        resp = self._s3.list_objects_v2(Bucket=self.bucket, Prefix=prefix)
        out = []
        for obj in resp.get("Contents", []):
            name = obj["Key"][len(prefix):]
            if name.endswith(".npz"):
                out.append(name[: -len(".npz")])
        return out

    def delete(self, key: str) -> None:  # pragma: no cover
        self._s3.delete_object(Bucket=self.bucket, Key=self._key(key))

    def state_hash(self, exclude: str | None = None) -> str:  # pragma: no cover
        prefix = f"{self.prefix}/" if self.prefix else ""
        skip = self._key(exclude) if exclude else None
        resp = self._s3.list_objects_v2(Bucket=self.bucket, Prefix=prefix)
        items = sorted(
            (o["Key"], o["ETag"]) for o in resp.get("Contents", []) if o["Key"] != skip
        )
        return hashlib.sha256(repr(items).encode()).hexdigest()[:16]


class WeightStore:
    """Typed view over a SharedFolder: one latest NodeUpdate per node.

    Implements the push / state-hash-check / pull triad from Algorithm 1.
    ``keep_history`` additionally retains per-counter blobs so experiments can
    audit the full federation trace.
    """

    def __init__(self, folder: SharedFolder, *, quantized: bool = False, keep_history: bool = False):
        self.folder = folder
        self.quantized = quantized
        self.keep_history = keep_history
        self._ser = serialize_update_quantized if quantized else serialize_update
        self._de = deserialize_update_quantized if quantized else deserialize_update

    # -- push ---------------------------------------------------------------
    def push(self, update: NodeUpdate) -> None:
        blob = self._ser(update)
        self.folder.put(f"latest/{update.node_id}", blob)
        if self.keep_history:
            self.folder.put(f"history/{update.node_id}/{update.counter:06d}", blob)

    # -- state hash fast path -------------------------------------------------
    def state_hash(self, exclude_node: str | None = None) -> str:
        exclude = f"latest/{exclude_node}" if exclude_node else None
        return self.folder.state_hash(exclude=exclude)

    # -- pull ---------------------------------------------------------------
    def node_ids(self) -> list[str]:
        return sorted(
            key[len("latest/"):] for key in self.folder.keys() if key.startswith("latest/")
        )

    def pull(self, exclude: str | None = None) -> list[NodeUpdate]:
        """Latest update per node (optionally excluding the caller's own)."""
        out = []
        for node_id in self.node_ids():
            if node_id == exclude:
                continue
            blob = self.folder.get(f"latest/{node_id}")
            if blob is not None:
                out.append(self._de(blob))
        return out

    def pull_node(self, node_id: str) -> NodeUpdate | None:
        blob = self.folder.get(f"latest/{node_id}")
        return self._de(blob) if blob is not None else None

    def pull_round(self, counter: int, exclude: str | None = None) -> list[NodeUpdate]:
        """Exact-round blobs (requires keep_history=True) — used by the
        synchronous barrier so every client aggregates the identical set even
        if a fast peer has already deposited round t+1."""
        prefix = "history/"
        out = []
        for key in sorted(self.folder.keys()):
            if not key.startswith(prefix):
                continue
            _, node_id, ctr = key.split("/")
            if int(ctr) != counter or node_id == exclude:
                continue
            blob = self.folder.get(key)
            if blob is not None:
                out.append(self._de(blob))
        return out

    def clear(self) -> None:
        for key in self.folder.keys():
            self.folder.delete(key)


def make_folder(uri: str) -> SharedFolder:
    """Folder factory: 'memory://', 's3://bucket/prefix', or a local path."""
    if uri.startswith("memory://"):
        return InMemoryFolder()
    if uri.startswith("s3://"):
        return S3Folder(uri[len("s3://"):])
    return DiskFolder(uri)
