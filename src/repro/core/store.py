"""The serverless weight store.

The paper's central abstraction: "any remote folder accessible by the client
machine" (S3 bucket, blob container, NFS mount). A client *pushes* its update
blob under its node-id key, reads the folder *state hash* to detect change,
and *pulls* the latest blob per peer.

Backends:
  * ``InMemoryFolder`` — thread-safe shared dict; mirrors the paper's
    python-multithreading simulation setup.
  * ``DiskFolder``    — a filesystem directory with atomic writes; this is the
    production backend (point it at an NFS/gcsfuse/s3fs mount).
  * ``S3Folder``      — thin boto3 adapter, import-guarded (the container is
    offline; the class exists so the public API matches the paper's usage
    snippet `S3Folder(directory="mybucket/experiment1")`).
  * ``CachingFolder`` — read-through wrapper over any backend: skips
    re-downloading blobs whose per-key ``version`` metadata is unchanged
    (the Algorithm 1 state-hash fast path at per-peer granularity).

All backends implement the tiny ``SharedFolder`` byte-blob protocol; the
``WeightStore`` wrapper above them speaks ``NodeUpdate`` pytrees, keeps one
*latest* blob per node (plus optional history), and exposes the state-hash
fast path from Algorithm 1. The wire *transport* itself — how an update
becomes deposited bytes — lives in ``transport.py`` as a codec pipeline
(``TransportPipeline``); the store routes every push/decode through it.
"""
from __future__ import annotations

import hashlib
import os
import random
import tempfile
import threading
import time
import urllib.parse
from abc import ABC, abstractmethod
from typing import Any

from .serialize import (
    NodeUpdate,
    deserialize_obs_blob,
    deserialize_strategy_state,
    serialize_obs_blob,
    serialize_strategy_state,
)
from .transport import (
    _LruCache,
    Prefetcher,
    StoreContext,
    TransportPipeline,
    family_transport_spec,
    parse_folder_uri,
)
from repro.logs import get_logger

_log = get_logger("store")

def _exclusion(exclude: "str | tuple[str, ...] | None"):
    """Normalize a state_hash exclusion — None, one exact key, or a tuple of
    exact keys / prefixes (trailing '/') — into a fast per-key predicate:
    one set lookup plus one C-level tuple-startswith, hoisted out of the
    per-key loop (state_hash runs this over every key in the folder)."""
    if exclude is None:
        return None
    if isinstance(exclude, str):
        exclude = (exclude,)
    exact = frozenset(e for e in exclude if not e.endswith("/"))
    prefixes = tuple(e for e in exclude if e.endswith("/"))
    if prefixes:
        return lambda key: key in exact or key.startswith(prefixes)
    return exact.__contains__


class SharedFolder(ABC):
    """Byte-blob folder: the minimal contract a 'remote folder' must satisfy."""

    @abstractmethod
    def put(self, key: str, blob: bytes) -> None: ...

    @abstractmethod
    def get(self, key: str) -> bytes | None: ...

    @abstractmethod
    def keys(self) -> list[str]: ...

    @abstractmethod
    def delete(self, key: str) -> None: ...

    def version(self, key: str) -> Any | None:
        """Cheap per-key change token (vclock, stat tuple, etag). Two calls
        returning equal non-None values imply the blob content is unchanged.
        ``None`` means the backend cannot answer cheaply (or the key is
        missing) — callers must fetch."""
        return None

    def list_version(self) -> Any | None:
        """Cheap folder-level change token: two calls returning equal
        non-None values imply the *key listing* (membership, not blob
        contents) is unchanged, so a parsed index over ``keys()`` may be
        reused. ``None`` means the backend cannot answer cheaper than
        listing — callers must re-list. Used by the sharded gossip store to
        skip re-splitting every summary key on steady-state pulls."""
        return None

    def put_if_absent(self, key: str, blob: bytes) -> bool:
        """Create ``key`` only if it does not exist; True when THIS call
        created it. The fleet launcher's slot-claim primitive: concurrent
        workers race ``put_if_absent`` on the same claim key and exactly one
        wins. Backends with an atomic create (``DiskFolder`` via link(2),
        ``InMemoryFolder`` under its lock) override this with a genuinely
        atomic version; this default is check-put-readback — best effort
        only, last-writer-wins backends (S3 without conditional puts) can
        double-claim under a tight race."""
        if self.get(key) is not None:
            return False
        self.put(key, blob)
        return self.get(key) == blob

    def state_hash(self, exclude: str | tuple[str, ...] | None = None) -> str:
        """Hash of (key, version) pairs — cheap change detection. ``exclude``
        drops keys (the caller's own deposits: exact keys, or prefixes ending
        in '/') so a client's push does not defeat its own skip check
        (Algorithm 1's hash comparison).

        Default derives versions from blob hashes; backends override with
        cheaper metadata (mtime, etag) when available.
        """
        skip = _exclusion(exclude)
        h = hashlib.sha256()
        for key in sorted(self.keys()):
            if skip is not None and skip(key):
                continue
            blob = self.get(key)
            if blob is not None:
                h.update(key.encode())
                h.update(hashlib.sha256(blob).digest())
        return h.hexdigest()[:16]


class InMemoryFolder(SharedFolder):
    """Thread-safe in-process folder (the paper's simulation backend)."""

    def __init__(self):
        self._blobs: dict[str, bytes] = {}
        self._versions: dict[str, int] = {}
        self._vclock = 0
        self._lock = threading.RLock()

    def put(self, key: str, blob: bytes) -> None:
        with self._lock:
            self._vclock += 1
            self._blobs[key] = blob
            self._versions[key] = self._vclock

    def get(self, key: str) -> bytes | None:
        with self._lock:
            return self._blobs.get(key)

    def keys(self) -> list[str]:
        with self._lock:
            return list(self._blobs.keys())

    def delete(self, key: str) -> None:
        with self._lock:
            # the vclock doubles as the listing token, so deletes must
            # advance it even though the departed key's version is dropped
            if self._blobs.pop(key, None) is not None:
                self._vclock += 1
            self._versions.pop(key, None)

    def version(self, key: str) -> int | None:
        with self._lock:
            return self._versions.get(key)

    def list_version(self) -> int:
        with self._lock:
            return self._vclock

    def put_if_absent(self, key: str, blob: bytes) -> bool:
        with self._lock:
            if key in self._blobs:
                return False
            self._vclock += 1
            self._blobs[key] = blob
            self._versions[key] = self._vclock
            return True

    def state_hash(self, exclude: str | tuple[str, ...] | None = None) -> str:
        skip = _exclusion(exclude)
        with self._lock:
            items = sorted(
                (k, v) for k, v in self._versions.items()
                if skip is None or not skip(k)
            )
        h = hashlib.sha256(repr(items).encode())
        return h.hexdigest()[:16]


class DiskFolder(SharedFolder):
    """Filesystem-backed folder with atomic writes (tmp + rename).

    Safe for multiple processes on a shared mount: readers never observe a
    torn write because rename is atomic on POSIX.
    """

    def __init__(self, directory: str):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)

    def _path(self, key: str) -> str:
        # Percent-encoding is reversible even when the key (a node id, say)
        # itself contains '/', '__', or '%' — '.replace("/", "__")' was not.
        safe = urllib.parse.quote(key, safe="")
        return os.path.join(self.directory, safe + ".npz")

    def put(self, key: str, blob: bytes) -> None:
        fd, tmp = tempfile.mkstemp(dir=self.directory, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(blob)
            # Stamp an explicit nanosecond mtime: the filesystem clock can be
            # coarse (1s on NFS), and inode numbers recycle, so without this a
            # rapid same-size rewrite could repeat a version() token and let a
            # CachingFolder serve stale bytes as a hit.
            now = time.time_ns()
            os.utime(tmp, ns=(now, now))
            os.replace(tmp, self._path(key))
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)

    def put_if_absent(self, key: str, blob: bytes) -> bool:
        """Atomic create: write a temp file, then link(2) it to the final
        name — link fails with EEXIST when the name is taken, and it is
        atomic on POSIX filesystems *including NFS* (unlike O_EXCL on NFSv2),
        which is exactly the mount a multi-host fleet shares. This is the
        mutual-exclusion primitive behind fleet slot claims."""
        fd, tmp = tempfile.mkstemp(dir=self.directory, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(blob)
            now = time.time_ns()
            os.utime(tmp, ns=(now, now))
            try:
                os.link(tmp, self._path(key))
            except FileExistsError:
                return False
            return True
        finally:
            os.unlink(tmp)

    def get(self, key: str) -> bytes | None:
        path = self._path(key)
        for _ in range(3):  # retry: concurrent replace() can race open()
            try:
                with open(path, "rb") as f:
                    return f.read()
            except FileNotFoundError:
                return None
            except OSError:
                time.sleep(0.01)
        return None

    def keys(self) -> list[str]:
        out = []
        for name in os.listdir(self.directory):
            if name.endswith(".npz"):
                out.append(urllib.parse.unquote(name[: -len(".npz")]))
        return out

    def delete(self, key: str) -> None:
        try:
            os.unlink(self._path(key))
        except FileNotFoundError:
            pass

    def version(self, key: str) -> tuple[int, int, int] | None:
        try:
            st = os.stat(self._path(key))
        except FileNotFoundError:
            return None
        # put() always replaces via a fresh temp file, so the inode changes on
        # every write — (inode, mtime, size) survives coarse mtime clocks.
        return (st.st_ino, st.st_mtime_ns, st.st_size)

    def list_version(self) -> tuple[int, int, int] | None:
        """Directory stat as the listing token: every put (mkstemp + rename
        into the directory) and delete (unlink) updates the directory's
        mtime/ctime on POSIX. A sub-nanosecond double-write could repeat a
        token, so consumers must only use this where a missed invalidation
        self-heals on the next write (the gossip summary index does)."""
        try:
            st = os.stat(self.directory)
        except FileNotFoundError:
            return None
        return (st.st_mtime_ns, st.st_ctime_ns, st.st_size)

    def state_hash(self, exclude: str | tuple[str, ...] | None = None) -> str:
        skip = _exclusion(exclude)
        items = []
        for name in sorted(os.listdir(self.directory)):
            if not name.endswith(".npz"):
                continue
            if skip is not None and skip(urllib.parse.unquote(name[: -len(".npz")])):
                continue
            path = os.path.join(self.directory, name)
            try:
                st = os.stat(path)
            except FileNotFoundError:
                continue
            # include the inode: a same-size rewrite within one mtime tick on a
            # coarse-timestamp mount must still change the hash (put() always
            # replaces via a fresh temp file)
            items.append((name, st.st_ino, st.st_mtime_ns, st.st_size))
        return hashlib.sha256(repr(items).encode()).hexdigest()[:16]


class S3Folder(SharedFolder):
    """S3-backed folder (paper's production backend). Requires boto3.

    Offline containers can still import this module; instantiation raises if
    boto3 is unavailable.
    """

    def __init__(self, directory: str):
        try:
            import boto3  # type: ignore
        except ImportError as e:  # pragma: no cover - offline container
            raise ImportError("S3Folder requires boto3") from e
        bucket, _, prefix = directory.partition("/")
        self._s3 = boto3.client("s3")
        self.bucket, self.prefix = bucket, prefix.rstrip("/")

    def _key(self, key: str) -> str:
        return f"{self.prefix}/{key}.npz" if self.prefix else f"{key}.npz"

    def put(self, key: str, blob: bytes) -> None:  # pragma: no cover
        self._s3.put_object(Bucket=self.bucket, Key=self._key(key), Body=blob)

    def get(self, key: str) -> bytes | None:  # pragma: no cover
        try:
            resp = self._s3.get_object(Bucket=self.bucket, Key=self._key(key))
            return resp["Body"].read()
        except self._s3.exceptions.NoSuchKey:
            return None

    def keys(self) -> list[str]:  # pragma: no cover
        prefix = f"{self.prefix}/" if self.prefix else ""
        resp = self._s3.list_objects_v2(Bucket=self.bucket, Prefix=prefix)
        out = []
        for obj in resp.get("Contents", []):
            name = obj["Key"][len(prefix):]
            if name.endswith(".npz"):
                out.append(name[: -len(".npz")])
        return out

    def delete(self, key: str) -> None:  # pragma: no cover
        self._s3.delete_object(Bucket=self.bucket, Key=self._key(key))

    def version(self, key: str) -> str | None:  # pragma: no cover
        try:
            resp = self._s3.head_object(Bucket=self.bucket, Key=self._key(key))
        except Exception:
            return None
        return resp.get("ETag")

    def state_hash(self, exclude: str | tuple[str, ...] | None = None) -> str:  # pragma: no cover
        prefix = f"{self.prefix}/" if self.prefix else ""
        skip = _exclusion(exclude)
        resp = self._s3.list_objects_v2(Bucket=self.bucket, Prefix=prefix)
        items = sorted(
            (o["Key"], o["ETag"])
            for o in resp.get("Contents", [])
            if o["Key"].endswith(".npz")
            and not (skip is not None and skip(o["Key"][len(prefix): -len(".npz")]))
        )
        return hashlib.sha256(repr(items).encode()).hexdigest()[:16]


class CachingFolder(SharedFolder):
    """Read-through cache over any SharedFolder.

    ``get`` first asks the inner backend for the key's cheap ``version`` token
    and returns the locally cached blob when it matches — so a peer whose
    deposit has not changed since the last pull costs one metadata lookup
    instead of a full download. This extends Algorithm 1's whole-store
    state-hash fast path to per-peer granularity, which matters once one slow
    peer would otherwise force re-downloading every fast peer's blob.

    Byte counters (``bytes_fetched`` / ``bytes_saved``) make transport
    experiments measurable. The cache holds at most ``max_entries`` blobs
    (LRU): a long sync federation with ``keep_history`` mints a new
    ``history/...`` key every round, and an unbounded cache would grow with
    the full federation trace.
    """

    def __init__(self, inner: SharedFolder, *, max_entries: int = 64):
        self.inner = inner
        self._cache: "_LruCache" = _LruCache(max_entries)  # key -> (version, blob)
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.bytes_fetched = 0
        self.bytes_saved = 0

    @property
    def max_entries(self) -> int:
        return self._cache.capacity

    @max_entries.setter
    def max_entries(self, value: int) -> None:
        self._cache.capacity = value

    def put(self, key: str, blob: bytes) -> None:
        self.inner.put(key, blob)
        # Invalidate rather than cache: version(key) here could already belong
        # to a concurrent writer's blob, and pairing their token with our bytes
        # would be a *persistent* stale hit. The next get refetches once.
        with self._lock:
            self._cache.pop(key)

    def put_if_absent(self, key: str, blob: bytes) -> bool:
        created = self.inner.put_if_absent(key, blob)
        with self._lock:
            self._cache.pop(key)  # same reasoning as put(): never pre-cache
        return created

    def get(self, key: str) -> bytes | None:
        # Read the version token *before* the blob: if a writer lands between
        # the two reads we may cache a fresh blob under a stale token, which
        # only costs one redundant refetch next time — never a stale hit.
        v = self.inner.version(key)
        if v is not None:
            with self._lock:
                hit = self._cache.get(key)  # refreshes LRU position
                if hit is not None and hit[0] == v:
                    self.hits += 1
                    self.bytes_saved += len(hit[1])
                    return hit[1]
        blob = self.inner.get(key)
        with self._lock:
            self.misses += 1
            if blob is not None:
                self.bytes_fetched += len(blob)
                if v is not None:
                    self._cache.put(key, (v, blob))
        return blob

    def keys(self) -> list[str]:
        return self.inner.keys()

    def delete(self, key: str) -> None:
        self.inner.delete(key)
        with self._lock:
            self._cache.pop(key)

    def version(self, key: str) -> Any | None:
        return self.inner.version(key)

    def list_version(self) -> Any | None:
        return self.inner.list_version()

    def state_hash(self, exclude: str | tuple[str, ...] | None = None) -> str:
        return self.inner.state_hash(exclude=exclude)

    def cache_stats(self) -> dict[str, int]:
        with self._lock:
            return {
                "hits": self.hits,
                "misses": self.misses,
                "bytes_fetched": self.bytes_fetched,
                "bytes_saved": self.bytes_saved,
            }


class RetryFolder(SharedFolder):
    """Transient-I/O armor over any SharedFolder: retries ``get``/``put``/
    ``keys`` (and ``version``/``delete``) with capped exponential backoff plus
    jitter when the inner backend raises ``OSError``/``TimeoutError`` — the
    flaky-NFS / object-store blips that would otherwise kill a fleet worker
    mid-round. ``retry+<uri>`` in the folder-URI grammar builds one.

    ``put_if_absent`` is deliberately single-attempt: after an ambiguous
    failure the key may exist with *our* bytes, and a retry would report
    ``False`` for a claim we actually won. Lease/claim writers already treat
    an exception as "not mine" and re-scan, which is safe under at-most-once.

    ``retries`` counts attempts that were retried; ``WeightStore`` folds the
    chain's total into ``PipelineStats.folder_retries`` so it surfaces in
    ``transport_stats()`` next to every other wire counter.
    """

    _RETRYABLE = (OSError, TimeoutError)

    def __init__(self, inner: SharedFolder, *, attempts: int = 4,
                 base_delay: float = 0.05, max_delay: float = 1.0):
        if attempts < 1:
            raise ValueError("attempts must be >= 1")
        self.inner = inner
        self.attempts = attempts
        self.base_delay = base_delay
        self.max_delay = max_delay
        self.retries = 0
        self._lock = threading.Lock()

    def _call(self, fn, *args):
        delay = self.base_delay
        for attempt in range(self.attempts):
            try:
                return fn(*args)
            except self._RETRYABLE:
                if attempt == self.attempts - 1:
                    raise
                with self._lock:
                    self.retries += 1
                # full jitter: sleep U(0, min(cap, base * 2^attempt))
                time.sleep(random.uniform(0.0, min(self.max_delay, delay)))
                delay *= 2.0

    def put(self, key: str, blob: bytes) -> None:
        self._call(self.inner.put, key, blob)

    def put_if_absent(self, key: str, blob: bytes) -> bool:
        return self.inner.put_if_absent(key, blob)  # at-most-once (see class doc)

    def get(self, key: str) -> bytes | None:
        return self._call(self.inner.get, key)

    def keys(self) -> list[str]:
        return self._call(self.inner.keys)

    def delete(self, key: str) -> None:
        self._call(self.inner.delete, key)

    def version(self, key: str) -> Any | None:
        return self._call(self.inner.version, key)

    def list_version(self) -> Any | None:
        return self._call(self.inner.list_version)

    def state_hash(self, exclude: str | tuple[str, ...] | None = None) -> str:
        return self._call(self.inner.state_hash, exclude)


def folder_retries(folder) -> int:
    """Total transient-I/O retries across a folder's wrapper chain (walks
    ``.inner`` links so ``cache+retry+<uri>`` compositions count too)."""
    total = 0
    while folder is not None:
        if isinstance(folder, RetryFolder):
            total += folder.retries
        folder = getattr(folder, "inner", None)
    return total


TRANSPORTS = ("full", "quantized", "delta", "delta_q", "topk")




class WeightStore:
    """Typed view over a SharedFolder: one latest NodeUpdate per node.

    .. note:: New code should open stores through :func:`repro.api.connect`,
       which validates the full URI/transport grammar in one place and picks
       the right store kind per URI. This constructor keeps working unchanged.

    Implements the push / state-hash-check / pull triad from Algorithm 1.
    ``keep_history`` additionally retains per-counter blobs so experiments can
    audit the full federation trace.

    The wire path is a ``TransportPipeline`` (see ``transport.py``) selected
    by ``transport=`` — either a legacy name (``full`` / ``quantized`` /
    ``delta`` / ``delta_q`` / ``topk``, wire-compatible with earlier
    revisions) or a full pipeline spec string such as::

        "delta(chain=4)|zstd"      # delta-against-delta chains + zstd frames
        "topk(adaptive)"           # error-feedback top-k, k ∝ residual norm
        "quantized|npz"            # int8 blobs inside deflate envelopes

    ``compress=`` ("none" / "npz" / "zstd") appends the envelope stage for
    callers using legacy names. Readers are policy-oblivious: blobs are
    self-describing, so heterogeneous pipelines coexist in one folder.

    ``pull``/``pull_node`` keep a bounded decoded-update cache keyed on the
    folder's per-key ``version`` token, so a peer whose deposit is unchanged
    costs one metadata lookup instead of an npz decode. Decodes land
    *directly in flat f32 vectors* (``FlatUpdate`` with a shared per-structure
    ``LeafSpec``); blobs whose leaves cannot embed losslessly in f32
    (int/f64) fall back to the per-leaf tree decode. Cached update objects
    are returned by reference — treat pulled params as read-only, as every
    caller in this repo already does.

    ``prefetch_interval`` (or ``start_prefetch()``) runs a background thread
    that warms the decoded-update cache from cheap ``version()`` listings
    between federation steps. Wire counters (bytes written/read, chain
    depths, residual norms, rebases) live on ``pipeline.stats``; the
    ``bytes_written`` / ``decode_hits`` / ``decode_misses`` properties remain
    as views onto it.
    """

    def __init__(
        self,
        folder: SharedFolder,
        *,
        quantized: bool = False,
        keep_history: bool = False,
        transport: str | None = None,
        families=None,
        rebase_every: int = 10,
        delta_density_threshold: float = 0.5,
        topk_fraction: float = 0.01,
        compress: str = "none",
        decode_cache_entries: int = 64,
        prefetch_interval: float | None = None,
    ):
        # Leaf-family selector sugar: families= builds the family(...) spec
        # (see transport.family_transport_spec) so pushes ship only the named
        # leaf families. An explicit transport= already encodes the policy —
        # passing both would be ambiguous.
        if families is not None:
            if transport is not None:
                raise ValueError("pass families= or transport=, not both")
            transport = family_transport_spec(families)
        self.folder = folder
        self.pipeline = TransportPipeline.from_spec(
            transport,
            quantized=quantized,
            compress=compress,
            rebase_every=rebase_every,
            delta_density_threshold=delta_density_threshold,
            topk_fraction=topk_fraction,
        )
        self.transport = self.pipeline.spec
        self.keep_history = keep_history
        self._ctx = StoreContext(folder, self.pipeline.stats)
        # decoded-update cache: latest/<node> key -> (version token, update).
        # Companion to CachingFolder: that layer skips the *download* of an
        # unchanged blob, this one skips the npz *decode* — keyed on the same
        # cheap folder.version() token. 0 disables.
        self.decode_cache_entries = decode_cache_entries
        self._decoded_latest = _LruCache(decode_cache_entries)
        self._prefetcher: Prefetcher | None = None
        if prefetch_interval is not None:
            self.start_prefetch(prefetch_interval)

    # -- legacy views onto the pipeline --------------------------------------
    @property
    def quantized(self) -> bool:
        return self.pipeline.policy.name == "quantized"

    @property
    def compress(self) -> str:
        return self.pipeline.compress

    @property
    def rebase_every(self) -> int:
        return self.pipeline.policy.rebase_every

    @rebase_every.setter
    def rebase_every(self, value: int) -> None:
        self.pipeline.policy.rebase_every = value

    @property
    def delta_density_threshold(self) -> float:
        return self.pipeline.policy.density_threshold

    @delta_density_threshold.setter
    def delta_density_threshold(self, value: float) -> None:
        self.pipeline.policy.density_threshold = value

    @property
    def topk_fraction(self) -> float:
        return self.pipeline.policy.topk_fraction

    @topk_fraction.setter
    def topk_fraction(self, value: float) -> None:
        self.pipeline.policy.topk_fraction = value

    @property
    def bytes_written(self) -> int:
        return self.pipeline.stats.bytes_written

    @property
    def bytes_read(self) -> int:
        return self.pipeline.stats.bytes_read

    @property
    def decode_hits(self) -> int:
        return self.pipeline.stats.decode_hits

    @property
    def decode_misses(self) -> int:
        return self.pipeline.stats.decode_misses

    def transport_stats(self) -> dict:
        """Every wire counter of this store's pipeline, one dict."""
        retried = folder_retries(self.folder)
        if retried:
            self.pipeline.stats.set_value("folder_retries", retried)
        return self.pipeline.stats.as_dict()

    # -- push ---------------------------------------------------------------
    def push(self, update: NodeUpdate) -> None:
        blob, is_delta = self.pipeline.push(update, self._ctx)
        if self.keep_history:
            if is_delta:
                # history stays self-contained (and, for topk, exact)
                blob = self.pipeline.encode_history(update)
            self._ctx.put(f"history/{update.node_id}/{update.counter:06d}", blob)

    # -- strategy-state recovery blobs ---------------------------------------
    def push_strategy_state(self, node_id: str, strategy: str, counter: int,
                            state: dict) -> None:
        """Persist a node's optimizer state under ``state/<node>`` (riding
        the pipeline's envelope) so a restarted node can resume its server-
        optimizer trajectory, not just its params."""
        blob = serialize_strategy_state(
            node_id, strategy, counter, state,
            compress=self.pipeline.compress_arg)
        self._ctx.put(f"state/{node_id}", blob)

    def pull_strategy_state(self, node_id: str) -> tuple[dict, dict] | None:
        """-> (state arrays, meta) from ``state/<node>``, or None."""
        blob = self._ctx.get(f"state/{node_id}")
        if blob is None:
            return None
        try:
            return deserialize_strategy_state(blob)
        except (ValueError, KeyError):
            return None

    # -- observability blobs --------------------------------------------------
    def attach_telemetry(self, telemetry) -> None:
        """Route this store's folder round-trips and codec work through a
        ``Telemetry`` instance (put/get/encode/decode spans)."""
        self._ctx.telemetry = telemetry

    def push_obs(self, node_id: str, seq: int, payload: dict, *,
                 keep: int | None = None) -> None:
        """Deposit one telemetry snapshot under ``obs/<node>/<seq>``.

        Writes go straight to the folder, not through the pipeline context:
        observability traffic must not skew the wire counters it exists to
        report. ``keep`` bounds the per-node trail — the deposit ``keep``
        sequences back is GC'd with each flush.
        """
        self.folder.put(f"obs/{node_id}/{seq:06d}",
                        serialize_obs_blob(node_id, seq, payload))
        if keep is not None and seq - keep >= 0:
            try:
                self.folder.delete(f"obs/{node_id}/{seq - keep:06d}")
            except OSError:
                _log.debug("obs GC failed for %s seq %d", node_id, seq - keep,
                           exc_info=True)

    def pull_obs(self, node_id: str | None = None) -> list[tuple[str, int, dict]]:
        """All (node_id, seq, payload) telemetry snapshots, seq-ordered."""
        out = []
        for key in sorted(self.folder.keys()):
            if not key.startswith("obs/"):
                continue
            nid, _, _seq = key[len("obs/"):].rpartition("/")
            if node_id is not None and nid != node_id:
                continue
            blob = self.folder.get(key)
            if blob is None:
                continue
            try:
                out.append(deserialize_obs_blob(blob))
            except (ValueError, KeyError):
                continue
        return out

    # -- state hash fast path -------------------------------------------------
    def state_hash(self, exclude_node: str | None = None) -> str:
        # A node's deposits span latest/, base/ + chain/ (delta rebases and
        # chain links) and history/; all of them must be excluded or the
        # node's own push would defeat its own skip check. state/ blobs are
        # optimizer recovery data and fleet/ blobs are launcher control
        # traffic (specs, claims, heartbeats, soak results) and obs/ blobs
        # are telemetry snapshots — none is federation signal, so all are
        # excluded for every node: a heartbeat or telemetry flush landing
        # between two pulls must not trigger a fleet-wide re-pull.
        exclude: tuple[str, ...] = ("state/", "fleet/", "obs/")
        if exclude_node:
            exclude = (
                f"latest/{exclude_node}",
                f"base/{exclude_node}/",
                f"chain/{exclude_node}/",
                f"history/{exclude_node}/",
                "state/",
                "fleet/",
                "obs/",
            )
        return self.folder.state_hash(exclude=exclude)

    # -- pull ---------------------------------------------------------------
    def node_ids(self) -> list[str]:
        return sorted(
            key[len("latest/"):] for key in self.folder.keys() if key.startswith("latest/")
        )

    def _decode(self, blob: bytes, node_id: str) -> NodeUpdate | None:
        """Decode a self-describing blob; None when a delta's reference chain
        cannot be resolved yet (caller refetches — the writer is mid-rebase
        or mid-GC)."""
        return self.pipeline.decode(blob, node_id, self._ctx)

    def _pull_latest(self, node_id: str) -> NodeUpdate | None:
        key = f"latest/{node_id}"
        stats = self.pipeline.stats
        # Version token read BEFORE the blob (same ordering as CachingFolder):
        # a writer landing in between can only cache a fresh update under a
        # stale token — one redundant decode next time, never a stale hit.
        v = self.folder.version(key) if self.decode_cache_entries else None
        if v is not None:
            hit = self._decoded_latest.get(key)  # refreshes LRU position
            if hit is not None and hit[0] == v:
                stats.incr("decode_hits")
                return hit[1]
        for _ in range(3):
            blob = self._ctx.get(key)
            if blob is None:
                return None
            update = self._decode(blob, node_id)
            if update is not None:
                stats.incr("decode_misses")
                if v is not None:
                    self._decoded_latest.put(key, (v, update))
                return update
            time.sleep(0.01)  # writer mid-rebase; refetch latest + bases
        return None

    def pull(self, exclude: str | None = None) -> list[NodeUpdate]:
        """Latest update per node (optionally excluding the caller's own)."""
        out = []
        for node_id in self.node_ids():
            if node_id == exclude:
                continue
            update = self._pull_latest(node_id)
            if update is not None:
                out.append(update)
        return out

    def pull_node(self, node_id: str) -> NodeUpdate | None:
        return self._pull_latest(node_id)

    def pull_round(self, counter: int, exclude: str | None = None) -> list[NodeUpdate]:
        """Exact-round blobs (requires keep_history=True) — used by the
        synchronous barrier so every client aggregates the identical set even
        if a fast peer has already deposited round t+1."""
        prefix = "history/"
        out = []
        for key in sorted(self.folder.keys()):
            if not key.startswith(prefix):
                continue
            # node ids may themselves contain '/' — split the counter off the
            # right instead of assuming exactly three segments.
            node_id, _, ctr = key[len(prefix):].rpartition("/")
            if not ctr.isdigit() or int(ctr) != counter or node_id == exclude:
                continue
            blob = self._ctx.get(key)
            if blob is not None:
                out.append(self._decode(blob, node_id))
        return [u for u in out if u is not None]

    # -- background prefetch --------------------------------------------------
    def warm_cache(self, exclude: str | None = None) -> int:
        """One prefetch sweep: decode every ``latest/`` blob whose cheap
        ``version()`` token is missing from (or stale in) the decoded-update
        cache. Returns how many peers were warmed. Safe to call from a
        background thread concurrently with pulls (all caches are locked)."""
        if not self.decode_cache_entries:
            return 0
        stats = self.pipeline.stats
        warmed = 0
        for node_id in self.node_ids():
            if node_id == exclude:
                continue
            key = f"latest/{node_id}"
            v = self.folder.version(key)
            hit = self._decoded_latest.get(key)
            if v is not None and hit is not None and hit[0] == v:
                continue
            if self._pull_latest(node_id) is not None:
                warmed += 1
        stats.incr("prefetch_cycles")
        stats.incr("prefetched", warmed)
        return warmed

    def start_prefetch(self, interval: float = 0.1, *,
                       exclude: str | None = None) -> Prefetcher:
        """Run ``warm_cache`` on a daemon thread every ``interval`` seconds
        (``exclude`` skips the owning node's own key). Returns the
        ``Prefetcher`` handle; ``stop_prefetch()`` (or handle.stop()) ends
        it."""
        if self._prefetcher is not None:
            self._prefetcher.stop()
        self._prefetcher = Prefetcher(self, interval=interval, exclude=exclude)
        return self._prefetcher

    def stop_prefetch(self) -> None:
        if self._prefetcher is not None:
            self._prefetcher.stop()
            self._prefetcher = None

    def clear(self) -> None:
        for key in self.folder.keys():
            self.folder.delete(key)
        self.pipeline.reset()
        self._ctx.clear()
        self._decoded_latest.clear()


_MEMORY_REGISTRY: dict[str, "InMemoryFolder"] = {}
_MEMORY_REGISTRY_LOCK = threading.Lock()


def make_folder(uri: str):
    """Folder factory: 'memory://', 's3://bucket/prefix', a local path, or any
    of those behind a read-through cache via a 'cache+' prefix
    (e.g. 'cache+/mnt/shared/exp1', 'cache+s3://bucket/exp1') and/or a
    transient-I/O retry layer via a 'retry+' prefix
    (e.g. 'retry+/mnt/flaky-nfs/exp1', 'cache+retry+s3://bucket/exp1').

    Bare 'memory://' mints a fresh anonymous folder per call; a named
    'memory://<name>' resolves through a process-global registry, so every
    store connected to the same name shares one folder — the in-process
    analogue of a shared mount (what the serving tier and multi-store tests
    rely on).

    A 'shard<G>+<uri>' prefix returns a ``ShardedFolders`` handle — G
    per-group folders of the inner kind (e.g. 'shard16+/mnt/shared/exp1',
    'shard8+cache+s3://bucket/exp1') — which the federated nodes turn into a
    gossip-sharded ``ShardedWeightStore`` instead of a flat ``WeightStore``.
    'shard<G>x<L>+<uri>' (e.g. 'shard64x2+/mnt/shared/exp1') additionally
    federates the G groups through an L-level hierarchical summary tree
    (rings of rings) instead of one flat ring — the planetary-scale layout.

    The URI grammar is the folder-side half of the transport spec grammar;
    ``transport.parse_folder_uri`` owns the parse. Wrappers apply
    outermost-first: 'cache+retry+<base>' caches over the retrying folder.

    .. note:: Most callers want :func:`repro.api.connect`, which wraps this
       factory and returns a ready store for any URI. ``make_folder`` stays
       for code that needs the raw folder handle.
    """
    wrappers, base = parse_folder_uri(uri)
    for i, (name, _args) in enumerate(wrappers):
        if name == "shard":
            if i != 0:
                raise ValueError(
                    f"shard<G>+ must be the outermost wrapper in {uri!r}")
            if any(n == "shard" for n, _ in wrappers[1:]):
                raise ValueError(
                    f"shard<G>+ may appear only once in {uri!r}")
            from .gossip import ShardedFolders  # circular-import guard

            return ShardedFolders.from_uri(uri)
    if base.startswith("memory://"):
        name = base[len("memory://"):].strip("/")
        if name:
            with _MEMORY_REGISTRY_LOCK:
                folder: SharedFolder = _MEMORY_REGISTRY.setdefault(
                    name, InMemoryFolder())
        else:
            folder = InMemoryFolder()
    elif base.startswith("s3://"):
        folder = S3Folder(base[len("s3://"):])
    else:
        folder = DiskFolder(base)
    # innermost wrapper wraps first, so the leftmost prefix ends up outermost
    for name, _args in reversed(wrappers):
        folder = RetryFolder(folder) if name == "retry" else CachingFolder(folder)
    return folder
