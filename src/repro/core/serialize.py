"""Wire format for the weight store.

A deposited update is a pytree of numpy arrays plus scalar metadata
(num_examples, local epoch counter, node id, wall time). We serialize to a
single npz blob: leaves stored under their key-path strings, metadata under a
reserved ``__meta__`` JSON entry. Key-path keyed storage (instead of pickling
a treedef) keeps the format language- and process-agnostic — the store really
could be an S3 bucket written by heterogeneous clients.
"""
from __future__ import annotations

import hashlib
import io
import json
from dataclasses import dataclass, field
from typing import Any

import jax
import numpy as np

from .tree import PyTree, path_str

_META_KEY = "__meta__"
_SEP = "|"  # npz keys cannot contain '/' reliably across tools; use '|'


@dataclass
class NodeUpdate:
    """One client's deposit in the weight store."""

    params: PyTree
    num_examples: int
    node_id: str
    counter: int = 0  # client-local epoch counter (no global round exists)
    timestamp: float = 0.0  # virtual or wall time, for staleness strategies
    metrics: dict = field(default_factory=dict)


def serialize_params(params: PyTree, meta: dict[str, Any] | None = None) -> bytes:
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(params)[0]
    arrays: dict[str, np.ndarray] = {}
    order: list[str] = []
    dtypes: dict[str, str] = {}
    for path, leaf in leaves_with_paths:
        key = path_str(path).replace("/", _SEP)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or arr.dtype.name in ("bfloat16", "float8_e4m3fn", "float8_e5m2"):
            # numpy cannot round-trip ml_dtypes through npz; ship f32 on the
            # wire (aggregation is f32 anyway) and restore dtype on load.
            dtypes[key] = arr.dtype.name
            arr = arr.astype(np.float32)
        arrays[key] = arr
        order.append(key)
    meta_blob = dict(meta or {})
    meta_blob["__order__"] = order
    meta_blob["__dtypes__"] = dtypes
    arrays[_META_KEY] = np.frombuffer(json.dumps(meta_blob).encode(), dtype=np.uint8)
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    return buf.getvalue()


def deserialize_params(blob: bytes) -> tuple[PyTree, dict[str, Any]]:
    """Returns (nested-dict params, meta). Key paths 'a|b|c' rebuild nesting."""
    with np.load(io.BytesIO(blob)) as data:
        meta = json.loads(bytes(data[_META_KEY].tobytes()).decode())
        order = meta.pop("__order__")
        dtypes = meta.pop("__dtypes__", {})
        tree: dict = {}
        for key in order:
            parts = key.split(_SEP)
            node = tree
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            leaf = data[key]
            if key in dtypes:
                import ml_dtypes

                leaf = leaf.astype(np.dtype(getattr(ml_dtypes, dtypes[key])))
            node[parts[-1]] = leaf
    return tree, meta


def serialize_update(update: NodeUpdate) -> bytes:
    return serialize_params(
        update.params,
        meta={
            "num_examples": int(update.num_examples),
            "node_id": update.node_id,
            "counter": int(update.counter),
            "timestamp": float(update.timestamp),
            "metrics": update.metrics,
        },
    )


def deserialize_update(blob: bytes) -> NodeUpdate:
    params, meta = deserialize_params(blob)
    return NodeUpdate(
        params=params,
        num_examples=int(meta["num_examples"]),
        node_id=str(meta["node_id"]),
        counter=int(meta["counter"]),
        timestamp=float(meta["timestamp"]),
        metrics=meta.get("metrics", {}),
    )


def content_hash(blob: bytes) -> str:
    return hashlib.sha256(blob).hexdigest()[:16]


# --- int8 compressed payloads (beyond-paper extension #4) -------------------


def quantize_leaf(x: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Symmetric per-tensor int8 quantization: returns (q, scale)."""
    x = np.asarray(x, np.float32)
    scale = np.maximum(np.abs(x).max(), 1e-12) / 127.0
    q = np.clip(np.round(x / scale), -127, 127).astype(np.int8)
    return q, np.float32(scale)


def dequantize_leaf(q: np.ndarray, scale: np.ndarray) -> np.ndarray:
    return q.astype(np.float32) * np.float32(scale)


def serialize_update_quantized(update: NodeUpdate) -> bytes:
    qtree = jax.tree.map(lambda x: quantize_leaf(np.asarray(x))[0], update.params)
    stree = jax.tree.map(lambda x: quantize_leaf(np.asarray(x))[1], update.params)
    return serialize_params(
        {"q": qtree, "s": stree},
        meta={
            "num_examples": int(update.num_examples),
            "node_id": update.node_id,
            "counter": int(update.counter),
            "timestamp": float(update.timestamp),
            "metrics": update.metrics,
            "quantized": True,
        },
    )


def deserialize_update_quantized(blob: bytes) -> NodeUpdate:
    packed, meta = deserialize_params(blob)
    params = jax.tree.map(dequantize_leaf, packed["q"], packed["s"])
    return NodeUpdate(
        params=params,
        num_examples=int(meta["num_examples"]),
        node_id=str(meta["node_id"]),
        counter=int(meta["counter"]),
        timestamp=float(meta["timestamp"]),
        metrics=meta.get("metrics", {}),
    )
