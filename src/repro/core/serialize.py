"""Wire format for the weight store.

A deposited update is a pytree of numpy arrays plus scalar metadata
(num_examples, local epoch counter, node id, wall time). We serialize to a
single npz blob: leaves stored under their key-path strings, metadata under a
reserved ``__meta__`` JSON entry. Key-path keyed storage (instead of pickling
a treedef) keeps the format language- and process-agnostic — the store really
could be an S3 bucket written by heterogeneous clients.
"""
from __future__ import annotations

import hashlib
import io
import json
from dataclasses import dataclass, field
from typing import Any

import jax
import numpy as np

from .tree import PyTree, path_str

_META_KEY = "__meta__"
_SEP = "|"  # npz keys cannot contain '/' reliably across tools; use '|'


@dataclass
class NodeUpdate:
    """One client's deposit in the weight store."""

    params: PyTree
    num_examples: int
    node_id: str
    counter: int = 0  # client-local epoch counter (no global round exists)
    timestamp: float = 0.0  # virtual or wall time, for staleness strategies
    metrics: dict = field(default_factory=dict)


def _wire_leaf(leaf) -> tuple[np.ndarray, str | None]:
    """Convert a leaf to its on-wire array. numpy cannot round-trip ml_dtypes
    through npz, so those ship as f32 (aggregation is f32 anyway); returns
    (array, original dtype name to restore on load — None when unneeded)."""
    arr = np.asarray(leaf)
    if arr.dtype.kind == "V" or arr.dtype.name in ("bfloat16", "float8_e4m3fn", "float8_e5m2"):
        return arr.astype(np.float32), arr.dtype.name
    return arr, None


def _rebuild_tree(order, dtypes, get_leaf) -> dict:
    """Rebuild the nested-dict pytree from 'a|b|c' key paths; restores the
    original dtype of leaves that shipped as f32."""
    tree: dict = {}
    for key in order:
        leaf = get_leaf(key)
        if key in dtypes:
            import ml_dtypes

            leaf = leaf.astype(np.dtype(getattr(ml_dtypes, dtypes[key])))
        parts = key.split(_SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return tree


def _pack_npz(arrays: dict[str, np.ndarray], order: list[str], dtypes: dict[str, str],
              meta: dict[str, Any] | None) -> bytes:
    """The one wire envelope: leaf arrays + __order__/__dtypes__ under a JSON
    __meta__ entry, zipped into an npz. Full and delta blobs both go through
    here so envelope changes cannot desynchronize the two formats."""
    meta_blob = dict(meta or {})
    meta_blob["__order__"] = order
    meta_blob["__dtypes__"] = dtypes
    arrays[_META_KEY] = np.frombuffer(json.dumps(meta_blob).encode(), dtype=np.uint8)
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    return buf.getvalue()


def serialize_params(params: PyTree, meta: dict[str, Any] | None = None) -> bytes:
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(params)[0]
    arrays: dict[str, np.ndarray] = {}
    order: list[str] = []
    dtypes: dict[str, str] = {}
    for path, leaf in leaves_with_paths:
        key = path_str(path).replace("/", _SEP)
        arr, original_dtype = _wire_leaf(leaf)
        if original_dtype:
            dtypes[key] = original_dtype
        arrays[key] = arr
        order.append(key)
    return _pack_npz(arrays, order, dtypes, meta)


def deserialize_params(blob: bytes) -> tuple[PyTree, dict[str, Any]]:
    """Returns (nested-dict params, meta). Key paths 'a|b|c' rebuild nesting."""
    with np.load(io.BytesIO(blob)) as data:
        meta = json.loads(bytes(data[_META_KEY].tobytes()).decode())
        order = meta.pop("__order__")
        dtypes = meta.pop("__dtypes__", {})
        tree = _rebuild_tree(order, dtypes, lambda key: data[key])
    return tree, meta


def canonicalize_params(params: PyTree) -> PyTree:
    """The nested-dict tree a reader reconstructs after a serialize round-trip
    (wire dtype conversion included), computed without the npz I/O. A delta
    writer diffs future updates against this so its view of the base is
    bitwise-identical to every reader's."""
    wire = _flat_wire(params)
    dtypes = {k: dt for k, (_, dt) in wire.items() if dt}
    return _rebuild_tree(list(wire), dtypes, lambda key: np.array(wire[key][0], copy=True))


def _update_meta(update: NodeUpdate, **extra: Any) -> dict[str, Any]:
    return {
        "num_examples": int(update.num_examples),
        "node_id": update.node_id,
        "counter": int(update.counter),
        "timestamp": float(update.timestamp),
        "metrics": update.metrics,
        **extra,
    }


def _update_from_meta(params: PyTree, meta: dict[str, Any]) -> NodeUpdate:
    return NodeUpdate(
        params=params,
        num_examples=int(meta["num_examples"]),
        node_id=str(meta["node_id"]),
        counter=int(meta["counter"]),
        timestamp=float(meta["timestamp"]),
        metrics=meta.get("metrics", {}),
    )


def serialize_update(update: NodeUpdate) -> bytes:
    return serialize_params(update.params, meta=_update_meta(update))


def deserialize_update(blob: bytes) -> NodeUpdate:
    params, meta = deserialize_params(blob)
    return _update_from_meta(params, meta)


def content_hash(blob: bytes) -> str:
    return hashlib.sha256(blob).hexdigest()[:16]


def peek_meta(blob: bytes) -> dict[str, Any]:
    """Read only the ``__meta__`` entry of a serialized blob (cheap dispatch:
    full vs quantized vs delta) without materializing the weight arrays."""
    with np.load(io.BytesIO(blob)) as data:
        return json.loads(bytes(data[_META_KEY].tobytes()).decode())


# --- group summaries (sharded gossip store) ---------------------------------
#
# A group's deposit in the gossip layer: the example-weighted mean of the
# group's latest params plus enough metadata for receivers to (a) weight it
# like a pseudo-peer in client-side aggregation (``num_examples`` = the total
# behind the mean) and (b) order competing copies by freshness. The blob rides
# the same self-describing npz envelope as every other deposit — ``peek_meta``
# dispatches on ``summary_of`` exactly like it does on ``delta_of`` /
# ``quantized`` — so heterogeneous readers never need out-of-band schema.


@dataclass
class GroupSummary:
    """One group's aggregate deposit in the gossip layer."""

    params: PyTree              # example-weighted mean of the group's latest params
    num_examples: int           # total examples behind that mean
    origin: int                 # group index that produced the summary
    version: int                # monotone freshness scalar: sum of (counter + 1)
    version_vector: dict        # node_id -> latest counter folded into the mean
    timestamp: float = 0.0      # newest member timestamp (staleness strategies)


def serialize_group_summary(summary: GroupSummary) -> bytes:
    return serialize_params(
        summary.params,
        meta={
            "summary_of": int(summary.origin),
            "num_examples": int(summary.num_examples),
            "version": int(summary.version),
            "version_vector": {str(k): int(v) for k, v in summary.version_vector.items()},
            "timestamp": float(summary.timestamp),
        },
    )


def deserialize_group_summary(blob: bytes) -> GroupSummary:
    params, meta = deserialize_params(blob)
    if "summary_of" not in meta:
        raise ValueError("not a group-summary blob")
    return GroupSummary(
        params=params,
        num_examples=int(meta["num_examples"]),
        origin=int(meta["summary_of"]),
        version=int(meta["version"]),
        version_vector={str(k): int(v) for k, v in meta["version_vector"].items()},
        timestamp=float(meta.get("timestamp", 0.0)),
    )


# --- int8 compressed payloads (beyond-paper extension #4) -------------------


def quantize_leaf(x: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Symmetric per-tensor int8 quantization: returns (q, scale)."""
    x = np.asarray(x, np.float32)
    scale = np.maximum(np.abs(x).max(), 1e-12) / 127.0
    q = np.clip(np.round(x / scale), -127, 127).astype(np.int8)
    return q, np.float32(scale)


def dequantize_leaf(q: np.ndarray, scale: np.ndarray) -> np.ndarray:
    return q.astype(np.float32) * np.float32(scale)


def serialize_update_quantized(update: NodeUpdate) -> bytes:
    qtree = jax.tree.map(lambda x: quantize_leaf(np.asarray(x))[0], update.params)
    stree = jax.tree.map(lambda x: quantize_leaf(np.asarray(x))[1], update.params)
    return serialize_params(
        {"q": qtree, "s": stree}, meta=_update_meta(update, quantized=True)
    )


def deserialize_update_quantized(blob: bytes) -> NodeUpdate:
    packed, meta = deserialize_params(blob)
    params = jax.tree.map(dequantize_leaf, packed["q"], packed["s"])
    return _update_from_meta(params, meta)


# --- delta payloads against a content-hashed base ---------------------------
#
# Transport fast path for the weight store: after the first full deposit, a
# node ships only the entries that changed relative to a *base* blob it also
# deposited (content-addressed, so readers can verify they reconstruct against
# the exact bytes the writer diffed against). The sparse encoding stores the
# NEW values at changed positions — not arithmetic differences — so
# reconstruction is bitwise-exact and aggregation over reconstructed params
# equals aggregation over full blobs exactly.

_DENSE = "d" + _SEP  # per-leaf dense fallback
_IDX = "i" + _SEP    # changed flat indices
_VAL = "v" + _SEP    # new values at those indices
_SCALE = "c" + _SEP  # int8 scale when the delta values are quantized


class DeltaBaseMismatch(RuntimeError):
    """The base blob a delta references is missing or has different content."""


def _flat_wire(params: PyTree) -> dict[str, tuple[np.ndarray, str | None]]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        out[path_str(path).replace("/", _SEP)] = _wire_leaf(leaf)
    return out


def delta_density(params: PyTree, base_params: PyTree) -> float:
    """Fraction of entries that differ from the base (1.0 on any structural
    mismatch). Diagnostic helper for sizing experiments — the store itself
    decides delta-vs-rebase by comparing the encoded delta's size against the
    tree's raw byte size, which costs only the one serialization scan."""
    new, base = _flat_wire(params), _flat_wire(base_params)
    if set(new) != set(base):
        return 1.0
    changed = total = 0
    for key, (a, _) in new.items():
        b = base[key][0]
        if a.shape != b.shape or a.dtype != b.dtype:
            return 1.0
        total += a.size
        changed += int(np.count_nonzero(a.reshape(-1) != b.reshape(-1)))
    return changed / max(total, 1)


def serialize_update_delta(
    update: NodeUpdate,
    base_params: PyTree,
    base_hash: str,
    *,
    quantize: bool = False,
    density_threshold: float = 0.5,
) -> bytes:
    """Encode ``update`` as a sparse diff against ``base_params`` (whose full
    serialized blob hashes to ``base_hash``). Leaves denser than
    ``density_threshold`` fall back to dense storage; ``quantize`` ships the
    changed values int8-quantized (lossy — drop it when bitwise equality with
    the full-blob path matters)."""
    new, base = _flat_wire(update.params), _flat_wire(base_params)
    if set(new) != set(base):
        raise ValueError("delta requires identical tree structure with the base")
    arrays: dict[str, np.ndarray] = {}
    order: list[str] = []
    dtypes: dict[str, str] = {}
    for key, (a, dt) in new.items():
        order.append(key)
        if dt:
            dtypes[key] = dt
        b = base[key][0]
        if a.shape != b.shape or a.dtype != b.dtype:
            arrays[_DENSE + key] = a
            continue
        af, bf = a.reshape(-1), b.reshape(-1)
        idx = np.flatnonzero(af != bf)
        if idx.size > density_threshold * af.size:
            arrays[_DENSE + key] = a
            continue
        arrays[_IDX + key] = idx.astype(np.int64 if af.size > 2**31 else np.int32)
        vals = af[idx]
        if quantize and vals.dtype.kind == "f" and vals.size:
            q, scale = quantize_leaf(vals)
            arrays[_VAL + key] = q
            arrays[_SCALE + key] = np.asarray(scale)
        else:
            arrays[_VAL + key] = vals
    return _pack_npz(arrays, order, dtypes, _update_meta(update, delta_of=base_hash))


def deserialize_update_delta(blob: bytes, base_params: PyTree) -> NodeUpdate:
    """Reconstruct a full NodeUpdate from a delta blob + the base params it
    was diffed against (the caller is responsible for matching ``delta_of`` to
    the base blob's content hash; see WeightStore)."""
    base = _flat_wire(base_params)
    with np.load(io.BytesIO(blob)) as data:
        meta = json.loads(bytes(data[_META_KEY].tobytes()).decode())
        if "delta_of" not in meta:
            raise ValueError("not a delta blob")
        order = meta.pop("__order__")
        dtypes = meta.pop("__dtypes__", {})

        def reconstruct(key: str) -> np.ndarray:
            if _DENSE + key in data.files:
                return data[_DENSE + key]
            if key not in base:
                raise DeltaBaseMismatch(f"base is missing leaf {key!r}")
            b = base[key][0]
            flat = np.array(b, copy=True).reshape(-1)
            idx = data[_IDX + key]
            vals = data[_VAL + key]
            if _SCALE + key in data.files:
                vals = dequantize_leaf(vals, data[_SCALE + key])
            flat[idx] = vals.astype(flat.dtype, copy=False)
            return flat.reshape(b.shape)

        tree = _rebuild_tree(order, dtypes, reconstruct)
    return _update_from_meta(tree, meta)
