"""Wire format for the weight store.

A deposited update is a pytree of numpy arrays plus scalar metadata
(num_examples, local epoch counter, node id, wall time). We serialize to a
single npz blob: leaves stored under their key-path strings, metadata under a
reserved ``__meta__`` JSON entry. Key-path keyed storage (instead of pickling
a treedef) keeps the format language- and process-agnostic — the store really
could be an S3 bucket written by heterogeneous clients.
"""
from __future__ import annotations

import hashlib
import io
import json
from dataclasses import dataclass, field
from typing import Any

import jax
import numpy as np

from .tree import LeafSpec, PyTree, path_str

_META_KEY = "__meta__"
_SEP = "|"  # npz keys cannot contain '/' reliably across tools; use '|'

# zstd frame magic — lets deserializers sniff a zstd-wrapped npz envelope
_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"

COMPRESSIONS = ("none", "npz", "zstd")


_ZSTD_MODULE: object = None  # cached binding; False = probed and absent


def _zstd_module():
    """The first importable zstd binding, or None (offline containers).
    Cached: the import probe runs once per process, not per blob. Compressor
    contexts are still built per call — zstandard contexts are not
    thread-safe, and stores are shared across threads."""
    global _ZSTD_MODULE
    if _ZSTD_MODULE is None:
        import importlib

        for name in ("zstandard", "zstd", "compression.zstd"):
            try:
                _ZSTD_MODULE = importlib.import_module(name)
                break
            except ImportError:
                continue
        else:
            _ZSTD_MODULE = False
    return _ZSTD_MODULE or None


def _zstd_compress(blob: bytes) -> bytes:
    mod = _zstd_module()
    if mod is None:
        raise ImportError("compress='zstd' requires a zstd module (zstandard)")
    if hasattr(mod, "ZstdCompressor"):  # zstandard
        return mod.ZstdCompressor().compress(blob)
    return mod.compress(blob)

def maybe_decompress(blob: bytes) -> bytes:
    """Undo the optional zstd wire wrapping; readers stay format-agnostic.
    (``savez_compressed`` needs no sniffing — np.load handles it natively.)"""
    if blob[:4] != _ZSTD_MAGIC:
        return blob
    mod = _zstd_module()
    if mod is None:
        raise ImportError("blob is zstd-compressed but no zstd module is available")
    if hasattr(mod, "ZstdDecompressor"):  # zstandard
        return mod.ZstdDecompressor().decompress(blob)
    return mod.decompress(blob)


@dataclass
class NodeUpdate:
    """One client's deposit in the weight store."""

    params: PyTree
    num_examples: int
    node_id: str
    counter: int = 0  # client-local epoch counter (no global round exists)
    timestamp: float = 0.0  # virtual or wall time, for staleness strategies
    metrics: dict = field(default_factory=dict)
    # Fleet-lease epoch of the writer: 0 for a node on its original claim,
    # bumped each time the node's slot was adopted by a surviving worker.
    # Staleness-aware strategies (FedAsync) discount resurrected stragglers
    # by the epoch gap so an adopted node's resumed-from-old params cannot
    # yank the consensus backwards.
    lease_epoch: int = 0


class FlatUpdate(NodeUpdate):
    """A ``NodeUpdate`` whose params live as one contiguous f32 vector plus a
    shared ``LeafSpec``. ``params`` materializes the pytree lazily (and caches
    it), so every existing reader keeps working; flat-aware consumers (the
    vectorized strategies) grab ``flat``/``spec`` directly and never touch a
    nested dict. Treat both the flat vector and the materialized tree as
    read-only — they may be shared via the store's decode cache."""

    def __init__(self, flat: np.ndarray, spec: LeafSpec, *, num_examples: int,
                 node_id: str, counter: int = 0, timestamp: float = 0.0,
                 metrics: dict | None = None, lease_epoch: int = 0):
        self.flat = np.asarray(flat, np.float32).reshape(-1)
        self.spec = spec
        self._tree: PyTree | None = None
        NodeUpdate.__init__(
            self, params=None, num_examples=num_examples, node_id=node_id,
            counter=counter, timestamp=timestamp, metrics=metrics or {},
            lease_epoch=lease_epoch,
        )

    @property
    def params(self) -> PyTree:
        if self._tree is None:
            self._tree = self.spec.unflatten(self.flat)
        return self._tree

    @params.setter
    def params(self, value) -> None:  # dataclass __init__ assigns params=None
        self._tree = value

    def __repr__(self) -> str:  # avoid materializing the tree for debugging
        return (f"FlatUpdate(node_id={self.node_id!r}, counter={self.counter}, "
                f"num_examples={self.num_examples}, spec={self.spec!r})")


def _wire_leaf(leaf) -> tuple[np.ndarray, str | None]:
    """Convert a leaf to its on-wire array. numpy cannot round-trip ml_dtypes
    through npz, so those ship as f32 (aggregation is f32 anyway); returns
    (array, original dtype name to restore on load — None when unneeded)."""
    arr = np.asarray(leaf)
    if arr.dtype.kind == "V" or arr.dtype.name in ("bfloat16", "float8_e4m3fn", "float8_e5m2"):
        return arr.astype(np.float32), arr.dtype.name
    return arr, None


def _rebuild_tree(order, dtypes, get_leaf) -> dict:
    """Rebuild the nested-dict pytree from 'a|b|c' key paths; restores the
    original dtype of leaves that shipped as f32."""
    tree: dict = {}
    for key in order:
        leaf = get_leaf(key)
        if key in dtypes:
            import ml_dtypes

            leaf = leaf.astype(np.dtype(getattr(ml_dtypes, dtypes[key])))
        parts = key.split(_SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return tree


def _pack_npz(arrays: dict[str, np.ndarray], order: list[str], dtypes: dict[str, str],
              meta: dict[str, Any] | None, *, compress: str = "none") -> bytes:
    """The one wire envelope: leaf arrays + __order__/__dtypes__ under a JSON
    __meta__ entry, zipped into an npz. Full and delta blobs both go through
    here so envelope changes cannot desynchronize the two formats.

    ``compress``: 'none' (stored npz), 'npz' (deflate via savez_compressed —
    np.load decodes it natively), or 'zstd' (whole-blob zstd frame, sniffed by
    ``maybe_decompress``)."""
    if compress not in COMPRESSIONS:
        raise ValueError(f"unknown compress {compress!r}; options: {COMPRESSIONS}")
    meta_blob = dict(meta or {})
    meta_blob["__order__"] = order
    meta_blob["__dtypes__"] = dtypes
    arrays[_META_KEY] = np.frombuffer(json.dumps(meta_blob).encode(), dtype=np.uint8)
    buf = io.BytesIO()
    (np.savez_compressed if compress == "npz" else np.savez)(buf, **arrays)
    blob = buf.getvalue()
    if compress == "zstd":
        blob = _zstd_compress(blob)
    return blob


def serialize_params(params: PyTree, meta: dict[str, Any] | None = None, *,
                     compress: str = "none") -> bytes:
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(params)[0]
    arrays: dict[str, np.ndarray] = {}
    order: list[str] = []
    dtypes: dict[str, str] = {}
    for path, leaf in leaves_with_paths:
        key = path_str(path).replace("/", _SEP)
        arr, original_dtype = _wire_leaf(leaf)
        if original_dtype:
            dtypes[key] = original_dtype
        arrays[key] = arr
        order.append(key)
    return _pack_npz(arrays, order, dtypes, meta, compress=compress)


def deserialize_params(blob: bytes) -> tuple[PyTree, dict[str, Any]]:
    """Returns (nested-dict params, meta). Key paths 'a|b|c' rebuild nesting."""
    with np.load(io.BytesIO(maybe_decompress(blob))) as data:
        meta = json.loads(bytes(data[_META_KEY].tobytes()).decode())
        order = meta.pop("__order__")
        dtypes = meta.pop("__dtypes__", {})
        tree = _rebuild_tree(order, dtypes, lambda key: data[key])
    return tree, meta


# --- flat decode: npz blob → one preallocated f32 vector ---------------------
#
# The read side of the flat hot path. Instead of rebuilding a nested dict leaf
# by leaf, a blob is decoded *directly into one flat f32 vector* laid out by a
# LeafSpec derived from the blob's own __order__/__dtypes__ metadata. Specs are
# interned in a caller-owned table, so every update a store decodes for the
# same model shares one spec instance and aggregation can stack flats with an
# identity check instead of a structural comparison.


class FlatDecodeUnsupported(ValueError):
    """Blob holds leaves a flat f32 vector cannot represent losslessly
    (int/f64 wire arrays) — callers fall back to the per-leaf tree decode."""


def _restored_dtype(name: str) -> np.dtype:
    import ml_dtypes

    return np.dtype(getattr(ml_dtypes, name))


def _spec_table_key(order, dtypes, quantized, wire_dtypes=()) -> tuple:
    """Structure identity for spec interning. ``wire_dtypes`` (the native
    npz array dtypes, in leaf order) must participate: same-structure f16 and
    f32 models are indistinguishable by order + ``__dtypes__`` alone (that
    map only records ml_dtypes restores), and sharing one spec across them
    would silently retype leaves on unflatten."""
    return ("q" if quantized else "f", tuple(order),
            tuple(sorted(dtypes.items())), tuple(wire_dtypes))


def _build_wire_spec(order, dtypes, shapes_by_key, quantized) -> LeafSpec:
    """LeafSpec for a wire structure, in the *canonical* (rebuilt-dict flatten)
    leaf order — identical to the order a tree-path reader's pytree would
    flatten to, so flat and tree readers agree on layout byte-for-byte."""
    skeleton = _rebuild_tree(list(shapes_by_key), {}, lambda key: 0)
    canon_paths, treedef = jax.tree_util.tree_flatten_with_path(skeleton)
    paths, shapes, dts = [], [], []
    for path, _ in canon_paths:
        p = path_str(path)
        key = p.replace("/", _SEP)
        paths.append(p)
        shapes.append(shapes_by_key[key][0])
        if quantized:
            dts.append(np.dtype(np.float32))  # dequantized leaves are f32
        elif key in dtypes:
            dts.append(_restored_dtype(dtypes[key]))
        else:
            dts.append(shapes_by_key[key][1])
    return LeafSpec(paths, shapes, dts, treedef)


def _wire_keys(spec: LeafSpec) -> tuple[str, ...]:
    """Spec paths in wire ('|'-separated) form, cached on the spec object."""
    keys = getattr(spec, "_wire_keys", None)
    if keys is None:
        keys = tuple(p.replace("/", _SEP) for p in spec.paths)
        spec._wire_keys = keys
    return keys


def decode_params_flat(blob: bytes, specs: dict) -> tuple[LeafSpec, np.ndarray, dict]:
    """Decode a full or quantized npz blob straight into one preallocated flat
    f32 vector — no nested-dict rebuild. ``specs`` is a caller-owned interning
    table (structure key → LeafSpec); pass the same dict across calls so all
    updates of one model share a spec. Raises ``FlatDecodeUnsupported`` for
    blobs whose wire arrays don't embed losslessly in f32."""
    with np.load(io.BytesIO(maybe_decompress(blob))) as data:
        meta = json.loads(bytes(data[_META_KEY].tobytes()).decode())
        order = meta.pop("__order__")
        dtypes = meta.pop("__dtypes__", {})
        if "delta_of" in meta:
            raise ValueError("delta blob: use deserialize_update_delta_flat")
        quantized = bool(meta.get("quantized"))
        if quantized:
            # order lists the packed {"q":..., "s":...} tree; the spec
            # describes the original structure (q-keys with prefix stripped)
            leaf_keys = [k[2:] for k in order if k.startswith("q" + _SEP)]
            arrays = {k: data["q" + _SEP + k] for k in leaf_keys}
        else:
            leaf_keys = list(order)
            arrays = {k: data[k] for k in leaf_keys}
            for k, a in arrays.items():
                if a.dtype.kind != "f" or a.dtype.itemsize > 4:
                    raise FlatDecodeUnsupported(
                        f"leaf {k!r} has wire dtype {a.dtype} (not f32-exact)")
        wire_dtypes = () if quantized else tuple(arrays[k].dtype.str for k in leaf_keys)
        skey = _spec_table_key(order, dtypes, quantized, wire_dtypes)
        spec = specs.get(skey)
        drifted = False
        if spec is not None:
            # verify shapes still match the interned layout; drift → rebuild
            # (dtypes are part of the table key, so only shapes can drift)
            wire = _wire_keys(spec)
            if len(wire) != len(leaf_keys) or any(
                tuple(arrays[k].shape) != spec.shapes[spec.index[k.replace(_SEP, "/")]]
                for k in leaf_keys
            ):
                spec, drifted = None, True
        if spec is None:
            shapes_by_key = {k: (tuple(a.shape), a.dtype) for k, a in arrays.items()}
            spec = _build_wire_spec(order, dtypes, shapes_by_key, quantized)
            if drifted:
                specs[skey] = spec  # replace the stale layout
            else:
                # setdefault: a concurrent decode (the prefetch thread racing
                # a pull) must not intern two spec instances for one structure
                # — spec identity is what makes the stack cache zero-copy
                spec = specs.setdefault(skey, spec)
        flat = spec.empty_flat()
        index, offsets, sizes = spec.index, spec.offsets, spec.sizes
        if quantized:
            for k in leaf_keys:
                i = index[k.replace(_SEP, "/")]
                o, n = offsets[i], sizes[i]
                np.multiply(arrays[k].reshape(-1), np.float32(data["s" + _SEP + k]),
                            out=flat[o:o + n], dtype=np.float32, casting="unsafe")
        else:
            for k in leaf_keys:
                i = index[k.replace(_SEP, "/")]
                o, n = offsets[i], sizes[i]
                flat[o:o + n] = arrays[k].reshape(-1)
    return spec, flat, meta


def flat_update_from_meta(spec: LeafSpec, flat: np.ndarray,
                          meta: dict[str, Any]) -> FlatUpdate:
    return FlatUpdate(
        flat, spec,
        num_examples=int(meta["num_examples"]),
        node_id=str(meta["node_id"]),
        counter=int(meta["counter"]),
        timestamp=float(meta["timestamp"]),
        metrics=meta.get("metrics", {}),
        lease_epoch=int(meta.get("lease_epoch", 0)),
    )


def deserialize_update_flat(blob: bytes, specs: dict) -> FlatUpdate:
    """Full/quantized blob → FlatUpdate (see ``decode_params_flat``)."""
    spec, flat, meta = decode_params_flat(blob, specs)
    return flat_update_from_meta(spec, flat, meta)


def canonicalize_params(params: PyTree) -> PyTree:
    """The nested-dict tree a reader reconstructs after a serialize round-trip
    (wire dtype conversion included), computed without the npz I/O. A delta
    writer diffs future updates against this so its view of the base is
    bitwise-identical to every reader's."""
    wire = _flat_wire(params)
    dtypes = {k: dt for k, (_, dt) in wire.items() if dt}
    return _rebuild_tree(list(wire), dtypes, lambda key: np.array(wire[key][0], copy=True))


def _update_meta(update: NodeUpdate, **extra: Any) -> dict[str, Any]:
    return {
        "num_examples": int(update.num_examples),
        "node_id": update.node_id,
        "counter": int(update.counter),
        "timestamp": float(update.timestamp),
        "metrics": update.metrics,
        "lease_epoch": int(getattr(update, "lease_epoch", 0)),
        **extra,
    }


def _update_from_meta(params: PyTree, meta: dict[str, Any]) -> NodeUpdate:
    return NodeUpdate(
        params=params,
        num_examples=int(meta["num_examples"]),
        node_id=str(meta["node_id"]),
        counter=int(meta["counter"]),
        timestamp=float(meta["timestamp"]),
        metrics=meta.get("metrics", {}),
        lease_epoch=int(meta.get("lease_epoch", 0)),
    )


def serialize_update(update: NodeUpdate, *, compress: str = "none") -> bytes:
    return serialize_params(update.params, meta=_update_meta(update), compress=compress)


def deserialize_update(blob: bytes) -> NodeUpdate:
    params, meta = deserialize_params(blob)
    return _update_from_meta(params, meta)


def content_hash(blob: bytes) -> str:
    return hashlib.sha256(blob).hexdigest()[:16]


def peek_meta(blob: bytes) -> dict[str, Any]:
    """Read only the ``__meta__`` entry of a serialized blob (cheap dispatch:
    full vs quantized vs delta) without materializing the weight arrays."""
    with np.load(io.BytesIO(maybe_decompress(blob))) as data:
        return json.loads(bytes(data[_META_KEY].tobytes()).decode())


# --- group summaries (sharded gossip store) ---------------------------------
#
# A group's deposit in the gossip layer: the example-weighted mean of the
# group's latest params plus enough metadata for receivers to (a) weight it
# like a pseudo-peer in client-side aggregation (``num_examples`` = the total
# behind the mean) and (b) order competing copies by freshness. The blob rides
# the same self-describing npz envelope as every other deposit — ``peek_meta``
# dispatches on ``summary_of`` exactly like it does on ``delta_of`` /
# ``quantized`` — so heterogeneous readers never need out-of-band schema.


@dataclass
class GroupSummary:
    """One group's aggregate deposit in the gossip layer."""

    params: PyTree              # example-weighted mean of the group's latest params
    num_examples: int           # total examples behind that mean
    origin: int                 # group index that produced the summary
    version: int                # monotone freshness scalar: sum of (counter + 1)
    version_vector: dict        # node_id -> latest counter folded into the mean
    timestamp: float = 0.0      # newest member timestamp (staleness strategies)


def serialize_group_summary(summary: GroupSummary, *, compress: str = "none") -> bytes:
    return serialize_params(
        summary.params,
        compress=compress,
        meta={
            "summary_of": int(summary.origin),
            "num_examples": int(summary.num_examples),
            "version": int(summary.version),
            "version_vector": {str(k): int(v) for k, v in summary.version_vector.items()},
            "timestamp": float(summary.timestamp),
        },
    )


def deserialize_group_summary(blob: bytes) -> GroupSummary:
    params, meta = deserialize_params(blob)
    if "summary_of" not in meta:
        raise ValueError("not a group-summary blob")
    return GroupSummary(
        params=params,
        num_examples=int(meta["num_examples"]),
        origin=int(meta["summary_of"]),
        version=int(meta["version"]),
        version_vector={str(k): int(v) for k, v in meta["version_vector"].items()},
        timestamp=float(meta.get("timestamp", 0.0)),
    )


# --- super-summaries (hierarchical gossip tiers) ------------------------------
#
# A level-k aggregator's fold of one ring segment: the example-weighted mean of
# the segment's child summaries, plus per-child freshness (``child_versions``)
# so staleness is detectable per level without decoding. The version vector
# carries per-child counter *maxima* (keyed by the child's pseudo-peer id),
# not a fleet-wide node vector: the propagated counter — what ``FedAsync``
# discounting compares against its own epoch — stays exact through arbitrarily
# many tiers while blob metadata stays O(branching), and the true per-node
# vector remains one level-0 hop away. Dispatches on ``super_summary_of`` like
# every other wire family.


@dataclass
class SuperSummary:
    """One ring segment's folded deposit at tier ``level`` of the summary tree."""

    params: PyTree              # example-weighted mean of the child summaries
    num_examples: int           # total examples behind that mean
    origin: int                 # segment index at this level
    level: int                  # tier (>= 1; level-0 deposits are GroupSummary)
    version: int                # sum of the child version scalars (monotone)
    child_versions: dict        # child origin key -> version scalar folded in
    version_vector: dict        # child pseudo-peer id -> its counter maximum
    timestamp: float = 0.0      # newest child timestamp


def serialize_super_summary(summary: SuperSummary, *, compress: str = "none") -> bytes:
    return serialize_params(
        summary.params,
        compress=compress,
        meta={
            "super_summary_of": int(summary.origin),
            "level": int(summary.level),
            "num_examples": int(summary.num_examples),
            "version": int(summary.version),
            "child_versions": {str(k): int(v) for k, v in summary.child_versions.items()},
            "version_vector": {str(k): int(v) for k, v in summary.version_vector.items()},
            "timestamp": float(summary.timestamp),
        },
    )


def deserialize_super_summary(blob: bytes) -> SuperSummary:
    params, meta = deserialize_params(blob)
    if "super_summary_of" not in meta:
        raise ValueError("not a super-summary blob")
    return SuperSummary(
        params=params,
        num_examples=int(meta["num_examples"]),
        origin=int(meta["super_summary_of"]),
        level=int(meta["level"]),
        version=int(meta["version"]),
        child_versions={str(k): int(v) for k, v in meta["child_versions"].items()},
        version_vector={str(k): int(v) for k, v in meta["version_vector"].items()},
        timestamp=float(meta.get("timestamp", 0.0)),
    )


# --- strategy-state recovery blobs -------------------------------------------
#
# A node's optimizer state (FedAvgM momentum, FedAdam/FedYogi/FedAdagrad
# moments) lives client-side; a crashed-and-restarted node that recovers its
# params from ``latest/`` but restarts its strategy cold loses the server-
# optimizer trajectory. These blobs persist the flat state vectors under
# ``state/<node>`` — the same self-describing npz envelope as every other
# deposit (``peek_meta`` dispatches on ``state_of``), riding the pipeline's
# compressed envelope.


def serialize_strategy_state(node_id: str, strategy: str, counter: int,
                             state: dict[str, np.ndarray], *,
                             compress: str = "none") -> bytes:
    return serialize_params(
        {k: np.asarray(v) for k, v in state.items()},
        compress=compress,
        meta={"state_of": node_id, "strategy": strategy,
              "counter": int(counter)},
    )


def deserialize_strategy_state(blob: bytes) -> tuple[dict, dict]:
    """-> (state arrays by name, meta with state_of/strategy/counter)."""
    state, meta = deserialize_params(blob)
    if "state_of" not in meta:
        raise ValueError("not a strategy-state blob")
    return state, meta


# --- fleet-control blobs (launcher / chaos-soak harness) ---------------------
#
# The multi-host fleet launcher (``repro.fleet``) coordinates through the
# shared folder itself — spec, slot claims, heartbeats, per-node results and
# per-worker reports are all just deposits, so there is no coordinator in the
# data path. They ride the same self-describing npz envelope as every other
# blob (``peek_meta`` dispatches on ``fleet_of`` exactly like ``summary_of`` /
# ``state_of`` / ``delta_of``); the payload is pure JSON metadata, no arrays.
# Every fleet key lives under the ``fleet/`` prefix, which the stores exclude
# from state hashes: a heartbeat must never look like federation signal and
# trigger a fleet-wide re-pull.


def serialize_fleet_blob(kind: str, payload: dict, *, compress: str = "none") -> bytes:
    """One fleet-control deposit: ``kind`` ∈ {spec, claim, heartbeat, result,
    worker, ...} plus a JSON-serializable payload."""
    return serialize_params(
        {}, compress=compress,
        meta={"fleet_of": str(kind), "payload": dict(payload)},
    )


def deserialize_fleet_blob(blob: bytes) -> tuple[str, dict]:
    """-> (kind, payload). Raises ValueError on non-fleet blobs."""
    _params, meta = deserialize_params(blob)
    if "fleet_of" not in meta:
        raise ValueError("not a fleet-control blob")
    return str(meta["fleet_of"]), dict(meta.get("payload") or {})


# --- observability blobs (store-native telemetry plane) ----------------------
#
# Telemetry snapshots ride the store as their own family under ``obs/<node>/
# <seq>`` — the serverless answer to "where does a round's time go": there is
# no metrics server, so per-node phase latencies, staleness distributions and
# wire counters are deposited as blobs and assembled read-only by any peer
# (``python -m repro.obs``). Same envelope, same exclusion rule as ``fleet/``:
# an obs deposit must never perturb ``state_hash`` and trigger re-pulls.


def serialize_obs_blob(node_id: str, seq: int, payload: dict, *,
                       compress: str = "none") -> bytes:
    """One telemetry snapshot deposit for ``obs/<node_id>/<seq>``."""
    return serialize_params(
        {}, compress=compress,
        meta={"obs_of": str(node_id), "seq": int(seq),
              "payload": dict(payload)},
    )


def deserialize_obs_blob(blob: bytes) -> tuple[str, int, dict]:
    """-> (node_id, seq, payload). Raises ValueError on non-obs blobs."""
    _params, meta = deserialize_params(blob)
    if "obs_of" not in meta:
        raise ValueError("not a telemetry blob")
    return (str(meta["obs_of"]), int(meta.get("seq", 0)),
            dict(meta.get("payload") or {}))


# --- int8 compressed payloads (beyond-paper extension #4) -------------------


def quantize_leaf(x: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Symmetric per-tensor int8 quantization: returns (q, scale)."""
    x = np.asarray(x, np.float32)
    scale = np.maximum(np.abs(x).max(), 1e-12) / 127.0
    q = np.clip(np.round(x / scale), -127, 127).astype(np.int8)
    return q, np.float32(scale)


def dequantize_leaf(q: np.ndarray, scale: np.ndarray) -> np.ndarray:
    return q.astype(np.float32) * np.float32(scale)


def serialize_update_quantized(update: NodeUpdate, *, compress: str = "none") -> bytes:
    qtree = jax.tree.map(lambda x: quantize_leaf(np.asarray(x))[0], update.params)
    stree = jax.tree.map(lambda x: quantize_leaf(np.asarray(x))[1], update.params)
    return serialize_params(
        {"q": qtree, "s": stree}, meta=_update_meta(update, quantized=True),
        compress=compress
    )


def deserialize_update_quantized(blob: bytes) -> NodeUpdate:
    packed, meta = deserialize_params(blob)
    params = jax.tree.map(dequantize_leaf, packed["q"], packed["s"])
    return _update_from_meta(params, meta)


# --- delta payloads against a content-hashed base ---------------------------
#
# Transport fast path for the weight store: after the first full deposit, a
# node ships only the entries that changed relative to a *base* blob it also
# deposited (content-addressed, so readers can verify they reconstruct against
# the exact bytes the writer diffed against). The sparse encoding stores the
# NEW values at changed positions — not arithmetic differences — so
# reconstruction is bitwise-exact and aggregation over reconstructed params
# equals aggregation over full blobs exactly.

_DENSE = "d" + _SEP  # per-leaf dense fallback
_IDX = "i" + _SEP    # changed flat indices
_VAL = "v" + _SEP    # new values at those indices
_SCALE = "c" + _SEP  # int8 scale when the delta values are quantized


class DeltaBaseMismatch(RuntimeError):
    """The base blob a delta references is missing or has different content."""


def _flat_wire(params: PyTree) -> dict[str, tuple[np.ndarray, str | None]]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        out[path_str(path).replace("/", _SEP)] = _wire_leaf(leaf)
    return out


def delta_density(params: PyTree, base_params: PyTree) -> float:
    """Fraction of entries that differ from the base (1.0 on any structural
    mismatch). Diagnostic helper for sizing experiments — the store itself
    decides delta-vs-rebase by comparing the encoded delta's size against the
    tree's raw byte size, which costs only the one serialization scan."""
    new, base = _flat_wire(params), _flat_wire(base_params)
    if set(new) != set(base):
        return 1.0
    changed = total = 0
    for key, (a, _) in new.items():
        b = base[key][0]
        if a.shape != b.shape or a.dtype != b.dtype:
            return 1.0
        total += a.size
        changed += int(np.count_nonzero(a.reshape(-1) != b.reshape(-1)))
    return changed / max(total, 1)


def apply_update_delta_flat(blob: bytes, spec: LeafSpec,
                            flat: np.ndarray) -> dict[str, Any]:
    """Apply a delta blob's sparse entries *in place* on ``flat`` (which must
    already hold the referenced base state); returns the blob's meta. The
    in-place form is what lets a chain walk reconstruct K links with one base
    copy instead of K. On a raised exception ``flat`` may be partially
    mutated — callers discard it (the exceptions signal a structure/dtype
    mismatch, never a transient)."""
    with np.load(io.BytesIO(maybe_decompress(blob))) as data:
        meta = json.loads(bytes(data[_META_KEY].tobytes()).decode())
        if "delta_of" not in meta:
            raise ValueError("not a delta blob")
        order = meta.pop("__order__")
        meta.pop("__dtypes__", None)
        wire = _wire_keys(spec)
        if len(order) != len(wire) or set(order) != set(wire):
            raise ValueError("delta structure does not match the base spec")
        files = set(data.files)
        index, offsets, sizes = spec.index, spec.offsets, spec.sizes
        for key in order:
            i = index[key.replace(_SEP, "/")]
            o, n = offsets[i], sizes[i]
            if _DENSE + key in files:
                arr = data[_DENSE + key]
                if arr.size != n:
                    raise ValueError(f"dense leaf {key!r}: {arr.size} vs {n}")
                if _SCALE + key in files:  # dense int8-quantized leaf
                    flat[o:o + n] = dequantize_leaf(
                        arr.reshape(-1), data[_SCALE + key])
                    continue
                if arr.dtype.kind != "f" or arr.dtype.itemsize > 4:
                    raise FlatDecodeUnsupported(
                        f"leaf {key!r} has wire dtype {arr.dtype} (not f32-exact)")
                flat[o:o + n] = arr.reshape(-1)
                continue
            idx = data[_IDX + key]
            vals = data[_VAL + key]
            if _SCALE + key in files:
                vals = dequantize_leaf(vals, data[_SCALE + key])
            elif vals.size and (vals.dtype.kind != "f" or vals.dtype.itemsize > 4):
                raise FlatDecodeUnsupported(
                    f"leaf {key!r} delta values have wire dtype {vals.dtype}")
            flat[o + idx] = vals
    return meta


def deserialize_update_delta_flat(blob: bytes, spec: LeafSpec,
                                  base_flat: np.ndarray) -> FlatUpdate:
    """Reconstruct a FlatUpdate from a delta blob by applying its sparse
    entries in place on a copy of the *flat* base vector — no nested-dict
    rebuild, no per-leaf tree traversal. Raises ValueError when the blob's
    structure does not match ``spec`` (caller falls back to the tree path)."""
    flat = np.array(base_flat, dtype=np.float32, copy=True)
    meta = apply_update_delta_flat(blob, spec, flat)
    return flat_update_from_meta(spec, flat, meta)


def serialize_update_delta_from_flat(
    update: NodeUpdate,
    spec: LeafSpec,
    flat: np.ndarray,
    base_flat: np.ndarray,
    base_hash: str,
    *,
    changed: np.ndarray | None = None,
    density_threshold: float = 0.5,
    compress: str = "none",
    quantize_leaves: "frozenset[int] | set[int] | tuple[int, ...]" = (),
    extra_meta: dict[str, Any] | None = None,
) -> bytes:
    """Encode ``flat`` as a sparse per-leaf diff against ``base_flat`` — the
    exact wire format of ``serialize_update_delta``, so any reader reconstructs
    it with zero knowledge of how the writer chose the changed set (this is
    what makes writer-side top-k/error-feedback policies transparent).
    ``changed`` (sorted flat indices that differ from the base) may be passed
    when the caller already computed it; ``quantize_leaves`` names leaf
    indices whose changed values ship int8-quantized (per-segment scale under
    the ``c|`` key, lossy — the family codec's ``quantized`` sub-policy);
    ``extra_meta`` adds writer-side meta keys (e.g. the chain codec's
    ``chain_depth``). Vectorized: the only per-leaf work is emitting npz
    entries, which the wire format requires anyway."""
    flat = np.asarray(flat, np.float32).reshape(-1)
    if flat.size != spec.num_params:
        raise ValueError(f"{flat.size} params vs spec's {spec.num_params}")
    if changed is None:
        changed = np.flatnonzero(flat != np.asarray(base_flat).reshape(-1))
    arrays: dict[str, np.ndarray] = {}
    order: list[str] = []
    dtypes: dict[str, str] = {}
    keys = _wire_keys(spec)
    # one vectorized split of the changed set into per-leaf segments
    cuts = np.searchsorted(changed, spec.bounds)
    for i, key in enumerate(keys):
        order.append(key)
        dt = spec.dtypes[i]
        wire_dt, restored = _wire_leaf(np.empty((0,), dt))
        if restored:
            dtypes[key] = restored
        o, n = spec.offsets[i], spec.sizes[i]
        seg = changed[cuts[i]:cuts[i + 1]]
        if i in quantize_leaves and seg.size:
            if seg.size > density_threshold * n:
                # dense quantized: int8 leaf + per-leaf scale (a d|-plus-c|
                # pair, which readers dequantize) — 1 byte/entry where the
                # sparse form would pay 5 (int32 index + int8 value)
                q, scale = quantize_leaf(flat[o:o + n])
                arrays[_DENSE + key] = q.reshape(spec.shapes[i])
                arrays[_SCALE + key] = np.asarray(scale)
                continue
            arrays[_IDX + key] = (seg - o).astype(
                np.int64 if n > 2**31 else np.int32)
            q, scale = quantize_leaf(flat[seg])
            arrays[_VAL + key] = q
            arrays[_SCALE + key] = np.asarray(scale)
            continue
        if seg.size > density_threshold * n:
            arrays[_DENSE + key] = np.asarray(
                flat[o:o + n], dtype=wire_dt.dtype).reshape(spec.shapes[i])
            continue
        idx = (seg - o).astype(np.int64 if n > 2**31 else np.int32)
        arrays[_IDX + key] = idx
        arrays[_VAL + key] = np.asarray(flat[seg], dtype=wire_dt.dtype)
    meta = _update_meta(update, delta_of=base_hash, **(extra_meta or {}))
    return _pack_npz(arrays, order, dtypes, meta, compress=compress)


def serialize_update_delta(
    update: NodeUpdate,
    base_params: PyTree,
    base_hash: str,
    *,
    quantize: bool = False,
    density_threshold: float = 0.5,
    compress: str = "none",
) -> bytes:
    """Encode ``update`` as a sparse diff against ``base_params`` (whose full
    serialized blob hashes to ``base_hash``). Leaves denser than
    ``density_threshold`` fall back to dense storage; ``quantize`` ships the
    changed values int8-quantized (lossy — drop it when bitwise equality with
    the full-blob path matters)."""
    new, base = _flat_wire(update.params), _flat_wire(base_params)
    if set(new) != set(base):
        raise ValueError("delta requires identical tree structure with the base")
    arrays: dict[str, np.ndarray] = {}
    order: list[str] = []
    dtypes: dict[str, str] = {}
    for key, (a, dt) in new.items():
        order.append(key)
        if dt:
            dtypes[key] = dt
        b = base[key][0]
        if a.shape != b.shape or a.dtype != b.dtype:
            arrays[_DENSE + key] = a
            continue
        af, bf = a.reshape(-1), b.reshape(-1)
        idx = np.flatnonzero(af != bf)
        if idx.size > density_threshold * af.size:
            arrays[_DENSE + key] = a
            continue
        arrays[_IDX + key] = idx.astype(np.int64 if af.size > 2**31 else np.int32)
        vals = af[idx]
        if quantize and vals.dtype.kind == "f" and vals.size:
            q, scale = quantize_leaf(vals)
            arrays[_VAL + key] = q
            arrays[_SCALE + key] = np.asarray(scale)
        else:
            arrays[_VAL + key] = vals
    return _pack_npz(arrays, order, dtypes, _update_meta(update, delta_of=base_hash),
                     compress=compress)


def deserialize_update_delta(blob: bytes, base_params: PyTree) -> NodeUpdate:
    """Reconstruct a full NodeUpdate from a delta blob + the base params it
    was diffed against (the caller is responsible for matching ``delta_of`` to
    the base blob's content hash; see WeightStore)."""
    base = _flat_wire(base_params)
    with np.load(io.BytesIO(maybe_decompress(blob))) as data:
        meta = json.loads(bytes(data[_META_KEY].tobytes()).decode())
        if "delta_of" not in meta:
            raise ValueError("not a delta blob")
        order = meta.pop("__order__")
        dtypes = meta.pop("__dtypes__", {})

        def reconstruct(key: str) -> np.ndarray:
            if _DENSE + key in data.files:
                arr = data[_DENSE + key]
                if _SCALE + key in data.files:  # dense int8-quantized leaf
                    arr = dequantize_leaf(arr, data[_SCALE + key])
                return arr
            if key not in base:
                raise DeltaBaseMismatch(f"base is missing leaf {key!r}")
            b = base[key][0]
            flat = np.array(b, copy=True).reshape(-1)
            idx = data[_IDX + key]
            vals = data[_VAL + key]
            if _SCALE + key in data.files:
                vals = dequantize_leaf(vals, data[_SCALE + key])
            flat[idx] = vals.astype(flat.dtype, copy=False)
            return flat.reshape(b.shape)

        tree = _rebuild_tree(order, dtypes, reconstruct)
    return _update_from_meta(tree, meta)
