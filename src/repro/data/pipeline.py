"""Batching / sharding pipeline.

Host-side numpy batching (the federated experiments are CPU-local), plus
``shard_batch`` to place a global batch onto a Mesh for the distributed-silo
path (used by repro.launch.train).
"""
from __future__ import annotations

from typing import Iterator

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def batch_iterator(
    x: np.ndarray,
    y: np.ndarray,
    *,
    batch_size: int,
    seed: int = 0,
    epoch: int = 0,
    drop_remainder: bool = True,
) -> Iterator[dict]:
    """Shuffled minibatches; reshuffles deterministically per epoch."""
    n = x.shape[0]
    rng = np.random.default_rng(seed * 100003 + epoch)
    perm = rng.permutation(n)
    end = (n // batch_size) * batch_size if drop_remainder else n
    for i in range(0, end, batch_size):
        idx = perm[i : i + batch_size]
        yield {"x": x[idx], "y": y[idx]}


def lm_batch_iterator(
    tokens: np.ndarray,
    *,
    batch_size: int,
    seq_len: int,
    seed: int = 0,
    epoch: int = 0,
) -> Iterator[dict]:
    """Random contiguous windows; targets are inputs shifted by one."""
    n = tokens.shape[0] - seq_len - 1
    if n < 0:
        raise ValueError(f"token stream too short: {tokens.shape[0]} for seq_len {seq_len}")
    rng = np.random.default_rng(seed * 100003 + epoch + 17)
    num_batches = max(1, n // (batch_size * seq_len))
    for _ in range(num_batches):
        # valid window starts are 0..n inclusive: start n reads tokens[n:n+S]
        # with labels tokens[n+1:n+S+1] ending on the final token
        starts = rng.integers(0, n + 1, size=batch_size)
        xs = np.stack([tokens[s : s + seq_len] for s in starts])
        ys = np.stack([tokens[s + 1 : s + seq_len + 1] for s in starts])
        yield {"tokens": xs.astype(np.int32), "labels": ys.astype(np.int32)}


def shard_batch(batch: dict, mesh: Mesh, *, batch_axes: tuple[str, ...] = ("data",)) -> dict:
    """Place a host batch onto the mesh, batch dim sharded over batch_axes
    (falls back to replication when not divisible)."""

    def _place(arr):
        arr = np.asarray(arr)
        axis_size = int(np.prod([mesh.shape[a] for a in batch_axes]))
        spec = P(batch_axes) if arr.shape[0] % axis_size == 0 else P()
        return jax.device_put(arr, NamedSharding(mesh, spec))

    return jax.tree.map(_place, batch)
