from .synthetic import (
    SyntheticImageDataset,
    SyntheticTokenStream,
    make_synthetic_cifar,
    make_synthetic_mnist,
    make_synthetic_wikitext,
)
from .pipeline import batch_iterator, lm_batch_iterator, shard_batch

__all__ = [
    "SyntheticImageDataset",
    "SyntheticTokenStream",
    "make_synthetic_mnist",
    "make_synthetic_cifar",
    "make_synthetic_wikitext",
    "batch_iterator",
    "lm_batch_iterator",
    "shard_batch",
]
