"""Synthetic stand-ins for the paper's datasets (offline container).

The paper's claims are *comparative* (sync vs async, skew trends, node-count
trends), so the datasets only need (a) the right shapes/cardinalities and
(b) genuine learnable class/sequence structure so accuracy differences are
meaningful. Generators are deterministic given a seed.

* ``make_synthetic_mnist``   — 28×28×1, 10 classes: class-conditional stroke
  prototypes + elastic jitter + noise. Linearly non-trivial, CNN-learnable.
* ``make_synthetic_cifar``   — 32×32×3, 10 classes: class-conditional color/
  texture/frequency prototypes with augment-style perturbations.
* ``make_synthetic_wikitext``— token stream from a seeded order-2 Markov
  grammar over a configurable vocab; next-token prediction has a learnable
  ceiling well below 1.0, like real text.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class SyntheticImageDataset:
    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray
    num_classes: int


@dataclass
class SyntheticTokenStream:
    train_tokens: np.ndarray
    test_tokens: np.ndarray
    vocab_size: int


def _class_prototypes(rng: np.random.Generator, num_classes: int, h: int, w: int, c: int) -> np.ndarray:
    """Smooth low-frequency class prototypes: random Fourier features."""
    yy, xx = np.meshgrid(np.linspace(0, 1, h), np.linspace(0, 1, w), indexing="ij")
    protos = np.zeros((num_classes, h, w, c), np.float32)
    for k in range(num_classes):
        img = np.zeros((h, w), np.float32)
        for _ in range(6):
            fx, fy = rng.uniform(0.5, 4.0, size=2)
            px, py = rng.uniform(0, 2 * np.pi, size=2)
            amp = rng.uniform(0.4, 1.0)
            img += amp * np.sin(2 * np.pi * (fx * xx + fy * yy) + px + py)
        img = (img - img.min()) / (np.ptp(img) + 1e-6)
        for ch in range(c):
            protos[k, :, :, ch] = img * rng.uniform(0.5, 1.0)
    return protos


def _make_image_dataset(
    *, num_train: int, num_test: int, h: int, w: int, c: int, num_classes: int,
    noise: float, seed: int,
) -> SyntheticImageDataset:
    rng = np.random.default_rng(seed)
    protos = _class_prototypes(rng, num_classes, h, w, c)

    def sample(n: int, rng: np.random.Generator):
        y = rng.integers(0, num_classes, size=n)
        x = protos[y].copy()
        # random shift (±2 px) + multiplicative jitter + additive noise
        for i in range(n):
            dx, dy = rng.integers(-2, 3, size=2)
            x[i] = np.roll(np.roll(x[i], dx, axis=0), dy, axis=1)
        x *= rng.uniform(0.8, 1.2, size=(n, 1, 1, 1)).astype(np.float32)
        x += rng.normal(0, noise, size=x.shape).astype(np.float32)
        return x.astype(np.float32), y.astype(np.int32)

    x_tr, y_tr = sample(num_train, rng)
    x_te, y_te = sample(num_test, rng)
    return SyntheticImageDataset(x_tr, y_tr, x_te, y_te, num_classes)


def make_synthetic_mnist(num_train: int = 12000, num_test: int = 2000, seed: int = 0) -> SyntheticImageDataset:
    return _make_image_dataset(
        num_train=num_train, num_test=num_test, h=28, w=28, c=1, num_classes=10,
        noise=0.35, seed=seed + 101,
    )


def make_synthetic_cifar(num_train: int = 12000, num_test: int = 2000, seed: int = 0) -> SyntheticImageDataset:
    return _make_image_dataset(
        num_train=num_train, num_test=num_test, h=32, w=32, c=3, num_classes=10,
        noise=0.45, seed=seed + 202,
    )


def make_synthetic_wikitext(
    *, vocab_size: int = 512, train_tokens: int = 200_000, test_tokens: int = 20_000, seed: int = 0,
    branching: int = 4,
) -> SyntheticTokenStream:
    """Order-2 Markov 'language': each bigram context allows ``branching``
    successors with Zipf-ish probabilities. Entropy > 0 ⇒ accuracy ceiling < 1."""
    rng = np.random.default_rng(seed + 303)
    # successor table: for hash(context) pick `branching` candidate tokens
    succ = rng.integers(0, vocab_size, size=(vocab_size, branching))
    probs = np.array([1.0 / (i + 1) for i in range(branching)])
    probs /= probs.sum()

    def gen(n: int, rng: np.random.Generator) -> np.ndarray:
        # pre-draw all branch choices at once (per-step rng.choice is ~100×
        # slower); the chain itself is inherently sequential but cheap
        choices = rng.choice(branching, size=n, p=probs)
        out = np.empty(n, np.int32)
        a, b = rng.integers(0, vocab_size, size=2)
        for i in range(n):
            nxt = succ[(a * 31 + b * 7) % vocab_size, choices[i]]
            out[i] = nxt
            a, b = b, nxt
        return out

    return SyntheticTokenStream(gen(train_tokens, rng), gen(test_tokens, rng), vocab_size)
