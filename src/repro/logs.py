"""Logging for the ``repro.*`` hierarchy — env-gated, silent by default.

The library never configures the root logger and never prints unless asked:
every module calls ``get_logger("store")`` (→ ``repro.store``) and logs into
a hierarchy rooted at ``repro``, which carries a ``NullHandler``. Setting

    REPRO_LOG=debug            # or info / warning / error

attaches a single stderr handler to the ``repro`` root at that level, so
fleet workers, chaos events, prefetch failures, and store GC become visible
without touching application logging config. ``REPRO_LOG=debug:fleet``
scopes the verbosity to one subtree (``repro.fleet``) and leaves the rest at
warning.

Programmatic use: ``configure("debug")`` does the same thing as the env var
and is idempotent — repeated calls replace the level, not stack handlers.
"""
from __future__ import annotations

import logging
import os
import sys

ROOT = "repro"

_LEVELS = {
    "debug": logging.DEBUG,
    "info": logging.INFO,
    "warning": logging.WARNING,
    "warn": logging.WARNING,
    "error": logging.ERROR,
}

_handler: logging.Handler | None = None
_env_applied = False


def get_logger(name: str = "") -> logging.Logger:
    """Return ``repro.<name>`` (or the ``repro`` root for empty name)."""
    _apply_env_once()
    return logging.getLogger(f"{ROOT}.{name}" if name else ROOT)


def configure(spec: str | None = None, *, stream=None) -> logging.Logger:
    """Attach/adjust the single stderr handler per ``spec``.

    ``spec`` is ``<level>`` or ``<level>:<subtree>`` (e.g. ``debug:fleet``).
    ``None``/empty removes the handler and restores library silence.
    """
    global _handler
    root = logging.getLogger(ROOT)
    if not any(isinstance(h, logging.NullHandler) for h in root.handlers):
        root.addHandler(logging.NullHandler())
    if _handler is not None:
        for logger in _all_repro_loggers():
            logger.removeHandler(_handler)
        _handler = None
    if not spec:
        return root
    level_name, _, subtree = str(spec).partition(":")
    level = _LEVELS.get(level_name.strip().lower(), logging.INFO)
    target = logging.getLogger(f"{ROOT}.{subtree}" if subtree else ROOT)
    handler = logging.StreamHandler(stream if stream is not None else sys.stderr)
    handler.setFormatter(
        logging.Formatter("%(asctime)s %(levelname).1s %(name)s: %(message)s")
    )
    target.addHandler(handler)
    target.setLevel(level)
    _handler = handler
    return target


def _all_repro_loggers() -> list[logging.Logger]:
    out = [logging.getLogger(ROOT)]
    for name in list(logging.Logger.manager.loggerDict):
        if name.startswith(ROOT + "."):
            logger = logging.getLogger(name)
            out.append(logger)
    return out


def _apply_env_once() -> None:
    global _env_applied
    if _env_applied:
        return
    _env_applied = True
    spec = os.environ.get("REPRO_LOG", "")
    if spec:
        configure(spec)
