"""Hypothesis shim: real hypothesis when installed, deterministic fallback
otherwise.

The seed container ships without ``hypothesis``, which used to break
collection of every module importing it. Test modules import ``given`` /
``settings`` / ``strategies`` from here instead; when hypothesis is missing,
the fallback replays each property test over a fixed number of
pseudo-randomly drawn examples (seeded per test name, so failures
reproduce). No shrinking, no database — but the property coverage survives.
"""
from __future__ import annotations

import hashlib

try:  # pragma: no cover - exercised only where hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import numpy as np

    HAVE_HYPOTHESIS = False
    _DEFAULT_MAX_EXAMPLES = 20

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class strategies:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def lists(elements, *, min_size=0, max_size=10):
            def draw(rng):
                size = int(rng.integers(min_size, max_size + 1))
                return [elements.draw(rng) for _ in range(size)]

            return _Strategy(draw)

        @staticmethod
        def permutations(values):
            values = list(values)
            return _Strategy(lambda rng: [values[i] for i in rng.permutation(len(values))])

        @staticmethod
        def sampled_from(values):
            values = list(values)
            return _Strategy(lambda rng: values[int(rng.integers(len(values)))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

    def settings(*, max_examples=_DEFAULT_MAX_EXAMPLES, **_ignored):
        def deco(fn):
            fn._hyp_max_examples = max_examples
            return fn

        return deco

    def given(*arg_strategies, **kwarg_strategies):
        def deco(fn):
            # No functools.wraps: pytest must see a zero-arg signature, not the
            # original one (whose params would be mistaken for fixtures).
            def wrapper():
                # check both the wrapper (settings above given) and the bare fn
                # (settings below given) — real hypothesis accepts either order
                n = getattr(wrapper, "_hyp_max_examples",
                            getattr(fn, "_hyp_max_examples", _DEFAULT_MAX_EXAMPLES))
                seed = int.from_bytes(
                    hashlib.sha256(fn.__qualname__.encode()).digest()[:4], "big"
                )
                rng = np.random.default_rng(seed)
                for example in range(n):
                    drawn_args = tuple(s.draw(rng) for s in arg_strategies)
                    drawn_kwargs = {k: s.draw(rng) for k, s in kwarg_strategies.items()}
                    try:
                        fn(*drawn_args, **drawn_kwargs)
                    except AssertionError as e:
                        raise AssertionError(
                            f"fallback example {example}: args={drawn_args} "
                            f"kwargs={drawn_kwargs}: {e}"
                        ) from e

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco
