"""benchmarks.compare robustness: the nightly trend table is report-only, so
a missing, corrupt, or partially-overlapping baseline must degrade to "new"
rows — never crash the workflow."""
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.compare import (  # noqa: E402
    DEFAULT_NAMES,
    compare_payloads,
    load,
    main,
    render_markdown,
)


def _bench(results):
    return {"schema_version": 1, "git_sha": "deadbeef", "timestamp": "t",
            "results": results, "acceptance": {"passed": True}}


def test_gossip_bench_is_compared_by_default():
    assert "BENCH_gossip.json" in DEFAULT_NAMES


def test_load_tolerates_corrupt_and_non_dict_files(tmp_path):
    missing = tmp_path / "nope.json"
    corrupt = tmp_path / "corrupt.json"
    corrupt.write_text("{truncated nightly upload")
    nondict = tmp_path / "list.json"
    nondict.write_text("[1, 2, 3]")
    ok = tmp_path / "ok.json"
    ok.write_text(json.dumps(_bench({"a": 1.0})))
    assert load(str(missing)) is None
    assert load(str(corrupt)) is None
    assert load(str(nondict)) is None
    assert load(str(ok))["results"] == {"a": 1.0}


def test_missing_baseline_key_reports_new_not_crash():
    baseline = _bench({"1000": {"push_us": 10.0}})
    current = _bench({"1000": {"push_us": 12.0},
                      "100000": {"push_us": 11.0}})  # key absent in baseline
    rows = dict((p, (b, c, d)) for p, b, c, d in
                compare_payloads(baseline, current))
    assert rows["results/1000/push_us"][2] is not None  # delta computed
    base, _cur, delta = rows["results/100000/push_us"]
    assert base is None and delta is None
    md = render_markdown("BENCH_gossip.json", baseline, current)
    assert "| new |" in md and "+20.0%" in md


def test_main_survives_corrupt_baseline_dir(tmp_path, capsys):
    base_dir = tmp_path / "baseline"
    cur_dir = tmp_path / "cur"
    base_dir.mkdir()
    cur_dir.mkdir()
    (base_dir / "BENCH_gossip.json").write_text("not json at all")
    (cur_dir / "BENCH_gossip.json").write_text(
        json.dumps(_bench({"1000": {"push_us": 5.0}})))
    rc = main(["--baseline-dir", str(base_dir), "--current-dir", str(cur_dir),
               "--names", "BENCH_gossip.json"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "new" in out  # every metric degrades to new, report still renders


def test_main_flags_missing_current(tmp_path, capsys):
    rc = main(["--baseline-dir", str(tmp_path), "--current-dir", str(tmp_path),
               "--names", "BENCH_gossip.json"])
    out = capsys.readouterr().out
    assert rc == 1
    assert "current run missing" in out
