"""Serving tier: bulk prefill equivalence, hot-swap atomicity, restart
resume, the ``repro.api`` facade grammar, and the SERVE observability row."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import connect, serve
from repro.configs import get_config
from repro.core.gossip import ShardedWeightStore
from repro.core.serialize import NodeUpdate
from repro.core.store import CachingFolder, DiskFolder, InMemoryFolder, RetryFolder, WeightStore, make_folder
from repro.core.telemetry import collect_obs
from repro.models import build_model
from repro.obs import render_dashboard
from repro.serving import ServingNode, StoreWatcher


def _push(store, params, *, counter, node_id="trainer-0"):
    store.push(NodeUpdate(params=params, num_examples=1, node_id=node_id,
                          counter=counter, timestamp=time.time()))


# ---------------------------------------------------------------------------
# bulk prefill == token-at-a-time loop (every decode-path block family)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", [
    "pythia-14m",          # GQA attention
    "mamba2-130m",         # SSM (conv window + chunked scan state)
    "recurrentgemma-9b",   # RG-LRU + windowed attention hybrid
    "minicpm3-4b",         # MLA latent attention
    "gemma-7b",            # sliding window + logit softcap
    "seamless-m4t-medium", # enc-dec (self + cross attention)
])
def test_bulk_prefill_matches_decode_loop(arch):
    from repro.launch.serve import serve_batch, serve_batch_loop
    from repro.models.frontends import stub_audio_frames

    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    prompts = jax.random.randint(rng, (2, 8), 0, cfg.vocab_size, jnp.int32)
    kwargs = {}
    if cfg.is_encdec:
        kwargs["frames"] = stub_audio_frames(rng, cfg, 2, 16)
    fast = serve_batch(cfg, params, prompts, new_tokens=6, **kwargs)
    slow = serve_batch_loop(cfg, params, prompts, new_tokens=6, **kwargs)
    np.testing.assert_array_equal(np.asarray(fast), np.asarray(slow))


# ---------------------------------------------------------------------------
# ServingNode: deploy, hot swap, atomicity, restart resume
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke_cfg():
    return get_config("pythia-14m").reduced()


def test_hot_swap_no_torn_read(smoke_cfg):
    """A swap landing mid-batch must not affect that batch (snapshot
    semantics), and the NEXT batch must run on the new weights."""
    model = build_model(smoke_cfg)
    params_a = model.init(jax.random.PRNGKey(0))
    params_b = jax.tree.map(lambda x: -x, params_a)

    store = WeightStore(InMemoryFolder())
    _push(store, params_a, counter=0)
    node = ServingNode(store, smoke_cfg)  # no watcher thread: manual polls
    assert node.poll_once()
    prompts = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                           smoke_cfg.vocab_size, jnp.int32))

    expected_a, _ = node.generate(prompts, new_tokens=6)

    # deploy B mid-batch via the on_token hook (same thread -> the swap
    # really does complete between decode steps of the in-flight batch)
    def swap_mid_batch(t):
        if t == 2:
            _push(store, params_b, counter=1)
            assert node.poll_once()

    mid, meta = node.generate(prompts, new_tokens=6, on_token=swap_mid_batch)
    assert node.stats()["swaps"] == 2
    assert meta["counter"] == 0  # the batch kept its snapshot
    np.testing.assert_array_equal(mid, expected_a)

    after, meta = node.generate(prompts, new_tokens=6)
    assert meta["counter"] == 1
    from repro.launch.serve import serve_batch

    expected_b = np.asarray(serve_batch(smoke_cfg, params_b, jnp.asarray(prompts),
                                        new_tokens=6))
    np.testing.assert_array_equal(after, expected_b)
    assert not np.array_equal(expected_a, expected_b)


def test_restart_resumes_from_latest(smoke_cfg):
    model = build_model(smoke_cfg)
    params = model.init(jax.random.PRNGKey(0))
    folder = InMemoryFolder()
    _push(WeightStore(folder), params, counter=7)

    node1 = ServingNode(WeightStore(folder), smoke_cfg)
    assert node1.poll_once()
    assert node1.stats()["counter"] == 7

    # a fresh node against the same folder deploys from latest/ with no new
    # pushes — serving restarts are stateless
    node2 = ServingNode(WeightStore(folder), smoke_cfg)
    assert node2.poll_once()
    assert node2.stats()["counter"] == 7
    assert node2.stats()["deployed"]


def test_incompatible_updates_skipped(smoke_cfg):
    other_cfg = get_config("mamba2-130m").reduced()
    other_params = build_model(other_cfg).init(jax.random.PRNGKey(0))
    store = WeightStore(InMemoryFolder())
    _push(store, other_params, counter=3)

    node = ServingNode(store, smoke_cfg)
    assert not node.poll_once()
    assert not node.stats()["deployed"]
    assert node.watcher.skipped_incompatible >= 1
    # incompatible counters still drive the staleness reference
    assert node.watcher.last_max_counter == 3
    with pytest.raises(RuntimeError, match="no weights deployed"):
        node.generate(np.zeros((1, 4), np.int32), new_tokens=2)


def test_watcher_picks_freshest_and_dedups(smoke_cfg):
    model = build_model(smoke_cfg)
    params = model.init(jax.random.PRNGKey(0))
    store = WeightStore(InMemoryFolder())
    _push(store, params, counter=0, node_id="a")
    _push(store, params, counter=5, node_id="b")

    watcher = StoreWatcher(store, spec=ServingNode(store, smoke_cfg).spec)
    dep = watcher.poll()
    assert dep is not None and dep.source == "b" and dep.counter == 5
    assert watcher.poll() is None  # unchanged store -> no redeploy
    _push(store, params, counter=6, node_id="a")
    dep = watcher.poll()
    assert dep is not None and dep.source == "a" and dep.counter == 6


def test_serving_node_rejects_encdec():
    with pytest.raises(ValueError, match="decoder-only"):
        ServingNode(WeightStore(InMemoryFolder()), "seamless-m4t-medium",
                    reduced=True)


def test_stats_keys(smoke_cfg):
    node = ServingNode(WeightStore(InMemoryFolder()), smoke_cfg)
    stats = node.stats()
    for key in ("deployed", "source", "counter", "swaps", "requests", "tokens",
                "tokens_per_sec", "swap_ms_p50", "swap_ms_p99", "swap_ms_max",
                "staleness_mean", "staleness_max", "skipped_incompatible"):
        assert key in stats
    assert not stats["deployed"] and stats["counter"] == -1


# ---------------------------------------------------------------------------
# the repro.api facade
# ---------------------------------------------------------------------------


def test_connect_uri_stage_combinations(tmp_path):
    cases = {
        "memory://t-plain": InMemoryFolder,
        "cache+memory://t-cache": CachingFolder,
        "retry+memory://t-retry": RetryFolder,
        "cache+retry+memory://t-cr": CachingFolder,
        "retry+cache+memory://t-rc": RetryFolder,
        str(tmp_path / "disk"): DiskFolder,
    }
    params = {"w": np.arange(4, dtype=np.float32)}
    for uri, folder_kind in cases.items():
        writer = connect(uri)
        assert isinstance(writer, WeightStore)
        assert isinstance(writer.folder, folder_kind), uri
        _push(writer, params, counter=1)
        # a SECOND connect to the same URI sees the deposit (named memory://
        # shares one process-global folder; disk shares the directory)
        reader = connect(uri)
        updates = reader.pull()
        assert len(updates) == 1 and updates[0].counter == 1, uri
        np.testing.assert_array_equal(updates[0].params["w"], params["w"])


def test_connect_sharded_uris():
    for uri in ("shard2+memory://t-sh2", "shard4x2+memory://t-sh42",
                "shard2+cache+memory://t-shc"):
        store = connect(uri)
        assert isinstance(store, ShardedWeightStore)
    # named memory shares per-group folders across connects: a fleet-wide
    # scan on a SECOND connect sees the first connect's deposit
    a = connect("shard2+memory://t-shared")
    b = connect("shard2+memory://t-shared")
    a.push(NodeUpdate(params={"w": np.ones(3, np.float32)}, num_examples=1,
                      node_id="n0", counter=0, timestamp=time.time()))
    assert any(u.node_id == "n0" for u in b.pull())


def test_connect_validates_and_normalizes():
    with pytest.raises(ValueError):
        connect("shard2+shard2+memory://bad")  # shard must be outermost
    with pytest.raises(ValueError):
        connect("cache+shard2+memory://bad")
    with pytest.raises(ValueError, match="not both"):
        connect("memory://", transport="delta", families=("adapters",))
    with pytest.raises(ValueError):
        connect("memory://", transport="no-such-codec")
    # legacy names and flags still work, mapped to canonical pipeline specs
    for kwargs in ({"transport": "delta_q"}, {"transport": "full"},
                   {"quantized": True}):
        store = connect("memory://", **kwargs)
        _push(store, {"w": np.ones(8, np.float32)}, counter=0)
        assert len(store.pull()) == 1
    # quantized maps uniformly for sharded stores too (no ctor kwarg there)
    assert isinstance(connect("shard2+memory://", quantized=True),
                      ShardedWeightStore)


def test_connect_prefetch_contract():
    store = connect("memory://t-prefetch", prefetch=0.05)
    try:
        assert store._prefetcher is not None
    finally:
        store.stop_prefetch()
    with pytest.raises(ValueError, match="prefetch"):
        connect("shard2+memory://t-pf", prefetch=True)
    sharded = connect("shard2+memory://t-pf2", prefetch=(0.05, "n0"))
    sharded.stop_prefetch()


def test_fleet_spec_connect_uses_facade():
    from repro.core.fleet import FleetSpec

    spec = FleetSpec(store_uri="memory://t-fleet", transport="delta")
    store = spec.connect()
    assert isinstance(store, WeightStore)
    # the spec's transport is the default; an override wins
    assert isinstance(spec.connect(transport="full"), WeightStore)


def test_api_serve_facade(smoke_cfg):
    params = build_model(smoke_cfg).init(jax.random.PRNGKey(0))
    _push(connect("memory://t-serve-facade"), params, counter=0)
    node = serve("memory://t-serve-facade", smoke_cfg, poll_interval=0.02,
                 wait=30.0)
    try:
        assert node.stats()["deployed"]
        out, meta = node.generate(np.zeros((1, 4), np.int32), new_tokens=3)
        assert out.shape == (1, 3) and meta["counter"] == 0
    finally:
        node.stop()


# ---------------------------------------------------------------------------
# SERVE observability row
# ---------------------------------------------------------------------------


def test_serve_row_in_dashboard(smoke_cfg):
    params = build_model(smoke_cfg).init(jax.random.PRNGKey(0))
    uri = "memory://t-serve-obs"
    _push(connect(uri), params, counter=0)

    store = connect(uri)
    node = ServingNode(store, smoke_cfg, telemetry=True, node_id="server-0")
    assert node.poll_once()
    node.generate(np.zeros((2, 4), np.int32), new_tokens=3)
    node.flush_obs()

    rollups = render_dashboard(collect_obs(uri), printer=lambda *_: None)
    assert rollups["nodes"]["server-0"]["role"] == "serve"
    assert rollups["nodes"]["server-0"]["serve"]["swaps"] == 1
    assert rollups["fleet"]["serving_nodes"] == 1

    lines = []
    render_dashboard(collect_obs(uri), printer=lines.append)
    assert any("SERVE" in line for line in lines)
    assert any("server-0" in line for line in lines)
