import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.serialize import (
    NodeUpdate,
    content_hash,
    deserialize_update,
    deserialize_update_quantized,
    serialize_update,
    serialize_update_quantized,
)
from repro.core.store import DiskFolder, InMemoryFolder, WeightStore, make_folder


def params():
    return {
        "dense": {"w": np.random.default_rng(0).normal(size=(8, 4)).astype(np.float32)},
        "scale": np.ones((4,), np.float32),
    }


def bf16_params():
    return {"w": jnp.asarray(np.random.default_rng(1).normal(size=(16,)), jnp.bfloat16)}


def test_update_roundtrip():
    u = NodeUpdate(params(), num_examples=42, node_id="n0", counter=7, timestamp=3.25,
                   metrics={"loss": 1.5})
    u2 = deserialize_update(serialize_update(u))
    assert u2.num_examples == 42 and u2.node_id == "n0" and u2.counter == 7
    assert u2.metrics["loss"] == 1.5
    assert np.allclose(u2.params["dense"]["w"], u.params["dense"]["w"])


def test_bfloat16_roundtrip():
    """bf16 ships as f32 on the wire and is restored on load."""
    u = NodeUpdate(bf16_params(), num_examples=1, node_id="b")
    u2 = deserialize_update(serialize_update(u))
    assert u2.params["w"].dtype == jnp.bfloat16
    assert np.allclose(np.asarray(u2.params["w"], np.float32),
                       np.asarray(u.params["w"], np.float32))


def test_quantized_roundtrip_close():
    u = NodeUpdate(params(), num_examples=1, node_id="q")
    u2 = deserialize_update_quantized(serialize_update_quantized(u))
    w, w2 = u.params["dense"]["w"], u2.params["dense"]["w"]
    assert np.max(np.abs(w - w2)) <= np.abs(w).max() / 127.0 + 1e-6


def test_quantized_is_smaller():
    big = {"w": np.random.default_rng(2).normal(size=(64, 64)).astype(np.float32)}
    u = NodeUpdate(big, num_examples=1, node_id="q")
    assert len(serialize_update_quantized(u)) < 0.5 * len(serialize_update(u))


@pytest.mark.parametrize("folder_factory", [InMemoryFolder, None])
def test_folder_semantics(folder_factory, tmp_path):
    folder = folder_factory() if folder_factory else DiskFolder(str(tmp_path / "store"))
    h0 = folder.state_hash()
    folder.put("latest/a", b"hello")
    h1 = folder.state_hash()
    assert h0 != h1
    assert folder.get("latest/a") == b"hello"
    assert folder.get("latest/missing") is None
    assert folder.keys() == ["latest/a"]
    folder.put("latest/a", b"world")
    assert folder.state_hash() != h1
    folder.delete("latest/a")
    assert folder.keys() == []


def test_weight_store_latest_and_rounds(tmp_path):
    store = WeightStore(DiskFolder(str(tmp_path)), keep_history=True)
    for ctr in range(3):
        store.push(NodeUpdate(params(), num_examples=5, node_id="a", counter=ctr))
    store.push(NodeUpdate(params(), num_examples=9, node_id="b", counter=0))
    assert store.node_ids() == ["a", "b"]
    latest_a = store.pull_node("a")
    assert latest_a.counter == 2
    peers_of_a = store.pull(exclude="a")
    assert [u.node_id for u in peers_of_a] == ["b"]
    round0 = store.pull_round(0)
    assert sorted(u.node_id for u in round0) == ["a", "b"]
    assert [u.node_id for u in store.pull_round(2)] == ["a"]


def test_make_folder_dispatch(tmp_path):
    assert isinstance(make_folder("memory://"), InMemoryFolder)
    assert isinstance(make_folder(str(tmp_path / "x")), DiskFolder)


def test_content_hash_stability():
    blob = serialize_update(NodeUpdate(params(), num_examples=1, node_id="n"))
    assert content_hash(blob) == content_hash(blob)


# --- key round-tripping regressions -----------------------------------------


@pytest.mark.parametrize("node_id", ["a__b", "team/alpha", "pct%id", "dot.dash-_x", "sp ace"])
def test_diskfolder_key_roundtrip_hostile_node_ids(tmp_path, node_id):
    """DiskFolder must round-trip keys whose node id contains '/', '__', '%',
    or spaces (the old '__'-join encoding was lossy)."""
    folder = DiskFolder(str(tmp_path))
    key = f"latest/{node_id}"
    folder.put(key, b"payload")
    assert folder.keys() == [key]
    assert folder.get(key) == b"payload"
    h = folder.state_hash(exclude=key)
    folder.put(key, b"payload2")
    assert folder.state_hash(exclude=key) == h  # exclusion matches the key
    folder.delete(key)
    assert folder.keys() == []


@pytest.mark.parametrize("node_id", ["a__b", "team/alpha", "with__many__unders"])
def test_pull_round_with_hostile_node_ids(tmp_path, node_id):
    """pull_round used to assume history keys split into exactly 3 parts."""
    store = WeightStore(DiskFolder(str(tmp_path)), keep_history=True)
    store.push(NodeUpdate(params(), num_examples=2, node_id=node_id, counter=0))
    store.push(NodeUpdate(params(), num_examples=2, node_id=node_id, counter=1))
    store.push(NodeUpdate(params(), num_examples=5, node_id="plain", counter=0))
    assert store.node_ids() == sorted([node_id, "plain"])
    round0 = store.pull_round(0)
    assert sorted(u.node_id for u in round0) == sorted([node_id, "plain"])
    assert [u.node_id for u in store.pull_round(1)] == [node_id]
    assert [u.node_id for u in store.pull_round(0, exclude=node_id)] == ["plain"]


def test_diskfolder_version_changes_on_overwrite(tmp_path):
    folder = DiskFolder(str(tmp_path))
    assert folder.version("missing") is None
    folder.put("k", b"same-size")
    v1 = folder.version("k")
    folder.put("k", b"same-size")  # same content and size, new write
    v2 = folder.version("k")
    assert v1 is not None and v2 is not None
    assert v1 != v2  # fresh temp-file inode ⇒ version moves even at same mtime


def test_lease_epoch_rides_the_wire_meta():
    """Adopted nodes stamp their lease epoch into updates; decoders read it
    back, and updates predating the field default to epoch 0."""
    u = NodeUpdate(params(), num_examples=3, node_id="adoptee", counter=5,
                   lease_epoch=2)
    out = deserialize_update(serialize_update(u))
    assert out.lease_epoch == 2
    legacy = NodeUpdate(params(), num_examples=3, node_id="n0", counter=5)
    assert deserialize_update(serialize_update(legacy)).lease_epoch == 0


def test_lease_epoch_survives_weight_store_roundtrip(tmp_path):
    store = WeightStore(DiskFolder(str(tmp_path)))
    store.push(NodeUpdate(params(), num_examples=1, node_id="adoptee",
                          counter=1, lease_epoch=3))
    pulled = store.pull_node("adoptee")
    assert pulled is not None and pulled.lease_epoch == 3
