import math

import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core.simulation import simulate_timeline, straggler_speedup


def test_sync_wall_clock_is_sum_of_round_maxima():
    tl = simulate_timeline([[1, 2], [3, 1]], mode="sync")
    assert tl.wall_clock == 3 + 2
    assert tl.per_node_idle[0] == (3 - 1) + 0
    assert tl.per_node_idle[1] == 0 + (2 - 1)


def test_async_wall_clock_is_max_of_sums():
    tl = simulate_timeline([[1, 2], [3, 1]], mode="async")
    assert tl.wall_clock == max(1 + 2, 3 + 1)
    assert all(i == 0 for i in tl.per_node_idle)


@settings(max_examples=40, deadline=None)
@given(
    durations=st.lists(
        st.lists(st.floats(0.1, 10.0), min_size=3, max_size=3), min_size=2, max_size=5
    )
)
def test_async_never_slower_than_sync(durations):
    """Σ_rounds max_k ≥ max_k Σ_rounds — async wall-clock ≤ sync, always."""
    sync = simulate_timeline(durations, mode="sync")
    asyn = simulate_timeline(durations, mode="async")
    assert asyn.wall_clock <= sync.wall_clock + 1e-9


def test_sync_hangs_on_failure_async_does_not():
    durations = [[1, 1, 1], [1, 1, 1]]
    sync = simulate_timeline(durations, mode="sync", failures={1: 1})
    asyn = simulate_timeline(durations, mode="async", failures={1: 1})
    assert math.isinf(sync.wall_clock)
    assert asyn.wall_clock == 3  # the surviving node finishes all its epochs


def test_straggler_speedup_grows_with_variance():
    even = straggler_speedup([[1, 1], [1, 1]])
    # alternating fast/slow: sync pays the max every round
    skewed = straggler_speedup([[1, 3], [3, 1]])
    assert even == pytest.approx(1.0)
    assert skewed > 1.4  # sync 6 vs async 4


def test_federation_events_monotone_visibility():
    tl = simulate_timeline([[1, 1, 1], [2, 2, 2]], mode="async")
    by_node = {}
    for t, node, visible in tl.federation_events:
        assert visible <= 1
        by_node.setdefault(node, []).append(visible)
    # the slow node always sees the fast node's deposits
    assert all(v == 1 for v in by_node[1])
