import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (
    adafactor,
    adam,
    adamw,
    apply_updates,
    chain_clip,
    clip_by_global_norm,
    global_norm,
    sgd,
    warmup_cosine_schedule,
    with_accumulation,
)


def quadratic_losses(optimizer, steps=200, dim=4):
    target = jnp.arange(1.0, dim + 1)
    params = {"w": jnp.zeros((dim,))}
    state = optimizer.init(params)

    def loss_fn(p):
        return jnp.sum((p["w"] - target) ** 2)

    losses = []
    for _ in range(steps):
        loss, grads = jax.value_and_grad(loss_fn)(params)
        updates, state = optimizer.update(grads, state, params)
        params = apply_updates(params, updates)
        losses.append(float(loss))
    return losses


@pytest.mark.parametrize("opt", [
    sgd(0.1), sgd(0.05, momentum=0.9), sgd(0.05, momentum=0.9, nesterov=True),
    adam(0.1), adamw(0.1, weight_decay=0.0), adafactor(0.5),
])
def test_optimizers_converge_on_quadratic(opt):
    losses = quadratic_losses(opt)
    assert losses[-1] < 1e-2 * losses[0], (opt.name, losses[-1])


def test_adamw_decays_weights():
    params = {"w": jnp.ones((4,))}
    opt = adamw(0.1, weight_decay=0.5)
    state = opt.init(params)
    zero_grads = {"w": jnp.zeros((4,))}
    updates, _ = opt.update(zero_grads, state, params)
    assert float(updates["w"][0]) < 0  # pure decay pulls weights down


def test_clip_by_global_norm():
    grads = {"a": jnp.full((4,), 10.0)}
    clipped = clip_by_global_norm(grads, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    small = {"a": jnp.full((4,), 0.01)}
    assert np.allclose(clip_by_global_norm(small, 1.0)["a"], small["a"])


def test_chain_clip_converges():
    losses = quadratic_losses(chain_clip(adam(0.1), 1.0))
    assert losses[-1] < 1e-2 * losses[0]


def test_accumulation_matches_large_batch():
    """K micro-steps with accumulation == one step on the averaged gradient."""
    opt_plain = sgd(0.1)
    opt_acc = with_accumulation(sgd(0.1), 2)
    params = {"w": jnp.ones((3,))}
    g1 = {"w": jnp.asarray([1.0, 0.0, -1.0])}
    g2 = {"w": jnp.asarray([0.0, 2.0, 1.0])}
    mean = {"w": (g1["w"] + g2["w"]) / 2}

    s = opt_acc.init(params)
    u1, s = opt_acc.update(g1, s, params)
    assert np.allclose(u1["w"], 0.0)  # buffered, no update yet
    u2, s = opt_acc.update(g2, s, params)
    ref, _ = opt_plain.update(mean, opt_plain.init(params), params)
    assert np.allclose(u2["w"], ref["w"], atol=1e-6)


def test_warmup_cosine_shape():
    sched = warmup_cosine_schedule(1.0, warmup_steps=10, total_steps=100, min_ratio=0.1)
    assert float(sched(0)) == 0.0
    assert float(sched(10)) == pytest.approx(1.0)
    assert float(sched(100)) == pytest.approx(0.1, abs=1e-3)
    assert float(sched(55)) < float(sched(20))
