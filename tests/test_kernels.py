"""Kernel validation: every Pallas kernel swept over shapes/dtypes in
interpret mode and assert_allclose'd against its pure-jnp ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.fed_agg import ops as fed_ops
from repro.kernels.fed_agg.kernel import fed_agg
from repro.kernels.fed_agg.ref import fed_agg_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref, ssd_scan_sequential


# ---------------------------------------------------------------------------
# fed_agg
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("K,N", [(2, 128), (3, 8192), (8, 8193), (16, 40000), (32, 7)])
def test_fed_agg_shapes(K, N):
    rng = np.random.default_rng(K * 1000 + N)
    x = rng.normal(size=(K, N)).astype(np.float32)
    w = rng.random(K).astype(np.float32)
    w /= w.sum()
    np.testing.assert_allclose(
        np.asarray(fed_agg(jnp.asarray(x), jnp.asarray(w), interpret=True)),
        np.asarray(fed_agg_ref(x, w)), rtol=1e-5, atol=1e-5,
    )


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_fed_agg_pytree_matches_tree_mean(dtype):
    from repro.core.tree import tree_weighted_mean

    rng = np.random.default_rng(0)
    trees = [{"a": rng.normal(size=(17, 3)).astype(dtype), "b": {"c": rng.normal(size=(5,)).astype(dtype)}}
             for _ in range(4)]
    weights = [1, 2, 3, 4]
    out = fed_ops.aggregate_pytrees(trees, weights, force_kernel=True)
    ref = tree_weighted_mean(trees, weights)
    np.testing.assert_allclose(out["a"], ref["a"], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(out["b"]["c"], ref["b"]["c"], rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,S,KV,G,hd,window", [
    (2, 256, 2, 2, 64, 0),
    (1, 256, 1, 4, 64, 128),    # MQA + sliding window
    (2, 512, 4, 1, 128, 0),
    (1, 128, 2, 2, 256, 64),    # gemma-style head_dim 256
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, KV, G, hd, window, dtype):
    rng = jax.random.PRNGKey(S + hd)
    k1, k2, k3 = jax.random.split(rng, 3)
    q = jax.random.normal(k1, (B, S, KV, G, hd), dtype)
    k = jax.random.normal(k2, (B, S, KV, hd), dtype)
    v = jax.random.normal(k3, (B, S, KV, hd), dtype)
    bq = min(128, S)
    out = flash_attention(q, k, v, window=window, block_q=bq, block_k=bq, interpret=True)
    ref = flash_attention_ref(q, k, v, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


def test_flash_matches_model_attention():
    """Kernel agrees with the model's own chunked_sdpa path."""
    from repro.models.attention import chunked_sdpa

    rng = jax.random.PRNGKey(9)
    k1, k2, k3 = jax.random.split(rng, 3)
    B, S, KV, G, hd = 1, 256, 2, 3, 64
    q = jax.random.normal(k1, (B, S, KV, G, hd))
    k = jax.random.normal(k2, (B, S, KV, hd))
    v = jax.random.normal(k3, (B, S, KV, hd))
    out = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    ref = chunked_sdpa(q, k, v, causal=True, qblock=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("BH,S,P,N,chunk", [
    (2, 128, 64, 32, 32),
    (3, 256, 64, 128, 64),
    (1, 512, 128, 64, 128),
    (2, 256, 64, 128, 256),     # single chunk
])
def test_ssd_scan_vs_oracles(BH, S, P, N, chunk):
    rng = jax.random.PRNGKey(BH * S)
    ks = jax.random.split(rng, 4)
    x = jax.random.normal(ks[0], (BH, S, P)) * 0.5
    dA = -jax.nn.softplus(jax.random.normal(ks[1], (BH, S)))
    Bm = jax.random.normal(ks[2], (BH, S, N)) * 0.5
    Cm = jax.random.normal(ks[3], (BH, S, N)) * 0.5
    out = np.asarray(ssd_scan(x, dA, Bm, Cm, chunk=chunk, interpret=True))
    np.testing.assert_allclose(out, np.asarray(ssd_scan_ref(x, dA, Bm, Cm, chunk=chunk)),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(out, np.asarray(ssd_scan_sequential(x, dA, Bm, Cm)),
                               rtol=1e-3, atol=1e-3)


def test_ssd_ops_matches_model_layout():
    from repro.kernels.ssd_scan import ops as ssd_ops
    from repro.models.ssm import ssd_chunked

    rng = jax.random.PRNGKey(5)
    ks = jax.random.split(rng, 4)
    B, S, H, P, N = 2, 128, 3, 64, 32
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dA = -jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    Bm = jax.random.normal(ks[2], (B, S, H, N)) * 0.5
    Cm = jax.random.normal(ks[3], (B, S, H, N)) * 0.5
    out = ssd_ops.ssd(x, dA, Bm, Cm, chunk=64, force_kernel=True)
    ref, _ = ssd_chunked(x, dA, Bm, Cm, 64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,KV,G,hd,C", [
    (2, 2, 4, 64, 1024),
    (1, 8, 1, 128, 512),
    (3, 1, 6, 64, 2048),
    (1, 2, 2, 256, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, KV, G, hd, C, dtype):
    rng = jax.random.PRNGKey(C + hd)
    ks = jax.random.split(rng, 4)
    q = jax.random.normal(ks[0], (B, KV, G, hd), dtype)
    k = jax.random.normal(ks[1], (B, C, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, C, KV, hd), dtype)
    valid = jax.random.bernoulli(ks[3], 0.7, (C,))
    out = decode_attention(q, k, v, valid, block_k=min(256, C), interpret=True)
    ref = decode_attention_ref(q, k, v, valid)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


def test_decode_attention_in_model_path():
    """attn_decode(use_kernel=True) == jnp path on a real ring cache."""
    from repro.configs import get_config
    from repro.models import attention as A

    cfg = get_config("granite-3-2b").reduced()
    rng = jax.random.PRNGKey(7)
    p = A.init_attention(rng, cfg)
    x = jax.random.normal(rng, (2, 1, cfg.d_model), cfg.jdtype)
    cache = A.init_attn_cache(cfg, 2, 16)
    out_ref, cache_ref = A.attn_decode(p, cfg, x, cache, jnp.int32(0))
    out_k, _ = A.attn_decode(p, cfg, x, cache, jnp.int32(0), use_kernel=True)
    np.testing.assert_allclose(np.asarray(out_k, np.float32), np.asarray(out_ref, np.float32),
                               rtol=2e-2, atol=2e-2)
