"""Kernel validation: every Pallas kernel swept over shapes/dtypes in
interpret mode and assert_allclose'd against its pure-jnp ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.fed_agg import ops as fed_ops
from repro.kernels.fed_agg.kernel import fed_agg
from repro.kernels.fed_agg.ref import fed_agg_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref, ssd_scan_sequential


# ---------------------------------------------------------------------------
# fed_agg
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("K,N", [(2, 128), (3, 8192), (8, 8193), (16, 40000), (32, 7)])
def test_fed_agg_shapes(K, N):
    rng = np.random.default_rng(K * 1000 + N)
    x = rng.normal(size=(K, N)).astype(np.float32)
    w = rng.random(K).astype(np.float32)
    w /= w.sum()
    np.testing.assert_allclose(
        np.asarray(fed_agg(jnp.asarray(x), jnp.asarray(w), interpret=True)),
        np.asarray(fed_agg_ref(x, w)), rtol=1e-5, atol=1e-5,
    )


@pytest.mark.parametrize("K,N", [(65, 8192), (130, 8193), (200, 4000)])
def test_fed_agg_k_tiled_streaming(K, N):
    """Fleets wider than BK stream the client axis in (BK, BN) stripes with
    on-chip accumulation — must match the one-shot einsum."""
    from repro.kernels.fed_agg.kernel import BK

    assert K > BK
    rng = np.random.default_rng(K + N)
    x = rng.normal(size=(K, N)).astype(np.float32)
    w = rng.random(K).astype(np.float32)
    w /= w.sum()
    np.testing.assert_allclose(
        np.asarray(fed_agg(jnp.asarray(x), jnp.asarray(w), interpret=True)),
        np.asarray(fed_agg_ref(x, w)), rtol=1e-4, atol=1e-5,
    )


@pytest.mark.parametrize("variant", ["adam", "yogi", "adagrad"])
@pytest.mark.parametrize("K,N", [(3, 4096), (5, 8193)])
def test_fed_opt_fused_matches_ref(variant, K, N):
    """The fused pseudo-gradient+moment kernel ≡ the unfused jnp chain."""
    from repro.kernels.fed_agg.kernel import fed_opt
    from repro.kernels.fed_agg.ref import fed_opt_ref

    rng = np.random.default_rng(N + ord(variant[0]))
    x = rng.normal(size=(K, N)).astype(np.float32)
    w = rng.random(K).astype(np.float32)
    w /= w.sum()
    p = rng.normal(size=(N,)).astype(np.float32)
    m = rng.normal(size=(N,)).astype(np.float32) * 0.1
    v = np.abs(rng.normal(size=(N,))).astype(np.float32) * 0.01
    hp = dict(lr=0.3, b1=0.9, b2=0.95, tau=1e-2, variant=variant)
    got = fed_opt(jnp.asarray(x), jnp.asarray(w), jnp.asarray(p),
                  jnp.asarray(m), jnp.asarray(v), interpret=True, **hp)
    want = fed_opt_ref(x, w, p, m, v, **hp)
    for g, r, name in zip(got, want, ("x", "m", "v")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   rtol=1e-5, atol=1e-5, err_msg=f"{variant}/{name}")


def test_fed_opt_wide_fleet_streams_client_axis():
    """K > BK takes the two-pass route (K-streaming fed_agg + fused apply);
    results must still match the one-shot reference."""
    from repro.kernels.fed_agg.kernel import BK, fed_opt
    from repro.kernels.fed_agg.ref import fed_opt_ref

    K, N = BK + 33, 4097
    rng = np.random.default_rng(K)
    x = rng.normal(size=(K, N)).astype(np.float32)
    w = rng.random(K).astype(np.float32)
    w /= w.sum()
    p = rng.normal(size=(N,)).astype(np.float32)
    m = np.zeros((N,), np.float32)
    v = np.zeros((N,), np.float32)
    hp = dict(lr=0.5, b1=0.9, b2=0.99, tau=1e-2, variant="yogi")
    got = fed_opt(jnp.asarray(x), jnp.asarray(w), jnp.asarray(p),
                  jnp.asarray(m), jnp.asarray(v), interpret=True, **hp)
    want = fed_opt_ref(x, w, p, m, v, **hp)
    for g, r in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   rtol=1e-4, atol=1e-5)


def test_fed_opt_multi_step_stateful_matches_ref():
    """Chained fed_opt calls (state threaded through) track the reference over
    several rounds — the usage pattern of FedAdam(use_kernel=True)."""
    from repro.kernels.fed_agg import ops as fed_ops
    from repro.kernels.fed_agg.ref import fed_opt_ref

    rng = np.random.default_rng(0)
    K, N = 4, 1000
    w = np.full((K,), 1.0 / K, np.float32)
    x_k = x_r = rng.normal(size=(N,)).astype(np.float32)
    m_k = m_r = np.zeros((N,), np.float32)
    v_k = v_r = np.zeros((N,), np.float32)
    hp = dict(variant="adam", server_lr=0.5, beta1=0.9, beta2=0.99, tau=1e-2)
    for step in range(4):
        stacked = rng.normal(size=(K, N)).astype(np.float32)
        x_k, m_k, v_k = fed_ops.fed_opt_flat(stacked, w, x_k, m_k, v_k,
                                             force_kernel=True, **hp)
        x_r, m_r, v_r = (np.asarray(a) for a in fed_opt_ref(
            jnp.asarray(stacked), jnp.asarray(w), jnp.asarray(x_r),
            jnp.asarray(m_r), jnp.asarray(v_r),
            lr=hp["server_lr"], b1=hp["beta1"], b2=hp["beta2"],
            tau=hp["tau"], variant="adam"))
        np.testing.assert_allclose(x_k, x_r, rtol=1e-4, atol=1e-5,
                                   err_msg=f"step {step}")


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_fed_agg_pytree_matches_tree_mean(dtype):
    from repro.core.tree import tree_weighted_mean

    rng = np.random.default_rng(0)
    trees = [{"a": rng.normal(size=(17, 3)).astype(dtype), "b": {"c": rng.normal(size=(5,)).astype(dtype)}}
             for _ in range(4)]
    weights = [1, 2, 3, 4]
    out = fed_ops.aggregate_pytrees(trees, weights, force_kernel=True)
    ref = tree_weighted_mean(trees, weights)
    np.testing.assert_allclose(out["a"], ref["a"], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(out["b"]["c"], ref["b"]["c"], rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,S,KV,G,hd,window", [
    (2, 256, 2, 2, 64, 0),
    (1, 256, 1, 4, 64, 128),    # MQA + sliding window
    (2, 512, 4, 1, 128, 0),
    (1, 128, 2, 2, 256, 64),    # gemma-style head_dim 256
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, KV, G, hd, window, dtype):
    rng = jax.random.PRNGKey(S + hd)
    k1, k2, k3 = jax.random.split(rng, 3)
    q = jax.random.normal(k1, (B, S, KV, G, hd), dtype)
    k = jax.random.normal(k2, (B, S, KV, hd), dtype)
    v = jax.random.normal(k3, (B, S, KV, hd), dtype)
    bq = min(128, S)
    out = flash_attention(q, k, v, window=window, block_q=bq, block_k=bq, interpret=True)
    ref = flash_attention_ref(q, k, v, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


def test_flash_matches_model_attention():
    """Kernel agrees with the model's own chunked_sdpa path."""
    from repro.models.attention import chunked_sdpa

    rng = jax.random.PRNGKey(9)
    k1, k2, k3 = jax.random.split(rng, 3)
    B, S, KV, G, hd = 1, 256, 2, 3, 64
    q = jax.random.normal(k1, (B, S, KV, G, hd))
    k = jax.random.normal(k2, (B, S, KV, hd))
    v = jax.random.normal(k3, (B, S, KV, hd))
    out = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    ref = chunked_sdpa(q, k, v, causal=True, qblock=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("BH,S,P,N,chunk", [
    (2, 128, 64, 32, 32),
    (3, 256, 64, 128, 64),
    (1, 512, 128, 64, 128),
    (2, 256, 64, 128, 256),     # single chunk
])
def test_ssd_scan_vs_oracles(BH, S, P, N, chunk):
    rng = jax.random.PRNGKey(BH * S)
    ks = jax.random.split(rng, 4)
    x = jax.random.normal(ks[0], (BH, S, P)) * 0.5
    dA = -jax.nn.softplus(jax.random.normal(ks[1], (BH, S)))
    Bm = jax.random.normal(ks[2], (BH, S, N)) * 0.5
    Cm = jax.random.normal(ks[3], (BH, S, N)) * 0.5
    out = np.asarray(ssd_scan(x, dA, Bm, Cm, chunk=chunk, interpret=True))
    np.testing.assert_allclose(out, np.asarray(ssd_scan_ref(x, dA, Bm, Cm, chunk=chunk)),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(out, np.asarray(ssd_scan_sequential(x, dA, Bm, Cm)),
                               rtol=1e-3, atol=1e-3)


def test_ssd_ops_matches_model_layout():
    from repro.kernels.ssd_scan import ops as ssd_ops
    from repro.models.ssm import ssd_chunked

    rng = jax.random.PRNGKey(5)
    ks = jax.random.split(rng, 4)
    B, S, H, P, N = 2, 128, 3, 64, 32
    x = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    dA = -jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    Bm = jax.random.normal(ks[2], (B, S, H, N)) * 0.5
    Cm = jax.random.normal(ks[3], (B, S, H, N)) * 0.5
    out = ssd_ops.ssd(x, dA, Bm, Cm, chunk=64, force_kernel=True)
    ref, _ = ssd_chunked(x, dA, Bm, Cm, 64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,KV,G,hd,C", [
    (2, 2, 4, 64, 1024),
    (1, 8, 1, 128, 512),
    (3, 1, 6, 64, 2048),
    (1, 2, 2, 256, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, KV, G, hd, C, dtype):
    rng = jax.random.PRNGKey(C + hd)
    ks = jax.random.split(rng, 4)
    q = jax.random.normal(ks[0], (B, KV, G, hd), dtype)
    k = jax.random.normal(ks[1], (B, C, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, C, KV, hd), dtype)
    valid = jax.random.bernoulli(ks[3], 0.7, (C,))
    out = decode_attention(q, k, v, valid, block_k=min(256, C), interpret=True)
    ref = decode_attention_ref(q, k, v, valid)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


def test_decode_attention_in_model_path():
    """attn_decode(use_kernel=True) == jnp path on a real ring cache."""
    from repro.configs import get_config
    from repro.models import attention as A

    cfg = get_config("granite-3-2b").reduced()
    rng = jax.random.PRNGKey(7)
    p = A.init_attention(rng, cfg)
    x = jax.random.normal(rng, (2, 1, cfg.d_model), cfg.jdtype)
    cache = A.init_attn_cache(cfg, 2, 16)
    out_ref, cache_ref = A.attn_decode(p, cfg, x, cache, jnp.int32(0))
    out_k, _ = A.attn_decode(p, cfg, x, cache, jnp.int32(0), use_kernel=True)
    np.testing.assert_allclose(np.asarray(out_k, np.float32), np.asarray(out_ref, np.float32),
                               rtol=2e-2, atol=2e-2)
