import numpy as np

from repro.data import (
    batch_iterator,
    lm_batch_iterator,
    make_synthetic_cifar,
    make_synthetic_mnist,
    make_synthetic_wikitext,
)


def test_synthetic_mnist_shapes_and_determinism():
    d1 = make_synthetic_mnist(num_train=200, num_test=50, seed=3)
    d2 = make_synthetic_mnist(num_train=200, num_test=50, seed=3)
    assert d1.x_train.shape == (200, 28, 28, 1)
    assert d1.num_classes == 10
    assert np.array_equal(d1.x_train, d2.x_train)
    assert set(np.unique(d1.y_train)) <= set(range(10))


def test_synthetic_cifar_shapes():
    d = make_synthetic_cifar(num_train=100, num_test=20)
    assert d.x_train.shape == (100, 32, 32, 3)


def test_synthetic_classes_are_separable():
    """Class structure must be learnable: nearest-prototype beats chance."""
    d = make_synthetic_mnist(num_train=2000, num_test=400, seed=0)
    protos = np.stack([d.x_train[d.y_train == c].mean(0) for c in range(10)])
    dists = ((d.x_test[:, None] - protos[None]) ** 2).sum(axis=(2, 3, 4))
    acc = (dists.argmin(1) == d.y_test).mean()
    assert acc > 0.5, acc


def test_wikitext_stream_has_structure():
    """Order-2 Markov stream: bigram-conditional entropy ≪ vocab entropy."""
    d = make_synthetic_wikitext(vocab_size=64, train_tokens=20000, branching=3)
    t = d.train_tokens
    assert t.min() >= 0 and t.max() < 64
    # top-1 successor frequency per bigram should dominate
    from collections import Counter, defaultdict

    succ = defaultdict(Counter)
    for i in range(len(t) - 2):
        succ[(t[i], t[i + 1])][t[i + 2]] += 1
    top1 = np.mean([c.most_common(1)[0][1] / sum(c.values())
                    for c in succ.values() if sum(c.values()) >= 5])
    assert top1 > 0.45, top1  # Zipf over 3 branches → ~0.55 expected


def test_batch_iterator_epoch_reshuffles():
    x = np.arange(64)[:, None].astype(np.float32)
    y = np.arange(64) % 4
    b0 = next(iter(batch_iterator(x, y, batch_size=16, seed=1, epoch=0)))
    b1 = next(iter(batch_iterator(x, y, batch_size=16, seed=1, epoch=1)))
    assert not np.array_equal(b0["x"], b1["x"])
    again = next(iter(batch_iterator(x, y, batch_size=16, seed=1, epoch=0)))
    assert np.array_equal(b0["x"], again["x"])


def test_lm_batch_iterator_targets_shifted():
    tokens = np.arange(1000, dtype=np.int32)
    batch = next(iter(lm_batch_iterator(tokens, batch_size=4, seq_len=16, seed=0)))
    assert batch["tokens"].shape == (4, 16)
    assert np.array_equal(batch["labels"][:, :-1], batch["tokens"][:, 1:])
