"""Flat-vector hot path ≡ per-leaf tree path.

The PR-3 contract: every strategy executed vectorized over stacked flats
matches the PR-2 per-leaf reference (``strategies_ref``) within 1e-6 over
multi-round *stateful* sequences (momentum/moment buffers, FedBuff buffering,
FedAsync staleness), the store's flat decode reproduces the tree decode
bitwise for every transport (full/quantized/delta/delta_q/topk), and
flat↔tree round-trips preserve mixed-dtype pytrees exactly.
"""
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import (
    CachingFolder,
    DiskFolder,
    FlatUpdate,
    InMemoryFolder,
    LeafSpec,
    NodeUpdate,
    WeightStore,
)
from repro.core.serialize import (
    content_hash,
    decode_params_flat,
    deserialize_update,
    deserialize_update_delta,
    deserialize_update_quantized,
    peek_meta,
    serialize_update,
)
from repro.core.strategies import STRATEGIES, FedAvg, FedAvgM, get_strategy
from repro.core.strategies_ref import REF_STRATEGIES, get_ref_strategy


def tree_of(vals, shift=0.0):
    """A small multi-leaf nested model, deterministic in (vals, shift)."""
    rng = np.random.default_rng(int(abs(vals[0]) * 1000) % 2**31)
    return {
        "enc": {
            "w": (np.linspace(-1, 1, 12, dtype=np.float32).reshape(4, 3)
                  * np.float32(vals[0]) + np.float32(shift)),
            "b": np.full((3,), np.float32(vals[1] % 3.0)),
        },
        "head": (rng.normal(size=(5,)).astype(np.float32) * np.float32(0.1)
                 + np.float32(vals[1])),
    }


def pair(vals, *, n=10, node="x", counter=0, spec=None):
    """(tree NodeUpdate, FlatUpdate) with identical content — the tree one
    feeds the reference path, the flat one the vectorized path."""
    params = tree_of(vals)
    tree_u = NodeUpdate(params, num_examples=n, node_id=node, counter=counter)
    spec = spec or LeafSpec.of(params)
    flat_u = FlatUpdate(spec.flatten(params), spec,
                        num_examples=n, node_id=node, counter=counter)
    return tree_u, flat_u, spec


STRATEGY_KWARGS = {
    "fedavg": {},
    "fedavgm": dict(server_lr=0.7, momentum=0.85),
    "fedadam": dict(server_lr=0.3, tau=0.05),
    "fedyogi": dict(server_lr=0.3, tau=0.05),
    "fedadagrad": dict(server_lr=0.3, tau=0.05),
    "fedasync": dict(alpha=0.55, staleness_fn="poly", a=0.6),
    "fedbuff": dict(buffer_size=2),
    "partial_fedavg": dict(shared_pattern=r"^enc/"),
}


@settings(max_examples=8, deadline=None)
@given(
    rounds=st.lists(st.lists(st.floats(-2, 2), min_size=2, max_size=8),
                    min_size=3, max_size=5),
    ns=st.lists(st.integers(1, 50), min_size=8, max_size=8),
    lags=st.lists(st.integers(0, 6), min_size=8, max_size=8),
)
def test_every_strategy_flat_matches_tree_over_stateful_rounds(rounds, ns, lags):
    """Multi-round equivalence: the SAME strategy instance carries its state
    (momentum buffers, FedBuff buffer, FedAsync staleness) across rounds on
    both paths; results must stay within 1e-6 at every round."""
    assert sorted(STRATEGIES) == sorted(REF_STRATEGIES)
    for name in sorted(STRATEGIES):
        flat_strat = get_strategy(name, **STRATEGY_KWARGS[name])
        ref_strat = get_ref_strategy(name, **STRATEGY_KWARGS[name])
        spec = None
        for r, vals in enumerate(rounds):
            own_vals, peer_vals = vals[:2], vals[2:]
            own_t, own_f, spec = pair(own_vals, n=ns[0], node="me",
                                      counter=r + 6, spec=spec)
            peers_t, peers_f = [], []
            for i in range(0, len(peer_vals), 2):
                pv = peer_vals[i:i + 2]
                if len(pv) < 2:
                    pv = [pv[0], 0.5]
                j = i // 2
                pt, pf, spec = pair(pv, n=ns[1 + j], node=f"p{j}",
                                    counter=max(0, r + 6 - lags[j]), spec=spec)
                peers_t.append(pt)
                peers_f.append(pf)
            out_ref = ref_strat.aggregate(own_t, peers_t)
            out_flat = flat_strat.aggregate(own_f, peers_f)
            for leaf_path in (("enc", "w"), ("enc", "b"), ("head",)):
                a, b = out_flat, out_ref
                for k in leaf_path:
                    a, b = a[k], b[k]
                np.testing.assert_allclose(
                    np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6,
                    err_msg=f"{name} diverged at round {r}, leaf {leaf_path}")


def test_flat_strategies_accept_plain_tree_updates():
    """No store in the loop: strategies build their own spec from tree-only
    NodeUpdates and still agree with the reference."""
    own = NodeUpdate(tree_of([1.0, 2.0]), num_examples=3, node_id="a")
    peer = NodeUpdate(tree_of([0.5, -1.0]), num_examples=9, node_id="b")
    out = FedAvg().aggregate(own, [peer])
    ref = get_ref_strategy("fedavg").aggregate(own, [peer])
    np.testing.assert_allclose(out["enc"]["w"], ref["enc"]["w"], rtol=1e-6, atol=1e-6)
    assert out["head"].dtype == np.float32


def test_use_kernel_is_plumbed_through_every_strategy(monkeypatch):
    """Satellite regression: FedAvgM/_FedOpt used to drop use_kernel on the
    floor. Now every strategy's combine routes through the kernel ops when
    asked — observed by counting aggregate_flat/fed_opt_flat calls."""
    from repro.kernels.fed_agg import ops as fed_ops

    calls = {"n": 0}
    real_agg, real_opt = fed_ops.aggregate_flat, fed_ops.fed_opt_flat

    def spy_agg(*a, **k):
        calls["n"] += 1
        return real_agg(*a, **k)

    def spy_opt(*a, **k):
        calls["n"] += 1
        return real_opt(*a, **k)

    monkeypatch.setattr(fed_ops, "aggregate_flat", spy_agg)
    monkeypatch.setattr(fed_ops, "fed_opt_flat", spy_opt)
    for name in sorted(STRATEGIES):
        kwargs = dict(STRATEGY_KWARGS[name], use_kernel=True)
        if name == "fedbuff":
            kwargs["buffer_size"] = 1
        strat = get_strategy(name, **kwargs)
        before = calls["n"]
        own, own_f, spec = pair([1.0, 0.5], node="me", counter=3)
        _, p0, spec = pair([0.2, -0.3], node="p0", counter=2, spec=spec)
        strat.aggregate(own_f, [p0])
        assert calls["n"] > before, f"{name} never reached the kernel ops"


def test_kernel_and_plain_flat_paths_agree():
    for name in sorted(STRATEGIES):
        plain = get_strategy(name, **STRATEGY_KWARGS[name])
        kern = get_strategy(name, **dict(STRATEGY_KWARGS[name], use_kernel=True))
        own_t, own_f, spec = pair([1.5, -0.5], node="me", counter=4)
        _, p0, spec = pair([0.3, 0.9], node="p0", counter=3, spec=spec)
        _, p1, spec = pair([-1.1, 0.1], node="p1", counter=1, spec=spec)
        a = plain.aggregate(own_f, [p0, p1])
        b = kern.aggregate(own_f, [p0, p1])
        np.testing.assert_allclose(a["enc"]["w"], b["enc"]["w"],
                                   rtol=1e-5, atol=1e-5, err_msg=name)


# --- flat ↔ tree round-trips -------------------------------------------------


def test_leafspec_roundtrip_mixed_dtypes():
    """bf16 / f16 / int32 / f32 leaves all survive flatten→unflatten exactly
    (ints small enough to embed in f32 — the store refuses the rest)."""
    tree = {
        "w32": np.linspace(-3, 3, 8, dtype=np.float32).reshape(2, 4),
        "h": {"w16": np.linspace(-1, 1, 6, dtype=np.float16),
              "steps": np.arange(5, dtype=np.int32)},
        "wb": jnp.asarray(np.linspace(-2, 2, 7), jnp.bfloat16),
    }
    spec = LeafSpec.of(tree)
    assert spec.num_params == 8 + 6 + 5 + 7
    out = spec.unflatten(spec.flatten(tree))
    assert out["w32"].dtype == np.float32 and out["w32"].shape == (2, 4)
    assert out["h"]["w16"].dtype == np.float16
    assert out["h"]["steps"].dtype == np.int32
    assert out["wb"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(out["w32"], tree["w32"])
    np.testing.assert_array_equal(out["h"]["w16"], tree["h"]["w16"])
    np.testing.assert_array_equal(out["h"]["steps"], np.asarray(tree["h"]["steps"]))
    np.testing.assert_array_equal(np.asarray(out["wb"], np.float32),
                                  np.asarray(tree["wb"], np.float32))
    # shared layout: a second tree of the same structure reuses the spec
    assert spec.describes(out) and spec.f32_exact is False  # int leaf


@settings(max_examples=15, deadline=None)
@given(vals=st.lists(st.floats(-4, 4), min_size=2, max_size=2))
def test_leafspec_flatten_matches_wire_decode(vals):
    """spec.flatten(tree) == the flat vector the store decodes from that
    tree's wire blob — the invariant the topk writer's error feedback rests
    on."""
    params = tree_of(vals)
    spec = LeafSpec.of(params)
    blob = serialize_update(NodeUpdate(params, num_examples=1, node_id="n"))
    wire_spec, flat, _meta = decode_params_flat(blob, {})
    assert wire_spec.key == spec.key
    np.testing.assert_array_equal(flat, spec.flatten(params))


def test_leafspec_shared_identity_across_store_pulls():
    """Stacked-flat pulls: every FlatUpdate a store returns for one model
    shares ONE spec instance, and unchanged peers' flats are the same array
    object across pulls (zero-copy steady state for the stack cache)."""
    store = WeightStore(InMemoryFolder())
    for i in range(3):
        store.push(NodeUpdate(tree_of([1.0 + i, -i * 0.5]), num_examples=1,
                              node_id=f"n{i}", counter=0))
    first = store.pull()
    assert len(first) == 3
    assert all(isinstance(u, FlatUpdate) for u in first)
    specs = {id(u.spec) for u in first}
    assert len(specs) == 1, "peers of one model must share a spec instance"
    again = store.pull()
    for a, b in zip(first, again):
        assert a.flat is b.flat  # decode-cache hit: identical array object


# --- transport equivalence: flat decode ≡ tree decode, bitwise ---------------


def _run_store(tmp_path, transport, rounds=6, **kw):
    folder = DiskFolder(str(tmp_path / transport))
    store = WeightStore(folder, transport=transport, rebase_every=3, **kw)
    rng = np.random.default_rng(7)
    params = tree_of([1.0, 0.5])
    history = []
    for ctr in range(rounds):
        # sparse local step: the regime delta/topk transports are for
        flat_view = np.concatenate([params["enc"]["w"].ravel(),
                                    params["enc"]["b"], params["head"]])
        idx = rng.choice(flat_view.size, size=3, replace=False)
        flat_view[idx] += rng.normal(size=3).astype(np.float32)
        w = flat_view[:12].reshape(4, 3).copy()
        params = {"enc": {"w": w, "b": flat_view[12:15].copy()},
                  "head": flat_view[15:].copy()}
        store.push(NodeUpdate(params, num_examples=1, node_id="n", counter=ctr))
        history.append(params)
    return folder, store, history


@pytest.mark.parametrize("transport", ["full", "quantized", "delta", "delta_q", "topk"])
def test_flat_decode_matches_tree_decode_bitwise(tmp_path, transport):
    """For every transport: a fresh reader's flat-path pull reconstructs the
    byte-identical params the per-leaf tree decode of the same blobs yields."""
    folder, _writer, _history = _run_store(tmp_path, transport)
    reader = WeightStore(folder)
    pulled = reader.pull_node("n")
    assert isinstance(pulled, FlatUpdate)
    # decode the very same blobs through the PR-2 per-leaf path
    blob = folder.get("latest/n")
    meta = peek_meta(blob)
    if meta.get("delta_of"):
        base_blob = folder.get(f"base/n/{meta['delta_of']}")
        assert content_hash(base_blob) == meta["delta_of"]
        ref = deserialize_update_delta(blob, deserialize_update(base_blob).params)
    elif meta.get("quantized"):
        ref = deserialize_update_quantized(blob)
    else:
        ref = deserialize_update(blob)
    for path in (("enc", "w"), ("enc", "b"), ("head",)):
        a, b = pulled.params, ref.params
        for k in path:
            a, b = a[k], b[k]
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{transport} leaf {path}")
    assert (pulled.counter, pulled.num_examples) == (ref.counter, ref.num_examples)


def test_lossless_transports_reproduce_pushed_params_exactly(tmp_path):
    for transport in ("full", "delta"):
        folder, _store, history = _run_store(tmp_path, transport)
        pulled = WeightStore(folder).pull_node("n")
        np.testing.assert_array_equal(pulled.params["enc"]["w"], history[-1]["enc"]["w"])
        np.testing.assert_array_equal(pulled.params["head"], history[-1]["head"])


def test_int_params_fall_back_to_tree_decode_losslessly():
    """Leaves that don't embed in f32 must NOT go flat: a big int64 value
    survives the store bit-exactly via the tree fallback."""
    store = WeightStore(InMemoryFolder())
    big = np.asarray([2**40 + 3, 7], np.int64)
    store.push(NodeUpdate({"ids": big, "w": np.ones((2,), np.float32)},
                          num_examples=1, node_id="n", counter=0))
    pulled = store.pull_node("n")
    assert not isinstance(pulled, FlatUpdate)
    np.testing.assert_array_equal(pulled.params["ids"], big)
    # and strategies still aggregate such updates (via spec.flatten fallback)
    out = FedAvg().aggregate(pulled, [pulled])
    assert out["w"].shape == (2,)


# --- top-k / error feedback ---------------------------------------------------


def big_tree(fill) -> dict:
    """Large enough that npz container overhead never trips the writer's
    'delta must actually be smaller than a full deposit' rebase guard."""
    return {"w": np.full((64, 64), np.float32(fill)),
            "b": np.linspace(-1, 1, 512, dtype=np.float32) * np.float32(fill)}


def test_topk_error_feedback_drains_residual():
    """Pushing the SAME params repeatedly must converge the readers' view to
    those params exactly: each push ships the top-k of what is still unsent,
    so the residual drains to zero within ~1/fraction pushes."""
    store = WeightStore(InMemoryFolder(), transport="topk", topk_fraction=0.25,
                        rebase_every=100)
    store.push(NodeUpdate(big_tree(1.0), num_examples=1, node_id="n", counter=0))
    target = big_tree(-2.0)  # every entry differs from base
    for ctr in range(1, 7):  # ceil(1/0.25) + slack
        store.push(NodeUpdate(target, num_examples=1, node_id="n", counter=ctr))
    pulled = WeightStore(store.folder).pull_node("n")
    np.testing.assert_array_equal(pulled.params["w"], target["w"])
    np.testing.assert_array_equal(pulled.params["b"], target["b"])


def test_topk_ships_bounded_updates_and_reader_progresses():
    """Each non-rebase push ships ≤ k new entries; intermediate reader views
    move monotonically toward the target (lossy but convergent)."""
    N = 4096
    k = int(0.01 * N)
    store = WeightStore(InMemoryFolder(), transport="topk", topk_fraction=0.01,
                        rebase_every=100)
    store.push(NodeUpdate({"w": np.zeros((N,), np.float32)}, num_examples=1,
                          node_id="n", counter=0))
    target = {"w": np.linspace(1, 2, N).astype(np.float32)}
    errs = []
    reader = WeightStore(store.folder)
    for ctr in range(1, 5):
        store.push(NodeUpdate(target, num_examples=1, node_id="n", counter=ctr))
        pulled = reader.pull_node("n")
        errs.append(float(np.abs(pulled.params["w"] - target["w"]).sum()))
        changed = int(np.count_nonzero(pulled.params["w"]))
        assert 0 < changed <= k * ctr
    assert errs == sorted(errs, reverse=True)
    assert errs[0] > errs[-1]


def test_topk_blobs_are_smaller_than_full():
    store = WeightStore(InMemoryFolder(), transport="topk", topk_fraction=0.01,
                        rebase_every=100)
    store.push(NodeUpdate(big_tree(1.0), num_examples=1, node_id="n", counter=0))
    store.push(NodeUpdate(big_tree(1.5), num_examples=1, node_id="n", counter=1))
    blob = store.folder.get("latest/n")
    assert peek_meta(blob)["delta_of"]
    full = store.folder.get(f"base/n/{peek_meta(blob)['delta_of']}")
    assert len(blob) < 0.5 * len(full)


# --- compressed wire envelope -------------------------------------------------


def test_npz_compressed_envelope_roundtrips_and_counts_bytes(tmp_path):
    compressible = {"w": np.zeros((4096,), np.float32),
                    "b": np.ones((64,), np.float32)}
    plain = WeightStore(DiskFolder(str(tmp_path / "plain")))
    packed = WeightStore(DiskFolder(str(tmp_path / "packed")), compress="npz")
    u = NodeUpdate(compressible, num_examples=1, node_id="n", counter=0)
    plain.push(u)
    packed.push(u)
    assert plain.bytes_written > 0 and packed.bytes_written > 0
    assert packed.bytes_written < 0.5 * plain.bytes_written
    pulled = WeightStore(packed.folder).pull_node("n")  # readers sniff format
    np.testing.assert_array_equal(pulled.params["w"], compressible["w"])
    assert peek_meta(packed.folder.get("latest/n"))["node_id"] == "n"


def test_zstd_envelope_gated_or_roundtrips(tmp_path):
    from repro.core.serialize import _zstd_module

    if _zstd_module() is None:
        with pytest.raises(ImportError):
            WeightStore(InMemoryFolder(), compress="zstd")
        return
    store = WeightStore(InMemoryFolder(), compress="zstd")
    params = {"w": np.zeros((2048,), np.float32)}
    store.push(NodeUpdate(params, num_examples=1, node_id="n", counter=0))
    pulled = WeightStore(store.folder).pull_node("n")
    np.testing.assert_array_equal(pulled.params["w"], params["w"])


def test_compressed_delta_transport_stays_bitwise(tmp_path):
    folder, _store, history = _run_store(tmp_path, "delta", compress="npz")
    pulled = WeightStore(folder).pull_node("n")
    np.testing.assert_array_equal(pulled.params["enc"]["w"], history[-1]["enc"]["w"])


# --- steady-state shape of the hot path --------------------------------------


def test_stack_cache_reuses_buffer_and_rows():
    from repro.core.strategies import _StackCache

    spec = LeafSpec.of(tree_of([1.0, 1.0]))
    mk = lambda f: FlatUpdate(f, spec, num_examples=1, node_id="u")
    f0, f1 = spec.flatten(tree_of([1.0, 1.0])), spec.flatten(tree_of([2.0, 0.0]))
    u0, u1 = mk(f0), mk(f1)
    cache = _StackCache()
    buf1 = cache.stack(spec, [u0, u1])
    np.testing.assert_array_equal(buf1[0], u0.flat)
    buf1[0, 0] = 123.0  # poison: a reused row must be overwritten only if source changed
    buf2 = cache.stack(spec, [u0, u1])
    assert buf2 is buf1  # same buffer object, no realloc
    assert buf2[0, 0] == 123.0  # row NOT recopied: same source flat object
    u0b = mk(u0.flat.copy())
    buf3 = cache.stack(spec, [u0b, u1])
    assert buf3[0, 0] == u0.flat[0]  # new source object → row refreshed
    # tree-only updates are flattened into their row every call
    t = NodeUpdate(tree_of([3.0, 1.0]), num_examples=1, node_id="t")
    buf4 = cache.stack(spec, [t, u1])
    np.testing.assert_array_equal(buf4[0], spec.flatten(t.params))


def test_partial_fedavg_personal_leaves_exact_for_nonf32_models():
    """Personal (non-federated) leaves of int/f64 models must pass through
    bit-exact — never rounded through the f32 flat."""
    from repro.core.strategies import PartialFedAvg

    big = np.asarray([2**53 + 1.0, 7.5], np.float64)  # not f32-representable
    ids = np.asarray([2**40 + 3, 5], np.int64)
    own = NodeUpdate({"enc": {"w": np.ones((4,), np.float32)},
                      "head": big.copy(), "steps": ids.copy()},
                     num_examples=1, node_id="a")
    peer = NodeUpdate({"enc": {"w": np.zeros((4,), np.float32)},
                       "head": big * 0.5, "steps": ids * 0},
                      num_examples=1, node_id="b")
    out = PartialFedAvg(shared_pattern=r"^enc/").aggregate(own, [peer])
    np.testing.assert_allclose(out["enc"]["w"], 0.5)        # federated
    np.testing.assert_array_equal(out["head"], big)         # exact, f64
    np.testing.assert_array_equal(out["steps"], ids)        # exact, int64
    assert out["head"].dtype == np.float64 and out["steps"].dtype == np.int64


def test_leafspec_flatten_rejects_leaf_shape_permutation():
    """Two leaves swapping sizes under the same treedef must not silently
    produce a mislaid flat vector (same total, different offsets)."""
    spec = LeafSpec.of({"a": np.zeros((10, 2), np.float32),
                        "b": np.zeros((2, 10), np.float32),
                        "c": np.zeros((5,), np.float32)})
    permuted = {"a": np.zeros((4,), np.float32),        # 20 → 4
                "b": np.zeros((21,), np.float32),       # 20 → 21
                "c": np.zeros((20,), np.float32)}       # 5 → 20 (total 45 = 45)
    with pytest.raises(ValueError):
        spec.flatten(permuted)
    with pytest.raises(ValueError):
        spec.flatten_into(permuted, spec.empty_flat())


def test_mixed_f16_f32_peers_keep_their_dtypes():
    """Same-structure f16 and f32 models must not share a spec: each peer's
    pulled params keep their native dtype and exact values (regression: the
    interning key once ignored native wire dtypes)."""
    store = WeightStore(InMemoryFolder())
    p16 = {"w": np.linspace(-1, 1, 8, dtype=np.float16)}
    p32 = {"w": np.linspace(-1, 1, 8, dtype=np.float32) * np.float32(0.1)}
    store.push(NodeUpdate(p16, num_examples=1, node_id="h", counter=0))
    store.push(NodeUpdate(p32, num_examples=1, node_id="s", counter=0))
    pulled = {u.node_id: u for u in store.pull()}
    assert pulled["h"].params["w"].dtype == np.float16
    assert pulled["s"].params["w"].dtype == np.float32
    np.testing.assert_array_equal(pulled["h"].params["w"], p16["w"])
    np.testing.assert_array_equal(pulled["s"].params["w"], p32["w"])
    assert pulled["h"].spec.key != pulled["s"].spec.key


def test_sharded_bytes_written_includes_summary_traffic():
    from repro.core.gossip import ShardedFolders, ShardedWeightStore

    store = ShardedWeightStore(
        ShardedFolders(2, factory=lambda g: InMemoryFolder()),
        group_of=lambda nid: int(nid[1]) % 2)
    for i in range(2):
        store.push(NodeUpdate(tree_of([1.0 + i, 0.5]), num_examples=1,
                              node_id=f"n{i}", counter=0))
    stats = store.cache_stats()
    assert stats["summary_bytes_written"] > 0  # refreshes + ring forwards
    # total includes BOTH per-group latest traffic and the summary layer
    assert stats["bytes_written"] > stats["summary_bytes_written"]


def test_node_transport_stats_uniform_shape():
    from repro.core import AsyncFederatedNode
    from repro.core.gossip import ShardedFolders

    flat_node = AsyncFederatedNode(shared_folder=InMemoryFolder(), node_id="a")
    sharded_node = AsyncFederatedNode(
        shared_folder=ShardedFolders(2, factory=lambda g: InMemoryFolder()),
        node_id="b")
    for node in (flat_node, sharded_node):
        node.update_parameters(tree_of([1.0, 0.0]), num_examples=1)
        stats = node.transport_stats()
        assert set(stats) >= {"decode_hits", "decode_misses", "bytes_written"}
        assert stats["bytes_written"] > 0


def test_fedavgm_state_is_flat_vectors():
    strat = FedAvgM()
    own_t, own_f, spec = pair([1.0, 2.0], node="a")
    _, p, spec = pair([0.0, 0.0], node="b", spec=spec)
    strat.aggregate(own_f, [p])
    assert isinstance(strat.x, np.ndarray) and strat.x.ndim == 1
    assert strat.x.size == spec.num_params
    assert isinstance(strat.buf, np.ndarray) and strat.buf.dtype == np.float32
