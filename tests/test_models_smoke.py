"""Per-architecture smoke tests (REQUIRED): each assigned arch instantiates a
REDUCED variant (≤2 pattern units of layers, d_model ≤ 256, ≤4 experts), runs
one forward + one real train step on CPU, asserts output shapes and no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model
from repro.models.cnn import MnistCNN, ResNet
from repro.models.frontends import stub_audio_frames, stub_patch_embeddings
from repro.optim import adamw, apply_updates

B, S = 2, 32


def make_batch(cfg, rng):
    batch = {
        "tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
    }
    if cfg.is_encdec:
        batch["frames"] = stub_audio_frames(rng, cfg, B, 16)
    elif cfg.frontend == "vision":
        batch["embeds"] = stub_patch_embeddings(rng, cfg, B)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS + ["pythia-14m"])
def test_reduced_arch_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    batch = make_batch(cfg, rng)

    # forward: logits shape + finite
    if cfg.is_encdec:
        logits, _ = model.apply(params, batch["tokens"], batch["frames"])
    else:
        logits, _ = model.apply(params, batch["tokens"], batch.get("embeds"))
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"

    # one real train step: loss finite, params move, still finite
    opt = adamw(1e-3)
    state = opt.init(params)

    @jax.jit
    def step(p, s):
        (loss, metrics), grads = jax.value_and_grad(
            lambda q: model.loss(q, batch), has_aux=True
        )(p)
        upd, s = opt.update(grads, s, p)
        return apply_updates(p, upd), s, loss

    new_params, state, loss = step(params, state)
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    leaves_new = jax.tree.leaves(new_params)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in leaves_new), f"{arch}: NaN params"
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), leaves_new)
    )
    assert moved, f"{arch}: train step did not change params"


@pytest.mark.parametrize("arch", ["granite-3-2b", "mamba2-130m", "recurrentgemma-9b",
                                  "minicpm3-4b", "grok-1-314b", "seamless-m4t-medium"])
def test_reduced_arch_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    rng = jax.random.PRNGKey(1)
    params = model.init(rng)
    tokens = jax.random.randint(rng, (B, 16), 0, cfg.vocab_size)
    if cfg.is_encdec:
        frames = stub_audio_frames(rng, cfg, B, 8)
        full, _ = model.apply(params, tokens, frames)
        cache = model.init_cache(params, frames, capacity=16)
    else:
        full, _ = model.apply(params, tokens)
        cache = model.init_cache(B, capacity=16)
    step = jax.jit(lambda p, t, c, i: model.decode_step(p, t, c, i))
    for t in range(16):
        logits, cache = step(params, tokens[:, t], cache, jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full[:, t]), rtol=2e-2, atol=2e-2,
            err_msg=f"{arch} step {t}",
        )


def test_mla_absorbed_decode_matches_naive():
    cfg = get_config("minicpm3-4b").reduced()
    model_naive = build_model(cfg)
    model_abs = build_model(cfg.replace(mla_absorb=True))
    rng = jax.random.PRNGKey(2)
    params = model_naive.init(rng)
    tokens = jax.random.randint(rng, (B, 8), 0, cfg.vocab_size)
    c1 = model_naive.init_cache(B, capacity=8)
    c2 = model_abs.init_cache(B, capacity=8)
    for t in range(8):
        l1, c1 = model_naive.decode_step(params, tokens[:, t], c1, jnp.int32(t))
        l2, c2 = model_abs.decode_step(params, tokens[:, t], c2, jnp.int32(t))
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-3, atol=1e-3)


def test_sliding_window_decode_matches_windowed_forward():
    """long_500k mechanism: decode with window_override == windowed forward."""
    cfg = get_config("qwen3-14b").reduced()
    model = build_model(cfg)
    rng = jax.random.PRNGKey(3)
    params = model.init(rng)
    T, W = 24, 8
    tokens = jax.random.randint(rng, (B, T), 0, cfg.vocab_size)
    full, _ = model.apply(params, tokens, window_override=W)
    cache = model.init_cache(B, capacity=T, window_override=W)
    assert cache["u0_attn"]["k"].shape[2] == W  # ring capacity = window
    for t in range(T):
        logits, cache = model.decode_step(params, tokens[:, t], cache, jnp.int32(t),
                                          window_override=W)
        np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, t]),
                                   rtol=2e-2, atol=2e-2, err_msg=f"t={t}")


def test_paper_cnn_models_train():
    rng = jax.random.PRNGKey(0)
    for model, shape in [(MnistCNN(), (8, 28, 28, 1)), (ResNet(width=1, blocks_per_stage=1), (4, 32, 32, 3))]:
        params = model.init(rng)
        batch = {"x": jax.random.normal(rng, shape),
                 "y": jax.random.randint(rng, (shape[0],), 0, 10)}
        loss, metrics = model.loss(params, batch)
        assert bool(jnp.isfinite(loss))
        grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
        assert all(bool(jnp.all(jnp.isfinite(g))) for g in jax.tree.leaves(grads))
